"""The request-level flight recorder: per-request lifecycle spans and
latency histograms, emitted by every replay simulator.

Each finished (or rejected) request becomes a small span tree on the
tracer's virtual timeline, anchored at the enclosing replay span::

    request (rid, tenant, priority, isl, osl, outcome[, replica])
      request.queued    arrival       -> first schedule
      request.prefill   first sched   -> first token
      request.decode    first token   -> finish        (osl > 1 only)

Emission happens *after* the replay body, so it can never perturb the
simulation: the simulators run exactly the iterations an uninstrumented
replay runs, then the recorder walks the finished requests and writes
their spans in rid order.  Under :data:`~repro.obs.trace.NULL_TRACER`
(``records_spans`` False) the walk is skipped outright — byte-free.

Big traces stay bounded through two sampling knobs
(:func:`configure_flight_recorder`): ``sample_every`` keeps every n-th
request id, ``max_request_spans`` caps the total span-tree count per
replay.

The same per-request walk feeds the latency histograms: fixed
log2-bucket (:data:`~repro.obs.metrics.LATENCY_MS_BUCKETS`) TTFT /
TPOT / queue-wait / e2e distributions, serialized compactly into
``ReplayMetrics.histograms`` for the schema-v7 report and observed into
the installed :class:`~repro.obs.metrics.MetricsRegistry` (when any).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.obs.metrics import LATENCY_MS_BUCKETS, get_metrics

__all__ = [
    "FlightRecorderConfig", "configure_flight_recorder",
    "emit_engine_request_spans", "emit_request_spans", "flight_config",
    "latency_histograms", "request_latencies_ms",
]

#: The four lifecycle latencies every replay distributes, in emission
#: order (one histogram each in ``ReplayMetrics.histograms`` and one
#: ``repro_request_<name>`` registry histogram).
HISTOGRAM_METRICS = ("ttft_ms", "tpot_ms", "queue_wait_ms", "e2e_ms")


@dataclasses.dataclass
class FlightRecorderConfig:
    """Span-sampling knobs (histograms always see every request)."""
    sample_every: int = 1            # keep request ids where rid % n == 0
    max_request_spans: int = 512     # per-replay span-tree cap

    def __post_init__(self):
        if self.sample_every < 1:
            raise ValueError(f"sample_every must be >= 1, got "
                             f"{self.sample_every}")
        if self.max_request_spans < 0:
            raise ValueError(f"max_request_spans must be >= 0, got "
                             f"{self.max_request_spans}")


_CONFIG = FlightRecorderConfig()


def flight_config() -> FlightRecorderConfig:
    return _CONFIG


def configure_flight_recorder(sample_every: int = 1,
                              max_request_spans: int = 512
                              ) -> FlightRecorderConfig:
    """Install (and return) the process-local sampling configuration."""
    global _CONFIG
    _CONFIG = FlightRecorderConfig(sample_every=sample_every,
                                   max_request_spans=max_request_spans)
    return _CONFIG


# ---------------------------------------------------------------------------
# per-request latencies
# ---------------------------------------------------------------------------

def request_latencies_ms(req) -> Dict[str, Optional[float]]:
    """The ms-scale lifecycle latencies of one request (None where the
    lifecycle stage never happened: rejected requests have no TTFT,
    ``osl == 1`` requests no TPOT)."""
    ttft = req.ttft
    tpot = req.tpot
    queue = (req.t_first_sched - req.arrival
             if req.t_first_sched is not None else None)
    e2e = (req.t_finish - req.arrival
           if req.t_finish is not None else None)
    return {
        "ttft_ms": 1e3 * ttft if ttft is not None else None,
        "tpot_ms": 1e3 * tpot if tpot is not None else None,
        "queue_wait_ms": 1e3 * queue if queue is not None else None,
        "e2e_ms": 1e3 * e2e if e2e is not None else None,
    }


def latency_histograms(completed: Iterable, sim: str) -> Dict[str, Dict]:
    """Fold finished requests into the compact serialized histogram
    section (one ``{"buckets", "counts", "sum", "count"}`` entry per
    lifecycle latency — the same shape a ``MetricsRegistry`` snapshot
    uses, so the two diff with one code path).

    When a metrics registry is installed, the same observations land in
    its ``repro_request_<metric>{sim=...}`` histograms.
    """
    buckets = LATENCY_MS_BUCKETS
    section = {name: {"buckets": list(buckets),
                      "counts": [0] * (len(buckets) + 1),
                      "sum": 0.0, "count": 0}
               for name in HISTOGRAM_METRICS}
    registry = get_metrics()
    for req in completed:
        for name, value in request_latencies_ms(req).items():
            if value is None:
                continue
            h = section[name]
            for i, le in enumerate(buckets):
                if value <= le:
                    h["counts"][i] += 1
                    break
            else:
                h["counts"][-1] += 1
            h["sum"] += value
            h["count"] += 1
            if registry is not None:
                registry.observe(f"repro_request_{name}", value,
                                 buckets=buckets, sim=sim)
    return section


# ---------------------------------------------------------------------------
# span emission
# ---------------------------------------------------------------------------

def emit_request_spans(tracer, completed: Sequence, rejected: Sequence,
                       base: float, replica_of=None) -> int:
    """Write the per-request span trees for one finished replay.

    ``completed``/``rejected`` are :class:`~repro.serving.request.Request`
    objects (rejected ones never scheduled: their spans are zero-length
    with ``outcome="rejected"``); ``base`` is the enclosing replay
    span's virtual start, so request timelines nest correctly under it;
    ``replica_of`` optionally maps ``id(request) -> replica index`` for
    the multi-engine simulators.  Returns the number of request trees
    emitted (0, without touching the tracer's clock, when the tracer
    does not record spans).
    """
    if not getattr(tracer, "records_spans", False):
        return 0
    cfg = _CONFIG
    reqs: List[Tuple[int, object, str]] = \
        [(r.rid, r, "completed") for r in completed] \
        + [(r.rid, r, "rejected") for r in rejected]
    reqs.sort(key=lambda t: t[0])
    emitted = 0
    for rid, req, outcome in reqs:
        if emitted >= cfg.max_request_spans:
            break
        if cfg.sample_every > 1 and rid % cfg.sample_every != 0:
            continue
        attrs = {"rid": rid, "tenant": req.tenant,
                 "priority": req.priority, "isl": req.isl, "osl": req.osl,
                 "outcome": outcome}
        if replica_of is not None:
            replica = replica_of.get(id(req))
            if replica is not None:
                attrs["replica"] = replica
        tracer.virtual_time = base + req.arrival
        with tracer.span("request", **attrs):
            if outcome == "completed" and req.t_first_token is not None:
                if req.t_first_sched is not None:
                    with tracer.span("request.queued"):
                        tracer.virtual_time = base + req.t_first_sched
                with tracer.span("request.prefill"):
                    tracer.virtual_time = base + req.t_first_token
                if req.t_finish is not None \
                        and req.t_finish > req.t_first_token:
                    with tracer.span("request.decode"):
                        tracer.virtual_time = base + req.t_finish
                if req.t_finish is not None:
                    tracer.virtual_time = base + req.t_finish
        emitted += 1
    return emitted


def emit_engine_request_spans(tracer, engines: Sequence,
                              base: float) -> int:
    """Multi-engine variant: gather every replica's finished and
    rejected requests and emit them with replica attribution.  Shared
    by the cluster and autoscale simulators (any object with ``idx``,
    ``done`` and ``rejected_reqs`` qualifies as an engine)."""
    if not getattr(tracer, "records_spans", False):
        return 0
    completed = [r for eng in engines for r in eng.done]
    rejected = [r for eng in engines for r in eng.rejected_reqs]
    replica_of = {id(r): eng.idx for eng in engines
                  for r in list(eng.done) + list(eng.rejected_reqs)}
    return emit_request_spans(tracer, completed, rejected, base=base,
                              replica_of=replica_of)
