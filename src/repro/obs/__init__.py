"""repro.obs — observability for the configurator: tracing spans, a
process-local metrics registry, and per-candidate cost attribution.

Three layers, all zero-cost until installed:

* :mod:`repro.obs.trace` — ``Tracer`` / ``span(name, **attrs)`` with a
  deterministic virtual clock plus wallclock timers, frozen into a
  versioned JSONL ``TraceArtifact`` (sha256 digest, lossless
  round-trip).  The default :data:`NULL_TRACER` makes every span a
  shared no-op.
* :mod:`repro.obs.metrics` — ``MetricsRegistry`` counters / gauges /
  histograms threaded through ``TaskRunner``, ``PerfDatabase`` and the
  simulators; exports JSON and Prometheus text format.
* :mod:`repro.obs.flight` — the request-level flight recorder:
  per-request lifecycle spans (arrival → queued → prefill → decode)
  and fixed log2-bucket latency histograms, emitted by every replay
  simulator and sampled through ``configure_flight_recorder``.
* :mod:`repro.obs.diff` — telemetry diffing: counter/gauge deltas and
  per-histogram distribution shifts between two snapshots (surfaced as
  the ``obs diff`` CLI subcommand).
* :mod:`repro.obs.bench` — the performance-regression sentinel:
  versioned ``BenchArtifact`` suite runs (repeat timings, work-counter
  snapshots, phase breakdowns, environment fingerprints) and the
  two-tier hard/soft comparator behind ``obs bench run|compare|gate|
  trend``.  Loads lazily — it reaches into the pricing stack for the
  PerfDatabase fingerprint.
* :mod:`repro.obs.explain` — the operator-family latency waterfall per
  serving phase, and a two-candidate diff (surfaced as
  ``Configurator.explain`` and the ``explain`` CLI subcommand).

``trace``/``metrics``/``flight``/``diff`` are import-light (stdlib
only); ``explain`` pulls in the pricing stack and loads lazily so the
core modules can import this package without a cycle.
"""
from repro.obs.diff import diff_metrics, format_diff, load_metrics_snapshot
from repro.obs.flight import (FlightRecorderConfig,
                              configure_flight_recorder,
                              emit_engine_request_spans, emit_request_spans,
                              flight_config, latency_histograms,
                              request_latencies_ms)
from repro.obs.metrics import (LATENCY_MS_BUCKETS, MetricsRegistry,
                               disable_metrics, enable_metrics, get_metrics,
                               histogram_quantile)
from repro.obs.trace import (NULL_TRACER, SUPPORTED_TRACE_SCHEMA_VERSIONS,
                             TRACE_SCHEMA_VERSION, NullTracer, SpanRecord,
                             TraceArtifact, Tracer, disable_tracing,
                             enable_tracing, get_tracer, set_tracer)

_EXPLAIN_NAMES = ("CandidateExplanation", "Explanation", "ExplanationDiff",
                  "PhaseWaterfall", "diff_explanations", "explain_candidate",
                  "explain_spec")

_BENCH_NAMES = ("BenchArtifact", "BenchRecord", "BenchTiming",
                "EnvironmentMismatch", "GateResult", "compare_artifacts",
                "environment_fingerprint", "gate_artifacts", "soft_exceeds",
                "trend_summary")

__all__ = [
    "FlightRecorderConfig", "LATENCY_MS_BUCKETS", "MetricsRegistry",
    "NULL_TRACER", "NullTracer", "SpanRecord",
    "SUPPORTED_TRACE_SCHEMA_VERSIONS", "TRACE_SCHEMA_VERSION",
    "TraceArtifact", "Tracer", "configure_flight_recorder",
    "diff_metrics", "disable_metrics", "disable_tracing",
    "emit_engine_request_spans", "emit_request_spans", "enable_metrics",
    "enable_tracing",
    "flight_config", "format_diff", "get_metrics", "get_tracer",
    "histogram_quantile", "latency_histograms", "load_metrics_snapshot",
    "request_latencies_ms", "set_tracer", "telemetry_section",
    *_EXPLAIN_NAMES,
    *_BENCH_NAMES,
]


def telemetry_section(tracer=None, metrics=None) -> dict:
    """The schema-v6 ``telemetry`` report section: deterministic trace
    identity (digest + span count, no wall times) and a metrics snapshot."""
    section = {"trace": None, "metrics": None}
    if tracer is not None and tracer is not NULL_TRACER:
        art = tracer.artifact()
        section["trace"] = {"schema_version": TRACE_SCHEMA_VERSION,
                            "digest": art.digest(),
                            "n_spans": art.n_spans}
    if metrics is not None:
        section["metrics"] = metrics.to_dict()
    return section


def __getattr__(name):
    if name in _EXPLAIN_NAMES:
        from repro.obs import explain as _explain
        return getattr(_explain, name)
    if name in _BENCH_NAMES:
        from repro.obs import bench as _bench
        return getattr(_bench, name)
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
