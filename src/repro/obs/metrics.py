"""Process-local metrics registry: counters, gauges, histograms.

The hot paths (``TaskRunner``, ``PerfDatabase``, the simulators) report
into whatever registry is installed via :func:`enable_metrics`; with no
registry installed (the default) every instrumentation site is a single
``get_metrics() is None`` check, so pricing-path numerics and CLI output
bytes are untouched.

Metric identity is ``(name, sorted labels)``, Prometheus-style:

    m = enable_metrics()
    m.inc("repro_db_ops_total", 128, family="gemm", path="grid",
          mode="batched")
    m.to_dict()        # JSON-able snapshot, deterministically keyed
    m.to_prometheus()  # text exposition format, hand-rolled (no deps)

Counters only ever increase, gauges hold the last value set, histograms
use fixed log-spaced buckets (seconds-scale by default) and expose
``_bucket``/``_sum``/``_count`` in the Prometheus rendering.  All
exports sort by (name, labels) so two runs with identical workloads
serialize byte-identically.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "DEFAULT_BUCKETS", "LATENCY_MS_BUCKETS", "MetricsRegistry",
    "disable_metrics", "enable_metrics", "get_metrics",
    "histogram_quantile",
]

# log-spaced seconds: 1us .. 100s, the span of a kernel to a whole search
DEFAULT_BUCKETS: Tuple[float, ...] = (
    1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0, 100.0)

#: Fixed log2-spaced milliseconds for request-latency histograms:
#: 0.25 ms .. ~35 min (0.25 * 2**i, i < 24).  One shared schema means
#: every replay's TTFT/TPOT/queue-wait/e2e distribution is directly
#: comparable (and diffable) bucket-for-bucket.
LATENCY_MS_BUCKETS: Tuple[float, ...] = tuple(
    0.25 * 2.0 ** i for i in range(24))

_LabelKey = Tuple[Tuple[str, str], ...]


def _labels_key(labels: Dict[str, object]) -> _LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _flat_name(name: str, key: _LabelKey) -> str:
    if not key:
        return name
    inner = ",".join(f"{k}={v}" for k, v in key)
    return f"{name}{{{inner}}}"


def _escape_label_value(v: str) -> str:
    """Prometheus text exposition format: label values escape backslash,
    double-quote, and line-feed (in that order — backslash first, or the
    other escapes would be double-escaped)."""
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _prom_labels(key: _LabelKey, extra: Tuple[Tuple[str, str], ...] = ()
                 ) -> str:
    pairs = key + extra
    if not pairs:
        return ""
    inner = ",".join(f'{k}="{_escape_label_value(v)}"' for k, v in pairs)
    return f"{{{inner}}}"


def histogram_quantile(buckets: Sequence[float], counts: Sequence[int],
                       p: float) -> Optional[float]:
    """Estimate the p-quantile (p in [0, 1]) of a bucketed histogram.

    ``counts`` has ``len(buckets) + 1`` entries (the last is the +Inf
    overflow).  The estimator locates the bucket holding the sample at
    rank ``p * (count - 1)`` — the same rank convention as the exact
    :func:`repro.serving.sim.percentile` — and interpolates linearly
    inside it, so the estimate always lands within one bucket of the
    exact sample percentile.  The first bucket interpolates from 0, the
    overflow bucket clamps to the last finite edge.  Empty histograms
    return None (never NaN).
    """
    if not 0.0 <= p <= 1.0:
        raise ValueError(f"quantile p must be in [0, 1], got {p}")
    if len(counts) != len(buckets) + 1:
        raise ValueError(f"expected {len(buckets) + 1} counts "
                         f"(+Inf overflow slot), got {len(counts)}")
    total = sum(counts)
    if total == 0:
        return None
    rank = p * (total - 1)
    cum = 0
    for i, c in enumerate(counts):
        if cum + c > rank:
            if i >= len(buckets):          # overflow: clamp, no far edge
                return float(buckets[-1])
            lo = float(buckets[i - 1]) if i > 0 else 0.0
            hi = float(buckets[i])
            return lo + (hi - lo) * ((rank - cum + 0.5) / c)
        cum += c
    return float(buckets[-1])


def _fmt(v: float) -> str:
    if v != v:                       # NaN never serializes silently
        return "NaN"
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


class MetricsRegistry:
    """Counters / gauges / histograms keyed by (name, labels)."""

    def __init__(self, buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        if not buckets or any(b <= a for a, b in zip(buckets, buckets[1:])):
            raise ValueError("histogram buckets must be strictly increasing")
        self.buckets = tuple(float(b) for b in buckets)
        self._counters: Dict[Tuple[str, _LabelKey], float] = {}
        self._gauges: Dict[Tuple[str, _LabelKey], float] = {}
        # histogram value: [[bucket counts..., +Inf count], sum, count,
        # bucket schema] — the schema is pinned per metric key at first
        # observation (registry default unless ``observe(buckets=...)``)
        self._hists: Dict[Tuple[str, _LabelKey], List] = {}

    # -- write side ------------------------------------------------------
    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        if value < 0:
            raise ValueError(f"counter {name!r} cannot decrease")
        k = (name, _labels_key(labels))
        self._counters[k] = self._counters.get(k, 0.0) + value

    def set_gauge(self, name: str, value: float, **labels) -> None:
        self._gauges[(name, _labels_key(labels))] = float(value)

    def observe(self, name: str, value: float,
                buckets: Optional[Sequence[float]] = None,
                **labels) -> None:
        k = (name, _labels_key(labels))
        h = self._hists.get(k)
        if h is None:
            schema = (self.buckets if buckets is None
                      else tuple(float(b) for b in buckets))
            if not schema or any(b <= a
                                 for a, b in zip(schema, schema[1:])):
                raise ValueError(
                    "histogram buckets must be strictly increasing")
            h = [[0] * (len(schema) + 1), 0.0, 0, schema]
            self._hists[k] = h
        elif buckets is not None and tuple(float(b) for b in buckets) \
                != h[3]:
            raise ValueError(
                f"histogram {name!r} already pinned to a different "
                f"bucket schema")
        v = float(value)
        for i, le in enumerate(h[3]):
            if v <= le:
                h[0][i] += 1
                break
        else:
            h[0][-1] += 1
        h[1] += v
        h[2] += 1

    def reset(self) -> None:
        self._counters.clear()
        self._gauges.clear()
        self._hists.clear()

    # -- read side -------------------------------------------------------
    def counter_value(self, name: str, **labels) -> float:
        return self._counters.get((name, _labels_key(labels)), 0.0)

    def counter_total(self, name: str) -> float:
        """Sum of a counter across every label combination."""
        return sum(v for (n, _), v in self._counters.items() if n == name)

    def quantile(self, name: str, p: float, **labels) -> Optional[float]:
        """Estimate the p-quantile of a recorded histogram via
        :func:`histogram_quantile`; None when the histogram does not
        exist or holds no observations."""
        h = self._hists.get((name, _labels_key(labels)))
        if h is None:
            return None
        return histogram_quantile(h[3], h[0], p)

    def to_dict(self) -> Dict:
        counters = {_flat_name(n, k): self._counters[(n, k)]
                    for n, k in sorted(self._counters)}
        gauges = {_flat_name(n, k): self._gauges[(n, k)]
                  for n, k in sorted(self._gauges)}
        hists = {}
        for n, k in sorted(self._hists):
            cum, total, count, schema = self._hists[(n, k)]
            hists[_flat_name(n, k)] = {
                "buckets": list(schema), "counts": list(cum),
                "sum": total, "count": count}
        return {"counters": counters, "gauges": gauges,
                "histograms": hists}

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines: List[str] = []
        seen_type: Dict[str, str] = {}

        def typed(name: str, kind: str):
            if seen_type.get(name) is None:
                lines.append(f"# TYPE {name} {kind}")
                seen_type[name] = kind

        for n, k in sorted(self._counters):
            typed(n, "counter")
            lines.append(f"{n}{_prom_labels(k)} "
                         f"{_fmt(self._counters[(n, k)])}")
        for n, k in sorted(self._gauges):
            typed(n, "gauge")
            lines.append(f"{n}{_prom_labels(k)} {_fmt(self._gauges[(n, k)])}")
        for n, k in sorted(self._hists):
            typed(n, "histogram")
            per_bucket, total, count, schema = self._hists[(n, k)]
            cum = 0
            for le, c in zip(schema, per_bucket[:-1]):
                cum += c
                lines.append(f"{n}_bucket{_prom_labels(k, (('le', _fmt(le)),))}"
                             f" {cum}")
            cum += per_bucket[-1]
            lines.append(f"{n}_bucket{_prom_labels(k, (('le', '+Inf'),))}"
                         f" {cum}")
            lines.append(f"{n}_sum{_prom_labels(k)} {_fmt(total)}")
            lines.append(f"{n}_count{_prom_labels(k)} {count}")
        return "\n".join(lines) + ("\n" if lines else "")

    def finite(self) -> bool:
        """Every exported value is finite (CI sanity probe)."""
        vals = list(self._counters.values()) + list(self._gauges.values())
        for _, total, _, _ in self._hists.values():
            vals.append(total)
        return all(math.isfinite(v) for v in vals)


# -- process-local installation ---------------------------------------------

_REGISTRY: Optional[MetricsRegistry] = None


def get_metrics() -> Optional[MetricsRegistry]:
    """The installed registry, or None when metrics are disabled."""
    return _REGISTRY


def enable_metrics(registry: Optional[MetricsRegistry] = None
                   ) -> MetricsRegistry:
    """Install (and return) a process-local registry."""
    global _REGISTRY
    _REGISTRY = registry if registry is not None else MetricsRegistry()
    return _REGISTRY


def disable_metrics() -> None:
    """Back to the zero-cost default: instrumentation sites become no-ops."""
    global _REGISTRY
    _REGISTRY = None
