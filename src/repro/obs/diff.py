"""Telemetry diffing: compare two metrics snapshots distribution-to-
distribution.

The regression-detection primitive the ISSUE-era benchmarks gate on:
given two ``MetricsRegistry.to_dict()`` snapshots (or two SearchReport
files carrying ``telemetry.metrics``, or two bare replay histogram
sections), :func:`diff_metrics` reports

* counter deltas (added / removed / changed, with signed deltas),
* gauge deltas,
* a per-histogram distribution-shift summary — count/mean deltas plus
  p50/p95/p99 shifts estimated with
  :func:`~repro.obs.metrics.histogram_quantile`,
* the SLO-attainment delta, read from the
  ``repro_replay_slo_attainment`` gauges the simulators export.

Everything is plain dict-in / dict-out and deterministic, surfaced on
the CLI as ``obs diff a.json b.json [--json]``.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.obs.metrics import histogram_quantile

__all__ = ["diff_metrics", "format_diff", "load_metrics_snapshot"]

_QUANTILES = (("p50", 0.50), ("p95", 0.95), ("p99", 0.99))
_ATTAINMENT_GAUGE = "repro_replay_slo_attainment"


def _is_histogram(v) -> bool:
    return (isinstance(v, dict)
            and {"buckets", "counts", "sum", "count"} <= set(v))


def load_metrics_snapshot(source) -> Dict:
    """Normalize a diffable payload into snapshot shape.

    ``source`` is a path or an already-loaded dict, holding one of:

    * a ``MetricsRegistry.to_dict()`` snapshot
      (``{"counters", "gauges", "histograms"}``),
    * a ``SearchReport`` JSON with a non-null ``telemetry.metrics``,
    * a ``BenchArtifact`` JSON (``kind == "repro-bench"``) — each
      record's work counters are flattened to
      ``<bench_name>/<counter>`` so two suite runs diff per-benchmark,
    * a bare replay histogram section (every value a
      ``{"buckets", "counts", "sum", "count"}`` dict), wrapped as
      histograms-only.
    """
    d = source
    if isinstance(source, str):
        with open(source) as f:
            d = json.load(f)
    if not isinstance(d, dict):
        raise ValueError("metrics snapshot must be a JSON object")
    if d.get("kind") == "repro-bench":
        counters = {f"{r['name']}/{k}": v
                    for r in d.get("records", [])
                    for k, v in r.get("counters", {}).items()}
        return {"counters": counters, "gauges": {}, "histograms": {}}
    if "schema_version" in d and "telemetry" in d:
        tel = d.get("telemetry") or {}
        metrics = tel.get("metrics")
        if metrics is None:
            raise ValueError(
                "report carries no telemetry.metrics section (search ran "
                "without a metrics registry installed)")
        d = metrics
    if {"counters", "gauges", "histograms"} <= set(d):
        return {"counters": dict(d["counters"]),
                "gauges": dict(d["gauges"]),
                "histograms": dict(d["histograms"])}
    if d and all(_is_histogram(v) for v in d.values()):
        return {"counters": {}, "gauges": {}, "histograms": dict(d)}
    raise ValueError(
        "unrecognized snapshot shape: expected a metrics registry dump, "
        "a SearchReport with telemetry, or a replay histogram section")


def _diff_scalars(a: Dict, b: Dict) -> Dict:
    added = {k: b[k] for k in sorted(set(b) - set(a))}
    removed = {k: a[k] for k in sorted(set(a) - set(b))}
    changed = {k: {"a": a[k], "b": b[k], "delta": b[k] - a[k]}
               for k in sorted(set(a) & set(b)) if a[k] != b[k]}
    return {"added": added, "removed": removed, "changed": changed}


def _hist_stats(h: Dict) -> Dict:
    count = h["count"]
    stats = {"count": count,
             "mean": h["sum"] / count if count else None}
    for label, p in _QUANTILES:
        stats[label] = histogram_quantile(h["buckets"], h["counts"], p)
    return stats


def _diff_histograms(a: Dict, b: Dict) -> Dict:
    out: Dict = {"added": sorted(set(b) - set(a)),
                 "removed": sorted(set(a) - set(b)),
                 "changed": {}}
    for k in sorted(set(a) & set(b)):
        ha, hb = a[k], b[k]
        if ha == hb:
            continue
        sa, sb = _hist_stats(ha), _hist_stats(hb)
        entry: Dict = {
            "count": {"a": sa["count"], "b": sb["count"],
                      "delta": sb["count"] - sa["count"]},
            "mean": {"a": sa["mean"], "b": sb["mean"],
                     "delta": (sb["mean"] - sa["mean"]
                               if sa["mean"] is not None
                               and sb["mean"] is not None else None)},
            "schema_changed": ha["buckets"] != hb["buckets"],
        }
        for label, _ in _QUANTILES:
            qa, qb = sa[label], sb[label]
            entry[label] = {
                "a": qa, "b": qb,
                "shift": (qb - qa if qa is not None and qb is not None
                          else None)}
        out["changed"][k] = entry
    return out


def _attainment(gauges: Dict) -> Optional[float]:
    """Mean over every ``repro_replay_slo_attainment`` gauge variant (a
    snapshot may carry one per simulator label)."""
    vals = [v for k, v in gauges.items()
            if k == _ATTAINMENT_GAUGE or k.startswith(_ATTAINMENT_GAUGE + "{")]
    return sum(vals) / len(vals) if vals else None


def diff_metrics(a, b) -> Dict:
    """Diff two snapshots (any :func:`load_metrics_snapshot` shape)."""
    sa, sb = load_metrics_snapshot(a), load_metrics_snapshot(b)
    att_a, att_b = _attainment(sa["gauges"]), _attainment(sb["gauges"])
    d = {
        "counters": _diff_scalars(sa["counters"], sb["counters"]),
        "gauges": _diff_scalars(sa["gauges"], sb["gauges"]),
        "histograms": _diff_histograms(sa["histograms"],
                                       sb["histograms"]),
        "slo_attainment": (
            None if att_a is None and att_b is None
            else {"a": att_a, "b": att_b,
                  "delta": (att_b - att_a
                            if att_a is not None and att_b is not None
                            else None)}),
    }
    d["identical"] = (not any(d["counters"].values())
                      and not any(d["gauges"].values())
                      and not any(d["histograms"].values()))
    return d


def _fmt(v) -> str:
    if v is None:
        return "n/a"
    if isinstance(v, float):
        return f"{v:+.3f}" if abs(v) < 1e6 else f"{v:+.3e}"
    return f"{v:+d}" if isinstance(v, int) else str(v)


def format_diff(d: Dict) -> str:
    """Human-readable rendering of a :func:`diff_metrics` result."""
    if d["identical"]:
        return "snapshots are identical"
    lines: List[str] = []
    for kind in ("counters", "gauges"):
        sec = d[kind]
        for k, v in sec["added"].items():
            lines.append(f"{kind[:-1]} {k}: added (b = {v})")
        for k, v in sec["removed"].items():
            lines.append(f"{kind[:-1]} {k}: removed (a = {v})")
        for k, c in sec["changed"].items():
            lines.append(f"{kind[:-1]} {k}: {c['a']} -> {c['b']} "
                         f"({_fmt(c['delta'])})")
    hsec = d["histograms"]
    for k in hsec["added"]:
        lines.append(f"histogram {k}: added")
    for k in hsec["removed"]:
        lines.append(f"histogram {k}: removed")
    for k, h in hsec["changed"].items():
        shifts = "  ".join(
            f"{q} {_fmt(h[q]['shift'])}" for q, _ in _QUANTILES)
        lines.append(f"histogram {k}: count {h['count']['a']} -> "
                     f"{h['count']['b']}, mean {_fmt(h['mean']['delta'])}, "
                     f"{shifts}"
                     + (" [bucket schema changed]"
                        if h["schema_changed"] else ""))
    att = d["slo_attainment"]
    if att is not None:
        lines.append(f"slo attainment: {att['a']} -> {att['b']} "
                     f"({_fmt(att['delta'])})")
    return "\n".join(lines)
