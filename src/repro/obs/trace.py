"""Date-free tracing spans and the versioned ``TraceArtifact``.

A :class:`Tracer` records nested spans opened with
``tracer.span(name, **attrs)``.  Each span carries two timebases:

* **virtual** — ``tracer.virtual_time``, the simulated-seconds clock the
  simulators already advance deterministically.  Spans snapshot it at
  enter/exit (``v_start``/``v_end``), so a seeded run serializes
  byte-identically every time.
* **wallclock** — ``wall_s``, measured with ``time.perf_counter``.
  Wall durations are for live introspection (benchmark phase breakdowns,
  ``Tracer.wall_by_name``) and stay **out** of the canonical artifact
  bytes unless explicitly requested, because they are the one
  non-deterministic thing a trace holds.

The default tracer is :data:`NULL_TRACER`: ``span()`` hands back a shared
no-op context manager, so instrumentation in the pricing hot paths costs
one attribute call when tracing is off and never perturbs results.

``TraceArtifact`` follows the house JSONL artifact style (see
``repro.autoscale.timeline.ClusterTimeline``): a header line with a
schema version, one ``json.dumps(..., sort_keys=True)`` record per span,
a 16-hex sha256 ``digest()``, and a strict ``from_jsonl`` that
round-trips losslessly.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from typing import Dict, List, Optional, Tuple

__all__ = [
    "NULL_TRACER", "SUPPORTED_TRACE_SCHEMA_VERSIONS",
    "TRACE_SCHEMA_VERSION", "NullTracer", "Span", "SpanRecord",
    "TraceArtifact", "Tracer", "disable_tracing", "enable_tracing",
    "get_tracer", "set_tracer",
]

TRACE_SCHEMA_VERSION = 1
SUPPORTED_TRACE_SCHEMA_VERSIONS = (1,)


# ---------------------------------------------------------------------------
# frozen artifact records
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SpanRecord:
    """One completed span, as serialized into a :class:`TraceArtifact`."""
    seq: int                       # start order, 0-based, == artifact index
    name: str
    parent: Optional[int]          # seq of the enclosing span, None at root
    depth: int
    v_start: float                 # virtual-clock seconds at enter/exit
    v_end: float
    attrs: Dict                    # JSON-able, deterministic span payload
    wall_ms: Optional[float] = None  # only with include_wall=True

    def to_dict(self) -> Dict:
        d = {"seq": self.seq, "name": self.name, "parent": self.parent,
             "depth": self.depth, "v_start": self.v_start,
             "v_end": self.v_end, "attrs": dict(self.attrs)}
        if self.wall_ms is not None:
            d["wall_ms"] = self.wall_ms
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "SpanRecord":
        return cls(seq=d["seq"], name=d["name"], parent=d["parent"],
                   depth=d["depth"], v_start=d["v_start"],
                   v_end=d["v_end"], attrs=dict(d["attrs"]),
                   wall_ms=d.get("wall_ms"))


@dataclasses.dataclass(frozen=True)
class TraceArtifact:
    """Versioned, digestable JSONL serialization of one trace."""
    spans: Tuple[SpanRecord, ...]
    meta: Dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        object.__setattr__(self, "spans", tuple(self.spans))
        object.__setattr__(self, "meta", dict(self.meta))
        for i, s in enumerate(self.spans):
            if s.seq != i:
                raise ValueError(
                    f"span seq {s.seq} out of order at position {i}")
            if s.parent is not None and not 0 <= s.parent < i:
                raise ValueError(
                    f"span {i} references parent {s.parent} not yet open")

    @property
    def n_spans(self) -> int:
        return len(self.spans)

    def wall_by_name(self) -> Dict[str, float]:
        """Total wall seconds per span name (empty without wall data)."""
        out: Dict[str, float] = {}
        for s in self.spans:
            if s.wall_ms is not None:
                out[s.name] = out.get(s.name, 0.0) + s.wall_ms / 1e3
        return out

    def to_jsonl(self) -> str:
        header = {"type": "header",
                  "schema_version": TRACE_SCHEMA_VERSION,
                  "n_spans": len(self.spans), "meta": self.meta}
        lines = [json.dumps(header, sort_keys=True)]
        lines.extend(json.dumps(s.to_dict(), sort_keys=True)
                     for s in self.spans)
        return "\n".join(lines) + "\n"

    @classmethod
    def from_jsonl(cls, text: str) -> "TraceArtifact":
        lines = [ln for ln in text.splitlines() if ln.strip()]
        if not lines:
            raise ValueError("empty trace artifact")
        header = json.loads(lines[0])
        if header.get("type") != "header":
            raise ValueError("trace artifact must start with a header record")
        version = header.get("schema_version")
        if version not in SUPPORTED_TRACE_SCHEMA_VERSIONS:
            raise ValueError(f"unsupported trace schema version {version!r}")
        spans = []
        for ln in lines[1:]:
            try:
                spans.append(SpanRecord.from_dict(json.loads(ln)))
            except (KeyError, TypeError) as e:
                raise ValueError(f"malformed trace span record: {e}") from e
        declared = header.get("n_spans")
        if declared is not None and declared != len(spans):
            raise ValueError(f"trace header declares {declared} spans, "
                             f"found {len(spans)}")
        return cls(spans=tuple(spans), meta=dict(header.get("meta") or {}))

    def digest(self) -> str:
        return hashlib.sha256(self.to_jsonl().encode()).hexdigest()[:16]

    # -- Chrome trace_event / Perfetto export ---------------------------
    def _lane(self, span: SpanRecord) -> str:
        """The thread lane a span renders on: the nearest ancestor
        (including itself) carrying a request id gets a per-request
        lane, a replica attribute gets a per-replica lane, everything
        else shares the component's main lane."""
        s: Optional[SpanRecord] = span
        while s is not None:
            if "rid" in s.attrs:
                return f"request {s.attrs['rid']}"
            if "replica" in s.attrs:
                return f"replica {s.attrs['replica']}"
            s = self.spans[s.parent] if s.parent is not None else None
        return "main"

    def _component(self, span: SpanRecord) -> str:
        """The process a span renders under: the first dot-segment of
        its root ancestor's name, so request spans nested inside
        ``serving.replay`` stay in the ``serving`` process group."""
        s = span
        while s.parent is not None:
            s = self.spans[s.parent]
        return s.name.split(".", 1)[0]

    def to_chrome_trace(self) -> Dict:
        """Map the trace to the Chrome ``trace_event`` JSON object
        format (opens directly in Perfetto / ``chrome://tracing``).

        Every span becomes one ``ph:"X"`` complete event on the virtual
        timebase (microsecond ``ts``/``dur``); integer pids number the
        component processes, integer tids the lanes inside them, and
        ``ph:"M"`` metadata events carry the human names.  Wall times
        never enter the export, so seeded runs serialize
        byte-identically.
        """
        pids: Dict[str, int] = {}
        tids: Dict[Tuple[int, str], int] = {}
        events = []
        for s in self.spans:
            comp = self._component(s)
            pid = pids.setdefault(comp, len(pids) + 1)
            lane = self._lane(s)
            tid = tids.setdefault((pid, lane),
                                  1 + sum(1 for p, _ in tids if p == pid))
            events.append({
                "name": s.name, "cat": comp, "ph": "X",
                "ts": s.v_start * 1e6,
                "dur": max(0.0, (s.v_end - s.v_start) * 1e6),
                "pid": pid, "tid": tid,
                "args": dict(s.attrs),
            })
        meta_events = [
            {"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
             "args": {"name": comp}}
            for comp, pid in sorted(pids.items(), key=lambda kv: kv[1])
        ] + [
            {"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
             "args": {"name": lane}}
            for (pid, lane), tid in sorted(tids.items(),
                                           key=lambda kv: (kv[0][0], kv[1]))
        ]
        return {
            "traceEvents": meta_events + events,
            "displayTimeUnit": "ms",
            "otherData": {"trace_schema_version": TRACE_SCHEMA_VERSION,
                          "digest": self.digest(), "meta": dict(self.meta)},
        }

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_jsonl())
        return path

    @classmethod
    def load(cls, path: str) -> "TraceArtifact":
        with open(path) as f:
            return cls.from_jsonl(f.read())


# ---------------------------------------------------------------------------
# live spans
# ---------------------------------------------------------------------------

class Span:
    """A live span; also its own context manager."""
    __slots__ = ("name", "seq", "parent", "depth", "attrs",
                 "v_start", "v_end", "wall_s", "_tracer", "_t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict):
        self.name = name
        self.attrs = attrs
        self._tracer = tracer
        self.seq = -1
        self.parent: Optional[int] = None
        self.depth = 0
        self.v_start = 0.0
        self.v_end = 0.0
        self.wall_s = 0.0
        self._t0 = 0.0

    def set(self, **attrs) -> "Span":
        """Attach attributes mid-span (e.g. counts known only at exit)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        t = self._tracer
        self.seq = len(t.spans)
        self.parent = t._stack[-1].seq if t._stack else None
        self.depth = len(t._stack)
        self.v_start = t.virtual_time
        t.spans.append(self)
        t._stack.append(self)
        self._t0 = t._clock()
        return self

    def __exit__(self, exc_type, exc, tb):
        t = self._tracer
        self.wall_s = t._clock() - self._t0
        self.v_end = t.virtual_time
        if t._stack and t._stack[-1] is self:
            t._stack.pop()
        else:                       # tolerate mis-nested exits
            t._stack = [s for s in t._stack if s is not self]
        return False

    def record(self, include_wall: bool = False) -> SpanRecord:
        return SpanRecord(
            seq=self.seq, name=self.name, parent=self.parent,
            depth=self.depth, v_start=self.v_start, v_end=self.v_end,
            attrs=dict(self.attrs),
            wall_ms=self.wall_s * 1e3 if include_wall else None)


class Tracer:
    """Collects nested spans against a virtual + wallclock timebase."""

    #: real tracers record spans; the flight recorder checks this one
    #: attribute before materializing per-request span payloads, so
    #: replays under :data:`NULL_TRACER` build nothing at all
    records_spans = True

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self.spans: List[Span] = []
        self._stack: List[Span] = []
        self.virtual_time = 0.0     # simulators advance this (sim seconds)

    def span(self, name: str, **attrs) -> Span:
        return Span(self, name, attrs)

    def wall_by_name(self) -> Dict[str, float]:
        """Total wall seconds per span name, for live phase breakdowns."""
        out: Dict[str, float] = {}
        for s in self.spans:
            out[s.name] = out.get(s.name, 0.0) + s.wall_s
        return out

    def artifact(self, meta: Optional[Dict] = None,
                 include_wall: bool = False) -> TraceArtifact:
        """Freeze collected spans; deterministic bytes unless
        ``include_wall=True`` opts into wallclock durations."""
        if self._stack:
            raise ValueError(
                f"cannot serialize with {len(self._stack)} span(s) open "
                f"(innermost: {self._stack[-1].name!r})")
        return TraceArtifact(
            spans=tuple(s.record(include_wall) for s in self.spans),
            meta=dict(meta or {}))


class _NullSpan:
    """Shared no-op span: enter/exit/set do nothing, allocate nothing.
    ``v_start``/``v_end`` read 0.0 so instrumented code can compute
    against them (``tracer.virtual_time = sp.v_start + dt``) without
    branching on whether tracing is enabled."""
    __slots__ = ()
    v_start = 0.0
    v_end = 0.0

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False

    def set(self, **attrs):
        return self


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Zero-cost default: every span() returns the shared no-op span."""
    __slots__ = ("virtual_time",)

    records_spans = False

    def __init__(self):
        self.virtual_time = 0.0

    def span(self, name: str, **attrs) -> _NullSpan:
        return _NULL_SPAN

    def wall_by_name(self) -> Dict[str, float]:
        return {}


NULL_TRACER = NullTracer()

_TRACER = NULL_TRACER


def get_tracer():
    """The installed tracer (the shared :class:`NullTracer` by default)."""
    return _TRACER


def set_tracer(tracer) -> None:
    global _TRACER
    _TRACER = tracer if tracer is not None else NULL_TRACER


def enable_tracing(tracer: Optional[Tracer] = None) -> Tracer:
    """Install (and return) a real tracer."""
    t = tracer if tracer is not None else Tracer()
    set_tracer(t)
    return t


def disable_tracing() -> None:
    set_tracer(None)
