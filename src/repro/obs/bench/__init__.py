"""repro.obs.bench — versioned bench artifacts + regression gates.

See :mod:`repro.obs.bench.artifact` for the ``BenchArtifact`` schema
and :mod:`repro.obs.bench.gate` for the two-tier comparator behind
``obs bench compare|gate|trend``.  ``docs/benchmarking.md`` documents
the workflow.
"""
from repro.obs.bench.artifact import (
    BENCH_KIND, BENCH_SCHEMA_VERSION, SUPPORTED_BENCH_SCHEMA_VERSIONS,
    BenchArtifact, BenchRecord, BenchTiming, environment_fingerprint,
)
from repro.obs.bench.gate import (
    DEFAULT_ABS_TOL_US, DEFAULT_REL_TOL, EnvironmentMismatch, GateResult,
    append_history, compare_artifacts, diff_environment, format_compare,
    format_trend, gate_artifacts, history_entry, load_history, soft_exceeds,
    trend_summary,
)

__all__ = [
    "BENCH_KIND", "BENCH_SCHEMA_VERSION", "BenchArtifact", "BenchRecord",
    "BenchTiming", "DEFAULT_ABS_TOL_US", "DEFAULT_REL_TOL",
    "EnvironmentMismatch", "GateResult", "SUPPORTED_BENCH_SCHEMA_VERSIONS",
    "append_history", "compare_artifacts", "diff_environment",
    "environment_fingerprint", "format_compare", "format_trend",
    "gate_artifacts", "history_entry", "load_history", "soft_exceeds",
    "trend_summary",
]
