"""Two-tier benchmark regression comparator + history trend diffing.

Tier 1 — **hard gates on work counters**.  A ``MetricsRegistry``
counter snapshot is a pure function of code + seeds + ``REPRO_*``
knobs: two runs of the same suite on any machines produce identical
counters, byte for byte.  So the hard tier compares them exactly —
a counter that *grew*, *appeared*, or *vanished* versus the baseline
is a real algorithmic change (more grid queries per candidate, more
pricing chunks, more replay iterations), never noise, and fails the
gate.  Shrinks are reported as improvements, not violations.

Tier 2 — **soft gates on wallclock**.  ``us_per_call`` is noisy, so
the soft tier compares min-of-k (``BenchTiming.min_us``) under a
relative tolerance plus an absolute floor, and *refuses to run at all*
when the two artifacts carry different environment fingerprints —
cross-machine or cross-knob wallclock deltas are meaningless.  The
hard tier still runs on an environment mismatch caused by ``REPRO_*``
knobs: that is exactly the synthetic-regression case
(``REPRO_PRICING_CHUNK=1`` inflates ``repro_search_chunks_total``)
the CI sentinel injects.

``compare_artifacts`` is the strict determinism check behind
``obs bench compare`` (two identical runs → identical canonical
records); ``gate_artifacts`` is the baseline gate behind
``obs bench gate``; ``append_history``/``load_history``/
``trend_summary`` maintain and summarize the append-only
``results/bench_history.jsonl`` trajectory behind ``obs bench trend``.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Tuple

from repro.obs.bench.artifact import BenchArtifact

__all__ = [
    "EnvironmentMismatch", "GateResult", "append_history",
    "compare_artifacts", "diff_environment", "format_compare",
    "gate_artifacts", "load_history", "soft_exceeds", "trend_summary",
]

#: Default soft-gate tolerances: flag only when the current min-of-k is
#: more than 50% above baseline *and* the excess tops 5 ms — generous
#: enough for shared-CI noise, tight enough to catch an order of
#: magnitude given back.
DEFAULT_REL_TOL = 0.50
DEFAULT_ABS_TOL_US = 5000.0


class EnvironmentMismatch(ValueError):
    """Raised by :func:`compare_artifacts` when the two artifacts were
    produced under different environment fingerprints — comparing them
    would produce a misleading delta (CLI maps this to exit 2)."""


def _flatten(env: Dict, prefix: str = "") -> Dict[str, object]:
    out: Dict[str, object] = {}
    for k in sorted(env):
        v = env[k]
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(_flatten(v, key + "."))
        else:
            out[key] = v
    return out


def diff_environment(a: Dict, b: Dict) -> Dict[str, Tuple[object, object]]:
    """Flattened ``key -> (a_value, b_value)`` for every fingerprint
    entry that differs (missing keys show as ``None``)."""
    fa, fb = _flatten(a), _flatten(b)
    out: Dict[str, Tuple[object, object]] = {}
    for k in sorted(set(fa) | set(fb)):
        va, vb = fa.get(k), fb.get(k)
        if va != vb:
            out[k] = (va, vb)
    return out


def _counter_delta(base: Dict[str, float], cur: Dict[str, float]) -> Dict:
    """Exact counter-snapshot diff: added/removed names and changed
    values, or ``{}`` when identical."""
    added = sorted(set(cur) - set(base))
    removed = sorted(set(base) - set(cur))
    changed = {k: (base[k], cur[k])
               for k in sorted(set(base) & set(cur)) if base[k] != cur[k]}
    if not (added or removed or changed):
        return {}
    return {"added": added, "removed": removed, "changed": changed}


# ---------------------------------------------------------------------------
# compare — strict determinism check between two runs
# ---------------------------------------------------------------------------

def compare_artifacts(a: BenchArtifact, b: BenchArtifact) -> Dict:
    """Strict comparison of two suite runs (the ``obs bench compare``
    engine).  Raises :class:`EnvironmentMismatch` when the environment
    fingerprints differ; otherwise returns a dict whose ``identical``
    flag is True iff the canonical views match: same record names, and
    for every record the same status and byte-identical counters.
    Wallclock deltas are reported informationally, never judged."""
    env_delta = diff_environment(a.environment, b.environment)
    if env_delta:
        lines = [f"  {k}: {va!r} != {vb!r}" for k, (va, vb) in env_delta.items()]
        raise EnvironmentMismatch(
            "environment fingerprints differ — refusing to compare "
            "(wallclock and knob-sensitive counters are not comparable):\n"
            + "\n".join(lines))

    only_a = sorted(set(a.names) - set(b.names))
    only_b = sorted(set(b.names) - set(a.names))
    records: Dict[str, Dict] = {}
    wallclock: Dict[str, Dict] = {}
    for ra in a.records:
        rb = b.record(ra.name)
        if rb is None:
            continue
        delta: Dict = {}
        if ra.status != rb.status:
            delta["status"] = (ra.status, rb.status)
        cdelta = _counter_delta(ra.counters, rb.counters)
        if cdelta:
            delta["counters"] = cdelta
        if delta:
            records[ra.name] = delta
        wallclock[ra.name] = {
            "a_median_us": ra.timing.median_us,
            "b_median_us": rb.timing.median_us,
        }
    identical = not (records or only_a or only_b)
    return {"identical": identical, "records": records,
            "only_a": only_a, "only_b": only_b,
            "wallclock": wallclock,
            "digest_a": a.digest(), "digest_b": b.digest()}


def format_compare(cmp: Dict) -> str:
    lines: List[str] = []
    if cmp["identical"]:
        lines.append(f"identical work (digest {cmp['digest_a']})")
    else:
        lines.append("NOT identical:")
        for name in cmp["only_a"]:
            lines.append(f"  only in first:  {name}")
        for name in cmp["only_b"]:
            lines.append(f"  only in second: {name}")
        for name, delta in sorted(cmp["records"].items()):
            if "status" in delta:
                sa, sb = delta["status"]
                lines.append(f"  {name}: status {sa} -> {sb}")
            cd = delta.get("counters", {})
            for k in cd.get("added", []):
                lines.append(f"  {name}: counter appeared  {k}")
            for k in cd.get("removed", []):
                lines.append(f"  {name}: counter vanished  {k}")
            for k, (va, vb) in cd.get("changed", {}).items():
                lines.append(f"  {name}: {k}  {va:g} -> {vb:g}")
    for name, w in sorted(cmp["wallclock"].items()):
        lines.append(f"  wall {name}: {w['a_median_us']:.0f}us vs "
                     f"{w['b_median_us']:.0f}us (informational)")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# gate — baseline vs current, two tiers
# ---------------------------------------------------------------------------

def soft_exceeds(base_us: float, cur_us: float,
                 rel_tol: float = DEFAULT_REL_TOL,
                 abs_tol_us: float = DEFAULT_ABS_TOL_US) -> bool:
    """The soft-gate predicate, kept pure for property testing: flag
    iff ``cur_us > base_us * (1 + rel_tol) + abs_tol_us``.  Monotone in
    ``cur_us`` and antitone in both tolerances."""
    return cur_us > base_us * (1.0 + rel_tol) + abs_tol_us


@dataclasses.dataclass
class GateResult:
    """Outcome of gating a current run against a baseline artifact."""
    hard_violations: List[Dict]          # counter grew/appeared/vanished
    improvements: List[Dict]             # counter shrank (not a failure)
    soft_violations: List[Dict]          # wallclock beyond tolerance
    soft_skipped: str = ""               # reason the soft tier did not run
    uncovered: List[str] = dataclasses.field(default_factory=list)
    new_benches: List[str] = dataclasses.field(default_factory=list)
    errored: List[str] = dataclasses.field(default_factory=list)
    rel_tol: float = DEFAULT_REL_TOL
    abs_tol_us: float = DEFAULT_ABS_TOL_US

    @property
    def ok(self) -> bool:
        return not self.hard_violations and not self.soft_violations

    def to_dict(self) -> Dict:
        return {"ok": self.ok,
                "hard_violations": self.hard_violations,
                "improvements": self.improvements,
                "soft_violations": self.soft_violations,
                "soft_skipped": self.soft_skipped,
                "uncovered": self.uncovered,
                "new_benches": self.new_benches,
                "errored": self.errored,
                "rel_tol": self.rel_tol,
                "abs_tol_us": self.abs_tol_us}

    def format(self) -> str:
        lines: List[str] = []
        verdict = "PASS" if self.ok else "FAIL"
        lines.append(f"bench gate: {verdict}  "
                     f"({len(self.hard_violations)} hard, "
                     f"{len(self.soft_violations)} soft violations)")
        for v in self.hard_violations:
            lines.append(f"  HARD {v['bench']}: {v['counter']} {v['kind']}  "
                         f"{v['baseline']:g} -> {v['current']:g}")
        for v in self.soft_violations:
            lines.append(f"  SOFT {v['bench']}: min {v['baseline_us']:.0f}us "
                         f"-> {v['current_us']:.0f}us "
                         f"({v['ratio']:.2f}x, tol {self.rel_tol:+.0%} "
                         f"+ {self.abs_tol_us:.0f}us)")
        for v in self.improvements:
            lines.append(f"  good {v['bench']}: {v['counter']}  "
                         f"{v['baseline']:g} -> {v['current']:g}")
        if self.soft_skipped:
            lines.append(f"  note: soft (wallclock) tier skipped: "
                         f"{self.soft_skipped}")
        if self.errored:
            lines.append(f"  note: skipped errored benches: "
                         f"{', '.join(self.errored)}")
        if self.uncovered:
            lines.append(f"  note: baseline benches not in current run: "
                         f"{', '.join(self.uncovered)}")
        if self.new_benches:
            lines.append(f"  note: benches without a baseline: "
                         f"{', '.join(self.new_benches)}")
        return "\n".join(lines)


def gate_artifacts(baseline: BenchArtifact, current: BenchArtifact,
                   rel_tol: float = DEFAULT_REL_TOL,
                   abs_tol_us: float = DEFAULT_ABS_TOL_US,
                   hard_only: bool = False) -> GateResult:
    """Gate ``current`` against ``baseline`` over the benchmarks both
    runs cover (a ``--only`` run gates against the full committed
    baseline).  The hard counter tier always runs — even across
    mismatched environments, where counter drift caused by a ``REPRO_*``
    knob is precisely the regression being hunted.  The soft wallclock
    tier runs only when the fingerprints match (and ``hard_only`` is
    False); otherwise it is skipped with a reason naming the first
    differing keys."""
    res = GateResult(hard_violations=[], improvements=[],
                     soft_violations=[], rel_tol=rel_tol,
                     abs_tol_us=abs_tol_us)
    res.uncovered = sorted(set(baseline.names) - set(current.names))
    res.new_benches = sorted(set(current.names) - set(baseline.names))

    env_delta = diff_environment(baseline.environment, current.environment)
    soft_enabled = not hard_only
    if hard_only:
        res.soft_skipped = "--hard-only"
    elif env_delta:
        keys = ", ".join(list(env_delta)[:4])
        res.soft_skipped = (f"environment fingerprints differ ({keys}) — "
                            "wallclock not comparable")
        soft_enabled = False

    for rb in baseline.records:
        rc = current.record(rb.name)
        if rc is None:
            continue
        if rb.status != "ok" or rc.status != "ok":
            res.errored.append(rb.name)
            continue
        # hard tier: exact counter comparison
        for k in sorted(set(rb.counters) | set(rc.counters)):
            vb, vc = rb.counters.get(k), rc.counters.get(k)
            if vb is None:
                res.hard_violations.append(
                    {"bench": rb.name, "counter": k, "kind": "appeared",
                     "baseline": 0.0, "current": vc})
            elif vc is None:
                res.hard_violations.append(
                    {"bench": rb.name, "counter": k, "kind": "vanished",
                     "baseline": vb, "current": 0.0})
            elif vc > vb:
                res.hard_violations.append(
                    {"bench": rb.name, "counter": k, "kind": "grew",
                     "baseline": vb, "current": vc})
            elif vc < vb:
                res.improvements.append(
                    {"bench": rb.name, "counter": k,
                     "baseline": vb, "current": vc})
        # soft tier: min-of-k wallclock under tolerance
        if soft_enabled and soft_exceeds(rb.timing.min_us, rc.timing.min_us,
                                         rel_tol, abs_tol_us):
            base_us = rb.timing.min_us
            res.soft_violations.append(
                {"bench": rb.name, "baseline_us": base_us,
                 "current_us": rc.timing.min_us,
                 "ratio": (rc.timing.min_us / base_us
                           if base_us > 0 else float("inf"))})
    return res


# ---------------------------------------------------------------------------
# history — append-only trajectory + trend summary
# ---------------------------------------------------------------------------

def history_entry(art: BenchArtifact) -> Dict:
    """One JSONL line: run identity plus the per-bench work digest and
    headline timings the trend view tracks."""
    return {"created_at": art.created_at,
            "suite": art.suite,
            "digest": art.digest(),
            "env_digest": art.environment_digest(),
            "benches": {r.name: {"status": r.status,
                                 "median_us": r.timing.median_us,
                                 "min_us": r.timing.min_us,
                                 "counters_digest": r.counters_digest()}
                        for r in art.records}}


def append_history(path: str, art: BenchArtifact) -> Dict:
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    entry = history_entry(art)
    with open(path, "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")
    return entry


def load_history(path: str) -> List[Dict]:
    entries: List[Dict] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                entries.append(json.loads(line))
    return entries


def trend_summary(entries: List[Dict], suite: Optional[str] = None) -> Dict:
    """Per-benchmark trajectory across history entries (in file order):
    first/last median, relative wallclock change, best min-of-k ever,
    and how many times the work-counter digest changed — the count that
    matters, because each change is a real algorithmic shift."""
    if suite:
        entries = [e for e in entries if e.get("suite") == suite]
    benches: Dict[str, Dict] = {}
    for e in entries:
        for name, b in e.get("benches", {}).items():
            if b.get("status") != "ok":
                continue
            t = benches.setdefault(name, {
                "runs": 0, "first_median_us": b["median_us"],
                "last_median_us": b["median_us"],
                "best_min_us": b["min_us"],
                "work_changes": 0, "_last_work": None})
            t["runs"] += 1
            t["last_median_us"] = b["median_us"]
            t["best_min_us"] = min(t["best_min_us"], b["min_us"])
            if (t["_last_work"] is not None
                    and b["counters_digest"] != t["_last_work"]):
                t["work_changes"] += 1
            t["_last_work"] = b["counters_digest"]
    for t in benches.values():
        del t["_last_work"]
        first = t["first_median_us"]
        t["median_change_pct"] = (
            100.0 * (t["last_median_us"] - first) / first if first > 0 else 0.0)
    return {"n_entries": len(entries),
            "benches": {k: benches[k] for k in sorted(benches)}}


def format_trend(summary: Dict) -> str:
    lines = [f"bench history: {summary['n_entries']} runs"]
    if not summary["benches"]:
        lines.append("  (no ok benchmark entries)")
        return "\n".join(lines)
    width = max(len(n) for n in summary["benches"])
    for name, t in summary["benches"].items():
        lines.append(
            f"  {name:<{width}}  runs {t['runs']:>3}  "
            f"median {t['first_median_us']:>10.0f}us -> "
            f"{t['last_median_us']:>10.0f}us ({t['median_change_pct']:+6.1f}%)  "
            f"best {t['best_min_us']:>10.0f}us  "
            f"work-changes {t['work_changes']}")
    return "\n".join(lines)
