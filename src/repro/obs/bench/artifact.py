"""Versioned benchmark artifact — one suite run as a file.

A :class:`BenchArtifact` is what ``benchmarks/run.py`` (and the CLI's
``obs bench run``) produces and what the two-tier regression comparator
in :mod:`repro.obs.bench.gate` consumes: per-benchmark records carrying

* **repeat-timing stats** (:class:`BenchTiming` — median/min/IQR of
  ``us_per_call`` over ``--repeat`` samples),
* a **work-counter snapshot** — the ``MetricsRegistry`` counters the
  instrumented run incremented (grid queries, candidates priced/pruned,
  pricing chunks, replay iterations, …).  Work counters are a pure
  function of code + seeds + ``REPRO_*`` knobs, so they are
  byte-stable across runs and machines: *any* drift is a real
  algorithmic change, never noise,
* a tracer-span-derived **phase breakdown** (wall seconds per span
  name — the same ``search.chunk``/``price.kernel``/``serving.replay``
  spans ``search --trace-out`` captures), and
* an **environment fingerprint** (platform, python, ``REPRO_*`` pricing
  knobs, PerfDatabase grid hash) stamped once per suite run so the
  comparator can refuse to gate wallclock across mismatched setups.

Like :class:`repro.calibrate.artifact.CalibrationArtifact`, the
artifact is Date-free — ``created_at`` is caller-supplied, never
ambient wall-clock — and round-trips losslessly:
``BenchArtifact.from_json(a.to_json()) == a`` (golden fixture under
``tests/fixtures/``).  The :meth:`BenchArtifact.digest` covers only the
**canonical** view — suite, environment, and per-record (name, status,
counters) — with every wallclock-derived field (timing stats, phase
breakdown, derived strings, ``created_at``) excluded, so two
deterministic runs share a digest no matter how fast they ran.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import statistics
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "BENCH_KIND", "BENCH_SCHEMA_VERSION", "BenchArtifact", "BenchRecord",
    "BenchTiming", "SUPPORTED_BENCH_SCHEMA_VERSIONS",
    "environment_fingerprint",
]

#: Bump on any backwards-incompatible change to the artifact JSON layout.
BENCH_SCHEMA_VERSION = 1
SUPPORTED_BENCH_SCHEMA_VERSIONS = (1,)

#: Sanity marker so a SearchReport / calibration blob is never loaded
#: as a bench artifact (house convention, see CalibrationArtifact.KIND).
BENCH_KIND = "repro-bench"


def environment_fingerprint(include_perf_db: bool = True) -> Dict:
    """The setup a benchmark's wallclock numbers are only comparable
    within: host platform + python, the resolved ``REPRO_*`` pricing
    knobs (resolved through :mod:`repro.core.jaxenv`, so defaults and
    explicit settings fingerprint identically), and the default
    PerfDatabase's grid hash (any change to the operator data changes
    every measured number downstream).
    """
    import platform as _platform
    from repro.core import jaxenv

    env: Dict = {
        "platform": _platform.platform(),
        "machine": _platform.machine(),
        "python": _platform.python_version(),
        "repro": {
            "REPRO_BATCHED_PRICING": jaxenv.batched_pricing_default(),
            "REPRO_PRICING_BACKEND": jaxenv.pricing_backend(),
            "REPRO_PRICING_CHUNK": jaxenv.pricing_chunk(),
        },
    }
    try:
        import numpy
        env["numpy"] = numpy.__version__
    except ImportError:                      # pragma: no cover - numpy is a dep
        env["numpy"] = None
    if include_perf_db:
        from repro.core.perf_database import PerfDatabase
        fp = PerfDatabase("tpu_v5e", "repro-jax").fingerprint()
        env["perf_db"] = {"platform": fp["platform"],
                          "backend": fp["backend"],
                          "grid_hash": fp["grid_hash"]}
    else:
        env["perf_db"] = None
    return env


def _digest12(blob: Dict) -> str:
    return hashlib.sha256(
        json.dumps(blob, sort_keys=True).encode()).hexdigest()[:12]


@dataclasses.dataclass(frozen=True)
class BenchTiming:
    """Repeat-timing stats for one benchmark: ``us_per_call`` samples
    plus the order statistics the soft (wallclock) gate reads —
    ``min_us`` is the min-of-k the comparator trusts most."""
    n: int
    samples_us: Tuple[float, ...]
    median_us: float
    min_us: float
    iqr_us: float

    @classmethod
    def from_samples(cls, samples_us: Sequence[float]) -> "BenchTiming":
        s = tuple(float(x) for x in samples_us)
        if not s:
            raise ValueError("timing needs at least one sample")
        srt = sorted(s)
        if len(srt) >= 4:
            q = statistics.quantiles(srt, n=4)
            iqr = q[2] - q[0]
        elif len(srt) > 1:
            iqr = srt[-1] - srt[0]
        else:
            iqr = 0.0
        return cls(n=len(s), samples_us=s,
                   median_us=float(statistics.median(srt)),
                   min_us=float(srt[0]), iqr_us=float(iqr))

    def to_dict(self) -> Dict:
        return {"n": self.n, "samples_us": list(self.samples_us),
                "median_us": self.median_us, "min_us": self.min_us,
                "iqr_us": self.iqr_us}

    @classmethod
    def from_dict(cls, d: Dict) -> "BenchTiming":
        return cls(n=d["n"], samples_us=tuple(d["samples_us"]),
                   median_us=d["median_us"], min_us=d["min_us"],
                   iqr_us=d["iqr_us"])


@dataclasses.dataclass(frozen=True)
class BenchRecord:
    """One benchmark's result inside a suite run."""
    name: str
    status: str                    # "ok" | "error"
    timing: BenchTiming
    counters: Dict[str, float]     # MetricsRegistry counter snapshot
    phases: Dict[str, float]       # wall seconds per tracer span name
    derived: str = ""              # the CSV line's human headline
    error: str = ""

    def __post_init__(self):
        if self.status not in ("ok", "error"):
            raise ValueError(f"bad record status {self.status!r}")
        object.__setattr__(self, "counters", dict(self.counters))
        object.__setattr__(self, "phases", dict(self.phases))

    def canonical_dict(self) -> Dict:
        """The deterministic view: name, status, work counters — no
        wallclock-derived field survives into the digest."""
        return {"name": self.name, "status": self.status,
                "counters": {k: self.counters[k]
                             for k in sorted(self.counters)}}

    def counters_digest(self) -> str:
        """12-hex digest over this record's counter snapshot (the
        per-bench work identity ``bench_history.jsonl`` tracks)."""
        return _digest12(self.canonical_dict()["counters"])

    def to_dict(self) -> Dict:
        return {"name": self.name, "status": self.status,
                "derived": self.derived, "error": self.error,
                "counters": {k: self.counters[k]
                             for k in sorted(self.counters)},
                "phases": {k: self.phases[k]
                           for k in sorted(self.phases)},
                "timing": self.timing.to_dict()}

    @classmethod
    def from_dict(cls, d: Dict) -> "BenchRecord":
        return cls(name=d["name"], status=d["status"],
                   derived=d.get("derived", ""), error=d.get("error", ""),
                   counters=dict(d["counters"]), phases=dict(d["phases"]),
                   timing=BenchTiming.from_dict(d["timing"]))


@dataclasses.dataclass
class BenchArtifact:
    """The suite-run artifact: environment + per-benchmark records,
    versioned, digestable, losslessly JSON round-trippable."""
    suite: str                     # "quick" | "full"
    created_at: str                # ISO-8601, supplied by the caller
    environment: Dict
    records: List[BenchRecord]
    notes: str = ""
    schema_version: int = BENCH_SCHEMA_VERSION

    def __post_init__(self):
        names = [r.name for r in self.records]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate benchmark records: {names}")

    # -- lookups -------------------------------------------------------------
    def record(self, name: str) -> Optional[BenchRecord]:
        for r in self.records:
            if r.name == name:
                return r
        return None

    @property
    def names(self) -> List[str]:
        return [r.name for r in self.records]

    # -- identity ------------------------------------------------------------
    def canonical_dict(self) -> Dict:
        """Everything deterministic about the run (and nothing
        wallclock): suite, environment, per-record (name, status,
        counters).  ``created_at``/timing/phases/derived stay out."""
        return {"kind": BENCH_KIND,
                "schema_version": self.schema_version,
                "suite": self.suite,
                "environment": self.environment,
                "records": [r.canonical_dict() for r in self.records]}

    def digest(self) -> str:
        blob = json.dumps(self.canonical_dict(), sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    def environment_digest(self) -> str:
        return _digest12(self.environment)

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> Dict:
        return {"kind": BENCH_KIND,
                "schema_version": self.schema_version,
                "suite": self.suite,
                "created_at": self.created_at,
                "notes": self.notes,
                "environment": self.environment,
                "records": [r.to_dict() for r in self.records]}

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: Dict) -> "BenchArtifact":
        if d.get("kind") != BENCH_KIND:
            raise ValueError(
                f"not a bench artifact (kind={d.get('kind')!r}; "
                f"expected {BENCH_KIND!r})")
        version = d.get("schema_version")
        if version not in SUPPORTED_BENCH_SCHEMA_VERSIONS:
            raise ValueError(
                f"unsupported bench schema_version {version!r}; this "
                f"build reads versions "
                f"{', '.join(map(str, SUPPORTED_BENCH_SCHEMA_VERSIONS))}")
        return cls(suite=d["suite"], created_at=d["created_at"],
                   notes=d.get("notes", ""),
                   environment=dict(d["environment"]),
                   records=[BenchRecord.from_dict(r) for r in d["records"]],
                   schema_version=version)

    @classmethod
    def from_json(cls, text: str) -> "BenchArtifact":
        return cls.from_dict(json.loads(text))

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")
        return path

    @classmethod
    def load(cls, path: str) -> "BenchArtifact":
        with open(path) as f:
            return cls.from_json(f.read())
