"""Per-candidate cost attribution: where an iteration's milliseconds go.

The pricing model already decomposes every candidate into operator atoms
(``decompose.iteration_ops``) before summing them through
``PerfDatabase.sequence_latency`` — ``explain`` re-walks exactly that
list and buckets ``count * op_latency(op)`` by kernel family
(:func:`repro.core.operators.op_family`: gemm / attn_prefill /
attn_decode / moe / recurrent / comm / embedding / mem) per serving
phase (prefill / decode / mixed).  Because both walks price through the
same memoized oracle, the waterfall is conservative by construction:
per-phase family sums reproduce ``spec_latency_ms`` to float-summation
noise (tested ≤ 1e-9 relative across the model zoo, scalar and batched).

``diff_explanations`` compares two candidates family-by-family and names
the parallelism change responsible ("winner spends 38% less in comm
because tp=4→2").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core import decompose
from repro.core import operators as ops
from repro.core.config import CandidateConfig
from repro.serving.sim import StepSpec

__all__ = [
    "CandidateExplanation", "Explanation", "ExplanationDiff",
    "PhaseWaterfall", "diff_explanations", "explain_candidate",
    "explain_spec",
]

_PHASE_ORDER = ("prefill", "mixed", "decode")


def _phase_of(spec: StepSpec) -> str:
    if spec.prefill and spec.decode:
        return "mixed"
    return "prefill" if spec.prefill else "decode"


@dataclasses.dataclass(frozen=True)
class PhaseWaterfall:
    """Family-bucketed latency of one serving phase, in ms per iteration."""
    phase: str
    families: Dict[str, float]
    overhead_ms: float                  # backend launch/framework overhead
    n_atoms: int                        # pricing atoms merged into this phase

    @property
    def total_ms(self) -> float:
        return sum(self.families.values()) + self.overhead_ms

    def to_dict(self) -> Dict:
        return {"phase": self.phase,
                "families": {k: self.families[k]
                             for k in sorted(self.families)},
                "overhead_ms": self.overhead_ms,
                "total_ms": self.total_ms,
                "n_atoms": self.n_atoms}


@dataclasses.dataclass(frozen=True)
class CandidateExplanation:
    """The full waterfall for one candidate in one serving mode."""
    model: str
    mode: str
    describe: str
    parallel: Dict
    batch_size: int
    phases: Tuple[PhaseWaterfall, ...]

    @property
    def families(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for ph in self.phases:
            for fam, ms in ph.families.items():
                out[fam] = out.get(fam, 0.0) + ms
        return out

    @property
    def total_ms(self) -> float:
        return sum(ph.total_ms for ph in self.phases)

    def to_dict(self) -> Dict:
        return {"model": self.model, "mode": self.mode,
                "describe": self.describe, "parallel": dict(self.parallel),
                "batch_size": self.batch_size,
                "phases": [ph.to_dict() for ph in self.phases],
                "families": {k: v for k, v
                             in sorted(self.families.items())},
                "total_ms": self.total_ms}

    def summary(self) -> str:
        lines = [f"{self.model} {self.describe} [{self.mode}] — "
                 f"{self.total_ms:.3f} ms/iteration"]
        for ph in self.phases:
            lines.append(f"  {ph.phase}: {ph.total_ms:.3f} ms")
            ranked = sorted(ph.families.items(), key=lambda kv: -kv[1])
            for fam, ms in ranked:
                share = ms / ph.total_ms * 100 if ph.total_ms else 0.0
                lines.append(f"    {fam:<13} {ms:10.4f} ms  {share:5.1f}%")
            if ph.overhead_ms:
                share = ph.overhead_ms / ph.total_ms * 100
                lines.append(f"    {'overhead':<13} {ph.overhead_ms:10.4f} ms"
                             f"  {share:5.1f}%")
        return "\n".join(lines)


def explain_spec(session, par, spec: StepSpec, flags
                 ) -> Tuple[Dict[str, float], float]:
    """Family buckets (ms) + overhead (ms) for one pricing atom.

    Mirrors ``InferenceSession.spec_latency_ms`` exactly, including the
    sequential-prefill split, so bucket sums reconcile with the scalar
    oracle (and with the fused batch kernel, which prices the same
    atoms).
    """
    fam: Dict[str, float] = {}
    overhead = 0.0

    def add(sub: StepSpec):
        nonlocal overhead
        op_list = decompose.iteration_ops(
            session.cfg, par, sub, alpha=session.w.moe_alpha,
            backend=session.w.backend, dtype=session.w.dtype)
        for item in op_list:
            if isinstance(item, tuple):
                op, count = item
            else:
                op, count = item, 1
            f = ops.op_family(op)
            fam[f] = fam.get(f, 0.0) + 1e3 * count * session.db.op_latency(op)
        overhead += 1e3 * session.backend.iteration_overhead(
            len(sub.prefill), len(sub.decode), flags.enable_graph_capture)

    if session.backend.sequential_prefill and len(spec.prefill) > 1:
        for chunk in spec.prefill:
            add(StepSpec(prefill=(chunk,), decode=()))
        if spec.decode:
            add(StepSpec(prefill=(), decode=spec.decode))
    else:
        add(spec)
    return fam, overhead


def explain_candidate(session, cand: CandidateConfig,
                      mode: str) -> CandidateExplanation:
    """Waterfall for one (candidate, mode), built from the exact atoms the
    mode algorithm prices (recorded via ``InferenceSession.record_specs``)."""
    if mode == "static":
        fn = session.evaluate_static
    elif mode == "aggregated":
        fn = session.evaluate_aggregated
    else:
        raise ValueError(f"explain supports single-engine modes "
                         f"('static', 'aggregated'), not {mode!r}")
    mem = session._mem_ok(cand)
    if not mem[0]:
        raise ValueError(f"candidate {cand.describe()} does not fit memory "
                         f"on {session.platform.name}")
    _, atoms = session.record_specs(
        lambda: fn(cand, _mem=mem, _plan_only=True))
    acc: Dict[str, List] = {}       # phase -> [families, overhead, n_atoms]
    for par, spec, flags in atoms:
        ph = _phase_of(spec)
        fam, ov = explain_spec(session, par, spec, flags)
        slot = acc.setdefault(ph, [{}, 0.0, 0])
        for f, ms in fam.items():
            slot[0][f] = slot[0].get(f, 0.0) + ms
        slot[1] += ov
        slot[2] += 1
    phases = tuple(
        PhaseWaterfall(phase=ph, families=acc[ph][0],
                       overhead_ms=acc[ph][1], n_atoms=acc[ph][2])
        for ph in _PHASE_ORDER if ph in acc)
    return CandidateExplanation(
        model=session.w.model, mode=mode, describe=cand.describe(),
        parallel=dataclasses.asdict(cand.parallel),
        batch_size=cand.batch_size, phases=phases)


# ---------------------------------------------------------------------------
# two-candidate diff
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExplanationDiff:
    """Family-by-family comparison of two explained candidates."""
    candidate: str                   # describe() strings
    baseline: str
    families: Dict[str, Dict]        # fam -> {candidate_ms, baseline_ms, ...}
    parallel_changes: Dict[str, Tuple[int, int]]   # axis -> (cand, base)
    total_candidate_ms: float
    total_baseline_ms: float

    def to_dict(self) -> Dict:
        return {"candidate": self.candidate, "baseline": self.baseline,
                "families": {k: dict(v) for k, v
                             in sorted(self.families.items())},
                "parallel_changes": {k: list(v) for k, v
                                     in sorted(self.parallel_changes.items())},
                "total_candidate_ms": self.total_candidate_ms,
                "total_baseline_ms": self.total_baseline_ms}

    def summary(self) -> str:
        because = ""
        if self.parallel_changes:
            because = " because " + ", ".join(
                f"{ax}={b}→{a}" for ax, (a, b)
                in sorted(self.parallel_changes.items()))
        lines = [f"{self.candidate} vs {self.baseline}: "
                 f"{self.total_candidate_ms:.3f} ms vs "
                 f"{self.total_baseline_ms:.3f} ms per iteration{because}"]
        ranked = sorted(self.families.items(),
                        key=lambda kv: -abs(kv[1]["delta_ms"]))
        for fam, d in ranked:
            if d["baseline_ms"] <= 0 and d["candidate_ms"] <= 0:
                continue
            if d["baseline_ms"] > 0:
                pct = -d["delta_ms"] / d["baseline_ms"] * 100
                verb = "less" if pct >= 0 else "more"
                lines.append(
                    f"  {self.candidate} spends {abs(pct):.0f}% {verb} in "
                    f"{fam} ({d['candidate_ms']:.4f} vs "
                    f"{d['baseline_ms']:.4f} ms){because}")
                because = ""         # attribute the cause once, on top
            else:
                lines.append(f"  {fam}: {d['candidate_ms']:.4f} ms "
                             f"(absent in baseline)")
        return "\n".join(lines)


def diff_explanations(cand: CandidateExplanation,
                      base: CandidateExplanation) -> ExplanationDiff:
    fams = sorted(set(cand.families) | set(base.families))
    table = {}
    for fam in fams:
        a = cand.families.get(fam, 0.0)
        b = base.families.get(fam, 0.0)
        table[fam] = {"candidate_ms": a, "baseline_ms": b,
                      "delta_ms": a - b,
                      "ratio": a / b if b > 0 else float("inf")}
    changes = {ax: (cand.parallel[ax], base.parallel[ax])
               for ax in cand.parallel
               if cand.parallel[ax] != base.parallel[ax]}
    return ExplanationDiff(
        candidate=cand.describe, baseline=base.describe,
        families=table, parallel_changes=changes,
        total_candidate_ms=cand.total_ms,
        total_baseline_ms=base.total_ms)


@dataclasses.dataclass(frozen=True)
class Explanation:
    """What ``Configurator.explain`` returns: the explained candidate,
    optionally a baseline and their diff."""
    candidate: CandidateExplanation
    baseline: Optional[CandidateExplanation] = None
    diff: Optional[ExplanationDiff] = None

    def to_dict(self) -> Dict:
        return {"candidate": self.candidate.to_dict(),
                "baseline": (self.baseline.to_dict()
                             if self.baseline else None),
                "diff": self.diff.to_dict() if self.diff else None}

    def summary(self) -> str:
        parts = [self.candidate.summary()]
        if self.baseline is not None:
            parts.append(self.baseline.summary())
        if self.diff is not None:
            parts.append(self.diff.summary())
        return "\n\n".join(parts)
