"""Causal GQA flash attention (prefill) — Pallas TPU kernel.

Blockwise online-softmax attention: grid (B, H, num_q_blocks, num_kv_blocks)
with the KV block index as the minor (sequential) grid dimension; running
(max, sum, acc) live in VMEM scratch across KV iterations.  GQA is handled
in the BlockSpec index maps (kv head = q head // group), sliding windows by
masking and by skipping fully-out-of-window KV blocks.

VMEM working set per step: q (bq, D) + k,v (bk, D) + acc (bq, D) fp32 +
logits (bq, bk) fp32 — with bq = bk = 512, D = 128 that is ~1.4 MiB, well
inside the ~16 MiB v5e VMEM budget, and the (8, 128)-aligned block shapes
keep the MXU fed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific memory spaces; interpret mode runs without them
    from jax.experimental.pallas import tpu as pltpu
    _SCRATCH = lambda shape: pltpu.VMEM(shape, jnp.float32)
except Exception:  # pragma: no cover
    pltpu = None
    _SCRATCH = lambda shape: pl.VMEM(shape, jnp.float32)

DEFAULT_BQ = 512
DEFAULT_BK = 512
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int, sq: int, sk: int,
            bq: int, bk: int, nk: int):
    i = pl.program_id(2)      # q block
    j = pl.program_id(3)      # kv block (sequential, minor)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq, bk)

    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + (sk - sq)                               # align q to the END of k
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = (kpos <= qpos) if causal else (kpos >= 0)
    mask &= kpos < sk                             # key padding
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(p, v)
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = DEFAULT_BQ, block_k: int = DEFAULT_BK,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Sk, K, D).  Returns (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    assert H % K == 0, (H, K)
    G = H // K
    bq, bk = min(block_q, Sq), min(block_k, Sk)

    pad_q = (-Sq) % bq
    pad_k = (-Sk) % bk
    qt = jnp.moveaxis(q, 2, 1)                    # (B, H, Sq, D)
    kt = jnp.moveaxis(k, 2, 1)                    # (B, K, Sk, D)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = qt.shape[2] // bq
    nk = kt.shape[2] // bk

    kernel = functools.partial(
        _kernel, scale=D ** -0.5, causal=causal, window=window,
        sq=Sq, sk=Sk, bq=bq, bk=bk, nk=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            _SCRATCH((bq, D)), _SCRATCH((bq,)), _SCRATCH((bq,))],
        interpret=interpret,
    )(qt, kt, vt)

    if pad_q:
        out = out[:, :, :Sq]
    return jnp.moveaxis(out, 1, 2)
