"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, window: int = 0) -> jax.Array:
    """q: (B, Sq, H, D); k/v: (B, Sk, K, D) with H % K == 0."""
    B, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    g = H // K
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (D ** -0.5)
    if causal:
        qpos = jnp.arange(Sq)[:, None]
        kpos = jnp.arange(Sk)[None, :]
        m = kpos <= qpos + (Sk - Sq)
        if window:
            m &= kpos > qpos + (Sk - Sq) - window
        logits = jnp.where(m[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         valid_len: jax.Array) -> jax.Array:
    """q: (B, H, D); caches: (B, W, K, D); valid_len: (B,)."""
    B, H, D = q.shape
    W, K = k_cache.shape[1], k_cache.shape[2]
    g = H // K
    k = jnp.repeat(k_cache, g, axis=2).astype(jnp.float32)
    v = jnp.repeat(v_cache, g, axis=2).astype(jnp.float32)
    logits = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32), k) * (D ** -0.5)
    valid = jnp.arange(W)[None, :] < valid_len[:, None]
    logits = jnp.where(valid[:, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", p, v).astype(q.dtype)


def rglru_scan_ref(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """Linear recurrence h_t = a_t * h_{t-1} + b_t.

    a, b: (B, S, W) fp32; h0: (B, W).  Returns all states (B, S, W)."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    _, hs = jax.lax.scan(step, h0,
                         (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(hs, 0, 1)


def moe_gemm_ref(xe: jax.Array, we: jax.Array) -> jax.Array:
    """Grouped GEMM: xe (E, C, D) @ we (E, D, F) -> (E, C, F), fp32 accum."""
    return jnp.einsum("ecd,edf->ecf", xe.astype(jnp.float32),
                      we.astype(jnp.float32)).astype(xe.dtype)
