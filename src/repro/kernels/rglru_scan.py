"""RG-LRU gated linear recurrence — Pallas TPU kernel.

h_t = a_t * h_{t-1} + b_t over the sequence, blocked (batch, width) with the
sequence-block index as the minor (sequential) grid dimension; the running
state h lives in VMEM scratch across sequence blocks, so each (B, W) tile
streams its gates once from HBM — the recurrence is purely memory-bound,
matching the RecurrentOp model in core/operators.py.

(The pure-jnp path uses ``lax.associative_scan`` — log-depth but 3x the HBM
traffic; the kernel is the linear-traffic alternative the paper's operator
DB would profile.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _SCRATCH = lambda shape: pltpu.VMEM(shape, jnp.float32)
except Exception:  # pragma: no cover
    pltpu = None
    _SCRATCH = lambda shape: pl.VMEM(shape, jnp.float32)

DEFAULT_BB = 8
DEFAULT_BS = 128
DEFAULT_BW = 128


def _kernel(a_ref, b_ref, h0_ref, o_ref, h_ref, *, bs: int):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)      # (bs, bw)
    b = b_ref[0].astype(jnp.float32)
    h = h_ref[...]                        # (bb=1 squeezed? no: (bb, bw))

    def step(t, carry):
        h = carry
        h = a[t] * h + b[t]
        o_ref[0, t] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bs, step, h)
    h_ref[...] = h


def rglru_scan(a: jax.Array, b: jax.Array, h0: jax.Array, *,
               block_s: int = DEFAULT_BS, block_w: int = DEFAULT_BW,
               interpret: bool = False) -> jax.Array:
    """a, b: (B, S, W); h0: (B, W).  Returns all states (B, S, W).

    Batch is handled one row per program (bb=1) so the inner loop is a pure
    (bw,)-vector recurrence on the VPU."""
    B, S, W = a.shape
    bs = min(block_s, S)
    bw = min(block_w, W)
    pad_s = (-S) % bs
    pad_w = (-W) % bw
    if pad_s or pad_w:
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, pad_w)))
        b = jnp.pad(b, ((0, 0), (0, pad_s), (0, pad_w)))
    if pad_w:
        h0 = jnp.pad(h0, ((0, 0), (0, pad_w)))
    ns = a.shape[1] // bs
    nw = a.shape[2] // bw

    out = pl.pallas_call(
        functools.partial(_kernel, bs=bs),
        grid=(B, nw, ns),
        in_specs=[
            pl.BlockSpec((1, bs, bw), lambda i, w, s: (i, s, w)),
            pl.BlockSpec((1, bs, bw), lambda i, w, s: (i, s, w)),
            pl.BlockSpec((1, bw), lambda i, w, s: (i, w)),
        ],
        out_specs=pl.BlockSpec((1, bs, bw), lambda i, w, s: (i, s, w)),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        scratch_shapes=[_SCRATCH((bw,))],
        interpret=interpret,
    )(a, b, h0)
    if pad_s or pad_w:
        out = out[:, :S, :W]
    return out
