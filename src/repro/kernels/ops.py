"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels TARGET TPU and are validated against ref.py in interpret mode) and
False on real TPU backends.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import decode_attention as _da
from repro.kernels import moe_gemm as _mg
from repro.kernels import rglru_scan as _rs


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = _fa.DEFAULT_BQ,
                    block_k: int = _fa.DEFAULT_BK,
                    interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, valid_len, *,
                     block_k: int = _da.DEFAULT_BK,
                     interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _da.decode_attention(q, k_cache, v_cache, valid_len,
                                block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_s", "block_w", "interpret"))
def rglru_scan(a, b, h0, *, block_s: int = _rs.DEFAULT_BS,
               block_w: int = _rs.DEFAULT_BW,
               interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _rs.rglru_scan(a, b, h0, block_s=block_s, block_w=block_w,
                          interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_d",
                                             "interpret"))
def moe_gemm(xe, we, *, block_c: int = _mg.DEFAULT_BC,
             block_f: int = _mg.DEFAULT_BF, block_d: int = _mg.DEFAULT_BD,
             interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _mg.moe_gemm(xe, we, block_c=block_c, block_f=block_f,
                        block_d=block_d, interpret=interpret)
