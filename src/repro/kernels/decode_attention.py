"""GQA decode attention (flash-decode) — Pallas TPU kernel.

One query token per sequence against a ring-buffer KV cache.  Grid
(B, K, num_kv_blocks): each program owns one (batch row, kv head) and the
G = H/K query heads that share it; the KV block index is the minor
(sequential) dimension with running (max, sum, acc) in VMEM scratch —
i.e. the memory-bound phase streams the cache exactly once at HBM speed.

Validity masking uses the per-row ``valid_len`` (ring buffers are valid on
a prefix of slots; see models/common.KV semantics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _SCRATCH = lambda shape: pltpu.VMEM(shape, jnp.float32)
except Exception:  # pragma: no cover
    pltpu = None
    _SCRATCH = lambda shape: pl.VMEM(shape, jnp.float32)

DEFAULT_BK = 512
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, vl_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, bk: int, nk: int, width: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)           # (bk, D)
    v = v_ref[0, :, 0].astype(jnp.float32)
    valid_len = vl_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (G, bk)
    pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = (pos < valid_len) & (pos < width)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(p, v)
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     valid_len: jax.Array, *, block_k: int = DEFAULT_BK,
                     interpret: bool = False) -> jax.Array:
    """q: (B, H, D); k_cache/v_cache: (B, W, K, D); valid_len: (B,) int32.

    Returns (B, H, D)."""
    B, H, D = q.shape
    W, K = k_cache.shape[1], k_cache.shape[2]
    assert H % K == 0
    G = H // K
    bk = min(block_k, W)
    pad = (-W) % bk
    kc, vc = k_cache, v_cache
    if pad:
        kc = jnp.pad(kc, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(vc, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nk = kc.shape[1] // bk

    qg = q.reshape(B, K, G, D)
    kernel = functools.partial(_kernel, scale=D ** -0.5, bk=bk, nk=nk, width=W)
    out = pl.pallas_call(
        kernel,
        grid=(B, K, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1,), lambda b, h, j: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(qg.shape, q.dtype),
        scratch_shapes=[_SCRATCH((G, D)), _SCRATCH((G,)), _SCRATCH((G,))],
        interpret=interpret,
    )(qg, kc, vc, valid_len.astype(jnp.int32))
    return out.reshape(B, H, D)
