"""Grouped (per-expert) GEMM — Pallas TPU kernel.

ye[e] = xe[e] @ we[e] for E experts at once, the compute core of the
capacity-dispatched MoE layer (models/moe.py).  Grid
(E, C/bc, F/bf, D/bd) with the contraction block as the minor sequential
dimension accumulating into fp32 VMEM scratch; block shapes are
(8, 128)-aligned for the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _SCRATCH = lambda shape: pltpu.VMEM(shape, jnp.float32)
except Exception:  # pragma: no cover
    pltpu = None
    _SCRATCH = lambda shape: pl.VMEM(shape, jnp.float32)

DEFAULT_BC = 128
DEFAULT_BF = 128
DEFAULT_BD = 512


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, nd: int):
    l = pl.program_id(3)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                                   # (bc, bd)
    w = w_ref[0]                                   # (bd, bf)
    acc_ref[...] += jax.lax.dot(
        x, w, preferred_element_type=jnp.float32)

    @pl.when(l == nd - 1)
    def _finish():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def moe_gemm(xe: jax.Array, we: jax.Array, *, block_c: int = DEFAULT_BC,
             block_f: int = DEFAULT_BF, block_d: int = DEFAULT_BD,
             interpret: bool = False) -> jax.Array:
    """xe: (E, C, D); we: (E, D, F) -> (E, C, F)."""
    E, C, D = xe.shape
    F = we.shape[2]
    bc, bf, bd = min(block_c, C), min(block_f, F), min(block_d, D)
    pc, pf, pd = (-C) % bc, (-F) % bf, (-D) % bd
    if pc or pd:
        xe = jnp.pad(xe, ((0, 0), (0, pc), (0, pd)))
    if pd or pf:
        we = jnp.pad(we, ((0, 0), (0, pd), (0, pf)))
    nc, nf, nd = xe.shape[1] // bc, we.shape[2] // bf, xe.shape[2] // bd

    out = pl.pallas_call(
        functools.partial(_kernel, nd=nd),
        grid=(E, nc, nf, nd),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, i, j, l: (e, i, l)),
            pl.BlockSpec((1, bd, bf), lambda e, i, j, l: (e, l, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, i, j, l: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, xe.shape[1], we.shape[2]), xe.dtype),
        scratch_shapes=[_SCRATCH((bc, bf))],
        interpret=interpret,
    )(xe, we)
    if pc or pf:
        out = out[:, :C, :F]
    return out
