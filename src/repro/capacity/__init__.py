"""repro.capacity — multi-replica cluster simulation and capacity planning.

The search and replay layers evaluate one engine instance; production
deployments run N instances behind a router and are sized by the
smallest chip count that still holds the SLO through the bursts.  This
package supplies that cluster layer:

- :mod:`~repro.capacity.deployment` — :class:`DeploymentSpec`: one
  :class:`~repro.core.config.CandidateConfig` times a replica count,
  with the derived ``total_chips`` budget.
- :mod:`~repro.capacity.routing` — deterministic routing policies
  (``round_robin``, ``least_outstanding``, ``tenant_affinity``).
- :mod:`~repro.capacity.cluster` — :class:`ClusterSimulator`: fans one
  :class:`~repro.workloads.trace.WorkloadTrace` across N per-replica
  schedulers through a routing policy, producing aggregate
  :class:`ClusterReplayMetrics` plus per-replica load-imbalance stats.
- :mod:`~repro.capacity.planner` — :func:`iter_ladder` /
  :func:`sweep_ladder` / :func:`plan_min_chips`: replay a trace across
  a ladder of replica counts (and optionally across the analytical
  top-K candidates at each rung) and report the cheapest deployment
  whose goodput attains the :class:`~repro.workloads.slo.SLOSpec`,
  with monotone-cost pruning.

Canonical flow::

    from repro.workloads import SLOSpec

    report = cfg.plan_capacity("trace.jsonl",
                               SLOSpec(ttft_p99_ms=2000, tpot_p99_ms=100),
                               ladder=(1, 2, 4), routing="round_robin")
    report.capacity["plan"]          # min-chip deployment + attainment

CLI: ``python -m repro.core.cli capacity plan|sweep`` (docs/capacity.md).
"""
from repro.capacity.cluster import (ClusterReplayMetrics, ClusterSimulator,
                                    ReplicaEngine, aggregate_cluster_metrics)
from repro.capacity.deployment import DeploymentSpec
from repro.capacity.planner import (CAPACITY_SCHEMA_VERSION, CapacityPlan,
                                    DEFAULT_ATTAIN_TARGET, iter_ladder,
                                    plan_min_chips, sweep_ladder)
from repro.capacity.routing import ROUTING_POLICIES, Router, get_router

__all__ = [
    "CAPACITY_SCHEMA_VERSION", "CapacityPlan", "ClusterReplayMetrics",
    "ClusterSimulator", "DEFAULT_ATTAIN_TARGET", "DeploymentSpec",
    "ROUTING_POLICIES", "ReplicaEngine", "Router",
    "aggregate_cluster_metrics", "get_router", "iter_ladder",
    "plan_min_chips", "sweep_ladder",
]
