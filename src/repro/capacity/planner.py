"""Minimum-chip capacity planning: replay a trace up a replica ladder.

The autoscaling question the static search cannot answer: *how small a
deployment still holds the SLO through the bursts?*  ``iter_ladder``
replays one trace across a ladder of replica counts (optionally across
several engine candidates per rung), yielding one stream-friendly
record per evaluated deployment; ``sweep_ladder`` drains it into the
``capacity`` section of a schema-v4 SearchReport; ``plan_min_chips``
returns the cheapest attaining :class:`DeploymentSpec`.

Attainment is ``slo_attainment >= attain_target`` under the
:class:`~repro.workloads.slo.SLOSpec` — rejected and unfinished
requests count as misses, so a rung cannot attain by shedding load.

Pruning is monotonicity-aware in *cost*, not in replica count: once
some deployment attains at ``total_chips == C``, any deployment with
``total_chips >= C`` is recorded as pruned without simulation (it can
never be the minimum), and the ascending sweep stops outright when
every remaining rung is at least that expensive.  Cheaper rungs are
still evaluated, so the planner never assumes "more replicas always
attain" — it only assumes "more chips never get cheaper".
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence

from repro.core.config import CandidateConfig
from repro.workloads.slo import SLOSpec
from repro.workloads.trace import WorkloadTrace

from repro.capacity.deployment import DeploymentSpec
from repro.capacity.routing import ROUTING_POLICIES

#: Capacity sections written by :func:`sweep_ladder` carry this marker.
CAPACITY_SCHEMA_VERSION = 1

DEFAULT_ATTAIN_TARGET = 0.95


def _validate(ladder: Sequence[int], routing: str,
              attain_target: float) -> List[int]:
    rungs = list(ladder)
    if not rungs or any(r < 1 for r in rungs):
        raise ValueError(f"ladder must be non-empty positive replica "
                         f"counts, got {list(ladder)!r}")
    if rungs != sorted(rungs):
        raise ValueError(f"ladder must be ascending, got {rungs!r}")
    if len(set(rungs)) != len(rungs):
        raise ValueError(f"ladder has duplicate rungs: {rungs!r}")
    if routing not in ROUTING_POLICIES:
        raise ValueError(f"unknown routing policy {routing!r}; valid "
                         f"choices: {', '.join(ROUTING_POLICIES)}")
    if not 0.0 < attain_target <= 1.0:
        raise ValueError(f"attain_target must be in (0, 1], got "
                         f"{attain_target}")
    return rungs


def iter_ladder(runner, candidates: Sequence[CandidateConfig],
                trace: WorkloadTrace, slo: SLOSpec,
                ladder: Sequence[int] = (1, 2, 4),
                routing: str = "round_robin",
                attain_target: float = DEFAULT_ATTAIN_TARGET,
                max_steps: int = 200_000,
                priority_admission: bool = True,
                max_queue: int = 100_000) -> Iterator[Dict]:
    """Yield one record per (rung, candidate) deployment, cheapest-cost
    pruning applied online.

    ``runner`` is a :class:`repro.core.task_runner.TaskRunner`; its
    memoized session prices every replica's iterations, so the whole
    ladder shares one PerfDatabase with the analytical search.  Record
    shape::

        {"replicas", "candidate_rank", "deployment": {...},
         "total_chips", "pruned": None | reason,
         "attains": bool | None, "truncated": bool | None,
         "metrics": {...} | None}

    ``truncated`` surfaces the replay's step-budget flag per evaluated
    rung (``None`` for cost-pruned rungs): a rung that "misses the SLO"
    with ``truncated=True`` ran out of ``max_steps``, not of workload.
    """
    if not candidates:
        raise ValueError("at least one candidate is required")
    rungs = _validate(ladder, routing, attain_target)
    best_cost: Optional[int] = None
    for replicas in rungs:
        cheapest_next = min(replicas * c.parallel.chips for c in candidates)
        if best_cost is not None and cheapest_next >= best_cost:
            # every deployment at this rung (and, ladder ascending, at
            # every later one) costs at least the attained minimum
            return
        for rank, cand in enumerate(candidates):
            dep = DeploymentSpec(candidate=cand, replicas=replicas)
            record: Dict = {
                "replicas": replicas,
                "candidate_rank": rank,
                "deployment": dep.to_dict(),
                "total_chips": dep.total_chips,
                "pruned": None,
                "attains": None,
                "truncated": None,
                "metrics": None,
            }
            if best_cost is not None and dep.total_chips >= best_cost:
                record["pruned"] = (f"{dep.total_chips} chips >= attained "
                                    f"minimum {best_cost}")
                yield record
                continue
            sim = runner.cluster_simulator(
                dep, routing=routing,
                priority_admission=priority_admission, max_queue=max_queue)
            metrics = sim.replay(trace, slo=slo, max_steps=max_steps)
            record["metrics"] = metrics.to_dict()
            record["metrics"]["histograms"] = metrics.histograms
            record["truncated"] = metrics.truncated
            record["attains"] = (metrics.slo_attainment or 0.0) \
                >= attain_target
            if record["attains"]:
                best_cost = (dep.total_chips if best_cost is None
                             else min(best_cost, dep.total_chips))
            yield record


def sweep_ladder(runner, candidates: Sequence[CandidateConfig],
                 trace: WorkloadTrace, slo: SLOSpec,
                 ladder: Sequence[int] = (1, 2, 4),
                 routing: str = "round_robin",
                 attain_target: float = DEFAULT_ATTAIN_TARGET,
                 max_steps: int = 200_000,
                 priority_admission: bool = True,
                 max_queue: int = 100_000) -> Dict:
    """Drain :func:`iter_ladder` into the report-ready ``capacity``
    section (every rung record plus the min-chip plan)."""
    rungs = list(iter_ladder(
        runner, candidates, trace, slo, ladder=ladder, routing=routing,
        attain_target=attain_target, max_steps=max_steps,
        priority_admission=priority_admission, max_queue=max_queue))
    # attained records always carry distinct total_chips: once a cost
    # attains, every deployment at or above it is pruned unevaluated,
    # so the minimum needs no tiebreaker
    attained = [r for r in rungs if r["attains"]]
    best = (min(attained, key=lambda r: r["total_chips"])
            if attained else None)
    evaluated = [r for r in rungs if r["pruned"] is None]
    return {
        "schema_version": CAPACITY_SCHEMA_VERSION,
        "trace": {"digest": trace.digest(),
                  "n_requests": trace.n_requests,
                  "duration_s": trace.duration_s,
                  "tenants": trace.tenants,
                  "meta": trace.meta},
        "slo": slo.to_dict(),
        "routing": routing,
        "attain_target": attain_target,
        "ladder": list(ladder),
        "database": runner.session.db.fingerprint(),
        "rungs": rungs,
        "n_evaluated": len(evaluated),
        "n_pruned": len(rungs) - len(evaluated),
        "plan": {
            "attained": best is not None,
            "deployment": best["deployment"] if best else None,
            "total_chips": best["total_chips"] if best else None,
            "goodput_tok_s": (best["metrics"]["goodput_tok_s"]
                              if best else None),
            "slo_attainment": (best["metrics"]["slo_attainment"]
                               if best else None),
        },
    }


@dataclasses.dataclass
class CapacityPlan:
    """The planner's answer: the cheapest attaining deployment (if any)
    plus the full ``capacity`` section it was derived from."""
    deployment: Optional[DeploymentSpec]
    section: Dict

    @property
    def attained(self) -> bool:
        return self.deployment is not None

    @property
    def total_chips(self) -> Optional[int]:
        return self.deployment.total_chips if self.deployment else None

    def summary(self) -> str:
        plan = self.section["plan"]
        if not self.attained:
            return (f"no deployment on the ladder "
                    f"{self.section['ladder']} attains "
                    f"{100 * self.section['attain_target']:.0f}% of the SLO")
        return (f"min-chip deployment: {self.deployment.describe()} "
                f"({self.total_chips} chips, routing "
                f"{self.section['routing']}) — goodput "
                f"{plan['goodput_tok_s']:.1f} tok/s at "
                f"{100 * plan['slo_attainment']:.1f}% attainment")


def plan_min_chips(runner, candidates: Sequence[CandidateConfig],
                   trace: WorkloadTrace, slo: SLOSpec,
                   ladder: Sequence[int] = (1, 2, 4),
                   routing: str = "round_robin",
                   attain_target: float = DEFAULT_ATTAIN_TARGET,
                   max_steps: int = 200_000,
                   priority_admission: bool = True,
                   max_queue: int = 100_000) -> CapacityPlan:
    """Sweep the ladder and return the minimum-chip plan."""
    section = sweep_ladder(
        runner, candidates, trace, slo, ladder=ladder, routing=routing,
        attain_target=attain_target, max_steps=max_steps,
        priority_admission=priority_admission, max_queue=max_queue)
    dep = (DeploymentSpec.from_dict(section["plan"]["deployment"])
           if section["plan"]["attained"] else None)
    return CapacityPlan(deployment=dep, section=section)
