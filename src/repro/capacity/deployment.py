"""Deployment specs: a serving candidate scaled out to N replicas.

The analytical search prices one engine instance; a production
deployment runs N identical instances behind a router.  A
:class:`DeploymentSpec` names that scale-out point — one
:class:`~repro.core.config.CandidateConfig` times a replica count —
and derives the ``total_chips`` budget the capacity planner minimizes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.config import (CandidateConfig, ParallelismConfig,
                               RuntimeFlags)


@dataclasses.dataclass(frozen=True)
class DeploymentSpec:
    """One engine candidate replicated ``replicas`` times behind a router."""
    candidate: CandidateConfig
    replicas: int

    def __post_init__(self):
        if self.replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {self.replicas}")
        if self.candidate.parallel.dp != 1:
            # the cluster simulator runs one engine per replica; a dp>1
            # candidate would be billed for dp instances while only one
            # is simulated — replicas IS the data-parallel axis here
            raise ValueError(
                f"candidate has dp={self.candidate.parallel.dp}; "
                "DeploymentSpec.replicas supersedes ParallelismConfig.dp "
                "— use a dp=1 candidate and set replicas instead")

    @property
    def chips_per_replica(self) -> int:
        return self.candidate.parallel.chips_per_instance

    @property
    def total_chips(self) -> int:
        """The chip budget this deployment occupies — the planner's cost."""
        return self.replicas * self.chips_per_replica

    def describe(self) -> str:
        return f"{self.replicas}x[{self.candidate.describe()}]"

    def to_dict(self) -> Dict:
        return {
            "replicas": self.replicas,
            "total_chips": self.total_chips,
            "describe": self.describe(),
            "candidate": {
                "parallel": dataclasses.asdict(self.candidate.parallel),
                "batch_size": self.candidate.batch_size,
                "flags": dataclasses.asdict(self.candidate.flags),
            },
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "DeploymentSpec":
        c = d["candidate"]
        return cls(
            candidate=CandidateConfig(
                parallel=ParallelismConfig(**c["parallel"]),
                batch_size=c["batch_size"],
                flags=RuntimeFlags(**c.get("flags", {}))),
            replicas=d["replicas"])
