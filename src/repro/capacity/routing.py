"""Routing policies: which replica admits each arriving request.

The cluster simulator advances every replica's virtual clock to each
request's arrival time before asking the router to place it, so a
policy sees the replicas' *actual* state at the arrival instant — no
service-rate estimator sits between routing and simulation.

Policies are deterministic (ties break toward the lowest replica
index; tenant hashing uses sha256, never Python's per-process ``hash``)
so a capacity sweep is digest-stable across runs.

``tenant_affinity`` pins each tenant to one replica.  Today that is a
load/latency trade-off knob; it is also the hook the prefix-caching
roadmap item will exploit — a tenant's shared prompt prefixes only pay
off when that tenant's requests keep landing on the replica holding
the warm cache.
"""
from __future__ import annotations

import hashlib
from typing import Callable, Sequence

#: Every routing policy the cluster simulator accepts.
ROUTING_POLICIES = ("round_robin", "least_outstanding", "tenant_affinity")


def _tenant_slot(tenant: str, n: int) -> int:
    """Stable tenant -> replica hash (sha256; identical across runs)."""
    digest = hashlib.sha256(tenant.encode()).digest()
    return int.from_bytes(digest[:8], "big") % n


class Router:
    """Base router: ``select`` returns the replica index for one request.

    ``replicas`` is the live replica list; each element exposes
    ``outstanding`` (queued + in-flight requests, already advanced to
    the request's arrival time).  ``seq`` is the 0-based arrival
    ordinal of the request within the trace.
    """
    name = "base"

    def select(self, replicas: Sequence, request, seq: int) -> int:
        raise NotImplementedError


class RoundRobinRouter(Router):
    """Cycle through replicas in arrival order — the baseline spreader."""
    name = "round_robin"

    def select(self, replicas, request, seq):
        return seq % len(replicas)


class LeastOutstandingRouter(Router):
    """Send each request to the replica with the fewest outstanding
    requests at its arrival instant (join-the-shortest-queue); ties go
    to the lowest index."""
    name = "least_outstanding"

    def select(self, replicas, request, seq):
        return min(range(len(replicas)),
                   key=lambda i: (replicas[i].outstanding, i))


class TenantAffinityRouter(Router):
    """Hash each request's tenant onto a fixed replica, keeping one
    tenant's traffic (and, later, its shared prompt prefixes) on one
    engine.  Load balance then depends on the tenant mix."""
    name = "tenant_affinity"

    def select(self, replicas, request, seq):
        return _tenant_slot(getattr(request, "tenant", "default"),
                            len(replicas))


_ROUTERS: dict = {
    "round_robin": RoundRobinRouter,
    "least_outstanding": LeastOutstandingRouter,
    "tenant_affinity": TenantAffinityRouter,
}


def get_router(name: str) -> Router:
    """Instantiate a routing policy by name (``ROUTING_POLICIES``)."""
    try:
        return _ROUTERS[name]()
    except KeyError:
        raise ValueError(f"unknown routing policy {name!r}; valid choices: "
                         f"{', '.join(ROUTING_POLICIES)}") from None


RouterFactory = Callable[[], Router]
