"""Multi-replica cluster simulation: one trace, N engines, one router.

Scales the open-loop replay (`ServingSimulator.replay`) from a single
engine to a deployment: every replica runs its own
continuous-batching scheduler and virtual clock, a routing policy
places each request at its arrival instant, and the aggregate
:class:`ClusterReplayMetrics` carries the same tail-percentile /
goodput surface as the single-engine :class:`ReplayMetrics` plus
per-replica load-imbalance statistics.

Simulation is interleaved, not split-then-replay: before a request is
routed, every replica is advanced (iteration by iteration) to the
arrival time, so ``least_outstanding`` reads real queue states rather
than an analytical load estimate, and TTFT keeps its open-loop meaning
(first token time minus trace arrival, queueing included).  All
replicas share one latency callback — they are identical engines — but
never share scheduler state.
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import Callable, Dict, List, Optional, Tuple

from repro.obs.flight import emit_engine_request_spans, latency_histograms
from repro.obs.metrics import get_metrics
from repro.obs.trace import get_tracer
from repro.serving.request import Request
from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                     SchedulerConfig)
from repro.serving.sim import StepSpec, _pctl_dict, run_iteration

from repro.capacity.routing import ROUTING_POLICIES, get_router


class ReplicaEngine:
    """One engine instance inside the cluster: scheduler + private clock.

    Also the building block of ``repro.autoscale`` — the autoscale
    control loop subclasses it with spawn/drain lifecycle state, so
    per-iteration accounting stays byte-identical between a static
    cluster replay and an autoscaled run.
    """

    def __init__(self, idx: int, sched_cfg: SchedulerConfig,
                 latency_fn: Callable[[StepSpec], float]):
        self.idx = idx
        self.sched = ContinuousBatchingScheduler(sched_cfg)
        self.latency_fn = latency_fn
        self.t = 0.0
        self.busy_s = 0.0                  # time spent executing iterations
        self.steps = 0
        self.gen_tokens = 0
        self.depth_sum = 0
        self.depth_max = 0
        self.routed = 0
        self.rejected = 0
        self.done: List[Request] = []
        self.rejected_reqs: List[Request] = []   # flight-recorder spans

    @property
    def outstanding(self) -> int:
        """Requests queued or in flight — what the router load-balances."""
        return self.sched.active

    def admit(self, record, rid: int) -> None:
        self.routed += 1
        req = Request(rid=rid, isl=record.isl, osl=record.osl,
                      arrival=record.arrival_s,
                      tenant=getattr(record, "tenant", "default"),
                      priority=getattr(record, "priority", 0))
        if not self.sched.add(req):
            self.rejected += 1
            self.rejected_reqs.append(req)

    def step(self) -> bool:
        """Execute one iteration (the shared ``run_iteration`` body, so
        single- and multi-engine accounting cannot drift); False when
        the engine has no work."""
        out = run_iteration(self.sched, self.latency_fn, self.t)
        if out is None:
            return False
        self.depth_sum += out.waiting_depth
        self.depth_max = max(self.depth_max, out.waiting_depth)
        self.t = out.t
        self.busy_s += out.dt
        self.steps += 1
        self.gen_tokens += out.gen_tokens
        self.done.extend(out.finished)
        return True

    def advance_to(self, t_target: float, budget: int,
                   jump_idle: bool = True) -> int:
        """Simulate pending work up to ``t_target``; idle clocks jump.

        Returns the number of iterations executed (bounded by
        ``budget``).  A replica may overshoot ``t_target`` by a
        fraction of an iteration — admission happens at iteration
        boundaries, exactly as in the single-engine replay.

        ``jump_idle=False`` leaves an idle engine's clock where it is —
        used when advancing to a *sampling tick* rather than an arrival,
        so instrumented replays execute exactly the iterations an
        uninstrumented replay would and the metrics stay byte-identical.
        """
        used = 0
        while self.t < t_target and used < budget:
            if not self.step():
                break
            used += 1
        if jump_idle and self.t < t_target and self.sched.active == 0:
            self.t = t_target           # idle engine: clock jumps forward
        return used

    def drain(self, budget: int) -> int:
        """Run until the engine empties (or the step budget is gone)."""
        used = 0
        while used < budget:
            if not self.step():
                break
            used += 1
        return used


#: Backwards-compatible alias (pre-autoscale private name).
_ReplicaEngine = ReplicaEngine


@dataclasses.dataclass
class ClusterReplayMetrics:
    """Aggregate open-loop outcome of a trace across N replicas."""
    replicas: int
    routing: str
    n_requests: int
    completed: int
    rejected: int
    unfinished: int
    steps: int                             # iterations summed over replicas
    duration_s: float                      # cluster makespan (max replica clock)
    throughput_tok_s: float                # generated tokens / makespan
    ttft_ms: Dict[str, float]              # percentiles over ALL completed reqs
    tpot_ms: Dict[str, float]
    queue_depth_mean: float                # step-weighted across replicas
    queue_depth_max: int
    #: True when the ``max_steps`` budget (not the trace) ended the
    #: run — unrouted arrivals or in-flight work remained when the
    #: shared iteration budget ran out
    truncated: bool
    #: one row per replica: routed/completed/rejected counts, generated
    #: tokens, busy time, final clock, queue stats
    per_replica: List[Dict] = dataclasses.field(default_factory=list)
    #: load-imbalance view over the per-replica rows
    imbalance: Dict = dataclasses.field(default_factory=dict)
    #: (tenant, replica, ttft_s, tpot_s) per finished request
    per_request: List[Tuple[str, int, float, Optional[float]]] = \
        dataclasses.field(default_factory=list)
    slo: Optional[Dict] = None
    slo_attainment: Optional[float] = None
    goodput_tok_s: Optional[float] = None
    #: cluster-wide TTFT/TPOT/queue-wait/e2e distributions (fixed
    #: log2-ms buckets); popped from ``to_dict`` like ``per_request``
    #: so replay/autoscale CLI bytes are unchanged — report builders
    #: attach it explicitly (schema-v7 sections)
    histograms: Optional[Dict] = None

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d.pop("per_request")               # raw samples stay in-process
        d.pop("histograms")
        return d


def _imbalance(rows: List[Dict]) -> Dict:
    """Load spread across replicas: how unevenly the router dealt work."""
    routed = [r["routed"] for r in rows]
    tokens = [r["gen_tokens"] for r in rows]

    def max_over_mean(vals):
        m = statistics.mean(vals) if vals else 0.0
        return max(vals) / m if m > 0 else 0.0

    def cv(vals):
        m = statistics.mean(vals) if vals else 0.0
        if m <= 0 or len(vals) < 2:
            return 0.0
        return statistics.pstdev(vals) / m

    return {
        "routed_max_over_mean": max_over_mean(routed),
        "routed_cv": cv(routed),
        "tokens_max_over_mean": max_over_mean(tokens),
        "tokens_cv": cv(tokens),
    }


def aggregate_cluster_metrics(engines: List[ReplicaEngine],
                              n_requests: int, routing: str,
                              replicas: int, truncated: bool,
                              slo=None, sim: str = "cluster"
                              ) -> ClusterReplayMetrics:
    """Fold a list of (possibly retired) replica engines into one
    :class:`ClusterReplayMetrics` — shared by the static
    :meth:`ClusterSimulator.replay` and the autoscale control loop, so
    the two views aggregate identically by construction."""
    completed = [(eng.idx, r) for eng in engines for r in eng.done
                 if r.ttft is not None]
    rejected = sum(eng.rejected for eng in engines)
    steps = sum(eng.steps for eng in engines)
    gen_total = sum(eng.gen_tokens for eng in engines)
    makespan = max((eng.t for eng in engines), default=0.0)
    depth_sum = sum(eng.depth_sum for eng in engines)

    per_replica = [{
        "replica": eng.idx,
        "routed": eng.routed,
        "completed": sum(1 for r in eng.done if r.ttft is not None),
        "rejected": eng.rejected,
        "steps": eng.steps,
        "gen_tokens": eng.gen_tokens,
        "busy_s": eng.busy_s,
        "final_clock_s": eng.t,
        "queue_depth_max": eng.depth_max,
    } for eng in engines]

    ttfts_ms = [1e3 * r.ttft for _, r in completed]
    tpots_ms = [1e3 * r.tpot for _, r in completed if r.tpot is not None]
    metrics = ClusterReplayMetrics(
        replicas=replicas,
        routing=routing,
        n_requests=n_requests,
        completed=len(completed),
        rejected=rejected,
        unfinished=n_requests - rejected - len(completed),
        steps=steps,
        duration_s=makespan,
        throughput_tok_s=gen_total / makespan if makespan > 0 else 0.0,
        ttft_ms=_pctl_dict(ttfts_ms),
        tpot_ms=_pctl_dict(tpots_ms),
        queue_depth_mean=depth_sum / steps if steps else 0.0,
        queue_depth_max=max((eng.depth_max for eng in engines), default=0),
        truncated=truncated,
        per_replica=per_replica,
        imbalance=_imbalance(per_replica),
        per_request=[(r.tenant, idx, r.ttft, r.tpot)
                     for idx, r in completed],
        histograms=latency_histograms([r for _, r in completed], sim=sim),
    )
    if slo is not None:
        attaining = [r for _, r in completed
                     if slo.request_meets(r.ttft, r.tpot)]
        metrics.slo = {"ttft_p99_ms": slo.ttft_p99_ms,
                       "tpot_p99_ms": slo.tpot_p99_ms}
        metrics.slo_attainment = (len(attaining) / n_requests
                                  if n_requests else 0.0)
        metrics.goodput_tok_s = (sum(r.osl for r in attaining) / makespan
                                 if makespan > 0 else 0.0)
    return metrics


class ClusterSimulator:
    """N identical replica engines behind a routing policy.

    Constructed like a :class:`~repro.serving.sim.ServingSimulator`
    (scheduler config + latency callback) plus the replica count and
    the routing policy name (:data:`~repro.capacity.routing.ROUTING_POLICIES`).
    """

    def __init__(self, sched_cfg: SchedulerConfig,
                 latency_fn: Callable[[StepSpec], float],
                 replicas: int, routing: str = "round_robin"):
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        if routing not in ROUTING_POLICIES:
            raise ValueError(f"unknown routing policy {routing!r}; valid "
                             f"choices: {', '.join(ROUTING_POLICIES)}")
        self.sched_cfg = sched_cfg
        self.latency_fn = latency_fn
        self.replicas = replicas
        self.routing = routing

    # ------------------------------------------------------------------
    def replay(self, trace, slo=None, max_steps: int = 200_000,
               tick_s: Optional[float] = None,
               on_tick: Optional[Callable] = None) -> ClusterReplayMetrics:
        """Open-loop replay of ``trace`` across the whole deployment.

        ``max_steps`` bounds the *total* iteration count summed over
        replicas; requests still in flight when it runs out are counted
        as unfinished (and as SLO misses when ``slo`` is given) — a
        degenerate or saturating trace yields explicitly zeroed, always
        finite metrics, mirroring ``ServingSimulator.replay``.
        ``metrics.truncated`` records whether the budget (not the
        trace) ended the run.

        ``tick_s``/``on_tick`` instrument the replay with a fixed-tick
        emission hook: before each arrival past a tick boundary (and
        through the final drain), every engine is advanced to the
        boundary *without* idle-clock jumps and ``on_tick(t, engines)``
        is called — the ``repro.autoscale`` timeline recorder
        subscribes here.  The hook observes the same iteration sequence
        an uninstrumented replay executes (ticks never add or reorder
        work), so metrics are identical with or without it.
        """
        tracer = get_tracer()
        with tracer.span("cluster.replay", replicas=self.replicas,
                         routing=self.routing) as sp:
            metrics, engines = self._replay(trace, slo, max_steps,
                                            tick_s, on_tick)
            emit_engine_request_spans(tracer, engines, base=sp.v_start)
            tracer.virtual_time = sp.v_start + metrics.duration_s
            sp.set(n_requests=metrics.n_requests, steps=metrics.steps,
                   completed=metrics.completed, rejected=metrics.rejected,
                   truncated=metrics.truncated)
        m = get_metrics()
        if m is not None:
            m.inc("repro_replay_iterations_total", metrics.steps)
            m.inc("repro_replay_admissions_total",
                  metrics.n_requests - metrics.rejected)
            m.inc("repro_replay_rejections_total", metrics.rejected)
            m.inc("repro_replay_completions_total", metrics.completed)
            if metrics.slo_attainment is not None:
                m.set_gauge("repro_replay_slo_attainment",
                            metrics.slo_attainment, sim="cluster")
        return metrics

    def _replay(self, trace, slo, max_steps: int,
                tick_s: Optional[float],
                on_tick: Optional[Callable]):
        records = list(getattr(trace, "requests", trace))
        router = get_router(self.routing)
        engines = [ReplicaEngine(i, self.sched_cfg, self.latency_fn)
                   for i in range(self.replicas)]
        budget = max_steps
        if tick_s is not None and tick_s <= 0:
            raise ValueError(f"tick_s must be positive, got {tick_s}")
        ticking = tick_s is not None and on_tick is not None
        k = 0                              # ticks emitted so far

        for seq, rec in enumerate(records):
            while ticking and (k + 1) * tick_s <= rec.arrival_s \
                    and budget > 0:
                boundary = (k + 1) * tick_s
                for eng in engines:
                    budget -= eng.advance_to(boundary, budget,
                                             jump_idle=False)
                k += 1
                on_tick(boundary, engines)
            for eng in engines:
                budget -= eng.advance_to(rec.arrival_s, budget)
            target = router.select(engines, rec, seq)
            engines[target].admit(rec, rid=seq)
            if budget <= 0:
                break
        if ticking:
            # drain in tick-sized rounds so the hook keeps sampling; one
            # trailing tick covers the final partial window
            while budget > 0:
                boundary = (k + 1) * tick_s
                for eng in engines:
                    budget -= eng.advance_to(boundary, budget,
                                             jump_idle=False)
                k += 1
                on_tick(boundary, engines)
                if not any(eng.outstanding > 0 for eng in engines):
                    break
        else:
            for eng in engines:
                budget -= eng.drain(budget)

        routed = sum(eng.routed for eng in engines)
        truncated = budget <= 0 and (
            routed < len(records)
            or any(eng.outstanding > 0 for eng in engines))
        metrics = aggregate_cluster_metrics(
            engines, n_requests=len(records), routing=self.routing,
            replicas=self.replicas, truncated=truncated, slo=slo)
        return metrics, engines
