"""repro.api — the unified programmatic surface of the configurator.

This is the one stable entry point the CLI, examples, and benchmarks all
build on: describe the workload fluently, search, and get back a
schema-versioned, JSON-round-trippable :class:`SearchReport`.

Canonical quickstart::

    from repro.api import Configurator

    report = (Configurator.for_model("qwen3-32b")
              .traffic(isl=4000, osl=500)
              .sla(ttft_ms=1200, min_tokens_per_s_user=60)
              .cluster(chips=16, platform="tpu_v5e")
              .backend("repro-jax")
              .dtype("fp8")
              .search())

    print(report.summary())             # timing + best config
    for p in report.top_k(5): ...       # SLA-valid leaders
    print(report.launch.command)        # ready-to-run launch artifact
    report.save("report.json")          # schema-versioned interchange

    # round-trip: SearchReport.from_json(report.to_json()) == report

Every setter validates eagerly — unknown models, platforms, backends,
dtypes, or modes raise ``ValueError`` listing the valid choices before any
search starts.  A Configurator instance keeps its PerfDatabase and
InferenceSession warm across calls, so a second ``.search()``, a
``.compare()`` sweep over traffic shapes, or a ``.speculative()``
projection reuses every op-sequence latency the first search priced.

Third-party serving backends join in without touching core::

    from repro.core.backends.base import BackendProfile, register_backend

    @register_backend("my-engine", capabilities=("aggregated",))
    def _profile() -> BackendProfile:
        return BackendProfile(name="my-engine", ...)
"""
from repro.api.configurator import Comparison, Configurator
from repro.api.report import (SCHEMA_VERSION, SearchReport,
                              workload_from_dict, workload_to_dict)

__all__ = [
    "Comparison", "Configurator", "SCHEMA_VERSION", "SearchReport",
    "workload_from_dict", "workload_to_dict",
]
