"""repro.api — the unified programmatic surface of the configurator.

This is the one stable entry point the CLI, examples, and benchmarks all
build on: describe the workload fluently, search, and get back a
schema-versioned, JSON-round-trippable :class:`SearchReport`.

Canonical quickstart::

    from repro.api import Configurator

    report = (Configurator.for_model("qwen3-32b")
              .traffic(isl=4000, osl=500)
              .sla(ttft_ms=1200, min_tokens_per_s_user=60)
              .cluster(chips=16, platform="tpu_v5e")
              .backend("repro-jax")
              .dtype("fp8")
              .search())

    print(report.summary())             # timing + best config
    for p in report.top_k(5): ...       # SLA-valid leaders
    print(report.launch.command)        # ready-to-run launch artifact
    report.save("report.json")          # schema-versioned interchange

    # round-trip: SearchReport.from_json(report.to_json()) == report
    # (v1 report files are still readable and migrate to v2)

Streaming: ``search_iter`` prices candidates lazily and yields a
``SearchEvent`` per projection, with pluggable early-exit policies —
batch ``search()`` is literally "drain the iterator"::

    from repro.api import stop_after_n_valid

    stream = cfg.search_iter(policies=[stop_after_n_valid(3)])
    for event in stream:                # stops after 3 SLA-valid configs
        print(event.projection.tokens_per_s_per_chip, event.frontier_size)
    report = stream.report()            # early_exit recorded in the report

Every setter validates eagerly — unknown models, platforms, backends,
dtypes, or modes raise ``ValueError`` listing the valid choices before any
search starts.  A Configurator instance keeps its PerfDatabase and
InferenceSession warm across calls, so a second ``.search()``, a
``.compare()`` sweep over traffic shapes, or a ``.speculative()``
projection reuses every op-sequence latency the first search priced.

Third-party serving backends join in without touching core::

    from repro.core.backends.base import BackendProfile, register_backend

    @register_backend("my-engine", capabilities=("aggregated",))
    def _profile() -> BackendProfile:
        return BackendProfile(name="my-engine", ...)

Measured-kernel calibration (``repro.calibrate``, docs/calibration.md)
plugs in through one builder hook — the report's ``database`` section
then records exactly which calibration priced the search::

    report = cfg.with_calibration("cal.json").search()
    report.fingerprint["calibration"]["digest"]

Dynamic workloads (``repro.workloads``, docs/workloads.md): replay the
analytical frontier under a seeded trace and re-rank by goodput under a
tail-latency SLO — recorded in the schema-v3 ``workload_eval`` section::

    from repro.workloads import SLOSpec

    report = cfg.evaluate_frontier("trace.jsonl",
                                   SLOSpec(ttft_p99_ms=2000,
                                           tpot_p99_ms=80), top_k=3)
    report.workload_eval["ranking"]     # goodput order, with replay
                                        # percentiles per candidate

Capacity planning (``repro.capacity``, docs/capacity.md): scale the
replay from one engine to N replicas behind a routing policy and find
the minimum-chip deployment that holds the SLO through the bursts —
recorded in the schema-v4 ``capacity`` section::

    report = cfg.plan_capacity("trace.jsonl",
                               SLOSpec(ttft_p99_ms=2000, tpot_p99_ms=80),
                               ladder=(1, 2, 4),
                               routing="least_outstanding")
    report.capacity["plan"]             # cheapest attaining deployment

Reactive autoscaling (``repro.autoscale``, docs/autoscale.md): replay
the trace under a tick-driven control loop that resizes the fleet —
cold starts, drain-before-removal, asymmetric cooldowns — and compare
its chip-seconds against the static plan, recorded in the schema-v5
``autoscale`` section::

    from repro.autoscale import TargetQueueDepth

    report = cfg.autoscale("trace.jsonl",
                           SLOSpec(ttft_p99_ms=2000, tpot_p99_ms=80),
                           policy=TargetQueueDepth(max_replicas=4))
    report.autoscale["savings"]         # chip-seconds vs the static plan

Observability (``repro.obs``, docs/observability.md): install a tracer
and a metrics registry to watch a search work — spans over the pricing
chunks and replays, counters through the PerfDatabase and simulators —
and attribute any candidate's latency to operator families with a
per-phase waterfall and a two-candidate diff::

    from repro.obs import enable_metrics, enable_tracing

    tracer, registry = enable_tracing(), enable_metrics()
    report = cfg.search()               # telemetry section attached (v6)
    tracer.artifact().save("trace.jsonl")
    print(registry.to_prometheus())
    print(cfg.explain(rank=0, baseline=1, report=report).summary())

Tracing is zero-cost until enabled: the default tracer is a shared
no-op and every hot-path counter checks for an installed registry
first, so un-instrumented runs price byte-identically.
"""
from repro.api.configurator import Comparison, Configurator, StreamingSearch
from repro.api.policies import (SearchEvent, callback, deadline_s,
                                stop_after_n_valid)
from repro.api.report import (SCHEMA_VERSION, SUPPORTED_SCHEMA_VERSIONS,
                              SearchReport, workload_from_dict,
                              workload_to_dict)

__all__ = [
    "Comparison", "Configurator", "SCHEMA_VERSION",
    "SUPPORTED_SCHEMA_VERSIONS", "SearchEvent", "SearchReport",
    "StreamingSearch", "callback", "deadline_s", "stop_after_n_valid",
    "workload_from_dict", "workload_to_dict",
]
