"""Fluent, eagerly-validated entry point to the configurator.

One ``Configurator`` owns one :class:`~repro.core.perf_database.PerfDatabase`
per (platform, backend) and one :class:`~repro.core.session.InferenceSession`
per workload, shared across ``.search()``, ``.compare()`` and
``.speculative()`` calls — op-sequence latencies memoized during the first
search answer the next one, so repeated searches on the same instance are
measurably faster than a cold ``TaskRunner.run()``.

Every setter validates its inputs immediately: an unknown model, platform,
backend, dtype or mode raises ``ValueError`` (listing the valid choices) at
build time, never minutes into a search.
"""
from __future__ import annotations

import copy
import dataclasses
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.configs import list_archs
from repro.core import pareto
from repro.core.backends.base import SERVING_MODES, all_backends, get_backend
from repro.core.config import (ClusterSpec, ParallelismConfig, Projection,
                               SLA, WorkloadDescriptor)
from repro.core.generator import generate
from repro.core.hardware import PLATFORMS
from repro.core.perf_database import PerfDatabase
from repro.core.session import InferenceSession
from repro.core.task_runner import SearchProgress, SearchResult, TaskRunner

from repro.api.policies import Policy, SearchEvent
from repro.api.report import SCHEMA_VERSION, SearchReport

VALID_DTYPES = ("bf16", "fp16", "fp8")
VALID_MODES = SERVING_MODES


def _choices_error(kind: str, got: str, valid: Iterable[str]) -> ValueError:
    return ValueError(f"unknown {kind} {got!r}; valid choices: "
                      f"{', '.join(sorted(valid))}")


class Configurator:
    """Fluent builder over the TaskRunner/Pareto/Generator pipeline.

    >>> report = (Configurator.for_model("qwen3-32b")
    ...           .traffic(isl=4000, osl=500)
    ...           .sla(ttft_ms=1200, min_tokens_per_s_user=60)
    ...           .cluster(chips=16, platform="tpu_v5e")
    ...           .backend("repro-jax")
    ...           .search())
    """

    def __init__(self, model: str):
        known = list_archs(True)
        if model not in known:
            raise _choices_error("model", model, known)
        self._model = model
        self._isl: Optional[int] = None
        self._osl: Optional[int] = None
        self._prefix_len = 0
        self._sla = SLA()
        self._cluster = ClusterSpec()
        self._backend = "repro-jax"
        self._dtype = "bf16"
        self._modes: Tuple[str, ...] = ("aggregated", "disaggregated")
        self._moe_alpha = 1.2
        # shared engines: one PerfDatabase per (platform, backend), one
        # InferenceSession per workload — the memoization that makes a
        # second .search() on this instance fast
        self._dbs: Dict[Tuple[str, str], PerfDatabase] = {}
        self._session: Optional[InferenceSession] = None
        self._calibration = None   # repro.calibrate.CalibrationArtifact

    # -- fluent setters (each validates eagerly) -----------------------------
    @classmethod
    def for_model(cls, model: str) -> "Configurator":
        return cls(model)

    def traffic(self, isl: int, osl: int, prefix_len: int = 0) -> "Configurator":
        if isl is None or osl is None:
            raise ValueError("traffic shape requires both isl and osl")
        if isl <= 0 or osl <= 0:
            raise ValueError(f"isl/osl must be positive, got {isl}/{osl}")
        if prefix_len < 0 or prefix_len > isl:
            raise ValueError(f"prefix_len must be in [0, isl], got {prefix_len}")
        self._isl, self._osl, self._prefix_len = isl, osl, prefix_len
        return self

    def sla(self, ttft_ms: float = 1000.0,
            min_tokens_per_s_user: Optional[float] = None,
            tpot_ms: Optional[float] = None) -> "Configurator":
        if ttft_ms <= 0:
            raise ValueError(f"ttft_ms must be positive, got {ttft_ms}")
        self._sla = SLA(ttft_ms=ttft_ms, tpot_ms=tpot_ms,
                        min_tokens_per_s_user=min_tokens_per_s_user)
        return self

    def cluster(self, chips: int = 8, platform: str = "tpu_v5e",
                chips_per_host: int = 8) -> "Configurator":
        if platform not in PLATFORMS:
            raise _choices_error("platform", platform, PLATFORMS)
        if chips < 1:
            raise ValueError(f"chips must be >= 1, got {chips}")
        self._cluster = ClusterSpec(n_chips=chips, chips_per_host=chips_per_host,
                                    platform=platform)
        return self

    def backend(self, name: str) -> "Configurator":
        if name not in all_backends():
            raise _choices_error("backend", name, all_backends())
        self._backend = name
        return self

    def dtype(self, dtype: str) -> "Configurator":
        if dtype not in VALID_DTYPES:
            raise _choices_error("dtype", dtype, VALID_DTYPES)
        self._dtype = dtype
        return self

    def modes(self, *modes: str) -> "Configurator":
        if not modes:
            raise ValueError(f"at least one mode required; valid: "
                             f"{', '.join(VALID_MODES)}")
        for m in modes:
            if m not in VALID_MODES:
                raise _choices_error("mode", m, VALID_MODES)
        self._modes = tuple(modes)
        return self

    def moe_alpha(self, alpha: float) -> "Configurator":
        if alpha <= 0:
            raise ValueError(f"moe_alpha must be positive, got {alpha}")
        self._moe_alpha = alpha
        return self

    def with_calibration(self, artifact) -> "Configurator":
        """Price every search through a measured-kernel calibration layer.

        ``artifact`` is a :class:`repro.calibrate.CalibrationArtifact` or a
        path to one (loaded — and validated — eagerly, like every other
        setter).  The artifact must match this Configurator's current
        (platform, backend); call :meth:`cluster`/:meth:`backend` first.
        The resulting reports carry the calibration identity in their
        ``database`` section.  :meth:`compare` variants that override
        ``platform``/``backend`` away from the calibrated pair price
        uncalibrated (their reports record ``calibration: null``).
        """
        from repro.calibrate.artifact import CalibrationArtifact
        if isinstance(artifact, (str, bytes)):
            artifact = CalibrationArtifact.load(artifact)
        if artifact.platform != self._cluster.platform \
                or artifact.backend != self._backend:
            raise ValueError(
                f"calibration artifact is for ({artifact.platform}, "
                f"{artifact.backend}) but this Configurator targets "
                f"({self._cluster.platform}, {self._backend}); set "
                f".cluster()/.backend() before .with_calibration()")
        self._calibration = artifact
        db = self._dbs.get((self._cluster.platform, self._backend))
        if db is not None:
            db.apply_calibration(artifact)
        self._session = None        # cached latencies are stale now
        return self

    # -- assembly ------------------------------------------------------------
    def workload(self) -> WorkloadDescriptor:
        """Materialize the (validated) workload descriptor."""
        if self._isl is None or self._osl is None:
            raise ValueError("traffic shape not set: call "
                             ".traffic(isl=..., osl=...) before searching")
        profile = get_backend(self._backend)
        unsupported = [m for m in self._modes if not profile.supports(m)]
        if unsupported:
            raise ValueError(
                f"backend {self._backend!r} does not support mode(s) "
                f"{', '.join(unsupported)}; its capabilities: "
                f"{', '.join(sorted(profile.capabilities))}")
        return WorkloadDescriptor(
            model=self._model, isl=self._isl, osl=self._osl,
            sla=self._sla, cluster=self._cluster, backend=self._backend,
            prefix_len=self._prefix_len, modes=self._modes,
            moe_alpha=self._moe_alpha, dtype=self._dtype)

    def database(self) -> PerfDatabase:
        """The shared per-(platform, backend) PerfDatabase."""
        key = (self._cluster.platform, self._backend)
        cal = self._calibration
        if cal is not None and (cal.platform, cal.backend) != key:
            raise ValueError(
                f"calibration artifact covers ({cal.platform}, "
                f"{cal.backend}) but this search targets {key}; "
                f"re-run `calibrate run` for that pair or drop "
                f".with_calibration()")
        db = self._dbs.get(key)
        if db is None:
            db = self._dbs[key] = PerfDatabase(*key, calibration=cal)
        return db

    def _session_for(self, w: WorkloadDescriptor) -> InferenceSession:
        if self._session is None or self._session.w != w:
            self._session = InferenceSession(w, self.database())
        return self._session

    # -- operations ----------------------------------------------------------
    def search_iter(self, sweep_flags: bool = False,
                    keep_all_disagg: bool = False,
                    policies: Sequence[Policy] = (),
                    batched: Optional[bool] = None) -> "StreamingSearch":
        """Start an incremental search: a :class:`StreamingSearch` that
        yields one :class:`~repro.api.policies.SearchEvent` per priced
        projection, maintains the Pareto frontier online, consults
        ``policies`` after every yield, and materializes a
        :class:`SearchReport` via ``.report()`` whenever iteration stops
        (drained, policy-stopped, or abandoned).

        ``batched`` selects the fused batch-pricing kernel (``None``
        defers to ``REPRO_BATCHED_PRICING``); both settings yield the
        same event stream — see ``TaskRunner.iter_search``.
        """
        w = self.workload()
        runner = TaskRunner(w, session=self._session_for(w))
        return StreamingSearch(workload=w, runner=runner, db=self.database(),
                               sweep_flags=sweep_flags,
                               keep_all_disagg=keep_all_disagg,
                               policies=policies, batched=batched)

    def search(self, sweep_flags: bool = False, keep_all_disagg: bool = False,
               generate_launch: bool = True,
               policies: Sequence[Policy] = (),
               batched: Optional[bool] = None) -> SearchReport:
        """Run the configuration search and return a SearchReport.

        Implemented as "drain :meth:`search_iter`": batch and streaming
        search share one pricing code path, they only differ in whether a
        policy stops the iterator early.  ``policies`` apply here too —
        ``search(policies=[stop_after_n_valid(3)])`` returns the partial
        report (``early_exit`` set) without the caller driving the loop.
        """
        stream = self.search_iter(sweep_flags=sweep_flags,
                                  keep_all_disagg=keep_all_disagg,
                                  policies=policies, batched=batched)
        for _event in stream:
            pass
        return stream.report(generate_launch=generate_launch)

    def compare(self, variants: Sequence[Dict],
                labels: Optional[Sequence[str]] = None,
                **search_kwargs) -> "Comparison":
        """Sweep workload variants (scenario diversity) on shared databases.

        Each variant is a dict of overrides: any of ``isl``, ``osl``,
        ``prefix_len``, ``ttft_ms``, ``min_tokens_per_s_user``, ``tpot_ms``,
        ``chips``, ``platform``, ``backend``, ``dtype``, ``modes``,
        ``moe_alpha``.  Databases are shared across variants, so a sweep
        over traffic shapes on one platform pays the collection cost once.
        """
        labels = list(labels) if labels is not None else None
        if labels is not None and len(labels) != len(variants):
            raise ValueError("labels must match variants 1:1")
        out_labels, reports = [], []
        for i, overrides in enumerate(variants):
            c = self._variant(overrides)
            reports.append(c.search(**search_kwargs))
            out_labels.append(labels[i] if labels is not None
                              else _variant_label(overrides))
        return Comparison(reports=reports, labels=out_labels)

    def speculative(self, draft: str, acceptance: float = 0.8,
                    max_gamma: int = 8,
                    report: Optional[SearchReport] = None):
        """Project speculative decoding with ``draft`` on the best config.

        Returns ``(best, all_projections)`` —
        :class:`~repro.core.speculative.SpecDecodeProjection` objects for
        the best γ and the full sweep.  Reuses this Configurator's
        PerfDatabase (and the report from a prior ``.search()``, if given).
        """
        known = list_archs(True)
        if draft not in known:
            raise _choices_error("draft model", draft, known)
        if not 0.0 < acceptance < 1.0:
            raise ValueError(f"acceptance must be in (0, 1), got {acceptance}")
        from repro.core.speculative import SpeculativeEstimator
        w = self.workload()
        if not get_backend(self._backend).supports("speculative"):
            raise ValueError(f"backend {self._backend!r} does not declare "
                             "the 'speculative' capability")
        if report is None:
            report = self.search(generate_launch=False)
        best = report.best
        if best is None:
            raise ValueError("no SLA-valid configuration to speculate on; "
                             "relax the SLA or grow the cluster")
        if best.mode != "disaggregated":
            par = ParallelismConfig(
                **{k: best.config.get("parallel", {}).get(k, 1)
                   for k in ("tp", "pp", "ep", "dp")})
        else:
            par = ParallelismConfig(tp=min(w.cluster.n_chips, 8))
        est = SpeculativeEstimator(w, draft, self.database())
        return est.best_gamma(par, batch=best.batch_size,
                              acceptance=acceptance, max_gamma=max_gamma)

    def evaluate_frontier(self, trace, slo, top_k: int = 5,
                          report: Optional[SearchReport] = None,
                          max_steps: int = 200_000) -> SearchReport:
        """Replay the analytical frontier's top-K candidates under a
        dynamic trace and re-rank them by goodput under ``slo``.

        ``trace`` is a :class:`repro.workloads.WorkloadTrace` or a path
        to a trace JSONL file; ``slo`` is a
        :class:`repro.workloads.SLOSpec` (or a dict of its fields).
        Without ``report``, runs :meth:`search` first (sharing this
        instance's memoized PerfDatabase/session); with one, reuses its
        priced projections.  Returns the report with its schema-v3
        ``workload_eval`` section filled: per-candidate open-loop replay
        metrics (TTFT/TPOT percentiles, queue depth, goodput) and the
        goodput ranking next to the analytical one.
        """
        import os
        from repro.workloads import SLOSpec, WorkloadTrace, replay_frontier
        if isinstance(trace, (str, bytes, os.PathLike)):
            trace = WorkloadTrace.load(trace)
        if isinstance(slo, dict):
            slo = SLOSpec.from_dict(slo)
        if report is None:
            report = self.search()
        # replay prices through the report's own workload descriptor so a
        # loaded report replays consistently; when it matches this
        # instance's workload the memoized session is reused
        w = report.workload
        try:
            own = self.workload()
        except ValueError:
            own = None
        runner = (TaskRunner(w, session=self._session_for(w))
                  if own == w else TaskRunner(w))
        report.workload_eval = replay_frontier(
            runner, report.projections, trace, slo, top_k=top_k,
            sla=w.sla, max_steps=max_steps)
        return report

    def plan_capacity(self, trace, slo, ladder: Sequence[int] = (1, 2, 4),
                      top_k: int = 1, routing: str = "round_robin",
                      attain_target: float = 0.95,
                      report: Optional[SearchReport] = None,
                      max_steps: int = 200_000) -> SearchReport:
        """Size the deployment: replay ``trace`` across a ladder of
        replica counts and record the cheapest deployment whose goodput
        attains ``slo`` in the report's schema-v4 ``capacity`` section.

        ``trace``/``slo`` accept the same forms as
        :meth:`evaluate_frontier` (trace object or path; ``SLOSpec`` or
        dict).  ``ladder`` is the ascending replica-count ladder; with
        ``top_k > 1`` the analytical top-K replayable candidates are
        each tried at every rung, so the planner can trade a bigger
        engine at few replicas against a smaller engine at many.
        Without ``report``, runs :meth:`search` first on this
        instance's memoized PerfDatabase/session.  Disaggregated
        composites among the leaders are recorded as skipped (the
        cluster simulator replays single-engine replicas).  Returns the
        report with ``capacity`` filled: every evaluated rung (and the
        cost-pruned ones), per-replica load-imbalance stats, and the
        min-chip plan.
        """
        import os
        from repro.capacity.planner import sweep_ladder
        from repro.workloads import (DISAGG_SKIP_REASON, SLOSpec,
                                     WorkloadTrace, analytical_leaders,
                                     candidate_from_projection)
        if isinstance(trace, (str, bytes, os.PathLike)):
            trace = WorkloadTrace.load(trace)
        if isinstance(slo, dict):
            slo = SLOSpec.from_dict(slo)
        if top_k < 1:                      # fail before the search runs
            raise ValueError(f"top_k must be >= 1, got {top_k}")
        if report is None:
            report = self.search()
        w = report.workload
        try:
            own = self.workload()
        except ValueError:
            own = None
        runner = (TaskRunner(w, session=self._session_for(w))
                  if own == w else TaskRunner(w))
        leaders = analytical_leaders(report.projections, w.sla, top_k)
        index_of = {id(p): i for i, p in enumerate(report.projections)}
        candidates, cand_meta, skipped = [], [], []
        for rank, p in enumerate(leaders):
            cand = candidate_from_projection(p)
            if cand is None:
                skipped.append({
                    "index": index_of[id(p)], "analytical_rank": rank,
                    "mode": p.mode, "describe": p.config.get("describe", ""),
                    "reason": DISAGG_SKIP_REASON})
                continue
            candidates.append(cand)
            cand_meta.append({
                "index": index_of[id(p)],
                "analytical_rank": rank, "mode": p.mode,
                "describe": p.config.get("describe", ""),
                "tokens_per_s_per_chip": p.tokens_per_s_per_chip})
        if not candidates:
            raise ValueError(
                "no replayable candidate among the analytical top-"
                f"{top_k} (all disaggregated composites); raise top_k or "
                "search with modes('aggregated')")
        section = sweep_ladder(runner, candidates, trace, slo,
                               ladder=ladder, routing=routing,
                               attain_target=attain_target,
                               max_steps=max_steps)
        section["candidates"] = cand_meta
        section["skipped"] = skipped
        report.capacity = section
        return report

    def autoscale(self, trace, slo, policy=None,
                  ladder: Sequence[int] = (1, 2, 4),
                  routing: str = "round_robin",
                  attain_target: float = 0.95,
                  tick_s: float = 1.0, cold_start_s: float = 5.0,
                  initial_replicas: Optional[int] = None,
                  top_k: int = 3,
                  report: Optional[SearchReport] = None,
                  max_steps: int = 200_000) -> SearchReport:
        """Ride the load curve: run a reactive autoscaling control loop
        over ``trace`` next to the static min-chip plan and record both
        cost views in the report's schema-v5 ``autoscale`` section.

        ``trace``/``slo`` accept the same forms as
        :meth:`evaluate_frontier`.  ``policy`` is an
        :class:`~repro.autoscale.AutoscalerPolicy` (default:
        ``TargetQueueDepth()``).  The best replayable candidate among
        the analytical top-``top_k`` is used for both sides (its
        disaggregated betters, if any, are recorded as skipped); the
        autoscaler starts at the static plan's replica count and earns
        its savings by scaling down through the troughs.  Without
        ``report``, runs :meth:`search` first on this instance's
        memoized PerfDatabase/session.  Returns the report with
        ``autoscale`` filled: the policy and tick/cold-start model, the
        static baseline, the autoscaled run (chip-seconds, peak/mean
        replicas, scaling-event log, timeline digest), and the savings.
        """
        import os
        from repro.autoscale import TargetQueueDepth, build_autoscale_section
        from repro.workloads import (DISAGG_SKIP_REASON, SLOSpec,
                                     WorkloadTrace, analytical_leaders,
                                     candidate_from_projection)
        if isinstance(trace, (str, bytes, os.PathLike)):
            trace = WorkloadTrace.load(trace)
        if isinstance(slo, dict):
            slo = SLOSpec.from_dict(slo)
        if top_k < 1:                      # fail before the search runs
            raise ValueError(f"top_k must be >= 1, got {top_k}")
        if policy is None:
            policy = TargetQueueDepth()
        if report is None:
            report = self.search()
        w = report.workload
        try:
            own = self.workload()
        except ValueError:
            own = None
        runner = (TaskRunner(w, session=self._session_for(w))
                  if own == w else TaskRunner(w))
        leaders = analytical_leaders(report.projections, w.sla, top_k)
        index_of = {id(p): i for i, p in enumerate(report.projections)}
        chosen = cand = None
        skipped = []
        for rank, p in enumerate(leaders):
            c = candidate_from_projection(p)
            if c is None:
                skipped.append({
                    "index": index_of[id(p)], "analytical_rank": rank,
                    "mode": p.mode, "describe": p.config.get("describe", ""),
                    "reason": DISAGG_SKIP_REASON})
                continue
            chosen, cand = p, c
            break
        if cand is None:
            raise ValueError(
                "no replayable candidate among the analytical top-"
                f"{top_k} (all disaggregated composites); raise top_k or "
                "search with modes('aggregated')")
        section, _ = build_autoscale_section(
            runner, cand, trace, slo, policy, ladder=ladder,
            routing=routing, attain_target=attain_target, tick_s=tick_s,
            cold_start_s=cold_start_s, initial_replicas=initial_replicas,
            max_steps=max_steps)
        section["candidate"] = {
            "index": index_of[id(chosen)],
            "mode": chosen.mode,
            "describe": chosen.config.get("describe", ""),
            "tokens_per_s_per_chip": chosen.tokens_per_s_per_chip}
        section["skipped"] = skipped
        report.autoscale = section
        return report

    def explain(self, rank: int = 0, baseline: Optional[int] = None,
                candidate=None, mode: Optional[str] = None,
                report: Optional[SearchReport] = None,
                top_k: int = 5):
        """Attribute a candidate's projected latency to operator families.

        Re-prices the candidate through the same decomposition atoms the
        search used and buckets every operator's latency by family
        (gemm / attention / comm / memory / ...) per serving phase — a
        waterfall whose total reproduces ``sequence_latency`` exactly.

        ``rank`` selects among the analytical leaders of ``report``
        (0 = best replayable candidate; disaggregated composites are
        skipped — their two pools price through different engines).
        ``baseline`` names a second leader rank to diff against: the
        returned :class:`~repro.obs.Explanation` then carries a
        per-family delta and the parallelism changes that explain it.
        Alternatively pass an explicit
        :class:`~repro.core.config.CandidateConfig` as ``candidate``
        (with ``mode``, default ``"aggregated"``).  Without ``report``,
        runs :meth:`search` first on this instance's memoized
        PerfDatabase/session.
        """
        from repro.obs import (Explanation, diff_explanations,
                               explain_candidate)
        from repro.workloads import (analytical_leaders,
                                     candidate_from_projection)
        if candidate is not None:
            w = self.workload()
            session = self._session_for(w)
            expl = explain_candidate(session, candidate,
                                     mode or "aggregated")
            return Explanation(candidate=expl)
        if rank < 0:
            raise ValueError(f"rank must be >= 0, got {rank}")
        if baseline is not None and baseline < 0:
            raise ValueError(f"baseline must be >= 0, got {baseline}")
        if report is None:
            report = self.search(generate_launch=False)
        w = report.workload
        try:
            own = self.workload()
        except ValueError:
            own = None
        session = (self._session_for(w) if own == w
                   else TaskRunner(w).session)
        need = max(rank, baseline if baseline is not None else 0) + 1
        k = max(top_k, need)
        leaders = analytical_leaders(report.projections, w.sla, k)
        replayable = [(p, candidate_from_projection(p)) for p in leaders]
        replayable = [(p, c) for p, c in replayable if c is not None]
        if len(replayable) < need:
            raise ValueError(
                f"need {need} explainable candidate(s) among the "
                f"analytical top-{k} but found {len(replayable)} "
                "(disaggregated composites are skipped); raise top_k or "
                "search with modes('aggregated')")
        p, cand = replayable[rank]
        expl = explain_candidate(session, cand, p.mode)
        base = diff = None
        if baseline is not None:
            bp, bcand = replayable[baseline]
            base = explain_candidate(session, bcand, bp.mode)
            diff = diff_explanations(expl, base)
        return Explanation(candidate=expl, baseline=base, diff=diff)

    # -- internals -----------------------------------------------------------
    def _variant(self, overrides: Dict) -> "Configurator":
        c = copy.copy(self)          # shares self._dbs on purpose
        c._session = None
        known = {"isl", "osl", "prefix_len", "ttft_ms",
                 "min_tokens_per_s_user", "tpot_ms", "chips", "platform",
                 "chips_per_host", "backend", "dtype", "modes", "moe_alpha"}
        bad = set(overrides) - known
        if bad:
            raise ValueError(f"unknown compare override(s) {sorted(bad)}; "
                             f"valid: {sorted(known)}")
        o = dict(overrides)
        if {"isl", "osl", "prefix_len"} & set(o):
            c.traffic(o.pop("isl", self._isl), o.pop("osl", self._osl),
                      o.pop("prefix_len", self._prefix_len))
        if {"ttft_ms", "min_tokens_per_s_user", "tpot_ms"} & set(o):
            c.sla(o.pop("ttft_ms", self._sla.ttft_ms),
                  o.pop("min_tokens_per_s_user",
                        self._sla.min_tokens_per_s_user),
                  o.pop("tpot_ms", self._sla.tpot_ms))
        if {"chips", "platform", "chips_per_host"} & set(o):
            c.cluster(o.pop("chips", self._cluster.n_chips),
                      o.pop("platform", self._cluster.platform),
                      o.pop("chips_per_host", self._cluster.chips_per_host))
        if "backend" in o:
            c.backend(o.pop("backend"))
        if "dtype" in o:
            c.dtype(o.pop("dtype"))
        if "modes" in o:
            m = o.pop("modes")
            c.modes(*((m,) if isinstance(m, str) else m))
        if "moe_alpha" in o:
            c.moe_alpha(o.pop("moe_alpha"))
        cal = c._calibration
        if cal is not None and (cal.platform, cal.backend) \
                != (c._cluster.platform, c._backend):
            # a variant steering off the calibrated (platform, backend)
            # pair prices uncalibrated — its report's database section
            # says so — instead of aborting the whole compare sweep
            c._calibration = None
        return c


def _variant_label(overrides: Dict) -> str:
    return " ".join(f"{k}={v}" for k, v in overrides.items()) or "base"


class StreamingSearch:
    """Incremental search in flight: iterate to price candidates one at a
    time, stop whenever you (or a policy) want, then ask for the report.

    Yields :class:`~repro.api.policies.SearchEvent` objects.  State
    accumulated while iterating — ``projections``, the online Pareto
    ``frontier``, ``best``, ``n_valid``, ``early_exit`` — is readable at
    any point, so interactive consumers can render progress without
    waiting for the sweep to finish.  ``report()`` packages whatever has
    been priced so far into a schema-v2 :class:`SearchReport` carrying
    the PerfDatabase fingerprint; after a full drain that report is
    identical (modulo wall-clock timing) to ``Configurator.search()``'s.
    """

    def __init__(self, workload: WorkloadDescriptor, runner: TaskRunner,
                 db: PerfDatabase, sweep_flags: bool, keep_all_disagg: bool,
                 policies: Sequence[Policy] = (),
                 batched: Optional[bool] = None):
        self.workload = workload
        self.projections: List[Projection] = []
        self.n_valid = 0
        self.early_exit: Optional[Dict] = None
        self.elapsed_s = 0.0
        self._db = db
        self._policies = tuple(policies)
        self._progress = SearchProgress()
        self._acc = pareto.FrontierAccumulator()
        self._best: Optional[Projection] = None
        self._t0 = time.perf_counter()
        self._exhausted = False
        self._oob_reason: Optional[str] = None
        # out-of-band early exit: policies exposing check_elapsed (e.g.
        # deadline_s) can preempt the non-yielding disaggregated phase
        self._progress.abort = self._check_oob_policies
        self._inner = runner.iter_search(sweep_flags, keep_all_disagg,
                                         progress=self._progress,
                                         batched=batched)

    def _check_oob_policies(self) -> bool:
        elapsed = time.perf_counter() - self._t0
        for policy in self._policies:
            check = getattr(policy, "check_elapsed", None)
            if check is not None and check(elapsed):
                self._oob_reason = getattr(policy, "reason", "policy")
                return True
        return False

    # -- live views ----------------------------------------------------------
    @property
    def best(self) -> Optional[Projection]:
        return self._best

    @property
    def frontier(self) -> List[Projection]:
        return self._acc.frontier()

    @property
    def n_priced(self) -> int:
        return self._progress.n_evaluated

    # -- iteration -----------------------------------------------------------
    def __iter__(self) -> "StreamingSearch":
        return self

    def __next__(self) -> SearchEvent:
        if self._exhausted:
            raise StopIteration
        try:
            cand, p = next(self._inner)
        except StopIteration:
            self._finish()
            raise
        self.projections.append(p)
        self._acc.add(p)
        meets = p.meets(self.workload.sla)
        if meets:
            self.n_valid += 1
            if self._best is None or (p.tokens_per_s_per_chip
                                      > self._best.tokens_per_s_per_chip):
                self._best = p
        self.elapsed_s = time.perf_counter() - self._t0
        event = SearchEvent(
            candidate=cand, projection=p, index=len(self.projections) - 1,
            n_priced=self._progress.n_evaluated, n_valid=self.n_valid,
            elapsed_s=self.elapsed_s, frontier_size=len(self._acc),
            meets_sla=meets)
        for policy in self._policies:
            if policy(event):
                self.early_exit = {
                    "reason": getattr(policy, "reason",
                                      getattr(policy, "__name__", "policy")),
                    "n_yielded": len(self.projections),
                    "n_priced": self._progress.n_evaluated,
                }
                if self._progress.disagg_preempted:
                    # the disagg phase was already cut short out-of-band
                    # before this yield tripped the policy
                    self.early_exit["phase"] = "disaggregated"
                self._finish()
                break
        return event

    def close(self) -> None:
        """Stop the stream explicitly (idempotent).  Breaking out of a
        ``for`` loop leaves the underlying generator open until GC; call
        this to release it immediately and freeze ``elapsed_s``."""
        if not self._exhausted:
            self._finish()

    def _finish(self) -> None:
        self._exhausted = True
        if self.early_exit is None and self._progress.disagg_preempted:
            # a check_elapsed policy fired inside the disaggregated phase
            # (between yields): record it like any other early exit
            self.early_exit = {
                "reason": self._oob_reason or "disagg_preempted",
                "n_yielded": len(self.projections),
                "n_priced": self._progress.n_evaluated,
                "phase": "disaggregated",
            }
        self.elapsed_s = time.perf_counter() - self._t0
        self._inner.close()   # release the generator (skips remaining pricing)

    # -- terminal artifacts ---------------------------------------------------
    def result(self) -> SearchResult:
        """Core ``SearchResult`` over everything priced so far."""
        n = self._progress.n_evaluated
        return SearchResult(
            projections=list(self.projections), best=self._best,
            frontier=self._acc.frontier(), n_candidates=n,
            elapsed_s=self.elapsed_s,
            per_candidate_ms=1e3 * self.elapsed_s / max(n, 1),
            disagg_best=self._progress.disagg_best)

    def report(self, generate_launch: bool = True) -> SearchReport:
        """SearchReport over everything priced so far.  When a
        ``repro.obs`` tracer or metrics registry is installed, the
        schema-v6 ``telemetry`` section is attached (trace digest and
        span count, metrics snapshot — no wall times, so it stays
        deterministic across seeded runs)."""
        result = self.result()
        launch = (generate(self.workload, result.best)
                  if generate_launch and result.best is not None else None)
        rep = SearchReport.from_result(
            self.workload, result, launch=launch,
            fingerprint=self._db.fingerprint(), early_exit=self.early_exit)
        from repro.obs import telemetry_section
        from repro.obs.metrics import get_metrics
        from repro.obs.trace import NULL_TRACER, get_tracer
        tracer, metrics = get_tracer(), get_metrics()
        if tracer is not NULL_TRACER or metrics is not None:
            # a tracer with spans still open (report() called inside a
            # user span) can't freeze an artifact yet — skip its half
            live = (tracer if tracer is not NULL_TRACER
                    and not tracer._stack else None)
            rep.telemetry = telemetry_section(live, metrics)
        return rep


@dataclasses.dataclass
class Comparison:
    """Results of a ``Configurator.compare`` sweep."""
    reports: List[SearchReport]
    labels: List[str]

    def summary(self) -> str:
        width = max((len(l) for l in self.labels), default=4)
        lines = [f"{'scenario':<{width}} | {'best mode':>13} "
                 f"{'tok/s/chip':>11} {'tok/s/user':>11} {'TTFT ms':>9}"]
        for label, rep in zip(self.labels, self.reports):
            b = rep.best
            if b is None:
                lines.append(f"{label:<{width}} | {'—':>13} "
                             f"{'(no SLA-valid config)':>34}")
            else:
                lines.append(
                    f"{label:<{width}} | {b.mode:>13} "
                    f"{b.tokens_per_s_per_chip:>11.1f} "
                    f"{b.tokens_per_s_user:>11.1f} {b.ttft_ms:>9.1f}")
        return "\n".join(lines)

    def to_dict(self) -> Dict:
        return {"schema_version": SCHEMA_VERSION,
                "scenarios": [{"label": l, "report": r.to_dict()}
                              for l, r in zip(self.labels, self.reports)]}

    def to_json(self, indent: Optional[int] = 2) -> str:
        import json
        return json.dumps(self.to_dict(), indent=indent)
