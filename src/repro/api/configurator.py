"""Fluent, eagerly-validated entry point to the configurator.

One ``Configurator`` owns one :class:`~repro.core.perf_database.PerfDatabase`
per (platform, backend) and one :class:`~repro.core.session.InferenceSession`
per workload, shared across ``.search()``, ``.compare()`` and
``.speculative()`` calls — op-sequence latencies memoized during the first
search answer the next one, so repeated searches on the same instance are
measurably faster than a cold ``TaskRunner.run()``.

Every setter validates its inputs immediately: an unknown model, platform,
backend, dtype or mode raises ``ValueError`` (listing the valid choices) at
build time, never minutes into a search.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.configs import list_archs
from repro.core.backends.base import SERVING_MODES, all_backends, get_backend
from repro.core.config import (ClusterSpec, ParallelismConfig, SLA,
                               WorkloadDescriptor)
from repro.core.generator import generate
from repro.core.hardware import PLATFORMS
from repro.core.perf_database import PerfDatabase
from repro.core.session import InferenceSession
from repro.core.task_runner import TaskRunner

from repro.api.report import SearchReport

VALID_DTYPES = ("bf16", "fp16", "fp8")
VALID_MODES = SERVING_MODES


def _choices_error(kind: str, got: str, valid: Iterable[str]) -> ValueError:
    return ValueError(f"unknown {kind} {got!r}; valid choices: "
                      f"{', '.join(sorted(valid))}")


class Configurator:
    """Fluent builder over the TaskRunner/Pareto/Generator pipeline.

    >>> report = (Configurator.for_model("qwen3-32b")
    ...           .traffic(isl=4000, osl=500)
    ...           .sla(ttft_ms=1200, min_tokens_per_s_user=60)
    ...           .cluster(chips=16, platform="tpu_v5e")
    ...           .backend("repro-jax")
    ...           .search())
    """

    def __init__(self, model: str):
        known = list_archs(True)
        if model not in known:
            raise _choices_error("model", model, known)
        self._model = model
        self._isl: Optional[int] = None
        self._osl: Optional[int] = None
        self._prefix_len = 0
        self._sla = SLA()
        self._cluster = ClusterSpec()
        self._backend = "repro-jax"
        self._dtype = "bf16"
        self._modes: Tuple[str, ...] = ("aggregated", "disaggregated")
        self._moe_alpha = 1.2
        # shared engines: one PerfDatabase per (platform, backend), one
        # InferenceSession per workload — the memoization that makes a
        # second .search() on this instance fast
        self._dbs: Dict[Tuple[str, str], PerfDatabase] = {}
        self._session: Optional[InferenceSession] = None

    # -- fluent setters (each validates eagerly) -----------------------------
    @classmethod
    def for_model(cls, model: str) -> "Configurator":
        return cls(model)

    def traffic(self, isl: int, osl: int, prefix_len: int = 0) -> "Configurator":
        if isl is None or osl is None:
            raise ValueError("traffic shape requires both isl and osl")
        if isl <= 0 or osl <= 0:
            raise ValueError(f"isl/osl must be positive, got {isl}/{osl}")
        if prefix_len < 0 or prefix_len > isl:
            raise ValueError(f"prefix_len must be in [0, isl], got {prefix_len}")
        self._isl, self._osl, self._prefix_len = isl, osl, prefix_len
        return self

    def sla(self, ttft_ms: float = 1000.0,
            min_tokens_per_s_user: Optional[float] = None,
            tpot_ms: Optional[float] = None) -> "Configurator":
        if ttft_ms <= 0:
            raise ValueError(f"ttft_ms must be positive, got {ttft_ms}")
        self._sla = SLA(ttft_ms=ttft_ms, tpot_ms=tpot_ms,
                        min_tokens_per_s_user=min_tokens_per_s_user)
        return self

    def cluster(self, chips: int = 8, platform: str = "tpu_v5e",
                chips_per_host: int = 8) -> "Configurator":
        if platform not in PLATFORMS:
            raise _choices_error("platform", platform, PLATFORMS)
        if chips < 1:
            raise ValueError(f"chips must be >= 1, got {chips}")
        self._cluster = ClusterSpec(n_chips=chips, chips_per_host=chips_per_host,
                                    platform=platform)
        return self

    def backend(self, name: str) -> "Configurator":
        if name not in all_backends():
            raise _choices_error("backend", name, all_backends())
        self._backend = name
        return self

    def dtype(self, dtype: str) -> "Configurator":
        if dtype not in VALID_DTYPES:
            raise _choices_error("dtype", dtype, VALID_DTYPES)
        self._dtype = dtype
        return self

    def modes(self, *modes: str) -> "Configurator":
        if not modes:
            raise ValueError(f"at least one mode required; valid: "
                             f"{', '.join(VALID_MODES)}")
        for m in modes:
            if m not in VALID_MODES:
                raise _choices_error("mode", m, VALID_MODES)
        self._modes = tuple(modes)
        return self

    def moe_alpha(self, alpha: float) -> "Configurator":
        if alpha <= 0:
            raise ValueError(f"moe_alpha must be positive, got {alpha}")
        self._moe_alpha = alpha
        return self

    # -- assembly ------------------------------------------------------------
    def workload(self) -> WorkloadDescriptor:
        """Materialize the (validated) workload descriptor."""
        if self._isl is None or self._osl is None:
            raise ValueError("traffic shape not set: call "
                             ".traffic(isl=..., osl=...) before searching")
        profile = get_backend(self._backend)
        unsupported = [m for m in self._modes if not profile.supports(m)]
        if unsupported:
            raise ValueError(
                f"backend {self._backend!r} does not support mode(s) "
                f"{', '.join(unsupported)}; its capabilities: "
                f"{', '.join(sorted(profile.capabilities))}")
        return WorkloadDescriptor(
            model=self._model, isl=self._isl, osl=self._osl,
            sla=self._sla, cluster=self._cluster, backend=self._backend,
            prefix_len=self._prefix_len, modes=self._modes,
            moe_alpha=self._moe_alpha, dtype=self._dtype)

    def database(self) -> PerfDatabase:
        """The shared per-(platform, backend) PerfDatabase."""
        key = (self._cluster.platform, self._backend)
        db = self._dbs.get(key)
        if db is None:
            db = self._dbs[key] = PerfDatabase(*key)
        return db

    def _session_for(self, w: WorkloadDescriptor) -> InferenceSession:
        if self._session is None or self._session.w != w:
            self._session = InferenceSession(w, self.database())
        return self._session

    # -- operations ----------------------------------------------------------
    def search(self, sweep_flags: bool = False, keep_all_disagg: bool = False,
               generate_launch: bool = True) -> SearchReport:
        """Run the configuration search and return a SearchReport."""
        w = self.workload()
        runner = TaskRunner(w, session=self._session_for(w))
        result = runner.run(sweep_flags=sweep_flags,
                            keep_all_disagg=keep_all_disagg)
        launch = (generate(w, result.best)
                  if generate_launch and result.best is not None else None)
        return SearchReport.from_result(w, result, launch=launch)

    def compare(self, variants: Sequence[Dict],
                labels: Optional[Sequence[str]] = None,
                **search_kwargs) -> "Comparison":
        """Sweep workload variants (scenario diversity) on shared databases.

        Each variant is a dict of overrides: any of ``isl``, ``osl``,
        ``prefix_len``, ``ttft_ms``, ``min_tokens_per_s_user``, ``tpot_ms``,
        ``chips``, ``platform``, ``backend``, ``dtype``, ``modes``,
        ``moe_alpha``.  Databases are shared across variants, so a sweep
        over traffic shapes on one platform pays the collection cost once.
        """
        labels = list(labels) if labels is not None else None
        if labels is not None and len(labels) != len(variants):
            raise ValueError("labels must match variants 1:1")
        out_labels, reports = [], []
        for i, overrides in enumerate(variants):
            c = self._variant(overrides)
            reports.append(c.search(**search_kwargs))
            out_labels.append(labels[i] if labels is not None
                              else _variant_label(overrides))
        return Comparison(reports=reports, labels=out_labels)

    def speculative(self, draft: str, acceptance: float = 0.8,
                    max_gamma: int = 8,
                    report: Optional[SearchReport] = None):
        """Project speculative decoding with ``draft`` on the best config.

        Returns ``(best, all_projections)`` —
        :class:`~repro.core.speculative.SpecDecodeProjection` objects for
        the best γ and the full sweep.  Reuses this Configurator's
        PerfDatabase (and the report from a prior ``.search()``, if given).
        """
        known = list_archs(True)
        if draft not in known:
            raise _choices_error("draft model", draft, known)
        if not 0.0 < acceptance < 1.0:
            raise ValueError(f"acceptance must be in (0, 1), got {acceptance}")
        from repro.core.speculative import SpeculativeEstimator
        w = self.workload()
        if not get_backend(self._backend).supports("speculative"):
            raise ValueError(f"backend {self._backend!r} does not declare "
                             "the 'speculative' capability")
        if report is None:
            report = self.search(generate_launch=False)
        best = report.best
        if best is None:
            raise ValueError("no SLA-valid configuration to speculate on; "
                             "relax the SLA or grow the cluster")
        if best.mode != "disaggregated":
            par = ParallelismConfig(
                **{k: best.config.get("parallel", {}).get(k, 1)
                   for k in ("tp", "pp", "ep", "dp")})
        else:
            par = ParallelismConfig(tp=min(w.cluster.n_chips, 8))
        est = SpeculativeEstimator(w, draft, self.database())
        return est.best_gamma(par, batch=best.batch_size,
                              acceptance=acceptance, max_gamma=max_gamma)

    # -- internals -----------------------------------------------------------
    def _variant(self, overrides: Dict) -> "Configurator":
        c = copy.copy(self)          # shares self._dbs on purpose
        c._session = None
        known = {"isl", "osl", "prefix_len", "ttft_ms",
                 "min_tokens_per_s_user", "tpot_ms", "chips", "platform",
                 "chips_per_host", "backend", "dtype", "modes", "moe_alpha"}
        bad = set(overrides) - known
        if bad:
            raise ValueError(f"unknown compare override(s) {sorted(bad)}; "
                             f"valid: {sorted(known)}")
        o = dict(overrides)
        if {"isl", "osl", "prefix_len"} & set(o):
            c.traffic(o.pop("isl", self._isl), o.pop("osl", self._osl),
                      o.pop("prefix_len", self._prefix_len))
        if {"ttft_ms", "min_tokens_per_s_user", "tpot_ms"} & set(o):
            c.sla(o.pop("ttft_ms", self._sla.ttft_ms),
                  o.pop("min_tokens_per_s_user",
                        self._sla.min_tokens_per_s_user),
                  o.pop("tpot_ms", self._sla.tpot_ms))
        if {"chips", "platform", "chips_per_host"} & set(o):
            c.cluster(o.pop("chips", self._cluster.n_chips),
                      o.pop("platform", self._cluster.platform),
                      o.pop("chips_per_host", self._cluster.chips_per_host))
        if "backend" in o:
            c.backend(o.pop("backend"))
        if "dtype" in o:
            c.dtype(o.pop("dtype"))
        if "modes" in o:
            m = o.pop("modes")
            c.modes(*((m,) if isinstance(m, str) else m))
        if "moe_alpha" in o:
            c.moe_alpha(o.pop("moe_alpha"))
        return c


def _variant_label(overrides: Dict) -> str:
    return " ".join(f"{k}={v}" for k, v in overrides.items()) or "base"


@dataclasses.dataclass
class Comparison:
    """Results of a ``Configurator.compare`` sweep."""
    reports: List[SearchReport]
    labels: List[str]

    def summary(self) -> str:
        width = max((len(l) for l in self.labels), default=4)
        lines = [f"{'scenario':<{width}} | {'best mode':>13} "
                 f"{'tok/s/chip':>11} {'tok/s/user':>11} {'TTFT ms':>9}"]
        for label, rep in zip(self.labels, self.reports):
            b = rep.best
            if b is None:
                lines.append(f"{label:<{width}} | {'—':>13} "
                             f"{'(no SLA-valid config)':>34}")
            else:
                lines.append(
                    f"{label:<{width}} | {b.mode:>13} "
                    f"{b.tokens_per_s_per_chip:>11.1f} "
                    f"{b.tokens_per_s_user:>11.1f} {b.ttft_ms:>9.1f}")
        return "\n".join(lines)

    def to_dict(self) -> Dict:
        return {"schema_version": self.reports[0].schema_version
                if self.reports else 1,
                "scenarios": [{"label": l, "report": r.to_dict()}
                              for l, r in zip(self.labels, self.reports)]}

    def to_json(self, indent: Optional[int] = 2) -> str:
        import json
        return json.dumps(self.to_dict(), indent=indent)
