"""SearchReport: the serializable result of a configurator search.

Wraps the core :class:`~repro.core.task_runner.SearchResult` into a
schema-versioned, JSON-round-trippable artifact — projections, Pareto
frontier, disaggregated solution, search timing, and the resolved launch
artifact travel together.  ``SearchReport.from_json(r.to_json())``
reconstructs an equal report, making the report (not ad-hoc
``Projection.config`` dicts) the interchange format between the CLI,
benchmarks, dashboards, and downstream tooling.

Schema v2 makes the report an auditable deployment artifact: a
``database`` section fingerprints the PerfDatabase that priced the search
(platform/backend plus a digest over the collected latency grids), a
``memory`` section surfaces every candidate's per-chip memory footprint,
and ``search.early_exit`` records whether a streaming policy stopped the
sweep before the full space was priced.

Schema v3 adds the dynamic-workload axis: a ``workload_eval`` section
(written by ``Configurator.evaluate_frontier`` /
``repro.workloads.frontier.replay_frontier``; named to stay clear of the
v1 ``workload`` descriptor key) records the trace identity, the
tail-latency SLO, each replayed frontier candidate's open-loop metrics,
and the goodput-based re-ranking next to the analytical one.

Schema v4 adds the cluster axis: a ``capacity`` section (written by
``Configurator.plan_capacity`` / ``repro.capacity.sweep_ladder``)
records the minimum-chip autoscaling sweep — the trace and SLO, the
routing policy, every evaluated (replica-count × candidate) rung with
its aggregate cluster replay metrics and per-replica load-imbalance
stats, and the cheapest deployment whose goodput attains the SLO.

Schema v5 adds the elasticity axis: an ``autoscale`` section (written
by ``Configurator.autoscale`` /
``repro.autoscale.build_autoscale_section``) records a reactive
autoscaling run next to the static min-chip baseline on the same trace
— the policy and its knobs, the tick/cold-start model, both cost views
(chip-seconds, peak/mean replicas, the scaling-event log), the
timeline-artifact digest, and the chip-seconds saved while holding SLO
attainment.

Schema v6 adds the observability axis: a ``telemetry`` section
(written by ``Configurator.search`` when a ``repro.obs`` tracer or
metrics registry is installed) records the deterministic trace identity
— schema version, sha256 digest, and span count of the
:class:`~repro.obs.TraceArtifact` — plus a flat snapshot of the
counters/gauges/histograms the search incremented.  Wallclock timings
never enter the section, so it is byte-stable across seeded runs.

Schema v7 adds the request-level flight recorder: the replay-carrying
sections (``workload_eval`` candidate replays, ``capacity`` rungs, the
``autoscale`` run) each gain a ``histograms`` block — fixed
log2-ms-bucket TTFT/TPOT/queue-wait/e2e distributions
(:data:`~repro.obs.metrics.LATENCY_MS_BUCKETS`) folded from every
finished request, so the report carries full latency distributions
rather than just precomputed percentiles.  The section layout is
otherwise unchanged; v6 reports migrate with the block absent.

``from_json`` still accepts v1 through v6 payloads and migrates them
losslessly (sections a version never carried default to empty/None).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

from repro.core import modes, pareto
from repro.core.config import (ClusterSpec, DisaggConfig, Projection, SLA,
                               WorkloadDescriptor)
from repro.core.generator import LaunchConfig

#: Bump on any backwards-incompatible change to the JSON layout.
#: v1: initial layout.  v2: + database fingerprint, memory footprints,
#: early-exit record.  v3: + workload section (trace replay / SLO
#: re-ranking).  v4: + capacity section (multi-replica ladder sweep /
#: min-chip plan).  v5: + autoscale section (reactive autoscaling vs
#: the static plan).  v6: + telemetry section (trace digest + metrics
#: snapshot).  v7: + per-replay latency histograms (request-level
#: flight recorder).  ``from_json`` reads every version listed here.
SCHEMA_VERSION = 7
SUPPORTED_SCHEMA_VERSIONS = (1, 2, 3, 4, 5, 6, 7)


def workload_to_dict(w: WorkloadDescriptor) -> Dict:
    return {
        "model": w.model, "isl": w.isl, "osl": w.osl,
        "sla": dataclasses.asdict(w.sla),
        "cluster": dataclasses.asdict(w.cluster),
        "backend": w.backend, "prefix_len": w.prefix_len,
        "modes": list(w.modes), "moe_alpha": w.moe_alpha, "dtype": w.dtype,
    }


def workload_from_dict(d: Dict) -> WorkloadDescriptor:
    return WorkloadDescriptor(
        model=d["model"], isl=d["isl"], osl=d["osl"],
        sla=SLA(**d["sla"]), cluster=ClusterSpec(**d["cluster"]),
        backend=d["backend"], prefix_len=d["prefix_len"],
        modes=tuple(d["modes"]), moe_alpha=d["moe_alpha"], dtype=d["dtype"])


def _disagg_to_dict(d: modes.DisaggBest) -> Dict:
    describe = DisaggConfig(prefill=d.prefill.config, decode=d.decode.config,
                            x=d.x, y=d.y).describe()

    def pool(c: modes.PoolCandidate) -> Dict:
        return {"parallel": dataclasses.asdict(c.config.parallel),
                "batch": c.config.batch_size, "chips": c.chips,
                "latency_ms": c.latency_ms,
                "req_throughput": c.req_throughput}

    return {"describe": describe, "x": d.x, "y": d.y,
            "ttft_ms": d.ttft_ms, "tpot_ms": d.tpot_ms,
            "total_chips": d.total_chips, "req_per_s": d.req_per_s,
            "tokens_per_s_per_chip": d.tokens_per_s_per_chip,
            "prefill": pool(d.prefill), "decode": pool(d.decode)}


@dataclasses.dataclass
class SearchReport:
    """Everything one configurator search produced, in one artifact."""
    workload: WorkloadDescriptor
    projections: List[Projection]
    frontier_indices: List[int]
    best_index: Optional[int]
    n_candidates: int
    elapsed_s: float
    per_candidate_ms: float
    disagg: Optional[Dict] = None          # plain-dict (x)P(y)D solution
    launch: Optional[LaunchConfig] = None  # resolved artifact for `best`
    speculative: Optional[Dict] = None     # draft/gamma projection, if run
    fingerprint: Optional[Dict] = None     # PerfDatabase identity (v2)
    early_exit: Optional[Dict] = None      # streaming policy stop record (v2)
    workload_eval: Optional[Dict] = None   # trace replay / SLO re-rank (v3)
    capacity: Optional[Dict] = None        # replica-ladder min-chip plan (v4)
    autoscale: Optional[Dict] = None       # reactive autoscale vs static (v5)
    telemetry: Optional[Dict] = None       # trace digest + metrics (v6)
    schema_version: int = SCHEMA_VERSION

    # -- construction --------------------------------------------------------
    @classmethod
    def from_result(cls, workload: WorkloadDescriptor, result,
                    launch: Optional[LaunchConfig] = None,
                    speculative: Optional[Dict] = None,
                    fingerprint: Optional[Dict] = None,
                    early_exit: Optional[Dict] = None) -> "SearchReport":
        """Build from a core ``SearchResult`` (``TaskRunner.run`` output)."""
        idx = {id(p): i for i, p in enumerate(result.projections)}
        return cls(
            workload=workload,
            projections=list(result.projections),
            frontier_indices=[idx[id(p)] for p in result.frontier],
            best_index=idx[id(result.best)] if result.best is not None else None,
            n_candidates=result.n_candidates,
            elapsed_s=result.elapsed_s,
            per_candidate_ms=result.per_candidate_ms,
            disagg=(_disagg_to_dict(result.disagg_best)
                    if result.disagg_best is not None else None),
            launch=launch, speculative=speculative,
            fingerprint=fingerprint, early_exit=early_exit)

    # -- views ---------------------------------------------------------------
    @property
    def best(self) -> Optional[Projection]:
        return (self.projections[self.best_index]
                if self.best_index is not None else None)

    @property
    def frontier(self) -> List[Projection]:
        return [self.projections[i] for i in self.frontier_indices]

    def top_k(self, k: int = 5) -> List[Projection]:
        return pareto.top_k(self.projections, self.workload.sla, k)

    def summary(self) -> str:
        lines = [f"evaluated {self.n_candidates} candidates in "
                 f"{self.elapsed_s:.2f}s "
                 f"({self.per_candidate_ms:.2f} ms/config)"]
        if self.best:
            b = self.best
            lines.append(
                f"best [{b.mode}] {b.config.get('describe', '')}: "
                f"{b.tokens_per_s_per_chip:.1f} tok/s/chip @ "
                f"{b.tokens_per_s_user:.1f} tok/s/user "
                f"(TTFT {b.ttft_ms:.0f}ms)")
        we = self.workload_eval
        if we and we.get("best_index") is not None:
            wb = self.projections[we["best_index"]]
            replayed = [c for c in we["candidates"]
                        if c["replay"] is not None]
            lines.append(
                f"workload replay ({len(replayed)} candidates, trace "
                f"{we['trace']['digest']}): goodput best "
                f"[{wb.mode}] {wb.config.get('describe', '')}"
                + (" (re-ranked vs analytical)"
                   if we.get("reranked") else ""))
        cap = self.capacity
        if cap:
            plan = cap.get("plan") or {}
            if plan.get("attained"):
                dep = plan["deployment"]
                lines.append(
                    f"capacity plan (trace {cap['trace']['digest']}, "
                    f"routing {cap['routing']}): min-chip "
                    f"{dep['describe']} = {plan['total_chips']} chips at "
                    f"{100 * plan['slo_attainment']:.1f}% attainment")
            else:
                lines.append(
                    f"capacity plan (trace {cap['trace']['digest']}): no "
                    f"deployment on ladder {cap['ladder']} attains the SLO")
        asc = self.autoscale
        if asc:
            run = asc["run"]
            m = run["metrics"]
            attain = (f"{100 * m['slo_attainment']:.1f}%"
                      if m.get("slo_attainment") is not None else "n/a")
            line = (f"autoscale [{asc['policy']['name']}] (trace "
                    f"{asc['trace']['digest']}): "
                    f"{run['chip_seconds']:.1f} chip-s, replicas mean "
                    f"{run['mean_replicas']:.2f} peak "
                    f"{run['peak_replicas']}, attainment {attain}")
            sv = asc.get("savings")
            if sv is not None:
                line += (f" — saves {sv['chip_seconds']:.1f} chip-s "
                         f"({sv['chip_seconds_pct']:.1f}%) vs the "
                         f"static plan")
            lines.append(line)
        tel = self.telemetry
        if tel:
            tr = tel.get("trace")
            met = tel.get("metrics") or {}
            parts = []
            if tr:
                parts.append(f"trace {tr['digest']} ({tr['n_spans']} spans)")
            if met.get("counters"):
                parts.append(f"{len(met['counters'])} counters")
            if parts:
                lines.append("telemetry: " + ", ".join(parts))
        return "\n".join(lines)

    # -- serialization -------------------------------------------------------
    def memory_footprints(self) -> Dict:
        """Per-candidate memory view (the v2 ``memory`` section): one
        bytes-per-chip entry per projection, plus the peak."""
        per = [p.mem_bytes_per_chip for p in self.projections]
        return {"per_candidate_bytes_per_chip": per,
                "peak_bytes_per_chip": max(per, default=0.0)}

    def to_dict(self) -> Dict:
        return {
            "schema_version": SCHEMA_VERSION,
            "workload": workload_to_dict(self.workload),
            "search": {"n_candidates": self.n_candidates,
                       "elapsed_s": self.elapsed_s,
                       "per_candidate_ms": self.per_candidate_ms,
                       "early_exit": self.early_exit},
            "database": self.fingerprint,
            "memory": self.memory_footprints(),
            "projections": [dataclasses.asdict(p) for p in self.projections],
            "frontier": list(self.frontier_indices),
            "best": self.best_index,
            "disagg": self.disagg,
            "launch": (dataclasses.asdict(self.launch)
                       if self.launch is not None else None),
            "speculative": self.speculative,
            "workload_eval": self.workload_eval,
            "capacity": self.capacity,
            "autoscale": self.autoscale,
            "telemetry": self.telemetry,
        }

    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: Dict) -> "SearchReport":
        version = d.get("schema_version")
        if version not in SUPPORTED_SCHEMA_VERSIONS:
            raise ValueError(
                f"unsupported SearchReport schema_version {version!r}; "
                f"this build reads versions "
                f"{', '.join(map(str, SUPPORTED_SCHEMA_VERSIONS))}")
        try:
            return cls._from_dict_any(d, version)
        except (KeyError, TypeError) as e:
            raise ValueError(f"malformed SearchReport: {e}") from e

    @classmethod
    def _from_dict_any(cls, d: Dict, version: int) -> "SearchReport":
        # older payloads lack the sections later versions added (v2:
        # database/memory/early_exit, v3: workload); everything they do
        # carry maps 1:1, so migration is just "new fields default to
        # None" and the object re-serializes as the current version.
        return cls(
            workload=workload_from_dict(d["workload"]),
            projections=[Projection(**p) for p in d["projections"]],
            frontier_indices=list(d["frontier"]),
            best_index=d["best"],
            n_candidates=d["search"]["n_candidates"],
            elapsed_s=d["search"]["elapsed_s"],
            per_candidate_ms=d["search"]["per_candidate_ms"],
            disagg=d.get("disagg"),
            launch=(LaunchConfig(**d["launch"])
                    if d.get("launch") is not None else None),
            speculative=d.get("speculative"),
            fingerprint=d.get("database") if version >= 2 else None,
            early_exit=(d["search"].get("early_exit")
                        if version >= 2 else None),
            workload_eval=d.get("workload_eval") if version >= 3 else None,
            capacity=d.get("capacity") if version >= 4 else None,
            autoscale=d.get("autoscale") if version >= 5 else None,
            telemetry=d.get("telemetry") if version >= 6 else None,
            schema_version=SCHEMA_VERSION)

    @classmethod
    def from_json(cls, text: str) -> "SearchReport":
        return cls.from_dict(json.loads(text))

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return path

    @classmethod
    def load(cls, path: str) -> "SearchReport":
        with open(path) as f:
            return cls.from_json(f.read())
