"""Early-exit policies and progress events for streaming search.

``Configurator.search_iter`` yields one :class:`SearchEvent` per priced
projection and consults its policies after every yield; the first policy
that returns True stops the stream (remaining candidates are never
priced).  A policy is any callable ``SearchEvent -> bool`` — the
factories here cover the common cases and stamp a ``reason`` attribute
the terminal report records under ``early_exit``.

    stream = cfg.search_iter(policies=[stop_after_n_valid(3)])
    for event in stream:
        ui.update(event.projection, event.frontier_size)
    report = stream.report()          # report.early_exit names the policy
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from repro.core.config import CandidateConfig, Projection


@dataclasses.dataclass
class SearchEvent:
    """One priced projection, with running search state attached."""
    candidate: CandidateConfig
    projection: Projection
    index: int            # 0-based position in the yield stream
    n_priced: int         # candidates evaluated so far (incl. invalid/OOM)
    n_valid: int          # SLA-valid projections seen so far
    elapsed_s: float
    frontier_size: int    # current online Pareto-frontier size
    meets_sla: bool


#: A policy inspects the latest event and returns True to stop the search.
Policy = Callable[[SearchEvent], bool]


def _named(fn: Policy, reason: str) -> Policy:
    fn.reason = reason  # type: ignore[attr-defined]
    fn.__name__ = reason
    return fn


def stop_after_n_valid(n: int) -> Policy:
    """Stop once ``n`` SLA-valid projections have been yielded."""
    if n < 1:
        raise ValueError(f"stop_after_n_valid needs n >= 1, got {n}")
    return _named(lambda ev: ev.n_valid >= n, f"stop_after_n_valid({n})")


def deadline_s(seconds: float) -> Policy:
    """Stop once the search has run for ``seconds``.

    Checked per yield like every policy, AND out-of-band between yields:
    the policy carries a ``check_elapsed(elapsed_s) -> bool`` hook the
    streaming search threads into long non-yielding phases (the
    disaggregated pool pricing + rate matching), so the deadline preempts
    mid-match instead of waiting for the next projection.
    """
    if seconds <= 0:
        raise ValueError(f"deadline_s needs a positive deadline, got {seconds}")
    t0: Optional[float] = None

    def policy(ev: SearchEvent) -> bool:
        # anchor on each stream's first event so a pre-built (or reused)
        # policy object never counts time outside the current search
        nonlocal t0
        if t0 is None or ev.index == 0:
            t0 = time.perf_counter() - ev.elapsed_s
        return time.perf_counter() - t0 >= seconds

    policy = _named(policy, f"deadline_s({seconds})")
    policy.check_elapsed = lambda elapsed: elapsed >= seconds  # type: ignore[attr-defined]
    return policy


def callback(fn: Callable[[SearchEvent], object]) -> Policy:
    """Progress hook: ``fn`` sees every event; a truthy return stops the
    search, ``None``/falsy lets it continue."""
    name = getattr(fn, "__name__", "<fn>")
    return _named(lambda ev: bool(fn(ev)), f"callback({name})")
