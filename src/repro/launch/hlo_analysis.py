"""Trip-count-aware HLO accounting.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
program with ``lax.scan`` (our layer stacks, blockwise attention, microbatch
accumulation) under-reports FLOPs/bytes/collectives by the loop trip counts.
This module parses the partitioned HLO text, recovers the call graph
(while bodies x trip count, fusions, calls), and accumulates:

  - matmul FLOPs (dot ops, contracting dims resolved from operand shapes),
  - approximate HBM bytes (operand+result bytes of top-level ops at fusion
    boundaries — fused interiors stay on-chip),
  - per-kind collective bytes (result shapes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute).

Everything is per-device (the HLO is the per-device SPMD module).

Trip counts come from the canonical jax scan condition
``compare(iter, constant), direction=LT`` with iter starting at 0; loops
whose bound cannot be recovered default to 1 (and are reported).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
                "f8e5m2fnuz": 1, "f8e4m3fnuz": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALLED_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(
    r"(?:branch_computations=\{([^}]*)\}"
    r"|true_computation=%?([\w.\-]+)|false_computation=%?([\w.\-]+))")


def _called_all(rhs: str):
    out = list(_CALLED_RE.findall(rhs))
    for m in _BRANCHES_RE.finditer(rhs):
        if m.group(1):
            out += [b.strip().lstrip("%") for b in m.group(1).split(",")]
        for g in (m.group(2), m.group(3)):
            if g:
                out.append(g)
    return out
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_bytes(text: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(text):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_dims(text: str) -> List[int]:
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    rhs: str                      # full right-hand side text
    result_text: str              # type portion
    kind: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    is_fused: bool


def _split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s:
            continue
        # computation header: "%name (...) -> type {" or "ENTRY %name ..."
        if s.endswith("{") and ("(" in s) and ("=" not in s.split("(")[0]):
            header = s.split("(")[0].strip()
            name = header.replace("ENTRY", "").strip().lstrip("%").strip()
            cur = Computation(name=name, ops=[],
                              is_fused=name.startswith("fused_")
                              or ".fused" in name)
            comps[name] = cur
            continue
        if s == "}" or s.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        opname, rhs = m.group(1), m.group(2)
        # kind = first word after the type, e.g. "bf16[2,3]{1,0} dot(...)"
        km = re.search(r"\)?\s*([a-z][\w\-]*)\(", rhs)
        kind = km.group(1) if km else ""
        # result text = rhs up to the op kind
        rt = rhs[:km.start()] if km else rhs
        cur.ops.append(Op(name=opname, rhs=rhs, result_text=rt, kind=kind))
    return comps


def _trip_count(cond: Computation) -> Optional[int]:
    """Recover N from the canonical jax scan condition: the loop bound is
    the (max) s32 constant in the condition region.  (The compare itself is
    often inside a fused sub-computation, so we don't require seeing
    direction=LT here.)"""
    consts = []
    for op in cond.ops:
        consts += [int(c) for c in _CONST_RE.findall(op.rhs)]
    return max(consts) if consts else None


def _operands(op: Op) -> List[str]:
    """Operand names from the paren group FOLLOWING the op kind (tuple-typed
    results put a paren group before the op kind)."""
    m = re.search(r"\b" + re.escape(op.kind) + r"\(([^)]*)\)", op.rhs)
    if not m:
        return []
    return [o.strip().lstrip("%") for o in m.group(1).split(",") if o.strip()]


def _dot_flops(op: Op, symbols: Dict[str, str]) -> float:
    """2 * prod(result dims) * prod(contracting dim sizes of lhs)."""
    res_dims = shape_dims(op.result_text)
    operands = _operands(op)
    lhs_text = symbols.get(operands[0], "") if operands else ""
    lhs_dims = shape_dims(lhs_text if lhs_text else op.rhs)
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rhs)
    contract = 1
    if cm and lhs_dims:
        for d in cm.group(1).split(","):
            if d and int(d) < len(lhs_dims):
                contract *= lhs_dims[int(d)]
    n = 1
    for d in res_dims:
        n *= d
    return 2.0 * n * contract


def _fusion_input_bytes(op: Op, comps: Dict[str, "Computation"],
                        caller_tab: Dict[str, str]) -> float:
    """Bytes a fusion actually reads per input: inputs consumed ONLY by
    slice/dynamic-slice/gather inside the fused computation contribute the
    sliced size, not the full operand (scan bodies slice their stacked
    xs — counting the whole stack per iteration would overstate HBM
    traffic by the trip count)."""
    operands = _operands(op)
    targets = _CALLED_RE.findall(op.rhs)
    called = comps.get(targets[0]) if targets else None
    if called is None:
        return sum(shape_bytes(caller_tab.get(o, "")) for o in operands)
    # parameter name -> operand index
    param_idx: Dict[str, int] = {}
    for fop in called.ops:
        if fop.kind == "parameter":
            mm = re.search(r"parameter\((\d+)\)", fop.rhs)
            if mm:
                param_idx[fop.name] = int(mm.group(1))
    sliced_bytes: Dict[int, float] = {}
    full_needed: Dict[int, bool] = {}
    for fop in called.ops:
        if fop.kind == "parameter":
            continue
        for o in _operands(fop):
            if o in param_idx:
                idx = param_idx[o]
                if fop.kind in ("slice", "dynamic-slice", "gather"):
                    sliced_bytes[idx] = sliced_bytes.get(idx, 0.0) \
                        + shape_bytes(fop.result_text)
                else:
                    full_needed[idx] = True
    total = 0.0
    for i, o in enumerate(operands):
        full = shape_bytes(caller_tab.get(o, ""))
        if full_needed.get(i) or i not in sliced_bytes:
            total += full
        else:
            total += min(sliced_bytes[i], full)
    return total


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    unresolved_loops: int = 0

    def collective_total(self) -> float:
        return sum(self.collectives.values())


def analyze(hlo_text: str) -> HloStats:
    comps = _split_computations(hlo_text)
    stats = HloStats()

    # a computation is "fused" iff some fusion op calls it (names alone are
    # unreliable: kLoop fusions are often %wrapped_*_computation)
    for comp in comps.values():
        for op in comp.ops:
            if op.kind == "fusion":
                for target in _CALLED_RE.findall(op.rhs):
                    if target in comps:
                        comps[target].is_fused = True

    # symbol table per computation: op name -> result text (for operand shapes)
    symtabs: Dict[str, Dict[str, str]] = {}
    for cname, comp in comps.items():
        tab: Dict[str, str] = {}
        for op in comp.ops:
            tab[op.name] = op.result_text or op.rhs
        # parameters are declared like "%p = bf16[...] parameter(0)" — covered
        symtabs[cname] = tab

    # multipliers via worklist from ENTRY
    entry = None
    for cname, comp in comps.items():
        if "main" in cname or entry is None:
            if entry is None or "main" in cname:
                entry = cname
    mult: Dict[str, float] = {}
    work: List[Tuple[str, float]] = [(entry, 1.0)]
    visited_pairs = set()
    while work:
        cname, m = work.pop()
        if cname not in comps:
            continue
        mult[cname] = mult.get(cname, 0.0) + m
        comp = comps[cname]
        for op in comp.ops:
            if op.kind == "while":
                bm = _CALLED_RE.search(op.rhs)
                cm_ = _COND_RE.search(op.rhs)
                trips = None
                if cm_ and cm_.group(1) in comps:
                    trips = _trip_count(comps[cm_.group(1)])
                if trips is None:
                    trips = 1
                    stats.unresolved_loops += 1
                if bm:
                    key = (cname, op.name, bm.group(1))
                    if key not in visited_pairs:
                        visited_pairs.add(key)
                        work.append((bm.group(1), m * trips))
            elif op.kind in ("fusion", "call", "conditional",
                             "async-start", "custom-call"):
                # NOTE: conditional branches are both counted at the full
                # multiplier — an upper bound; runtime executes one branch
                # (the causal block-skip's saving is reported analytically)
                for target in _called_all(op.rhs):
                    key = (cname, op.name, target)
                    if key not in visited_pairs:
                        visited_pairs.add(key)
                        work.append((target, m))

    # accumulate
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        tab = symtabs[cname]
        for op in comp.ops:
            if op.kind == "dot":
                stats.flops += m * _dot_flops(op, tab)
            elif op.kind == "convolution":
                # approximate: 2 * result elems * (contraction guess skipped)
                res = shape_dims(op.result_text)
                n = 1
                for d in res:
                    n *= d
                stats.flops += m * 2.0 * n
            for kind in COLLECTIVES:
                if op.kind == kind or op.kind == kind + "-start":
                    stats.collectives[kind] += m * shape_bytes(op.result_text)
                    break
            # bytes: approximate the HBM traffic a WELL-FUSED (TPU) backend
            # would see.  Only memory-bearing ops count; pure elementwise /
            # reduce / copy / transpose chains are assumed fused into their
            # producers (the CPU backend leaves them unfused, which would
            # overstate traffic by orders of magnitude).
            if not comp.is_fused:
                if op.kind in ("slice", "dynamic-slice", "gather"):
                    # reads only the sliced region (+ writes it)
                    b = 2.0 * shape_bytes(op.result_text)
                elif op.kind in ("dynamic-update-slice", "scatter"):
                    # in-place region write: traffic ~ 2x the update operand
                    operands = _operands(op)
                    upd = operands[1] if len(operands) > 1 else None
                    b = 2.0 * shape_bytes(tab.get(upd, "")) if upd else 0.0
                elif op.kind == "fusion":
                    b = shape_bytes(op.result_text)
                    b += _fusion_input_bytes(op, comps, tab)
                elif op.kind in ("dot", "convolution"):
                    b = shape_bytes(op.result_text)
                    for operand in _operands(op):
                        if operand in tab:
                            b += shape_bytes(tab[operand])
                elif op.kind in COLLECTIVES or op.kind.rstrip("-start") in COLLECTIVES:
                    b = shape_bytes(op.result_text)
                else:
                    b = 0.0
                stats.bytes_accessed += m * b
    return stats
