import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  512 host-platform placeholder devices let
# jax.make_mesh build the production meshes; nothing is ever executed.

# Multi-pod dry-run: lower + compile every (architecture x input shape) on
# the production meshes and extract the roofline inputs.
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.jsonl
#
# Per pair and mesh this records: per-device memory analysis (proves fit),
# HLO FLOPs/bytes from compiled.cost_analysis(), per-collective byte sums
# parsed from the partitioned HLO (all-gather / all-reduce / reduce-scatter /
# all-to-all / collective-permute), and lower/compile wall times.

import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import models
from repro.configs import INPUT_SHAPES, dryrun_pairs, get_config
from repro.configs.base import InputShape, ModelConfig
from repro.launch import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models.common import ParamSpec
from repro.training import optimizer as opt
from repro.training.train_step import make_train_step


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------

def abstract_opt_state(cfg: ModelConfig):
    ap = models.abstract_params(cfg)
    f32 = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), ap)
    return opt.OptState(mu=f32, nu=jax.tree.map(lambda s: s, f32),
                        step=jax.ShapeDtypeStruct((), jnp.int32))


def abstract_cache(cfg: ModelConfig, batch: int, seq: int):
    """ShapeDtypeStruct tree matching each family's decode cache."""
    dt = models.param_dtype(cfg)
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    K, D = cfg.num_kv_heads, cfg.head_dim
    if cfg.family in ("dense", "vlm", "moe"):
        W = cfg.kv_cache_len(seq)
        L = cfg.num_layers
        if cfg.sharding.kv_quant and cfg.family != "moe":
            return {"k": sds((L, batch, W, K, D), jnp.int8),
                    "v": sds((L, batch, W, K, D), jnp.int8),
                    "k_scale": sds((L, batch, W, K), jnp.float32),
                    "v_scale": sds((L, batch, W, K), jnp.float32),
                    "pos": sds((), i32)}
        return {"k": sds((L, batch, W, K, D), dt),
                "v": sds((L, batch, W, K, D), dt),
                "pos": sds((), i32)}
    if cfg.family == "audio":
        L, H = cfg.num_layers, cfg.num_heads
        return {"k": sds((L, batch, seq, K, D), dt),
                "v": sds((L, batch, seq, K, D), dt),
                "ck": sds((L, batch, cfg.num_source_positions, H, D), dt),
                "cv": sds((L, batch, cfg.num_source_positions, H, D), dt),
                "pos": sds((), i32)}
    if cfg.family == "hybrid":
        from repro.models import hybrid
        G, T = hybrid.n_groups(cfg), hybrid.n_tail(cfg)
        W = min(seq, cfg.local_window)
        w, cw = cfg.lru_width, cfg.conv_width
        return {"k": sds((G, batch, W, K, D), dt),
                "v": sds((G, batch, W, K, D), dt),
                "h_group": sds((G, 2, batch, w), jnp.float32),
                "conv_group": sds((G, 2, batch, cw - 1, w), dt),
                "h_tail": sds((T, batch, w), jnp.float32),
                "conv_tail": sds((T, batch, cw - 1, w), dt),
                "pos": sds((), i32)}
    if cfg.family == "ssm":
        from repro.models import xlstm
        G = xlstm.n_pairs(cfg)
        nh, u, d = cfg.num_heads, xlstm.up_dim(cfg), cfg.d_model
        dhm, dhs = u // nh, d // nh
        return {"m": {"C": sds((G, batch, nh, dhm, dhm), jnp.float32),
                      "n": sds((G, batch, nh, dhm), jnp.float32),
                      "m": sds((G, batch, nh), jnp.float32),
                      "conv": sds((G, batch, cfg.conv_width - 1, u), dt)},
                "s": {"c": sds((G, batch, nh, dhs), jnp.float32),
                      "n": sds((G, batch, nh, dhs), jnp.float32),
                      "m": sds((G, batch, nh, dhs), jnp.float32),
                      "h": sds((G, batch, nh, dhs), jnp.float32)},
                "pos": sds((), i32)}
    raise ValueError(cfg.family)


def input_specs(arch: str, shape_name: str,
                cfg: Optional[ModelConfig] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this step."""
    cfg = cfg or get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        out = {"tokens": sds((B, S), jnp.int32),
               "labels": sds((B, S), jnp.int32)}
        out.update(models.extra_train_inputs(cfg, B, S, abstract=True))
        return out
    if shape.kind == "prefill":
        out = {"tokens": sds((B, S), jnp.int32)}
        out.update(models.extra_train_inputs(cfg, B, S, abstract=True))
        return out
    # decode
    out = {"token": sds((B, 1), jnp.int32),
           "cache": abstract_cache(cfg, B, S)}
    if cfg.family == "vlm":
        out["mrope_positions"] = sds((3, B, 1), jnp.int32)
    return out


# ---------------------------------------------------------------------------
# step builders: (fn, args, in_shardings, out_shardings)
# ---------------------------------------------------------------------------

def build_step(cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    specs = input_specs(cfg.name, shape.name, cfg=cfg)
    mode = "train" if shape.kind == "train" else "serve"
    p_sh = shd.param_shardings(cfg, mode, mesh)
    rep = NamedSharding(mesh, P())
    bsh = lambda nd: NamedSharding(
        mesh, shd.batch_spec(mesh, shape.global_batch, nd))
    extras_sh = {}
    for k in ("frames", "image_embeds"):
        if k in specs:
            extras_sh[k] = bsh(2)
    if "mrope_positions" in specs:
        extras_sh["mrope_positions"] = NamedSharding(
            mesh, P(None, *shd.batch_spec(mesh, shape.global_batch, 1)))

    if shape.kind == "train":
        ap = models.abstract_params(cfg)
        ostate = abstract_opt_state(cfg)
        o_sh = opt.OptState(mu=p_sh, nu=jax.tree.map(lambda s: s, p_sh),
                            step=rep)
        step = make_train_step(cfg)
        extras = {k: v for k, v in specs.items()
                  if k not in ("tokens", "labels")}

        def fn(params, opt_state, tokens, labels, ex):
            return step(params, opt_state, tokens, labels, **ex)

        args = (ap, ostate, specs["tokens"], specs["labels"], extras)
        in_sh = (p_sh, o_sh, bsh(1), bsh(1), extras_sh)
        out_sh = (p_sh, o_sh, None)
        return fn, args, in_sh, out_sh

    if shape.kind == "prefill":
        cache_sh = shd.cache_shardings(cfg, mesh, shape.global_batch)
        extras = {k: v for k, v in specs.items() if k != "tokens"}

        def fn(params, tokens, ex):
            return models.prefill(params, cfg, tokens, max_len=shape.seq_len,
                                  **ex)

        args = (models.abstract_params(cfg), specs["tokens"], extras)
        in_sh = (shd.param_shardings(cfg, "serve", mesh), bsh(1), extras_sh)
        out_sh = (None, cache_sh)
        return fn, args, in_sh, out_sh

    # decode
    cache_sh = shd.cache_shardings(cfg, mesh, shape.global_batch)
    extras = {k: v for k, v in specs.items() if k not in ("token", "cache")}

    def fn(params, token, cache, ex):
        return models.decode_step(params, cfg, token, cache, **ex)

    args = (models.abstract_params(cfg), specs["token"], specs["cache"],
            extras)
    in_sh = (shd.param_shardings(cfg, "serve", mesh), bsh(1), cache_sh,
             extras_sh)
    out_sh = (None, cache_sh)
    return fn, args, in_sh, out_sh


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(bf16|f32|f16|s32|u32|s8|u8|pred|f8\w*)\[([\d,]*)\]")
_DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(text: str) -> float:
    """Bytes of the first shape literal in an HLO result/type string
    (tuple shapes: sum all element shapes)."""
    total = 0.0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 2)
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op, by kind."""
    out = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        eq = s.find(" = ")
        if eq < 0:
            continue
        rhs = s[eq + 3:]
        for kind in _COLLECTIVES:
            # match op name: "bf16[...] all-reduce(" etc.
            if f" {kind}(" in rhs or rhs.startswith(kind + "("):
                out[kind] += _shape_bytes(rhs[:rhs.find(kind)] or s[:eq])
                break
    return out


# ---------------------------------------------------------------------------
# per-pair dry run
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DryrunResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    flops: float = 0.0                 # raw cost_analysis (loop bodies x1!)
    bytes_accessed: float = 0.0        # raw cost_analysis
    flops_corrected: float = 0.0       # trip-count-aware HLO accounting
    bytes_corrected: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(default_factory=dict)
    unresolved_loops: int = 0
    mem: Dict[str, float] = dataclasses.field(default_factory=dict)
    lower_s: float = 0.0
    compile_s: float = 0.0
    error: str = ""

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))


def run_pair(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, hlo_dir: str = "",
             sharding_overrides: Optional[Dict] = None,
             expert_axis: int = 0) -> DryrunResult:
    cfg = get_config(arch)
    if sharding_overrides:
        cfg = dataclasses.replace(
            cfg, sharding=dataclasses.replace(cfg.sharding,
                                              **sharding_overrides))
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    res = DryrunResult(arch=arch, shape=shape_name, mesh=mesh_name, ok=False)
    try:
        mesh = make_production_mesh(multi_pod=multi_pod,
                                    expert_axis=expert_axis)
        from repro.models import common as _cm
        _cm.set_mesh_axes(mesh)
        fn, args, in_sh, out_sh = build_step(cfg, shape, mesh)
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        t0 = time.perf_counter()
        with mesh:
            lowered = jitted.lower(*args)
            res.lower_s = time.perf_counter() - t0
            t1 = time.perf_counter()
            compiled = lowered.compile()
            res.compile_s = time.perf_counter() - t1
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        res.flops = float(ca.get("flops", 0.0))
        res.bytes_accessed = float(ca.get("bytes accessed", 0.0))
        ma = compiled.memory_analysis()
        if ma is not None:
            for field in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes",
                          "alias_size_in_bytes"):
                v = getattr(ma, field, None)
                if v is not None:
                    res.mem[field] = float(v)
        from repro.launch import hlo_analysis
        hlo_text = compiled.as_text()
        if hlo_dir:
            import gzip
            os.makedirs(hlo_dir, exist_ok=True)
            fn_out = os.path.join(
                hlo_dir, f"{arch}_{shape_name}_{mesh_name}.hlo.gz")
            with gzip.open(fn_out, "wt") as f:
                f.write(hlo_text)
        st = hlo_analysis.analyze(hlo_text)
        res.flops_corrected = st.flops
        res.bytes_corrected = st.bytes_accessed
        res.collectives = st.collectives
        res.unresolved_loops = st.unresolved_loops
        res.ok = True
        if verbose:
            peak = (res.mem.get("argument_size_in_bytes", 0)
                    + res.mem.get("temp_size_in_bytes", 0)
                    - res.mem.get("alias_size_in_bytes", 0))
            print(f"[OK] {arch} x {shape_name} on {mesh_name}: "
                  f"flops={res.flops:.3e} bytes={res.bytes_accessed:.3e} "
                  f"mem/device≈{peak/2**30:.2f}GiB "
                  f"(lower {res.lower_s:.1f}s compile {res.compile_s:.1f}s)",
                  flush=True)
    except Exception as e:  # noqa: BLE001 — recorded, rerun fails loudly
        res.error = f"{type(e).__name__}: {e}\n{traceback.format_exc()[-2000:]}"
        if verbose:
            print(f"[FAIL] {arch} x {shape_name} on {mesh_name}: "
                  f"{type(e).__name__}: {str(e)[:400]}", flush=True)
    finally:
        from repro.models import common as _cm
        _cm.set_mesh_axes(())
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--hlo-dir", default="",
                    help="archive partitioned HLO (gzip) for offline "
                         "re-analysis without recompiling")
    ap.add_argument("--sharding", default="",
                    help="ShardingRules overrides for perf iteration, "
                         "e.g. 'remat=dots,moe_mode=ffn,microbatches=2'")
    ap.add_argument("--expert-axis", type=int, default=0,
                    help="split the model axis into (expert, model) of this "
                         "expert width (perf-iteration 3-axis mesh)")
    args = ap.parse_args()
    overrides = {}
    for kv in filter(None, args.sharding.split(",")):
        k, v = kv.split("=")
        overrides[k] = (int(v) if v.lstrip("-").isdigit()
                        else v == "true" if v in ("true", "false") else v)

    pairs = dryrun_pairs() if args.all else [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    n_ok = n_fail = 0
    with open(args.out, "a") as f:
        for arch, shape_name in pairs:
            for mp in meshes:
                r = run_pair(arch, shape_name, mp, hlo_dir=args.hlo_dir,
                             sharding_overrides=overrides or None,
                             expert_axis=args.expert_axis)
                f.write(r.to_json() + "\n")
                f.flush()
                n_ok += r.ok
                n_fail += not r.ok
    print(f"\ndry-run complete: {n_ok} ok, {n_fail} failed -> {args.out}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
