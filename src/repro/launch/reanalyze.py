"""Offline re-analysis of archived partitioned HLO.

    PYTHONPATH=src python -m repro.launch.reanalyze \
        --jsonl results/dryrun.jsonl --hlo-dir results/hlo

Recomputes the trip-count-corrected FLOPs/bytes/collectives with the
current hlo_analysis and rewrites the jsonl in place — iterating on the
analyzer never requires recompiling the 68-entry matrix.
"""
from __future__ import annotations

import argparse
import gzip
import json
import os

from repro.launch import hlo_analysis


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default="results/dryrun.jsonl")
    ap.add_argument("--hlo-dir", default="results/hlo")
    args = ap.parse_args()

    recs = [json.loads(l) for l in open(args.jsonl)]
    n_updated = 0
    for r in recs:
        path = os.path.join(args.hlo_dir,
                            f"{r['arch']}_{r['shape']}_{r['mesh']}.hlo.gz")
        if not r.get("ok") or not os.path.exists(path):
            continue
        with gzip.open(path, "rt") as f:
            st = hlo_analysis.analyze(f.read())
        r["flops_corrected"] = st.flops
        r["bytes_corrected"] = st.bytes_accessed
        r["collectives"] = st.collectives
        r["unresolved_loops"] = st.unresolved_loops
        n_updated += 1
    with open(args.jsonl, "w") as f:
        for r in recs:
            f.write(json.dumps(r) + "\n")
    print(f"re-analyzed {n_updated}/{len(recs)} records -> {args.jsonl}")


if __name__ == "__main__":
    main()
