"""Serving driver — consumes the configurator's Generator output.

    # from a generated launch file:
    PYTHONPATH=src python -m repro.launch.serve --launch-config out.json

    # or directly:
    PYTHONPATH=src python -m repro.launch.serve --model internlm2-1.8b \
        --max-batch 4 --requests 8 --isl 16 --osl 8

Runs the real continuous-batching engine (reduced config on CPU) over a
synthetic workload and reports TTFT/TPOT/throughput — the measured
counterpart to the configurator's projections.
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import jax
import numpy as np

from repro import models
from repro.configs import get_config
from repro.serving.engine import Engine, EngineConfig
from repro.serving.request import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--launch-config", default="")
    ap.add_argument("--model", default="internlm2-1.8b")
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--pp", type=int, default=1)
    ap.add_argument("--ep", type=int, default=1)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-num-tokens", type=int, default=8192)
    ap.add_argument("--kv-cache-hbm-fraction", type=float, default=0.9)
    ap.add_argument("--chunked-prefill", action="store_true")
    ap.add_argument("--decode-bucketing", action="store_true")
    ap.add_argument("--disaggregated", action="store_true")
    ap.add_argument("--prefill", default="")
    ap.add_argument("--decode", default="")
    ap.add_argument("--decode-batch", type=int, default=0)
    ap.add_argument("--kv-frac", type=float, default=0.9)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--isl", type=int, default=16)
    ap.add_argument("--osl", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    model, max_batch = args.model, args.max_batch
    if args.launch_config:
        with open(args.launch_config) as f:
            lc = json.load(f)
        model = lc["model"]
        if lc.get("mode") == "disaggregated":
            max_batch = lc["decode_workers"]["batch_size"]
        else:
            max_batch = lc["batch_size"]
        print(f"loaded launch config: {lc.get('mode')} "
              f"{lc.get('parallel', lc.get('decode_workers'))}")
    if args.disaggregated:
        print(f"[disaggregated] prefill={args.prefill} decode={args.decode} "
              "— single-host run executes the decode pool shape")
        if args.decode_batch:
            max_batch = args.decode_batch

    cfg = get_config(model).reduced()
    params = models.init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = Engine(cfg, params, EngineConfig(
        max_batch=min(max_batch, 16),
        max_seq=max(args.isl + args.osl + 8, 64),
        kv_cache_hbm_fraction=args.kv_cache_hbm_fraction,
        decode_bucketing=args.decode_bucketing,
        max_num_tokens=args.max_num_tokens))

    rng = np.random.default_rng(args.seed)
    t_start = time.perf_counter()
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, args.isl).tolist()
        eng.add_request(Request(rid=i, isl=args.isl, osl=args.osl,
                                arrival=time.perf_counter(), prompt=prompt))
    done = eng.run_until_drained()
    wall = time.perf_counter() - t_start

    ttfts = [r.ttft for r in done if r.ttft is not None]
    tpots = [r.tpot for r in done if r.tpot is not None]
    gen = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests in {wall:.2f}s on "
          f"{jax.default_backend()}")
    print(f"TTFT p50 {1e3*statistics.median(ttfts):.1f}ms  "
          f"TPOT p50 {1e3*statistics.median(tpots):.2f}ms  "
          f"throughput {gen/wall:.1f} tok/s")


if __name__ == "__main__":
    main()
