"""Training driver.

CPU smoke scale by default (reduced config, host mesh); ``--production``
lowers against the full config on the production mesh first (sanity) and
refuses to execute on non-TPU backends.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --steps 20 --batch 4 --seq 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs import get_config
from repro.training import checkpoint as ckpt
from repro.training import data as dat
from repro.training import optimizer as opt
from repro.training.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (not reduced) config — TPU scale")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.reduced()
    print(f"arch={cfg.name} family={cfg.family} params≈{cfg.param_count():,}")

    params = models.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = opt.init(params)
    ocfg = opt.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                           total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, ocfg))
    ds = dat.make_dataset(cfg, args.seq, args.batch, args.seed)
    extras = models.extra_train_inputs(cfg, args.batch, args.seq)

    t0 = time.perf_counter()
    for i in range(args.steps):
        b = ds.batch(i)
        params, opt_state, m = step_fn(params, opt_state,
                                       jnp.asarray(b["tokens"]),
                                       jnp.asarray(b["labels"]), **extras)
        if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} "
                  f"lr {float(m['lr']):.2e}", flush=True)
    dt = time.perf_counter() - t0
    toks = args.steps * args.batch * args.seq
    print(f"done: {args.steps} steps, {toks/dt:.0f} tok/s on "
          f"{jax.default_backend()}")
    if args.checkpoint:
        path = ckpt.save(args.checkpoint, params, opt_state, step=args.steps)
        print(f"checkpoint -> {args.checkpoint}")


if __name__ == "__main__":
    main()
