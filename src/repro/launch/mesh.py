"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state; only dryrun.py sets the 512-placeholder-device
XLA flag, and only before its first jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, expert_axis: int = 0):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh.

    expert_axis > 0 splits the model axis into (expert, model) — the
    perf-iteration mesh for MoE archs whose expert count doesn't divide 16
    (e.g. mixtral 8e -> (16, 8, 2)); same chip count, different collective
    structure (see EXPERIMENTS.md §Perf)."""
    if expert_axis:
        assert 16 % expert_axis == 0
        if multi_pod:
            return jax.make_mesh((2, 16, expert_axis, 16 // expert_axis),
                                 ("pod", "data", "expert", "model"))
        return jax.make_mesh((16, expert_axis, 16 // expert_axis),
                             ("data", "expert", "model"))
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh on the real local device (CPU smoke runs)."""
    return jax.make_mesh((1, 1), ("data", "model"))
