"""Logical-axis -> mesh-axis sharding resolution.

Param schemas carry logical axes ('embed', 'vocab', 'heads', 'kv', 'ffn',
'experts', 'layers'); this module maps them to PartitionSpecs for a given
mesh + ShardingRules + mode, with a divisibility guard: a mesh axis is only
assigned when the dim divides evenly (uneven GSPMD padding is never relied
on — what doesn't divide is replicated, and the roofline shows the cost).

Baseline layout (megatron-style TP on 'model', FSDP on 'data' for training
and for serve-time models too big to replicate across the data axis,
expert-parallel on 'model' when num_experts divides):

  batch axes: ('pod', 'data') when the pod axis exists.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import models
from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec, schema_axes

SERVE_FSDP_THRESHOLD = 0.75     # of HBM capacity (v5e 16 GiB)
V5E_HBM = 16 * 2 ** 30


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _param_bytes(cfg: ModelConfig) -> float:
    return cfg.param_count() * 2.0          # bf16


def logical_map(cfg: ModelConfig, mode: str, mesh: Mesh) -> Dict[str, Any]:
    rules = cfg.sharding
    sizes = mesh_axis_sizes(mesh)
    model_n = sizes.get("model", 1)
    fsdp = mode == "train"
    if mode == "serve" and _param_bytes(cfg) / model_n > SERVE_FSDP_THRESHOLD * V5E_HBM:
        fsdp = True                          # too big to replicate (mixtral)
    if "expert" in sizes and cfg.num_experts \
            and cfg.num_experts % sizes["expert"] == 0:
        # dedicated expert axis (perf-iteration mesh): non-expert weights
        # TP across the COMBINED (expert, model) axes; expert weights EP on
        # 'expert' + TP on 'model' within each expert
        tp = ("expert", "model")
        return {
            "layers": None,
            "vocab": tp if rules.shard_vocab else None,
            "heads": tp if rules.shard_heads else None,
            "kv": tp,
            "ffn": ("model" if rules.shard_ffn and rules.moe_ffn_tp
                    else None),
            "experts": "expert",
            "embed": "data" if (mode == "train" or
                                _param_bytes(cfg) / model_n
                                > SERVE_FSDP_THRESHOLD * V5E_HBM) else None,
            None: None,
        }
    moe_expert_par = (cfg.num_experts and rules.moe_mode == "expert"
                      and cfg.num_experts % model_n == 0)
    return {
        "layers": None,
        "vocab": "model" if rules.shard_vocab else None,
        "heads": "model" if rules.shard_heads else None,
        "kv": "model",
        # note: for expert weights under EP, 'experts' consumes the model
        # axis first and the per-expert ffn dim stays unsharded (the `used`
        # set in spec_for enforces one use per mesh axis)
        "ffn": "model" if rules.shard_ffn else None,
        "experts": "model" if moe_expert_par else None,
        "embed": "data" if fsdp else None,
        None: None,
    }


def spec_for(pspec: ParamSpec, lmap: Dict[str, Any],
             sizes: Dict[str, int]) -> P:
    parts = []
    used = set()
    for dim, axis in zip(pspec.shape, pspec.axes):
        target = lmap.get(axis)
        if target is None:
            parts.append(None)
            continue
        taxes = target if isinstance(target, tuple) else (target,)
        if used & set(taxes):
            parts.append(None)
            continue
        n = 1
        for a in taxes:
            n *= sizes.get(a, 1)
        if n <= 1 or dim % n != 0:
            parts.append(None)
            continue
        parts.append(target)
        used.update(taxes)
    return P(*parts)


def param_specs(cfg: ModelConfig, mode: str, mesh: Mesh):
    """PartitionSpec tree mirroring the model schema."""
    sch = models.schema(cfg)
    lmap = logical_map(cfg, mode, mesh)
    sizes = mesh_axis_sizes(mesh)

    return jax.tree.map(lambda ps: spec_for(ps, lmap, sizes), sch,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def param_shardings(cfg: ModelConfig, mode: str, mesh: Mesh):
    return jax.tree.map(lambda p: NamedSharding(mesh, p),
                        param_specs(cfg, mode, mesh))


# ---------------------------------------------------------------------------
# cache / activation specs
# ---------------------------------------------------------------------------

def kv_cache_spec(cfg: ModelConfig, mesh: Mesh, batch: int,
                  width: int = 0) -> P:
    """(L, B, W, K, D) cache partition: batch over data axes when it
    divides; then either sequence-sharded (context-parallel decode,
    ShardingRules.shard_kv_seq) or KV heads over 'model' when divisible,
    else head_dim."""
    sizes = mesh_axis_sizes(mesh)
    model_n = sizes.get("model", 1)
    b_axes = batch_axes(mesh)
    b_total = int(np.prod([sizes[a] for a in b_axes])) if b_axes else 1
    bspec = b_axes if (b_axes and batch % b_total == 0) else None
    if cfg.sharding.shard_kv_seq and (width == 0 or width % model_n == 0):
        return P(None, bspec, "model", None, None)
    if cfg.num_kv_heads % model_n == 0:
        kv, hd = "model", None
    elif cfg.head_dim % model_n == 0:
        kv, hd = None, "model"
    else:
        kv = hd = None
    return P(None, bspec, None, kv, hd)


def batch_spec(mesh: Mesh, batch: int, extra_dims: int = 1) -> P:
    b_axes = batch_axes(mesh)
    sizes = mesh_axis_sizes(mesh)
    b_total = int(np.prod([sizes[a] for a in b_axes])) if b_axes else 1
    bspec = b_axes if (b_axes and batch % b_total == 0) else None
    return P(bspec, *([None] * extra_dims))


def cache_shardings(cfg: ModelConfig, mesh: Mesh, batch: int):
    """Sharding tree matching each family's decode-cache structure."""
    kv = NamedSharding(mesh, kv_cache_spec(cfg, mesh, batch))
    rep = NamedSharding(mesh, P())
    bsp = lambda nd: NamedSharding(mesh, batch_spec(mesh, batch, nd))
    if cfg.family in ("dense", "vlm", "moe"):
        if cfg.sharding.kv_quant and cfg.family != "moe":
            sc = NamedSharding(mesh, P(*kv_cache_spec(cfg, mesh, batch)[:4]))
            return {"k": kv, "v": kv, "k_scale": sc, "v_scale": sc,
                    "pos": rep}
        return {"k": kv, "v": kv, "pos": rep}
    if cfg.family == "audio":
        return {"k": kv, "v": kv,
                "ck": kv, "cv": kv, "pos": rep}
    if cfg.family == "hybrid":
        # h_group (G,2,B,w) conv_group (G,2,B,cw-1,w) h_tail (T,B,w) ...
        def state(nlead, ntail):
            sizes = mesh_axis_sizes(mesh)
            b_axes = batch_axes(mesh)
            b_total = int(np.prod([sizes[a] for a in b_axes])) if b_axes else 1
            bspec = b_axes if (b_axes and batch % b_total == 0) else None
            return NamedSharding(
                mesh, P(*([None] * nlead), bspec, *([None] * ntail)))
        return {"k": kv, "v": kv,
                "h_group": state(2, 1), "conv_group": state(2, 2),
                "h_tail": state(1, 1), "conv_tail": state(1, 2),
                "pos": rep}
    if cfg.family == "ssm":
        def state(ntail):
            sizes = mesh_axis_sizes(mesh)
            b_axes = batch_axes(mesh)
            b_total = int(np.prod([sizes[a] for a in b_axes])) if b_axes else 1
            bspec = b_axes if (b_axes and batch % b_total == 0) else None
            return NamedSharding(mesh, P(None, bspec, *([None] * ntail)))
        return {"m": {"C": state(3), "n": state(2), "m": state(1),
                      "conv": state(2)},
                "s": {"c": state(2), "n": state(2), "m": state(2),
                      "h": state(2)},
                "pos": rep}
    raise ValueError(cfg.family)
