"""Unified model API — dispatch by config family.

    schema(cfg)                      param schema tree (ParamSpec leaves)
    init_params(cfg, rng)            materialized params
    abstract_params(cfg)             ShapeDtypeStructs for dry-run lowering
    forward_train(params, cfg, batch)-> (hidden, aux_loss)
    prefill(params, cfg, ...)        -> (last logits, cache)
    decode_step(params, cfg, ...)    -> (logits, cache)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common, dense, encdec, hybrid, moe, xlstm
from repro.models.common import cross_entropy, lm_logits

_FAMILY_MOD = {
    "dense": dense,
    "vlm": dense,
    "moe": moe,
    "hybrid": hybrid,
    "ssm": xlstm,
    "audio": encdec,
}


def module_for(cfg: ModelConfig):
    return _FAMILY_MOD[cfg.family]


def schema(cfg: ModelConfig) -> Dict:
    return module_for(cfg).schema(cfg)


def param_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def init_params(cfg: ModelConfig, rng: jax.Array) -> Dict:
    return common.materialize(schema(cfg), rng, param_dtype(cfg))


def abstract_params(cfg: ModelConfig) -> Dict:
    return common.abstract_params(schema(cfg), param_dtype(cfg))


def forward_train(params: Dict, cfg: ModelConfig, tokens: jax.Array,
                  **extras) -> Tuple[jax.Array, jax.Array]:
    """Returns (final hidden states, aux loss)."""
    mod = module_for(cfg)
    out = mod.forward_train(params, cfg, tokens, **extras)
    if isinstance(out, tuple):
        return out
    return out, jnp.float32(0.0)


def prefill(params: Dict, cfg: ModelConfig, tokens: jax.Array, max_len: int,
            **extras) -> Tuple[jax.Array, Any]:
    return module_for(cfg).prefill(params, cfg, tokens, max_len, **extras)


def decode_step(params: Dict, cfg: ModelConfig, token: jax.Array, cache: Any,
                **extras) -> Tuple[jax.Array, Any]:
    return module_for(cfg).decode_step(params, cfg, token, cache, **extras)


def extra_train_inputs(cfg: ModelConfig, batch: int, seq: int,
                       abstract: bool = False, rng: Optional[jax.Array] = None):
    """Modality-frontend stub inputs (the allowed carve-out): whisper frame
    embeddings / VLM patch embeddings + M-RoPE position ids."""
    dt = param_dtype(cfg)
    out: Dict[str, Any] = {}

    def make(shape, dtype):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype) if dtype != jnp.int32 else \
            jnp.zeros(shape, jnp.int32)

    if cfg.family == "audio":
        out["frames"] = make((batch, cfg.num_source_positions, cfg.d_model), dt)
    if cfg.family == "vlm":
        out["image_embeds"] = make((batch, cfg.num_image_tokens, cfg.d_model), dt)
        out["mrope_positions"] = make((3, batch, seq), jnp.int32)
    return out
