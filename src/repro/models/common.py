"""Shared model components: param schemas, norms, RoPE/M-RoPE, GQA attention
(full / sliding-window / cross), SwiGLU FFN, embeddings.

All models are functional: params are nested dicts of arrays, layers are
stacked on a leading axis and iterated with ``lax.scan`` so the HLO stays
small and compile times stay tractable for the 512-device dry-run.

Param schemas double as the sharding source of truth: ``init`` builds the
arrays, ``specs`` builds the matching ``PartitionSpec`` tree from the same
schema, so the two can never drift.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# Param schema machinery
# ---------------------------------------------------------------------------

# Logical axis names used in schemas.  The launch layer maps these to mesh
# axes via ShardingRules (see repro/launch/sharding.py).
#   'layers'  — scan-stacking axis, never sharded
#   'embed'   — d_model
#   'vocab'   — vocabulary
#   'heads'   — flattened q heads
#   'kv'      — kv heads
#   'ffn'     — FFN hidden
#   'experts' — MoE expert axis
#   None      — replicated dim


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def materialize(schema: Dict[str, Any], rng: jax.Array, dtype: jnp.dtype):
    """Instantiate a schema tree into a param tree of arrays."""
    leaves, treedef = jax.tree.flatten(
        schema, is_leaf=lambda x: isinstance(x, ParamSpec))
    rngs = jax.random.split(rng, len(leaves))
    out = []
    for spec, key in zip(leaves, rngs):
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, dtype)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, dtype)
        else:
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            std = spec.scale / (fan_in ** 0.5)
            arr = (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def abstract_params(schema: Dict[str, Any], dtype: jnp.dtype):
    """ShapeDtypeStruct tree for dry-run lowering (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        schema, is_leaf=lambda x: isinstance(x, ParamSpec))


def schema_axes(schema: Dict[str, Any]):
    """Tree of logical-axes tuples mirroring the schema."""
    return jax.tree.map(lambda s: s.axes, schema,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# Sharding-constraint context
# ---------------------------------------------------------------------------
# The launch layer announces the mesh axes it lowers under; model code then
# pins activation shardings at propagation-fragile points (loss boundary,
# logits).  Empty axes (smoke tests, single-device engine) -> no-op.

_MESH_AXES: Dict[str, int] = {}


def set_mesh_axes(axes, sizes=None) -> None:
    """axes: mesh axis names; sizes: matching sizes (or a Mesh)."""
    global _MESH_AXES
    if hasattr(axes, "axis_names"):          # a Mesh
        _MESH_AXES = dict(zip(axes.axis_names, axes.devices.shape))
    elif sizes is not None:
        _MESH_AXES = dict(zip(axes, sizes))
    else:
        _MESH_AXES = {a: 0 for a in axes}    # sizes unknown: no div checks
    if not axes:
        _MESH_AXES = {}


def _fits(dim: int, axes) -> bool:
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= _MESH_AXES.get(a, 1) or 1
    return n > 0 and dim % n == 0


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Pin a sharding: 'batch' -> ('pod','data') axes present, 'model' ->
    model axis, None -> replicated dim.  Skips axes that don't divide."""
    if not _MESH_AXES:
        return x
    spec = []
    for dim, l in zip(x.shape, logical):
        if l == "batch":
            axes = tuple(a for a in ("pod", "data") if a in _MESH_AXES)
            spec.append(axes if axes and _fits(dim, axes) else None)
        elif l == "tp":
            axes = model_axes()
            spec.append(axes if axes and _fits(dim, axes) else None)
        elif l is not None and l in _MESH_AXES and _fits(dim, l):
            spec.append(l)
        else:
            spec.append(None)
    return lax.with_sharding_constraint(x, P(*spec))


def model_axes() -> Tuple[str, ...]:
    """The tensor-parallel axes: ('expert', 'model') on the 3-axis
    perf-iteration mesh (attention/FFN TP spans both; MoE splits them),
    ('model',) otherwise."""
    return tuple(a for a in ("expert", "model") if a in _MESH_AXES)


def tp_size() -> int:
    n = 1
    for a in model_axes():
        n *= _MESH_AXES.get(a, 1) or 1
    return n


def axis_size(name: str) -> int:
    return _MESH_AXES.get(name, 1) or 1


def constrain_spec(x: jax.Array, spec: P) -> jax.Array:
    """Raw with_sharding_constraint guarded by the mesh context."""
    if not _MESH_AXES:
        return x
    return lax.with_sharding_constraint(x, spec)


def seq_shard(x: jax.Array) -> jax.Array:
    """Megatron-style sequence parallelism for the residual stream:
    (B, S, d) -> batch on data axes, S on 'model'.  Remat-saved layer
    boundaries shrink by the model-axis size; GSPMD inserts the
    all-gather / reduce-scatter pairs around attention and FFN."""
    if x.ndim != 3 or x.shape[1] <= 1:
        return x
    return constrain(x, "batch", "tp", None)


def kv_shard(k: jax.Array) -> jax.Array:
    """Pin a (B, S, K, D) KV tensor to the decode-cache layout (KV heads on
    'model' when divisible, else head_dim) so the prefill write-out lands
    sharded instead of being assembled replicated and resharded."""
    if not _MESH_AXES or k.ndim != 4:
        return k
    B, S, K, D = k.shape
    n = tp_size()
    if n > 1 and K % n == 0:
        return constrain(k, "batch", None, "tp", None)
    if n > 1 and D % n == 0:
        return constrain(k, "batch", None, None, "tp")
    return constrain(k, "batch", None, None, None)


# ---------------------------------------------------------------------------
# KV-cache quantization (int8 per-token-per-head scales)
# ---------------------------------------------------------------------------

def kv_quantize(k: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(..., D) bf16/f32 -> (int8 values, f32 scale over the last dim).

    Per-(token, head) absmax scaling: the decode memory term is dominated
    by streaming the cache, so int8 storage halves it vs bf16; dequant is
    elementwise and fuses into the attention kernel on TPU."""
    scale = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.round(k.astype(jnp.float32) / scale[..., None])
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def kv_dequantize(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


# ---------------------------------------------------------------------------
# Basic layers
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * lax.rsqrt(var + eps)).astype(dt) * gamma


def layer_norm(x, gamma, beta, eps):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * lax.rsqrt(var + eps)).astype(dt) * gamma + beta


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU FFN: down( silu(x@gate) * (x@up) )."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


# ---------------------------------------------------------------------------
# RoPE (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                      # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                         # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: Tuple[int, int, int]) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, D); positions: (3, B, S) = (temporal, height, width) ids.
    Frequency slots are partitioned into ``sections`` (t, h, w); each section
    rotates by its own position component.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)                       # (half,)
    # (3, B, S, half)
    angles = positions[..., None].astype(jnp.float32) * freqs
    sec_id = jnp.concatenate([
        jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)])
    # pick the per-slot component: (B, S, half)
    angles = jnp.take_along_axis(
        jnp.moveaxis(angles, 0, -1),                             # (B,S,half,3)
        sec_id[None, None, :, None], axis=-1)[..., 0]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

NEG_INF = -1e30

# Above this many logits per (batch*head) the plain einsum path would
# materialize an infeasible S x S tensor; switch to the blockwise
# (flash-style) scan.  4096^2 keeps train_4k-sized plain paths for tests.
BLOCKWISE_THRESHOLD = 2048 * 2048
BLOCK_Q = 512
BLOCK_K = 1024


def repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, S, K, D) -> (B, S, K*groups, D) for GQA."""
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def _plain_attention(q5, k, v, causal, window, q_offset):
    """Grouped-GQA einsum attention (no KV head expansion).

    q5: (B, Sq, K, G, D); k, v: (B, Sk, K, D)."""
    B, Sq, K, G, D = q5.shape
    Sk = k.shape[1]
    scale = D ** -0.5
    s = jnp.einsum("bqkgd,bskd->bkgqs", q5, k).astype(jnp.float32) * scale
    if causal:
        qpos = jnp.arange(Sq) + q_offset
        kpos = jnp.arange(Sk)
        m = kpos[None, :] <= qpos[:, None]
        if window:
            m &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(m, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q5.dtype)
    return jnp.einsum("bkgqs,bskd->bqkgd", p, v)


def _blockwise_attention(q5, k, v, causal, window, q_offset,
                         bq=BLOCK_Q, bk=BLOCK_K, q_shard=False):
    """Flash-style two-level blocked attention (scan over q and kv chunks);
    O(bq*bk) logits transient instead of O(Sq*Sk).  Differentiable.

    q_shard=True (ShardingRules.blockwise_q_shard): shard each q block's
    row dim on the model axis and keep the K/V chunks model-replicated, so
    all per-block math is local — no partial-logit all-reduces when the
    head count doesn't divide the mesh axis."""
    B, Sq, K, G, D = q5.shape
    Sk = k.shape[1]
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    pq, pk = (-Sq) % bq, (-Sk) % bk
    if pq:
        q5 = jnp.pad(q5, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = q5.shape[1] // bq, k.shape[1] // bk
    qc = jnp.moveaxis(q5.reshape(B, nq, bq, K, G, D), 1, 0)
    kc = jnp.moveaxis(k.reshape(B, nk, bk, K, D), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nk, bk, K, D), 1, 0)
    scale = D ** -0.5

    n_model = tp_size() if _MESH_AXES else 1
    do_qshard = q_shard and n_model > 1 and bq % n_model == 0
    if do_qshard:
        # replicate K/V across the model axis ONCE (outside both scans);
        # constraining inside the kv loop would re-gather every block
        kc = constrain(kc, None, "batch", None, None, None)
        vc = constrain(vc, None, "batch", None, None, None)
        qc = constrain(qc, None, "batch", "tp", None, None, None)

    def q_step(_, qi):
        qblk, i = qi                                      # (B,bq,K,G,D)
        qpos = i * bq + jnp.arange(bq) + q_offset
        if do_qshard:
            qblk = constrain(qblk, "batch", "tp", None, None, None)

        @jax.checkpoint
        def kv_step(carry, kj):
            kblk, vblk, j = kj

            def compute(carry):
                m_run, l_run, acc = carry
                kpos = j * bk + jnp.arange(bk)
                s = jnp.einsum("bqkgd,bskd->bkgqs", qblk,
                               kblk).astype(jnp.float32) * scale
                if do_qshard:
                    s = constrain(s, "batch", None, None, "tp", None)
                msk = kpos[None, :] < Sk
                if causal:
                    msk &= kpos[None, :] <= qpos[:, None]
                    if window:
                        msk &= kpos[None, :] > qpos[:, None] - window
                s = jnp.where(msk, s, NEG_INF)
                m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
                p = jnp.where(msk, jnp.exp(s - m_new[..., None]), 0.0)
                alpha = jnp.exp(m_run - m_new)
                l_new = l_run * alpha + jnp.sum(p, axis=-1)
                upd = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(qblk.dtype),
                                 vblk).astype(jnp.float32)
                acc = acc * alpha[..., None] + upd
                return (m_new, l_new, acc)

            if causal:
                # triangular skip: blocks entirely above the causal diagonal
                # (and entirely left of the window) do no work at runtime
                needed = j * bk <= i * bq + (bq - 1) + q_offset
                if window:
                    needed &= (j + 1) * bk - 1 > i * bq + q_offset - window
                carry = lax.cond(needed, compute, lambda c: c, carry)
            else:
                carry = compute(carry)
            return carry, None

        init = (jnp.full((B, K, G, bq), NEG_INF, jnp.float32),
                jnp.zeros((B, K, G, bq), jnp.float32),
                jnp.zeros((B, K, G, bq, D), jnp.float32))
        (m_f, l_f, acc), _ = lax.scan(
            kv_step, init, (kc, vc, jnp.arange(nk)))
        out = acc / jnp.maximum(l_f, 1e-20)[..., None]
        return None, jnp.moveaxis(out, 3, 1).astype(qblk.dtype)  # (B,bq,K,G,D)

    # checkpoint both scan levels: residuals stay O(block) instead of
    # O(Sq*Sk) during the backward pass (flash-attention remat semantics)
    q_step = jax.checkpoint(q_step)
    _, chunks = lax.scan(q_step, None, (qc, jnp.arange(nq)))
    out = jnp.moveaxis(chunks, 0, 1).reshape(B, nq * bq, K, G, D)
    if pq:
        out = out[:, :Sq]
    return out


def attention(q: jax.Array, k: jax.Array, v: jax.Array,
              mask: Optional[jax.Array], *, causal: bool,
              window: int = 0, q_offset: int = 0,
              q_shard: bool = False) -> jax.Array:
    """Softmax attention with GQA grouping.

    q: (B, Sq, H, D); k, v: (B, Sk, K, D) with H % K == 0.
    mask: optional (Sq, Sk)-broadcastable bool mask (plain path only).
    window: if >0, sliding-window causal attention of that width.
    q_offset: absolute position of q[0] relative to k[0].

    Dispatches to a flash-style blockwise scan when Sq*Sk is too large to
    materialize (prefill_32k/train paths on the production mesh).
    """
    B, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    q5 = q.reshape(B, Sq, K, H // K, D)
    if mask is None and Sq * Sk > BLOCKWISE_THRESHOLD:
        out = _blockwise_attention(q5, k, v, causal, window, q_offset,
                                   q_shard=q_shard)
        return out.reshape(B, Sq, H, D)
    if mask is not None:
        s = jnp.einsum("bqkgd,bskd->bkgqs", q5, k).astype(jnp.float32) \
            * (D ** -0.5)
        if causal:
            qpos = jnp.arange(Sq) + q_offset
            m = jnp.arange(Sk)[None, :] <= qpos[:, None]
            s = jnp.where(m, s, NEG_INF)
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
        return out.reshape(B, Sq, H, D)
    out = _plain_attention(q5, k, v, causal, window, q_offset)
    return out.reshape(B, Sq, H, D)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     valid_len: jax.Array, pin: bool = False,
                     seq_shard: bool = False) -> jax.Array:
    """Single-token decode attention against a (ring-buffer) cache.

    q: (B, 1, H, D); k_cache/v_cache: (B, W, K, D); valid_len: () or (B,)
    count of valid cache slots.  Grouped einsum — the KV cache is never
    expanded across query heads.

    pin=True (ShardingRules.decode_attn_pin) aligns q's (K, D) sharding
    with the cache layout so the contraction runs on the resident shards
    (partial logits + a small all-reduce) instead of GSPMD involuntarily
    rematerializing the whole cache every step.
    """
    B, W, K, D = k_cache.shape
    H = q.shape[2]
    q5 = q.reshape(B, 1, K, H // K, D)
    n = tp_size() if _MESH_AXES else 1
    if seq_shard and n > 1 and W % n == 0:
        # context-parallel decode: cache sharded on the sequence dim, q
        # replicated across the TP axes; softmax/out reductions over the
        # sharded axis cross the ICI as REDUCED tensors only (flash-decode
        # split-K combine semantics, cf. kernels/decode_attention.py)
        q5 = constrain(q5, "batch", None, None, None, None)
        k_cache = constrain(k_cache, "batch", "tp", None, None)
        v_cache = constrain(v_cache, "batch", "tp", None, None)
    elif pin and n > 1:
        if K % n == 0:
            q5 = constrain(q5, "batch", None, "tp", None, None)
        elif D % n == 0:
            q5 = constrain(q5, "batch", None, None, None, "tp")
    s = jnp.einsum("bqkgd,bskd->bkgqs", q5,
                   k_cache).astype(jnp.float32) * (D ** -0.5)
    if seq_shard and n > 1 and W % n == 0:
        s = constrain(s, "batch", None, None, None, "tp")
    elif pin and n > 1:
        kax = "tp" if K % n == 0 else None
        s = constrain(s, "batch", kax, None, None, None)
    valid = jnp.arange(W)[None] < jnp.reshape(valid_len, (-1, 1))   # (B, W)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache)
    return out.reshape(B, 1, H, D)


def cache_update(kc: jax.Array, vc: jax.Array, k: jax.Array, v: jax.Array,
                 pos: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Write one token into a ring-buffer cache.

    kc/vc: (B, W, K, D); k/v: (B, 1, K, D); pos: () uniform or (B,) per-row
    absolute positions (continuous batching serves slots at different
    depths).  Slot = pos % W.
    """
    W = kc.shape[1]
    slot = pos % W
    if pos.ndim == 0:
        kc = lax.dynamic_update_slice(kc, k, (0, slot, 0, 0))
        vc = lax.dynamic_update_slice(vc, v, (0, slot, 0, 0))
    else:
        rows = jnp.arange(kc.shape[0])
        kc = kc.at[rows, slot].set(k[:, 0])
        vc = vc.at[rows, slot].set(v[:, 0])
    return kc, vc


def decode_pos_vec(pos: jax.Array, batch: int) -> jax.Array:
    """(B, 1) position matrix from scalar or per-row pos."""
    return jnp.broadcast_to(jnp.reshape(pos, (-1, 1)), (batch, 1)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Attention block parameter schema (shared by all transformer families)
# ---------------------------------------------------------------------------

def attn_schema(cfg: ModelConfig, layers: int, cross: bool = False) -> Dict[str, ParamSpec]:
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    L = layers
    sch: Dict[str, ParamSpec] = {
        "wq": ParamSpec((L, d, hq * hd), ("layers", "embed", "heads")),
        "wk": ParamSpec((L, d, hkv * hd), ("layers", "embed", "kv")),
        "wv": ParamSpec((L, d, hkv * hd), ("layers", "embed", "kv")),
        "wo": ParamSpec((L, hq * hd, d), ("layers", "heads", "embed")),
    }
    if cfg.qkv_bias:
        sch["bq"] = ParamSpec((L, hq * hd), ("layers", "heads"), init="zeros")
        sch["bk"] = ParamSpec((L, hkv * hd), ("layers", "kv"), init="zeros")
        sch["bv"] = ParamSpec((L, hkv * hd), ("layers", "kv"), init="zeros")
    if cfg.qk_norm:
        sch["q_norm"] = ParamSpec((L, hd), ("layers", None), init="ones")
        sch["k_norm"] = ParamSpec((L, hd), ("layers", None), init="ones")
    return sch


def qkv_project(p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig,
                positions: jax.Array, *, rope: bool = True,
                mrope_positions: Optional[jax.Array] = None):
    """x: (B, S, d) -> q (B,S,H,D), k/v (B,S,K,D), RoPE applied."""
    B, S, _ = x.shape
    H, K, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    k = jnp.einsum("bsd,de->bse", x, p["wk"])
    v = jnp.einsum("bsd,de->bse", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, D)
    k = k.reshape(B, S, K, D)
    v = v.reshape(B, S, K, D)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        if cfg.mrope and mrope_positions is not None:
            q = apply_mrope(q, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def ffn_schema(cfg: ModelConfig, layers: int) -> Dict[str, ParamSpec]:
    d, f, L = cfg.d_model, cfg.d_ff, layers
    return {
        "w_gate": ParamSpec((L, d, f), ("layers", "embed", "ffn")),
        "w_up": ParamSpec((L, d, f), ("layers", "embed", "ffn")),
        "w_down": ParamSpec((L, f, d), ("layers", "ffn", "embed")),
    }


def norm_schema(layers: int, d: int, name_count: int = 2) -> Dict[str, ParamSpec]:
    return {f"norm{i}": ParamSpec((layers, d), ("layers", None), init="ones")
            for i in range(name_count)}


def embed_schema(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    sch = {
        "tok_embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                               scale=1.0),
        "final_norm": ParamSpec((cfg.d_model,), (None,), init="ones"),
    }
    if not cfg.tie_embeddings:
        sch["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return sch


def lm_logits(params: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = constrain(x, "batch", None, None)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = params["lm_head"] if not cfg.tie_embeddings else params["tok_embed"].T
    out = jnp.einsum("bsd,dv->bsv", x, w)
    return constrain(out, "batch", None, "tp")


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy, fp32 accumulation.

    The gold logit is extracted with an iota-compare masked sum rather than
    take_along_axis: under a vocab-sharded LM head, gather-by-label forces
    GSPMD to replicate the full logits; the masked sum stays a per-shard
    fused reduce + tiny all-reduce."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0),
                   axis=-1)
    return jnp.mean(logz - gold)


def chunked_loss(params, x, labels, cfg: ModelConfig, chunk: int) -> jax.Array:
    """Cross-entropy computed in vocab-preserving sequence chunks to bound the
    (B, S, vocab) logits transient (hillclimb knob: ShardingRules.loss_chunk)."""
    B, S, _ = x.shape
    n = max(1, S // chunk)
    xs = x.reshape(B, n, S // n, -1)
    ls = labels.reshape(B, n, S // n)

    def body(c, inp):
        xc, lc = inp
        logits = lm_logits(params, xc, cfg)
        return c + cross_entropy(logits, lc) * (1.0 / n), None

    total, _ = lax.scan(body, jnp.float32(0.0),
                        (jnp.moveaxis(xs, 1, 0), jnp.moveaxis(ls, 1, 0)))
    return total
