"""Griffin/RecurrentGemma hybrid: RG-LRU recurrent blocks + local-attention
(MQA) blocks in a (rec, rec, attn) repeating pattern [arXiv:2402.19427].

Temporal mixing alternates; every layer is followed by a GeGLU MLP.  The
RG-LRU is a gated linear recurrence — training/prefill use
``lax.associative_scan`` over the sequence (log-depth, sub-quadratic),
decode is an O(1) state update, which is why this arch runs ``long_500k``.

Layers are scanned in groups of three (rec, rec, attn); the <=2 remainder
layers (always rec) are unrolled.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import common as cm

C_RGLRU = 8.0  # recurrence sharpness constant from the Griffin paper


def n_groups(cfg: ModelConfig) -> int:
    return cfg.num_layers // 3


def n_tail(cfg: ModelConfig) -> int:
    return cfg.num_layers - 3 * n_groups(cfg)


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------

def _rec_schema(cfg: ModelConfig, lead: Tuple[int, ...]) -> Dict:
    d, w = cfg.d_model, cfg.lru_width
    la = tuple("layers" for _ in lead)
    sch = {
        "w_in": cm.ParamSpec(lead + (d, w), la + ("embed", "ffn")),
        "w_gate_branch": cm.ParamSpec(lead + (d, w), la + ("embed", "ffn")),
        "conv_w": cm.ParamSpec(lead + (cfg.conv_width, w), la + (None, "ffn")),
        "conv_b": cm.ParamSpec(lead + (w,), la + ("ffn",), init="zeros"),
        "w_a": cm.ParamSpec(lead + (w, w), la + ("ffn", None)),
        "b_a": cm.ParamSpec(lead + (w,), la + (None,), init="zeros"),
        "w_x": cm.ParamSpec(lead + (w, w), la + ("ffn", None)),
        "b_x": cm.ParamSpec(lead + (w,), la + (None,), init="zeros"),
        "lambda_p": cm.ParamSpec(lead + (w,), la + (None,), init="ones"),
        "w_out": cm.ParamSpec(lead + (w, d), la + ("ffn", "embed")),
    }
    return sch


def _mlp_schema(cfg: ModelConfig, lead: Tuple[int, ...]) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    la = tuple("layers" for _ in lead)
    return {
        "w_gate": cm.ParamSpec(lead + (d, f), la + ("embed", "ffn")),
        "w_up": cm.ParamSpec(lead + (d, f), la + ("embed", "ffn")),
        "w_down": cm.ParamSpec(lead + (f, d), la + ("ffn", "embed")),
        "norm0": cm.ParamSpec(lead + (d,), la + (None,), init="ones"),
        "norm1": cm.ParamSpec(lead + (d,), la + (None,), init="ones"),
    }


def schema(cfg: ModelConfig) -> Dict:
    G, T = n_groups(cfg), n_tail(cfg)
    sch = {"embed": cm.embed_schema(cfg)}
    if G:
        sch["rec_groups"] = {**_rec_schema(cfg, (G, 2)), **_mlp_schema(cfg, (G, 2))}
        attn = cm.attn_schema(cfg, G)
        attn.update(_mlp_schema(cfg, (G,)))
        sch["attn_groups"] = attn
    if T:
        sch["rec_tail"] = {**_rec_schema(cfg, (T,)), **_mlp_schema(cfg, (T,))}
    return sch


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def _gates(lp: Dict, x: jax.Array):
    """x: (..., W).  Returns (log_a, gated_input)."""
    r = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", x, lp["w_a"]) + lp["b_a"])
    i = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", x, lp["w_x"]) + lp["b_x"])
    log_a = -C_RGLRU * jax.nn.softplus(lp["lambda_p"]) * r
    a = jnp.exp(log_a.astype(jnp.float32))
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-6)) * (i * x).astype(jnp.float32)
    return a, b


def rglru_seq(lp: Dict, x: jax.Array, h0: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence RG-LRU via associative scan.  x: (B, S, W)."""
    a, b = _gates(lp, x)

    def combine(p, q):
        a1, b1 = p
        a2, b2 = q
        return a1 * a2, a2 * b1 + b2

    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))
    _, h = lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_step(lp: Dict, x: jax.Array, h: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One-token RG-LRU.  x: (B, 1, W); h: (B, W) fp32 state."""
    a, b = _gates(lp, x)
    h_new = a[:, 0] * h + b[:, 0]
    return h_new.astype(x.dtype)[:, None], h_new


def causal_conv_seq(lp: Dict, x: jax.Array) -> jax.Array:
    """Depthwise causal conv over sequence.  x: (B, S, W)."""
    cw = lp["conv_w"].shape[0]
    pad = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1]] * lp["conv_w"][i] for i in range(cw))
    return out + lp["conv_b"]


def causal_conv_step(lp: Dict, x: jax.Array, state: jax.Array):
    """x: (B, 1, W); state: (B, cw-1, W) last inputs. Returns (y, new_state)."""
    cw = lp["conv_w"].shape[0]
    window = jnp.concatenate([state, x], axis=1)              # (B, cw, W)
    y = jnp.einsum("bcw,cw->bw", window, lp["conv_w"]) + lp["conv_b"]
    return y[:, None], window[:, 1:]


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _rec_block_seq(cfg, lp, x, h0=None):
    """Recurrent temporal block + MLP (full sequence).

    Returns (x, last LRU state, last (conv_width-1) pre-conv inputs) so a
    prefill can hand an *exact* state to the step path.
    """
    B, S, _ = x.shape
    cw = cfg.conv_width
    h = cm.rms_norm(x, lp["norm0"], cfg.norm_eps)
    pre_conv = jnp.einsum("bsd,dw->bsw", h, lp["w_in"])
    if S >= cw - 1:
        conv_state = pre_conv[:, S - (cw - 1):]
    else:
        conv_state = jnp.pad(pre_conv, ((0, 0), (cw - 1 - S, 0), (0, 0)))
    main = causal_conv_seq(lp, pre_conv)
    main, h_last = rglru_seq(lp, main, h0)
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", h, lp["w_gate_branch"]))
    x = x + jnp.einsum("bsw,wd->bsd", main * gate, lp["w_out"])
    h2 = cm.rms_norm(x, lp["norm1"], cfg.norm_eps)
    x = x + cm.swiglu(h2, lp["w_gate"], lp["w_up"], lp["w_down"])
    return x, h_last, conv_state


def _rec_block_step(cfg, lp, x, lru_state, conv_state):
    B = x.shape[0]
    h = cm.rms_norm(x, lp["norm0"], cfg.norm_eps)
    main = jnp.einsum("bsd,dw->bsw", h, lp["w_in"])
    main, conv_state = causal_conv_step(lp, main, conv_state)
    main, lru_state = rglru_step(lp, main, lru_state)
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", h, lp["w_gate_branch"]))
    x = x + jnp.einsum("bsw,wd->bsd", main * gate, lp["w_out"])
    h2 = cm.rms_norm(x, lp["norm1"], cfg.norm_eps)
    x = x + cm.swiglu(h2, lp["w_gate"], lp["w_up"], lp["w_down"])
    return x, lru_state, conv_state


def _attn_block_seq(cfg, lp, x, positions):
    B, S, _ = x.shape
    h = cm.rms_norm(x, lp["norm0"], cfg.norm_eps)
    q, k, v = cm.qkv_project(lp, h, cfg, positions)
    attn = cm.attention(q, k, v, None, causal=True, window=cfg.local_window,
                        q_shard=cfg.sharding.blockwise_q_shard)
    x = x + jnp.einsum("bse,ed->bsd", attn.reshape(B, S, -1), lp["wo"])
    h2 = cm.rms_norm(x, lp["norm1"], cfg.norm_eps)
    x = x + cm.swiglu(h2, lp["w_gate"], lp["w_up"], lp["w_down"])
    return x, k, v


def _attn_block_step(cfg, lp, x, kc, vc, positions, pos, valid_len):
    B = x.shape[0]
    h = cm.rms_norm(x, lp["norm0"], cfg.norm_eps)
    q, k, v = cm.qkv_project(lp, h, cfg, positions)
    kc, vc = cm.cache_update(kc, vc, k, v, pos)
    attn = cm.decode_attention(q, kc, vc, valid_len,
                               pin=cfg.sharding.decode_attn_pin,
                                   seq_shard=cfg.sharding.shard_kv_seq)
    x = x + jnp.einsum("bse,ed->bsd", attn.reshape(B, 1, -1), lp["wo"])
    h2 = cm.rms_norm(x, lp["norm1"], cfg.norm_eps)
    x = x + cm.swiglu(h2, lp["w_gate"], lp["w_up"], lp["w_down"])
    return x, kc, vc


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def _seq_forward(params, cfg, x, positions, remat, collect_cache, max_len):
    """Shared full-sequence pass; optionally returns cache for decode."""
    G, T = n_groups(cfg), n_tail(cfg)
    W = min(max_len, cfg.local_window) if max_len else cfg.local_window
    S = x.shape[1]

    def group_body(carry, gp):
        y = carry
        rec_p, attn_p = gp
        h_lasts, c_states = [], []
        for j in range(2):
            lp = jax.tree.map(lambda a: a[j], rec_p)
            y, h_last, c_state = _rec_block_seq(cfg, lp, y)
            h_lasts.append(h_last)
            c_states.append(c_state)
        y, k, v = _attn_block_seq(cfg, attn_p, y, positions)
        return cm.seq_shard(y), (jnp.stack(h_lasts), jnp.stack(c_states),
                                 cm.kv_shard(k), cm.kv_shard(v))

    if remat == "full":
        group_body = jax.checkpoint(group_body)

    hs_g = cs_g = k_g = v_g = None
    if G:
        x, (hs_g, cs_g, k_g, v_g) = lax.scan(
            group_body, x, (params["rec_groups"], params["attn_groups"]))
    hs_t, cs_t = [], []
    for t in range(T):
        lp = jax.tree.map(lambda a: a[t], params["rec_tail"])
        x, h_last, c_state = _rec_block_seq(cfg, lp, x)
        hs_t.append(h_last)
        cs_t.append(c_state)

    cache = None
    if collect_cache:
        if k_g is not None and W >= S:
            pad = W - S
            k_g = jnp.pad(k_g, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            v_g = jnp.pad(v_g, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        elif k_g is not None:
            k_g = jnp.roll(k_g[:, :, S - W:], shift=S % W, axis=2)
            v_g = jnp.roll(v_g[:, :, S - W:], shift=S % W, axis=2)
        B = x.shape[0]
        cw, w = cfg.conv_width, cfg.lru_width
        cache = {
            "k": k_g, "v": v_g,
            "h_group": (hs_g.astype(jnp.float32) if hs_g is not None
                        else jnp.zeros((0, 2, B, w), jnp.float32)),
            "conv_group": (cs_g if cs_g is not None
                           else jnp.zeros((0, 2, B, cw - 1, w), x.dtype)),
            "h_tail": (jnp.stack(hs_t).astype(jnp.float32) if hs_t
                       else jnp.zeros((0, B, w), jnp.float32)),
            "conv_tail": (jnp.stack(cs_t) if cs_t
                          else jnp.zeros((0, B, cw - 1, w), x.dtype)),
            "pos": jnp.int32(S),
        }
    return x, cache


def forward_train(params: Dict, cfg: ModelConfig, tokens: jax.Array, **_):
    B, S = tokens.shape
    x = jnp.take(params["embed"]["tok_embed"], tokens, axis=0)
    positions = jnp.arange(S)[None, :]
    x, _ = _seq_forward(params, cfg, x, positions, cfg.sharding.remat,
                        False, 0)
    return x


def init_conv_states(cfg: ModelConfig, batch: int, dtype) -> Dict:
    G, T = n_groups(cfg), n_tail(cfg)
    cw, w = cfg.conv_width, cfg.lru_width
    return {
        "conv_group": jnp.zeros((max(G, 0), 2, batch, cw - 1, w), dtype),
        "conv_tail": jnp.zeros((T, batch, cw - 1, w), dtype),
    }


def prefill(params: Dict, cfg: ModelConfig, tokens: jax.Array, max_len: int, **_):
    B, S = tokens.shape
    x = jnp.take(params["embed"]["tok_embed"], tokens, axis=0)
    positions = jnp.arange(S)[None, :]
    x, cache = _seq_forward(params, cfg, x, positions, "none", True, max_len)
    logits = cm.lm_logits(params["embed"], x[:, -1:], cfg)
    return logits, cache


def decode_step(params: Dict, cfg: ModelConfig, token: jax.Array, cache: Dict, **_):
    B = token.shape[0]
    G, T = n_groups(cfg), n_tail(cfg)
    pos = cache["pos"]
    x = jnp.take(params["embed"]["tok_embed"], token, axis=0)
    positions = cm.decode_pos_vec(pos, B)

    if G:
        W = cache["k"].shape[2]
        valid_len = jnp.minimum(pos + 1, W)

        def group_body(carry, inp):
            y = carry
            rec_p, attn_p, hg, cg, kc, vc = inp
            new_h, new_c = [], []
            for j in range(2):
                lp = jax.tree.map(lambda a: a[j], rec_p)
                y, h_new, c_new = _rec_block_step(cfg, lp, y, hg[j], cg[j])
                new_h.append(h_new)
                new_c.append(c_new)
            y, kc, vc = _attn_block_step(cfg, attn_p, y, kc, vc,
                                         positions, pos, valid_len)
            return y, (jnp.stack(new_h), jnp.stack(new_c), kc, vc)

        x, (hg, cg, ks, vs) = lax.scan(
            group_body, x,
            (params["rec_groups"], params["attn_groups"],
             cache["h_group"], cache["conv_group"], cache["k"], cache["v"]))
    else:
        hg, cg, ks, vs = cache["h_group"], cache["conv_group"], cache["k"], cache["v"]

    ht, ct = [], []
    for t in range(T):
        lp = jax.tree.map(lambda a: a[t], params["rec_tail"])
        x, h_new, c_new = _rec_block_step(cfg, lp, x, cache["h_tail"][t],
                                          cache["conv_tail"][t])
        ht.append(h_new)
        ct.append(c_new)

    new_cache = {
        "k": ks, "v": vs, "h_group": hg, "conv_group": cg,
        "h_tail": jnp.stack(ht) if ht else cache["h_tail"],
        "conv_tail": jnp.stack(ct) if ct else cache["conv_tail"],
        "pos": pos + 1,
    }
    logits = cm.lm_logits(params["embed"], x, cfg)
    return logits, new_cache
