"""Dense GQA decoder family.

Covers: qwen3-14b (qk_norm), qwen2-7b (qkv bias), internlm2-1.8b,
h2o-danube-3-4b (sliding-window), qwen2-vl-2b (M-RoPE + stub patch
embeddings).  One schema + three entry points:

  ``forward_train``  full causal forward -> logits (or loss via train_step)
  ``prefill``        forward + KV-cache write-out (ring-buffer layout)
  ``decode_step``    ONE token against a cache of ``seq_len`` (ring buffer)
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import common as cm

# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------

def schema(cfg: ModelConfig) -> Dict:
    L = cfg.num_layers
    layers = {}
    layers.update(cm.attn_schema(cfg, L))
    layers.update(cm.ffn_schema(cfg, L))
    layers.update(cm.norm_schema(L, cfg.d_model, 2))
    return {"embed": cm.embed_schema(cfg), "layers": layers}


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _block(cfg: ModelConfig, x: jax.Array, lp: Dict, positions: jax.Array,
           mrope_positions: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decoder layer (full sequence).  Returns (x, k, v) for caching."""
    B, S, _ = x.shape
    h = cm.rms_norm(x, lp["norm0"], cfg.norm_eps)
    q, k, v = cm.qkv_project(lp, h, cfg, positions, mrope_positions=mrope_positions)
    attn = cm.attention(q, k, v, None, causal=True, window=cfg.sliding_window,
                        q_shard=cfg.sharding.blockwise_q_shard)
    x = x + jnp.einsum("bse,ed->bsd", attn.reshape(B, S, -1), lp["wo"])
    h = cm.rms_norm(x, lp["norm1"], cfg.norm_eps)
    x = x + cm.swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"])
    return x, k, v


def _embed_inputs(params: Dict, cfg: ModelConfig, tokens: jax.Array,
                  image_embeds: Optional[jax.Array]) -> jax.Array:
    x = jnp.take(params["embed"]["tok_embed"], tokens, axis=0)
    if cfg.family == "vlm" and image_embeds is not None:
        # Stub ViT frontend: precomputed patch embeddings occupy the first
        # num_image_tokens slots of the prompt (image-first layout).
        x = lax.dynamic_update_slice(x, image_embeds.astype(x.dtype), (0, 0, 0))
    return x


def _stack(cfg: ModelConfig, x: jax.Array, layers: Dict, positions: jax.Array,
           mrope_positions: Optional[jax.Array], remat: str,
           collect_kv: bool = False):
    """Scan the layer stack; returns (x, per-layer k, per-layer v).

    collect_kv=False (training) drops the per-layer KV outputs — stacking
    them is an O(L*B*S*K*D) buffer only prefill needs."""
    def body(carry, lp):
        y, k, v = _block(cfg, carry, lp, positions, mrope_positions)
        return cm.seq_shard(y), ((cm.kv_shard(k), cm.kv_shard(v))
                                 if collect_kv else None)

    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    x, ys = lax.scan(body, x, layers)
    if collect_kv:
        return x, ys[0], ys[1]
    return x, None, None


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def forward_train(params: Dict, cfg: ModelConfig, tokens: jax.Array,
                  image_embeds: Optional[jax.Array] = None,
                  mrope_positions: Optional[jax.Array] = None) -> jax.Array:
    """(B, S) tokens -> final hidden states (B, S, d)."""
    B, S = tokens.shape
    x = _embed_inputs(params, cfg, tokens, image_embeds)
    positions = jnp.arange(S)[None, :]
    x, _, _ = _stack(cfg, x, params["layers"], positions, mrope_positions,
                     cfg.sharding.remat)
    return x


def init_cache(cfg: ModelConfig, batch: int, width: int, dtype) -> Dict:
    """Ring-buffer KV cache: width = sliding window (SWA) or max_len."""
    L, K, D = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((L, batch, width, K, D), dtype),
        "v": jnp.zeros((L, batch, width, K, D), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def cache_width(cfg: ModelConfig, max_len: int) -> int:
    win = cfg.sliding_window
    return min(max_len, win) if win else max_len


def prefill(params: Dict, cfg: ModelConfig, tokens: jax.Array,
            max_len: int,
            image_embeds: Optional[jax.Array] = None,
            mrope_positions: Optional[jax.Array] = None) -> Tuple[jax.Array, Dict]:
    """Process the whole prompt; return (last-token logits, cache)."""
    B, S = tokens.shape
    x = _embed_inputs(params, cfg, tokens, image_embeds)
    positions = jnp.arange(S)[None, :]
    x, ks, vs = _stack(cfg, x, params["layers"], positions, mrope_positions,
                       "none", collect_kv=True)
    W = cache_width(cfg, max_len)
    if W >= S:
        pad = W - S
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        # keep last W positions, laid out ring-buffer style (slot = pos % W)
        ks = jnp.roll(ks[:, :, S - W:], shift=S % W, axis=2)
        vs = jnp.roll(vs[:, :, S - W:], shift=S % W, axis=2)
    if cfg.sharding.kv_quant:
        ks, ks_s = cm.kv_quantize(ks)
        vs, vs_s = cm.kv_quantize(vs)
        cache = {"k": ks, "v": vs, "k_scale": ks_s, "v_scale": vs_s,
                 "pos": jnp.int32(S)}
    else:
        cache = {"k": ks, "v": vs, "pos": jnp.int32(S)}
    logits = cm.lm_logits(params["embed"], x[:, -1:], cfg)
    return logits, cache


def decode_step(params: Dict, cfg: ModelConfig, token: jax.Array, cache: Dict,
                mrope_positions: Optional[jax.Array] = None) -> Tuple[jax.Array, Dict]:
    """One decode step.  token: (B, 1) int32.  Returns (logits, new cache)."""
    B = token.shape[0]
    pos = cache["pos"]
    W = cache["k"].shape[2]
    x = jnp.take(params["embed"]["tok_embed"], token, axis=0)  # (B,1,d)
    positions = cm.decode_pos_vec(pos, B)
    valid_len = jnp.minimum(pos + 1, W)

    quant = cfg.sharding.kv_quant

    def body(carry, inp):
        y = carry
        if quant:
            lp, kc, vc, kc_s, vc_s = inp
        else:
            lp, kc, vc = inp
            kc_s = vc_s = None
        h = cm.rms_norm(y, lp["norm0"], cfg.norm_eps)
        q, k, v = cm.qkv_project(lp, h, cfg, positions,
                                 mrope_positions=mrope_positions)
        if quant:
            kq, kq_s = cm.kv_quantize(k)
            vq, vq_s = cm.kv_quantize(v)
            kc, vc = cm.cache_update(kc, vc, kq, vq, pos)
            kc_s, vc_s = cm.cache_update(
                kc_s[..., None], vc_s[..., None],
                kq_s[..., None], vq_s[..., None], pos)
            kc_s, vc_s = kc_s[..., 0], vc_s[..., 0]
            k_full = cm.kv_dequantize(kc, kc_s, y.dtype)
            v_full = cm.kv_dequantize(vc, vc_s, y.dtype)
        else:
            kc, vc = cm.cache_update(kc, vc, k, v, pos)
            k_full, v_full = kc, vc
        attn = cm.decode_attention(q, k_full, v_full, valid_len,
                                   pin=cfg.sharding.decode_attn_pin,
                                   seq_shard=cfg.sharding.shard_kv_seq)
        y = y + jnp.einsum("bse,ed->bsd", attn.reshape(B, 1, -1), lp["wo"])
        h = cm.rms_norm(y, lp["norm1"], cfg.norm_eps)
        y = y + cm.swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"])
        return y, ((kc, vc, kc_s, vc_s) if quant else (kc, vc))

    if quant:
        x, (ks, vs, ks_s, vs_s) = lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"],
                      cache["k_scale"], cache["v_scale"]))
        logits = cm.lm_logits(params["embed"], x, cfg)
        return logits, {"k": ks, "v": vs, "k_scale": ks_s, "v_scale": vs_s,
                        "pos": pos + 1}
    x, (ks, vs) = lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    logits = cm.lm_logits(params["embed"], x, cfg)
    return logits, {"k": ks, "v": vs, "pos": pos + 1}
