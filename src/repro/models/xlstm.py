"""xLSTM family (xlstm-350m): alternating mLSTM / sLSTM blocks
[arXiv:2405.04517].

mLSTM: matrix-memory cell with exponential gating.  Training/prefill use the
*parallel form* (quadratic, attention-like, with the paper's log-space
stabilizer); decode uses the O(1) recurrent form.  The two are algebraically
identical — tests assert parallel == scan-of-steps.

sLSTM: scalar-memory cell with recurrent weights R (head-block-diagonal) —
inherently sequential, so both training and decode use ``lax.scan`` over
time.

State is O(1) in sequence length -> this arch runs ``long_500k``.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import common as cm


def n_pairs(cfg: ModelConfig) -> int:
    assert cfg.num_layers % 2 == 0, "xlstm stack scans (mLSTM, sLSTM) pairs"
    return cfg.num_layers // 2


def up_dim(cfg: ModelConfig) -> int:
    return int(cfg.d_model * cfg.mlstm_proj_factor)


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------

def _mlstm_schema(cfg: ModelConfig, lead) -> Dict:
    d, u, nh = cfg.d_model, up_dim(cfg), cfg.num_heads
    la = tuple("layers" for _ in lead)
    return {
        "m_norm": cm.ParamSpec(lead + (d,), la + (None,), init="ones"),
        "m_up": cm.ParamSpec(lead + (d, u), la + ("embed", "ffn")),
        "m_gate": cm.ParamSpec(lead + (d, u), la + ("embed", "ffn")),
        "m_conv_w": cm.ParamSpec(lead + (cfg.conv_width, u), la + (None, "ffn")),
        "m_conv_b": cm.ParamSpec(lead + (u,), la + ("ffn",), init="zeros"),
        "m_wq": cm.ParamSpec(lead + (u, u), la + ("ffn", None)),
        "m_wk": cm.ParamSpec(lead + (u, u), la + ("ffn", None)),
        "m_wv": cm.ParamSpec(lead + (u, u), la + ("ffn", None)),
        "m_wi": cm.ParamSpec(lead + (u, nh), la + ("ffn", None), scale=0.1),
        "m_bi": cm.ParamSpec(lead + (nh,), la + (None,), init="zeros"),
        "m_wf": cm.ParamSpec(lead + (u, nh), la + ("ffn", None), scale=0.1),
        "m_bf": cm.ParamSpec(lead + (nh,), la + (None,), init="ones"),
        "m_out_norm": cm.ParamSpec(lead + (u,), la + (None,), init="ones"),
        "m_down": cm.ParamSpec(lead + (u, d), la + ("ffn", "embed")),
    }


def _slstm_schema(cfg: ModelConfig, lead) -> Dict:
    d, nh = cfg.d_model, cfg.num_heads
    dh = d // nh
    f = int(d * cfg.slstm_proj_factor)
    la = tuple("layers" for _ in lead)
    return {
        "s_norm": cm.ParamSpec(lead + (d,), la + (None,), init="ones"),
        "s_w": cm.ParamSpec(lead + (d, 4 * d), la + ("embed", "ffn")),
        "s_r": cm.ParamSpec(lead + (nh, dh, 4 * dh), la + (None, None, None), scale=0.5),
        "s_b": cm.ParamSpec(lead + (4 * d,), la + ("ffn",), init="zeros"),
        "s_out_norm": cm.ParamSpec(lead + (d,), la + (None,), init="ones"),
        "s_ffn_norm": cm.ParamSpec(lead + (d,), la + (None,), init="ones"),
        "s_ffn_up": cm.ParamSpec(lead + (d, 2 * f), la + ("embed", "ffn")),
        "s_ffn_down": cm.ParamSpec(lead + (f, d), la + ("ffn", "embed")),
    }


def schema(cfg: ModelConfig) -> Dict:
    G = n_pairs(cfg)
    return {
        "embed": cm.embed_schema(cfg),
        "pairs": {**_mlstm_schema(cfg, (G,)), **_slstm_schema(cfg, (G,))},
    }


# ---------------------------------------------------------------------------
# mLSTM cell
# ---------------------------------------------------------------------------

def _mlstm_qkvif(cfg, lp, x):
    """x: (B, S, d) -> q,k,v (B,S,NH,dh), log-i/log-f (B,S,NH), gate (B,S,u)."""
    B, S, _ = x.shape
    nh = cfg.num_heads
    u = up_dim(cfg)
    dh = u // nh
    h = cm.rms_norm(x, lp["m_norm"], cfg.norm_eps)
    m = jnp.einsum("bsd,du->bsu", h, lp["m_up"])
    z = jnp.einsum("bsd,du->bsu", h, lp["m_gate"])
    c = jax.nn.silu(_conv(lp, m))
    q = jnp.einsum("bsu,uv->bsv", c, lp["m_wq"]).reshape(B, S, nh, dh)
    k = jnp.einsum("bsu,uv->bsv", c, lp["m_wk"]).reshape(B, S, nh, dh)
    v = jnp.einsum("bsu,uv->bsv", m, lp["m_wv"]).reshape(B, S, nh, dh)
    li = (jnp.einsum("bsu,un->bsn", c, lp["m_wi"]) + lp["m_bi"]).astype(jnp.float32)
    lf = jax.nn.log_sigmoid(
        (jnp.einsum("bsu,un->bsn", c, lp["m_wf"]) + lp["m_bf"]).astype(jnp.float32))
    return q, k, v, li, lf, z, m


def _conv(lp, x):
    cw = lp["m_conv_w"].shape[0]
    pad = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1]] * lp["m_conv_w"][i] for i in range(cw))
    return out + lp["m_conv_b"]


def _conv_step(lp, x, state):
    """x: (B,1,u); state (B,cw-1,u)."""
    window = jnp.concatenate([state, x], axis=1)
    y = jnp.einsum("bcu,cu->bu", window, lp["m_conv_w"]) + lp["m_conv_b"]
    return y[:, None], window[:, 1:]


def mlstm_parallel(q, k, v, li, lf):
    """Stabilized parallel form.  q,k,v: (B,S,NH,dh); li,lf: (B,S,NH)."""
    B, S, NH, dh = q.shape
    scale = dh ** -0.5
    Bc = jnp.cumsum(lf, axis=1)                                   # (B,S,NH)
    # logD_ij = Bc_i - Bc_j + li_j  (j <= i)
    logD = (Bc[:, :, None, :] - Bc[:, None, :, :]
            + li[:, None, :, :])                                  # (B,Sq,Sk,NH)
    tri = jnp.tril(jnp.ones((S, S), bool))
    logD = jnp.where(tri[None, :, :, None], logD, -jnp.inf)
    m = jnp.max(logD, axis=2, keepdims=True)                      # (B,S,1,NH)
    D = jnp.exp(logD - m)
    qk = jnp.einsum("bihd,bjhd->bijh", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * scale
    Sm = qk * D
    norm = jnp.maximum(jnp.abs(jnp.sum(Sm, axis=2)), jnp.exp(-m[:, :, 0]))
    h = jnp.einsum("bijh,bjhd->bihd", Sm, v.astype(jnp.float32))
    h = h / norm[..., None]
    return h.astype(q.dtype), m[:, -1, 0], Bc


def mlstm_chunkwise(q, k, v, li, lf, chunk: int = 256, return_state=False):
    """Chunkwise-parallel mLSTM: intra-chunk quadratic + inter-chunk
    recurrent (C, n, m) state — O(S*c) memory instead of O(S^2), same
    stabilized math as the parallel/recurrent forms (tests assert equality).

    q,k,v: (B,S,NH,dh); li,lf: (B,S,NH) log gates (fp32).
    """
    B, S, NH, dh = q.shape
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        li = jnp.pad(li, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        lf = jnp.pad(lf, ((0, 0), (0, pad), (0, 0)))
    n_chunks = q.shape[1] // c
    scale = dh ** -0.5

    def to_chunks(x):
        return jnp.moveaxis(x.reshape(B, n_chunks, c, *x.shape[2:]), 1, 0)

    qc, kc, vc, lic, lfc = map(to_chunks, (q, k, v, li, lf))

    def chunk_step(carry, inp):
        C_prev, n_prev, m_prev = carry                    # (B,NH,dh,dh) ...
        qb, kb, vb, lib, lfb = inp                        # (B,c,NH,*)
        b = jnp.cumsum(lfb, axis=1)                       # (B,c,NH) local
        # intra-chunk log weights: b_i - b_j + li_j   (j <= i)
        logD = (b[:, :, None, :] - b[:, None, :, :] + lib[:, None, :, :])
        tri = jnp.tril(jnp.ones((c, c), bool))
        logD = jnp.where(tri[None, :, :, None], logD, -jnp.inf)
        m_intra = jnp.max(logD, axis=2)                   # (B,c,NH)
        m_inter = b + m_prev[:, None, :]                  # (B,c,NH)
        m_i = jnp.maximum(m_intra, m_inter)
        D = jnp.exp(logD - m_i[:, :, None, :])
        qk = jnp.einsum("bihd,bjhd->bijh", qb.astype(jnp.float32),
                        kb.astype(jnp.float32)) * scale
        Sm = qk * D
        w_inter = jnp.exp(m_inter - m_i)                  # (B,c,NH)
        num_intra = jnp.einsum("bijh,bjhd->bihd", Sm, vb.astype(jnp.float32))
        num_inter = jnp.einsum("bihd,bhde->bihe", qb.astype(jnp.float32),
                               C_prev) * w_inter[..., None]
        den_intra = jnp.sum(Sm, axis=2)                   # (B,c,NH)
        den_inter = jnp.einsum("bihd,bhd->bih", qb.astype(jnp.float32),
                               n_prev) * w_inter
        den = jnp.maximum(jnp.abs(den_intra + den_inter), jnp.exp(-m_i))
        h = (num_intra + num_inter) / den[..., None]
        # ---- state update to end of chunk ----
        b_last = b[:, -1, :]                              # (B,NH)
        w_j = b_last[:, None, :] - b + lib                # (B,c,NH)
        m_new = jnp.maximum(b_last + m_prev, jnp.max(w_j, axis=1))
        ew = jnp.exp(w_j - m_new[:, None, :])
        C_new = (jnp.exp(b_last + m_prev - m_new)[..., None, None] * C_prev
                 + jnp.einsum("bjh,bjhd,bjhe->bhde", ew,
                              kb.astype(jnp.float32) * scale,
                              vb.astype(jnp.float32)))
        n_new = (jnp.exp(b_last + m_prev - m_new)[..., None] * n_prev
                 + jnp.einsum("bjh,bjhd->bhd", ew,
                              kb.astype(jnp.float32) * scale))
        return (C_new, n_new, m_new), h.astype(qb.dtype)

    init = (jnp.zeros((B, NH, dh, dh), jnp.float32),
            jnp.zeros((B, NH, dh), jnp.float32),
            jnp.full((B, NH), -1e30, jnp.float32))
    # remat per chunk: backward residuals stay O(c^2), not O(S*c)
    chunk_step = jax.checkpoint(chunk_step)
    state, hs = lax.scan(chunk_step, init, (qc, kc, vc, lic, lfc))
    hs = jnp.moveaxis(hs, 0, 1).reshape(B, n_chunks * c, NH, dh)
    hs = hs[:, :S] if pad else hs
    if return_state:
        # padding is state-exact: padded steps carry li=-1e30 (i'=0, no
        # input) and lf=0 (f=1, no decay)
        return hs, state
    return hs


def mlstm_step(q, k, v, li, lf, state):
    """Recurrent form.  q,k,v: (B,NH,dh); li,lf: (B,NH).

    state = (C (B,NH,dh,dh), n (B,NH,dh), m (B,NH)) fp32.
    """
    C, n, m = state
    dh = q.shape[-1]
    scale = dh ** -0.5
    q32, k32, v32 = (t.astype(jnp.float32) for t in (q, k, v))
    m_new = jnp.maximum(lf + m, li)
    fp = jnp.exp(lf + m - m_new)
    ip = jnp.exp(li - m_new)
    C = fp[..., None, None] * C + ip[..., None, None] * (
        k32[..., :, None] * scale * v32[..., None, :])            # (B,NH,dh,dh)
    n = fp[..., None] * n + ip[..., None] * k32 * scale
    num = jnp.einsum("bhd,bhde->bhe", q32, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q32, n)),
                      jnp.exp(-m_new))
    h = num / den[..., None]
    return h.astype(q.dtype), (C, n, m_new)


MLSTM_PARALLEL_MAX_SEQ = 512


def mlstm_block_seq(cfg, lp, x, return_state: bool = False):
    B, S, _ = x.shape
    u = up_dim(cfg)
    cw = cfg.conv_width
    q, k, v, li, lf, z, m_pre = _mlstm_qkvif(cfg, lp, x)
    state = None
    if return_state:
        h, (C, n, m) = mlstm_chunkwise(q, k, v, li, lf, return_state=True)
        if S >= cw - 1:
            conv_state = m_pre[:, S - (cw - 1):]
        else:
            conv_state = jnp.pad(m_pre, ((0, 0), (cw - 1 - S, 0), (0, 0)))
        state = {"C": C, "n": n, "m": m, "conv": conv_state}
    elif S > MLSTM_PARALLEL_MAX_SEQ:
        h = mlstm_chunkwise(q, k, v, li, lf)
    else:
        h, _, _ = mlstm_parallel(q, k, v, li, lf)
    h = h.reshape(B, S, u)
    h = cm.rms_norm(h, lp["m_out_norm"], cfg.norm_eps)
    out = jnp.einsum("bsu,ud->bsd", h * jax.nn.silu(z), lp["m_down"])
    if return_state:
        return x + out, state
    return x + out


def mlstm_block_step(cfg, lp, x, state):
    """x: (B,1,d); state dict with C,n,m,conv."""
    B = x.shape[0]
    nh, u = cfg.num_heads, up_dim(cfg)
    dh = u // nh
    h0 = cm.rms_norm(x, lp["m_norm"], cfg.norm_eps)
    mm = jnp.einsum("bsd,du->bsu", h0, lp["m_up"])
    z = jnp.einsum("bsd,du->bsu", h0, lp["m_gate"])
    cv, conv_state = _conv_step(lp, mm, state["conv"])
    cv = jax.nn.silu(cv)
    q = jnp.einsum("bsu,uv->bsv", cv, lp["m_wq"]).reshape(B, nh, dh)
    k = jnp.einsum("bsu,uv->bsv", cv, lp["m_wk"]).reshape(B, nh, dh)
    v = jnp.einsum("bsu,uv->bsv", mm, lp["m_wv"]).reshape(B, nh, dh)
    li = (jnp.einsum("bsu,un->bn", cv, lp["m_wi"]) + lp["m_bi"]).astype(jnp.float32)
    lf = jax.nn.log_sigmoid(
        (jnp.einsum("bsu,un->bn", cv, lp["m_wf"]) + lp["m_bf"]).astype(jnp.float32))
    h, (C, n, m) = mlstm_step(q, k, v, li, lf,
                              (state["C"], state["n"], state["m"]))
    h = h.reshape(B, 1, u)
    h = cm.rms_norm(h, lp["m_out_norm"], cfg.norm_eps)
    out = jnp.einsum("bsu,ud->bsd", h * jax.nn.silu(z), lp["m_down"])
    return x + out, {"C": C, "n": n, "m": m, "conv": conv_state}


# ---------------------------------------------------------------------------
# sLSTM cell
# ---------------------------------------------------------------------------

def _slstm_cell(lp, nh, dh, x_t, state):
    """One sLSTM time step.  x_t: (B, 4d) pre-activation (W x + b);
    state = (c, n, m, h) each (B, nh, dh) / m: (B, nh, dh)."""
    c, n, m, h = state
    rec = jnp.einsum("bhd,hde->bhe", h, lp["s_r"])                # (B,nh,4dh)
    B = x_t.shape[0]
    pre = x_t.reshape(B, nh, 4 * dh) + rec
    zt, it, ft, ot = jnp.split(pre.astype(jnp.float32), 4, axis=-1)
    z = jnp.tanh(zt)
    o = jax.nn.sigmoid(ot)
    lf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(lf + m, it)
    fp = jnp.exp(lf + m - m_new)
    ip = jnp.exp(it - m_new)
    c = fp * c + ip * z
    n = fp * n + ip
    h_new = o * (c / jnp.maximum(n, 1e-6))
    return (c, n, m_new, h_new)


def slstm_block_seq(cfg, lp, x, state=None):
    B, S, d = x.shape
    nh = cfg.num_heads
    dh = d // nh
    h0 = cm.rms_norm(x, lp["s_norm"], cfg.norm_eps)
    pre = jnp.einsum("bsd,de->bse", h0, lp["s_w"]) + lp["s_b"]    # (B,S,4d)
    if state is None:
        z = jnp.zeros((B, nh, dh), jnp.float32)
        state = (z, z, jnp.full_like(z, -1e30), z)

    def step(carry, x_t):
        carry = _slstm_cell(lp, nh, dh, x_t, carry)
        return carry, carry[3]

    state, hs = lax.scan(step, state, jnp.moveaxis(pre, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(x.dtype)  # (B,S,d)
    hs = cm.rms_norm(hs, lp["s_out_norm"], cfg.norm_eps)
    x = x + hs
    # GeGLU FFN (proj factor 4/3)
    h1 = cm.rms_norm(x, lp["s_ffn_norm"], cfg.norm_eps)
    uu = jnp.einsum("bsd,df->bsf", h1, lp["s_ffn_up"])
    g, u = jnp.split(uu, 2, axis=-1)
    x = x + jnp.einsum("bsf,fd->bsd", jax.nn.gelu(g) * u, lp["s_ffn_down"])
    return x, state


def slstm_block_step(cfg, lp, x, state):
    B = x.shape[0]
    d = cfg.d_model
    nh = cfg.num_heads
    dh = d // nh
    h0 = cm.rms_norm(x, lp["s_norm"], cfg.norm_eps)
    pre = (jnp.einsum("bsd,de->bse", h0, lp["s_w"]) + lp["s_b"])[:, 0]
    state = _slstm_cell(lp, nh, dh, pre, state)
    hs = state[3].reshape(B, 1, d).astype(x.dtype)
    hs = cm.rms_norm(hs, lp["s_out_norm"], cfg.norm_eps)
    x = x + hs
    h1 = cm.rms_norm(x, lp["s_ffn_norm"], cfg.norm_eps)
    uu = jnp.einsum("bsd,df->bsf", h1, lp["s_ffn_up"])
    g, u = jnp.split(uu, 2, axis=-1)
    x = x + jnp.einsum("bsf,fd->bsd", jax.nn.gelu(g) * u, lp["s_ffn_down"])
    return x, state


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def forward_train(params: Dict, cfg: ModelConfig, tokens: jax.Array, **_):
    x = jnp.take(params["embed"]["tok_embed"], tokens, axis=0)

    def pair_body(carry, lp):
        y = carry
        y = mlstm_block_seq(cfg, lp, y)
        y, _ = slstm_block_seq(cfg, lp, y)
        return cm.seq_shard(y), None

    if cfg.sharding.remat == "full":
        pair_body = jax.checkpoint(pair_body)
    x, _ = lax.scan(pair_body, x, params["pairs"])
    return x


def init_state(cfg: ModelConfig, batch: int, dtype) -> Dict:
    G = n_pairs(cfg)
    nh, u, d = cfg.num_heads, up_dim(cfg), cfg.d_model
    dhm, dhs = u // nh, d // nh
    z = jnp.zeros
    return {
        "m": {"C": z((G, batch, nh, dhm, dhm), jnp.float32),
              "n": z((G, batch, nh, dhm), jnp.float32),
              "m": z((G, batch, nh), jnp.float32),
              "conv": z((G, batch, cfg.conv_width - 1, u), dtype)},
        "s": {"c": z((G, batch, nh, dhs), jnp.float32),
              "n": z((G, batch, nh, dhs), jnp.float32),
              "m": jnp.full((G, batch, nh, dhs), -1e30, jnp.float32),
              "h": z((G, batch, nh, dhs), jnp.float32)},
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params: Dict, cfg: ModelConfig, tokens: jax.Array, max_len: int, **_):
    """Sequence-parallel prefill: chunkwise mLSTM (with exact final state)
    + scanned sLSTM; the recurrent state is the whole cache."""
    B, S = tokens.shape
    x = jnp.take(params["embed"]["tok_embed"], tokens, axis=0)

    def pair_body(carry, lp):
        y = carry
        y, mstate = mlstm_block_seq(cfg, lp, y, return_state=True)
        y, sstate = slstm_block_seq(cfg, lp, y)
        c, n, m, h = sstate
        return cm.seq_shard(y), (mstate, {"c": c, "n": n, "m": m, "h": h})

    x, (mstates, sstates) = lax.scan(pair_body, x, params["pairs"])
    logits = cm.lm_logits(params["embed"], x[:, -1:], cfg)
    return logits, {"m": mstates, "s": sstates, "pos": jnp.int32(S)}


def decode_step(params: Dict, cfg: ModelConfig, token: jax.Array, cache: Dict, **_):
    B = token.shape[0]
    x = jnp.take(params["embed"]["tok_embed"], token, axis=0)

    def pair_body(carry, inp):
        y = carry
        lp, ms, ss = inp
        y, ms_new = mlstm_block_step(cfg, lp, y, ms)
        c, n, m, h = ss["c"], ss["n"], ss["m"], ss["h"]
        y, (c, n, m, h) = slstm_block_step(cfg, lp, y, (c, n, m, h))
        return y, (ms_new, {"c": c, "n": n, "m": m, "h": h})

    x, (ms, ss) = lax.scan(
        pair_body, x,
        (params["pairs"], cache["m"],
         {k: cache["s"][k] for k in ("c", "n", "m", "h")}))
    logits = cm.lm_logits(params["embed"], x, cfg)
    return logits, {"m": ms, "s": ss, "pos": cache["pos"] + 1}
