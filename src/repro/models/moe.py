"""MoE decoder family (qwen3-moe-30b-a3b: 128e top-8; mixtral-8x22b: 8e top-2
with SWA).

Routing uses the capacity-based dispatch with *index* gathers/scatters
(GShard semantics) instead of dense (S, E, C) one-hot einsums, so the
dispatch transients stay O(S*K*E) int32 for the position cumsum and
O(E*C*D) for the dispatched activations.  Under expert-parallel sharding
(experts on the 'model' mesh axis) GSPMD turns the gathers into the
dispatch/combine collectives the paper models (§4.3, Fig. 4).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import common as cm

def capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = int(-(-tokens_per_group * cfg.top_k * cfg.capacity_factor
              // cfg.num_experts))
    return max(1, c)


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------

def schema(cfg: ModelConfig) -> Dict:
    L, d, f, E = cfg.num_layers, cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    layers = {}
    layers.update(cm.attn_schema(cfg, L))
    layers.update(cm.norm_schema(L, d, 2))
    layers["router"] = cm.ParamSpec((L, d, E), ("layers", "embed", None))
    layers["we_gate"] = cm.ParamSpec((L, E, d, f), ("layers", "experts", "embed", "ffn"))
    layers["we_up"] = cm.ParamSpec((L, E, d, f), ("layers", "experts", "embed", "ffn"))
    layers["we_down"] = cm.ParamSpec((L, E, f, d), ("layers", "experts", "ffn", "embed"))
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * f
        layers["ws_gate"] = cm.ParamSpec((L, d, fs), ("layers", "embed", "ffn"))
        layers["ws_up"] = cm.ParamSpec((L, d, fs), ("layers", "embed", "ffn"))
        layers["ws_down"] = cm.ParamSpec((L, fs, d), ("layers", "ffn", "embed"))
    return {"embed": cm.embed_schema(cfg), "layers": layers}


# ---------------------------------------------------------------------------
# Routing + expert compute
# ---------------------------------------------------------------------------

def route(cfg: ModelConfig, router_w: jax.Array, x: jax.Array):
    """x: (B, S, d) -> (top-k weights, expert ids, router probs).

    Weights are renormalized over the selected k (qwen3/mixtral convention).
    """
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topw, tope = lax.top_k(probs, cfg.top_k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)
    return topw, tope, probs


def load_balance_loss(cfg: ModelConfig, probs: jax.Array, tope: jax.Array) -> jax.Array:
    """Switch-style aux loss: E * <fraction routed to e> . <mean prob of e>.

    Computed via scatter-add (O(S*K)), not a (B,S,K,E) one-hot."""
    E = cfg.num_experts
    B, S, K = tope.shape
    counts = jnp.zeros((E,), jnp.float32).at[tope.reshape(-1)].add(1.0)
    frac = counts / (B * S)                                      # (E,)
    mean_prob = jnp.mean(probs, axis=(0, 1))                     # (E,)
    return E * jnp.sum(frac * mean_prob)


def moe_ffn(cfg: ModelConfig, lp: Dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Capacity-dispatched expert FFN.  x: (B, S, d) -> (out, aux_loss)."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    C = capacity(cfg, S)

    topw, tope, probs = route(cfg, lp["router"], x)
    aux = load_balance_loss(cfg, probs, tope)

    # position-in-expert via sort-based ranking: O(S*K log S*K) memory-lean
    # (a dense (S*K, E) one-hot cumsum would be terabytes at 32k x 128e)
    flat_e = tope.reshape(B, S * K)                              # (B, S*K)
    order = jnp.argsort(flat_e, axis=1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    sk_idx = jnp.arange(S * K, dtype=jnp.int32)
    grp_start = jax.vmap(
        lambda se: jnp.searchsorted(se, se, side="left"))(sorted_e)
    rank_sorted = sk_idx[None, :] - grp_start
    inv_order = jnp.argsort(order, axis=1)
    pos = jnp.take_along_axis(rank_sorted, inv_order, axis=1)    # (B, S*K)
    keep = pos < C
    safe_pos = jnp.where(keep, pos, C)                           # C -> dropped
    tok_idx = jnp.repeat(jnp.arange(S, dtype=jnp.int32), K)      # (S*K,)

    def scatter_one(fe, p, kp):
        idx = jnp.zeros((E, C), jnp.int32).at[fe, p].set(tok_idx, mode="drop")
        val = jnp.zeros((E, C), jnp.bool_).at[fe, p].set(kp, mode="drop")
        return idx, val

    idx, valid = jax.vmap(scatter_one)(flat_e, safe_pos, keep)   # (B,E,C)

    # Dispatch: gather tokens into per-expert slots.
    xe = jax.vmap(lambda xb, ib: xb[ib])(x, idx)                 # (B,E,C,D)
    xe = xe * valid[..., None].astype(x.dtype)

    # ---- explicit sharding pins (no-ops off-mesh) -------------------------
    # Preference order: dedicated 'expert' mesh axis (perf-iteration 3-axis
    # mesh) > EP on the model axis when expert count divides > TP on the
    # per-expert FFN dim.  Weights are gathered over the FSDP 'data' axis
    # at use site (MaxText pattern).
    if cm.axis_size("expert") > 1 and E % cm.axis_size("expert") == 0:
        ep = True
        e_ax = "expert"
        f_ax = ("model" if cm.axis_size("model") > 1
                and cfg.sharding.moe_ffn_tp else None)
    elif (cfg.sharding.moe_mode == "expert"
          and E % cm.axis_size("model") == 0 and cm.axis_size("model") > 1):
        ep = True
        e_ax, f_ax = "model", None
    else:
        ep = False
        e_ax, f_ax = None, "model"
    xe = cm.constrain(xe, "batch", e_ax, None, None)
    wg = cm.constrain(lp["we_gate"], e_ax, None, f_ax)
    wu = cm.constrain(lp["we_up"], e_ax, None, f_ax)
    wd = cm.constrain(lp["we_down"], e_ax, f_ax, None)

    # Expert FFN (einsum batched over experts; E-sharded under EP).
    g = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, wg))
    u = jnp.einsum("becd,edf->becf", xe, wu)
    g = cm.constrain(g, "batch", e_ax, None, f_ax)
    ye = jnp.einsum("becf,efd->becd", g * u, wd)                 # (B,E,C,D)
    d_ax = ("model" if (not ep and cfg.sharding.moe_down_rs
                        and D % cm.axis_size("model") == 0) else None)
    ye = cm.constrain(ye, "batch", e_ax, None, d_ax)

    # Combine: gather each assignment's expert output, weight, and sum over k.
    gpos = jnp.where(keep, pos, 0)
    yk = jax.vmap(lambda yb, fe, p: yb[fe, p])(ye, flat_e, gpos)  # (B,S*K,D)
    yk = yk * keep[..., None].astype(x.dtype)
    yk = yk.reshape(B, S, K, D)
    out = jnp.sum(yk * topw[..., None].astype(x.dtype), axis=2)
    return out, aux


# ---------------------------------------------------------------------------
# Blocks / entry points
# ---------------------------------------------------------------------------

def _block(cfg: ModelConfig, x: jax.Array, lp: Dict, positions: jax.Array):
    B, S, _ = x.shape
    h = cm.rms_norm(x, lp["norm0"], cfg.norm_eps)
    q, k, v = cm.qkv_project(lp, h, cfg, positions)
    attn = cm.attention(q, k, v, None, causal=True, window=cfg.sliding_window,
                        q_shard=cfg.sharding.blockwise_q_shard)
    x = x + jnp.einsum("bse,ed->bsd", attn.reshape(B, S, -1), lp["wo"])
    h = cm.rms_norm(x, lp["norm1"], cfg.norm_eps)
    y, aux = moe_ffn(cfg, lp, h)
    if cfg.n_shared_experts:
        # DeepSeek-style always-on shared expert(s) alongside the routed ones
        y = y + cm.swiglu(h, lp["ws_gate"], lp["ws_up"], lp["ws_down"])
    return x + y, aux, k, v


def _stack(cfg, x, layers, positions, remat: str, collect_kv: bool = False):
    def body(carry, lp):
        y, aux_acc = carry
        y, aux, k, v = _block(cfg, y, lp, positions)
        return (cm.seq_shard(y), aux_acc + aux), (
            (cm.kv_shard(k), cm.kv_shard(v)) if collect_kv else None)

    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    (x, aux), ys = lax.scan(body, (x, jnp.float32(0.0)), layers)
    if collect_kv:
        return x, aux / cfg.num_layers, ys[0], ys[1]
    return x, aux / cfg.num_layers, None, None


def forward_train(params: Dict, cfg: ModelConfig, tokens: jax.Array,
                  **_) -> Tuple[jax.Array, jax.Array]:
    """Returns (hidden, aux_loss)."""
    B, S = tokens.shape
    x = jnp.take(params["embed"]["tok_embed"], tokens, axis=0)
    positions = jnp.arange(S)[None, :]
    x, aux, _, _ = _stack(cfg, x, params["layers"], positions, cfg.sharding.remat)
    return x, aux


def cache_width(cfg: ModelConfig, max_len: int) -> int:
    win = cfg.sliding_window
    return min(max_len, win) if win else max_len


def prefill(params: Dict, cfg: ModelConfig, tokens: jax.Array, max_len: int,
            **_) -> Tuple[jax.Array, Dict]:
    B, S = tokens.shape
    x = jnp.take(params["embed"]["tok_embed"], tokens, axis=0)
    positions = jnp.arange(S)[None, :]
    x, _, ks, vs = _stack(cfg, x, params["layers"], positions, "none",
                          collect_kv=True)
    W = cache_width(cfg, max_len)
    if W >= S:
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, W - S), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, W - S), (0, 0), (0, 0)))
    else:
        ks = jnp.roll(ks[:, :, S - W:], shift=S % W, axis=2)
        vs = jnp.roll(vs[:, :, S - W:], shift=S % W, axis=2)
    logits = cm.lm_logits(params["embed"], x[:, -1:], cfg)
    return logits, {"k": ks, "v": vs, "pos": jnp.int32(S)}


def decode_step(params: Dict, cfg: ModelConfig, token: jax.Array, cache: Dict,
                **_) -> Tuple[jax.Array, Dict]:
    B = token.shape[0]
    pos, W = cache["pos"], cache["k"].shape[2]
    x = jnp.take(params["embed"]["tok_embed"], token, axis=0)
    positions = cm.decode_pos_vec(pos, B)
    valid_len = jnp.minimum(pos + 1, W)

    def body(carry, inp):
        y = carry
        lp, kc, vc = inp
        h = cm.rms_norm(y, lp["norm0"], cfg.norm_eps)
        q, k, v = cm.qkv_project(lp, h, cfg, positions)
        kc, vc = cm.cache_update(kc, vc, k, v, pos)
        attn = cm.decode_attention(q, kc, vc, valid_len,
                                   pin=cfg.sharding.decode_attn_pin,
                                   seq_shard=cfg.sharding.shard_kv_seq)
        y = y + jnp.einsum("bse,ed->bsd", attn.reshape(B, 1, -1), lp["wo"])
        h = cm.rms_norm(y, lp["norm1"], cfg.norm_eps)
        mo, _ = moe_ffn(cfg, lp, h)
        if cfg.n_shared_experts:
            mo = mo + cm.swiglu(h, lp["ws_gate"], lp["ws_up"], lp["ws_down"])
        return y + mo, (kc, vc)

    x, (ks, vs) = lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    logits = cm.lm_logits(params["embed"], x, cfg)
    return logits, {"k": ks, "v": vs, "pos": pos + 1}
