"""Whisper-style encoder-decoder (audio backbone) [arXiv:2212.04356].

The mel-spectrogram + conv feature extractor is a STUB (the one allowed
carve-out): inputs are precomputed frame embeddings (B, n_frames, d_model)
supplied by ``input_specs``.  We implement the transformer backbone:
bidirectional encoder + causal decoder with cross-attention, LayerNorm +
GELU MLP (whisper convention), sinusoidal positions, tied output head.

Decode caches: ring-buffer self-attention KV + static cross-attention KV
computed once from the encoder output.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import common as cm


def sinusoidal(positions: jax.Array, d_model: int) -> jax.Array:
    half = d_model // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / (half - 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _ln_schema(L: int, d: int, names) -> Dict:
    sch = {}
    for nm in names:
        sch[nm + "_g"] = cm.ParamSpec((L, d), ("layers", None), init="ones")
        sch[nm + "_b"] = cm.ParamSpec((L, d), ("layers", None), init="zeros")
    return sch


def _mlp_schema(cfg: ModelConfig, L: int) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_fc": cm.ParamSpec((L, d, f), ("layers", "embed", "ffn")),
        "b_fc": cm.ParamSpec((L, f), ("layers", "ffn"), init="zeros"),
        "w_proj": cm.ParamSpec((L, f, d), ("layers", "ffn", "embed")),
        "b_proj": cm.ParamSpec((L, d), ("layers", None), init="zeros"),
    }


def _cross_schema(cfg: ModelConfig, L: int) -> Dict:
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    return {
        "wq_c": cm.ParamSpec((L, d, h * hd), ("layers", "embed", "heads")),
        "wk_c": cm.ParamSpec((L, d, h * hd), ("layers", "embed", "heads")),
        "wv_c": cm.ParamSpec((L, d, h * hd), ("layers", "embed", "heads")),
        "wo_c": cm.ParamSpec((L, h * hd, d), ("layers", "heads", "embed")),
    }


def schema(cfg: ModelConfig) -> Dict:
    Le, Ld, d = cfg.encoder_layers, cfg.num_layers, cfg.d_model
    enc = {}
    enc.update(cm.attn_schema(cfg, Le))
    enc.update(_mlp_schema(cfg, Le))
    enc.update(_ln_schema(Le, d, ("ln0", "ln1")))
    dec = {}
    dec.update(cm.attn_schema(cfg, Ld))
    dec.update(_cross_schema(cfg, Ld))
    dec.update(_mlp_schema(cfg, Ld))
    dec.update(_ln_schema(Ld, d, ("ln0", "ln1", "ln2")))
    emb = {
        "tok_embed": cm.ParamSpec((cfg.vocab_size, d), ("vocab", "embed")),
        "final_norm": cm.ParamSpec((d,), (None,), init="ones"),
        "final_bias": cm.ParamSpec((d,), (None,), init="zeros"),
        "enc_norm_g": cm.ParamSpec((d,), (None,), init="ones"),
        "enc_norm_b": cm.ParamSpec((d,), (None,), init="zeros"),
    }
    return {"embed": emb, "enc_layers": enc, "dec_layers": dec}


def _mlp(lp, x):
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, lp["w_fc"]) + lp["b_fc"])
    return jnp.einsum("bsf,fd->bsd", h, lp["w_proj"]) + lp["b_proj"]


def encode(params: Dict, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, T, d) stub conv-frontend embeddings -> encoder states."""
    B, T, d = frames.shape
    x = frames + sinusoidal(jnp.arange(T)[None], d).astype(frames.dtype)

    def body(carry, lp):
        y = carry
        h = cm.layer_norm(y, lp["ln0_g"], lp["ln0_b"], cfg.norm_eps)
        q, k, v = cm.qkv_project(lp, h, cfg, jnp.arange(T)[None], rope=False)
        a = cm.attention(q, k, v, None, causal=False,
                         q_shard=cfg.sharding.blockwise_q_shard)
        y = y + jnp.einsum("bse,ed->bsd", a.reshape(B, T, -1), lp["wo"])
        h = cm.layer_norm(y, lp["ln1_g"], lp["ln1_b"], cfg.norm_eps)
        y = y + _mlp(lp, h)
        return y, None

    if cfg.sharding.remat == "full":
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, params["enc_layers"])
    e = params["embed"]
    return cm.layer_norm(x, e["enc_norm_g"], e["enc_norm_b"], cfg.norm_eps)


def _dec_block(cfg, lp, x, enc_kv, positions, self_attn_fn):
    """Shared decoder block; self_attn_fn handles seq vs cached-step attn."""
    B, S, _ = x.shape
    h = cm.layer_norm(x, lp["ln0_g"], lp["ln0_b"], cfg.norm_eps)
    x = x + self_attn_fn(lp, h)
    h = cm.layer_norm(x, lp["ln1_g"], lp["ln1_b"], cfg.norm_eps)
    qc = jnp.einsum("bsd,de->bse", h, lp["wq_c"]).reshape(
        B, S, cfg.num_heads, cfg.head_dim)
    kc, vc = enc_kv
    a = cm.attention(qc, kc, vc, None, causal=False,
                     q_shard=cfg.sharding.blockwise_q_shard)
    x = x + jnp.einsum("bse,ed->bsd", a.reshape(B, S, -1), lp["wo_c"])
    h = cm.layer_norm(x, lp["ln2_g"], lp["ln2_b"], cfg.norm_eps)
    return x + _mlp(lp, h)


def _cross_kv(cfg, lp, enc_out):
    B, T, _ = enc_out.shape
    kc = jnp.einsum("btd,de->bte", enc_out, lp["wk_c"]).reshape(
        B, T, cfg.num_heads, cfg.head_dim)
    vc = jnp.einsum("btd,de->bte", enc_out, lp["wv_c"]).reshape(
        B, T, cfg.num_heads, cfg.head_dim)
    return kc, vc


def forward_train(params: Dict, cfg: ModelConfig, tokens: jax.Array,
                  frames: Optional[jax.Array] = None, **_) -> jax.Array:
    """Teacher-forced decoder hidden states."""
    B, S = tokens.shape
    enc_out = encode(params, cfg, frames)
    x = jnp.take(params["embed"]["tok_embed"], tokens, axis=0)
    x = x + sinusoidal(jnp.arange(S)[None], cfg.d_model).astype(x.dtype)
    positions = jnp.arange(S)[None]

    def body(carry, lp):
        def self_attn(lp, h):
            q, k, v = cm.qkv_project(lp, h, cfg, positions, rope=False)
            a = cm.attention(q, k, v, None, causal=True,
                             q_shard=cfg.sharding.blockwise_q_shard)
            return jnp.einsum("bse,ed->bsd", a.reshape(B, S, -1), lp["wo"])
        y = _dec_block(cfg, lp, carry, _cross_kv(cfg, lp, enc_out),
                       positions, self_attn)
        return y, None

    if cfg.sharding.remat == "full":
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, params["dec_layers"])
    return x


def _final_logits(params, cfg, x):
    e = params["embed"]
    x = cm.constrain(x, "batch", None, None)
    x = cm.layer_norm(x, e["final_norm"], e["final_bias"], cfg.norm_eps)
    out = jnp.einsum("bsd,vd->bsv", x, e["tok_embed"])
    return cm.constrain(out, "batch", None, "tp")


def prefill(params: Dict, cfg: ModelConfig, tokens: jax.Array, max_len: int,
            frames: Optional[jax.Array] = None, **_):
    B, S = tokens.shape
    enc_out = encode(params, cfg, frames)
    x = jnp.take(params["embed"]["tok_embed"], tokens, axis=0)
    x = x + sinusoidal(jnp.arange(S)[None], cfg.d_model).astype(x.dtype)
    positions = jnp.arange(S)[None]

    def body(carry, lp):
        kv_box = {}

        def self_attn(lp, h):
            q, k, v = cm.qkv_project(lp, h, cfg, positions, rope=False)
            kv_box["kv"] = (k, v)
            a = cm.attention(q, k, v, None, causal=True,
                             q_shard=cfg.sharding.blockwise_q_shard)
            return jnp.einsum("bse,ed->bsd", a.reshape(B, S, -1), lp["wo"])

        y = _dec_block(cfg, lp, carry, _cross_kv(cfg, lp, enc_out),
                       positions, self_attn)
        ck, cv = _cross_kv(cfg, lp, enc_out)
        k, v = kv_box["kv"]
        return y, (cm.kv_shard(k), cm.kv_shard(v),
                   cm.kv_shard(ck), cm.kv_shard(cv))

    x, (ks, vs, cks, cvs) = lax.scan(body, x, params["dec_layers"])
    W = max_len
    ks = jnp.pad(ks, ((0, 0), (0, 0), (0, W - S), (0, 0), (0, 0)))
    vs = jnp.pad(vs, ((0, 0), (0, 0), (0, W - S), (0, 0), (0, 0)))
    cache = {"k": ks, "v": vs, "ck": cks, "cv": cvs, "pos": jnp.int32(S)}
    return _final_logits(params, cfg, x[:, -1:]), cache


def decode_step(params: Dict, cfg: ModelConfig, token: jax.Array, cache: Dict, **_):
    B = token.shape[0]
    pos, W = cache["pos"], cache["k"].shape[2]
    x = jnp.take(params["embed"]["tok_embed"], token, axis=0)
    positions = cm.decode_pos_vec(pos, B)
    x = x + sinusoidal(positions, cfg.d_model).astype(x.dtype)
    valid_len = jnp.minimum(pos + 1, W)

    def body(carry, inp):
        y = carry
        lp, kc, vc, ck, cv = inp
        box = {}

        def self_attn(lp, h):
            q, k, v = cm.qkv_project(lp, h, cfg, positions, rope=False)
            kcn, vcn = cm.cache_update(kc, vc, k, v, pos)
            box["kv"] = (kcn, vcn)
            a = cm.decode_attention(q, kcn, vcn, valid_len,
                                    pin=cfg.sharding.decode_attn_pin,
                                   seq_shard=cfg.sharding.shard_kv_seq)
            return jnp.einsum("bse,ed->bsd", a.reshape(B, 1, -1), lp["wo"])

        y = _dec_block(cfg, lp, y, (ck, cv), positions, self_attn)
        kcn, vcn = box["kv"]
        return y, (kcn, vcn)

    x, (ks, vs) = lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["ck"], cache["cv"]))
    logits = _final_logits(params, cfg, x)
    return logits, {"k": ks, "v": vs, "ck": cache["ck"], "cv": cache["cv"],
                    "pos": pos + 1}
