"""Discrete-event serving simulator — the fidelity ground truth.

Plays the role real silicon plays in the paper's §5 evaluation: it executes
the *same* continuous-batching scheduler as the engine, iteration by
iteration, advancing a virtual clock by a per-iteration latency obtained
from an operator-level latency callback (the perf DB).  Algorithm 2's
closed-form estimate is then validated against this step-accurate
execution (benchmarks/fig6_fidelity.py), reproducing the paper's MAPE
methodology without GPUs.

Two drive modes:

``run(isl, osl, concurrency)``
    Closed-loop at fixed concurrency — the paper's steady-state view.
    A finished request is immediately replaced, so the system never
    queues and TTFT is pure compute.

``replay(trace)``
    Open-loop, arrival-time-driven: requests are admitted when the
    virtual clock passes their trace arrival time regardless of how
    loaded the engine is, so queueing delay counts into TTFT and tail
    percentiles (p50/p95/p99), queue-depth stats, and goodput under a
    tail-latency SLO become measurable.  This is the dynamic-workload
    evaluation axis the static analytical model cannot see.
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.obs.flight import emit_request_spans, latency_histograms
from repro.obs.metrics import get_metrics
from repro.obs.trace import get_tracer
from repro.serving.request import Request
from repro.serving.scheduler import ContinuousBatchingScheduler, SchedulerConfig


@dataclasses.dataclass(frozen=True)
class StepSpec:
    """Shape of one iteration handed to the latency model."""
    prefill: Tuple[Tuple[int, int], ...]   # (chunk_len, past_len) per chunk
    decode: Tuple[int, ...]                # kv length per decode row


@dataclasses.dataclass(frozen=True)
class StepOutcome:
    """One executed scheduler iteration (see :func:`run_iteration`)."""
    t: float                               # clock after the iteration
    dt: float                              # iteration latency
    gen_tokens: int                        # tokens produced this iteration
    finished: List                         # requests that completed
    waiting_depth: int                     # queue depth when planned


def run_iteration(sched, latency_fn, t: float) -> Optional[StepOutcome]:
    """Plan and execute one scheduler iteration at clock ``t``.

    The single shared step body of every replay engine — the open-loop
    :meth:`ServingSimulator.replay` and the per-replica engines of
    ``repro.capacity.cluster`` — so iteration accounting (latency-spec
    assembly, generated-token counting including prefills that finish
    this step) can never drift between the single- and multi-engine
    views.  Returns ``None`` when the scheduler has nothing to run.
    """
    plan = sched.plan(t)
    if plan.empty:
        return None
    depth = len(sched.waiting)
    spec = StepSpec(
        prefill=tuple((c.length, c.start) for c in plan.prefill),
        decode=tuple(r.isl + r.generated for r in plan.decode),
    )
    dt = latency_fn(spec)
    t += dt
    gen = plan.gen_tokens + sum(
        1 for c in plan.prefill
        if c.start + c.length >= c.req.isl)
    return StepOutcome(t=t, dt=dt, gen_tokens=gen,
                       finished=sched.commit(plan, t), waiting_depth=depth)


@dataclasses.dataclass
class SimMetrics:
    ttft_ms: float
    tpot_ms: float
    throughput_tok_s: float                # generated tokens / wall
    tokens_per_s_per_user: float
    completed: int
    steps: int
    #: (ttft_s, tpot_s) per *finished* request; tpot_s is None for
    #: single-token outputs (no decode interval exists) — unfinished
    #: requests are dropped rather than coerced to 0.0, so percentiles
    #: computed from this list are never silently skewed.
    per_request: List[Tuple[float, Optional[float]]]


def percentile(values: Sequence[float], q: float) -> float:
    """Linear-interpolated percentile (q in [0, 1]) of a sample."""
    s = sorted(values)
    if not s:
        return float("nan")
    if len(s) == 1:
        return float(s[0])
    pos = q * (len(s) - 1)
    lo = int(pos)
    frac = pos - lo
    if lo + 1 >= len(s):
        return float(s[-1])
    return float(s[lo] * (1 - frac) + s[lo + 1] * frac)


def _pctl_dict(values_ms: Sequence[float]) -> Dict[str, float]:
    """p50/p95/p99 over a sample; an empty sample (degenerate trace,
    nothing completed) yields explicit zeros, never NaN — replay
    metrics stay finite and JSON-comparable."""
    if not values_ms:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    return {"p50": percentile(values_ms, 0.50),
            "p95": percentile(values_ms, 0.95),
            "p99": percentile(values_ms, 0.99)}


@dataclasses.dataclass
class ReplayMetrics:
    """Open-loop replay outcome: tail percentiles, queueing, goodput."""
    n_requests: int                        # submitted (trace size)
    completed: int
    rejected: int                          # bounced off max_queue
    unfinished: int                        # still in flight at cutoff
    steps: int
    duration_s: float                      # virtual makespan
    throughput_tok_s: float                # generated tokens / makespan
    ttft_ms: Dict[str, float]              # {"p50": ..., "p95": ..., "p99": ...}
    tpot_ms: Dict[str, float]
    queue_depth_mean: float
    queue_depth_max: int
    #: True when the ``max_steps`` budget (not the trace) ended the
    #: run — work was still pending when the iteration budget ran out,
    #: so ``unfinished`` reflects the budget, not the workload
    truncated: bool
    #: (tenant, ttft_s, tpot_s) per finished request, tpot_s None when
    #: no decode interval exists (osl == 1)
    per_request: List[Tuple[str, float, Optional[float]]]
    #: set when a SLO was supplied to replay()
    slo: Optional[Dict] = None
    slo_attainment: Optional[float] = None  # attaining / submitted
    goodput_tok_s: Optional[float] = None   # tokens from attaining reqs / s
    #: full TTFT/TPOT/queue-wait/e2e distributions over finished
    #: requests (fixed log2-ms buckets, see ``repro.obs.flight``);
    #: popped from ``to_dict`` so CLI replay bytes stay pre-flight-
    #: recorder identical — report builders attach it explicitly
    histograms: Optional[Dict] = None

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d.pop("per_request")           # raw samples stay in-process
        d.pop("histograms")
        return d


class ServingSimulator:
    def __init__(self, sched_cfg: SchedulerConfig, latency_fn: "LatencyFn"):
        self.sched_cfg = sched_cfg
        self.latency_fn = latency_fn

    def run(self, isl: int, osl: int, concurrency: int,
            max_requests: int = 64, warmup: int = 8) -> SimMetrics:
        """Closed-loop at fixed concurrency (the paper's steady-state view)."""
        sched = ContinuousBatchingScheduler(self.sched_cfg)
        t = 0.0
        rid = 0
        done: List[Request] = []

        def inject():
            nonlocal rid
            req = Request(rid=rid, isl=isl, osl=osl, arrival=t)
            sched.add(req)
            rid += 1

        for _ in range(min(concurrency, max_requests + warmup)):
            inject()

        steps = 0
        gen_window = 0
        t_window_start: Optional[float] = None
        while len(done) < max_requests + warmup and sched.active > 0:
            plan = sched.plan(t)
            if plan.empty:
                break
            spec = StepSpec(
                prefill=tuple((c.length, c.start) for c in plan.prefill),
                decode=tuple(r.isl + r.generated for r in plan.decode),
            )
            t += self.latency_fn(spec)
            steps += 1
            if len(done) >= warmup:
                if t_window_start is None:
                    t_window_start = t
                gen_window += plan.gen_tokens + sum(
                    1 for c in plan.prefill
                    if c.start + c.length >= c.req.isl)
            finished = sched.commit(plan, t)
            done.extend(finished)
            for _ in finished:
                if rid < max_requests + warmup:
                    inject()

        measured = done[warmup:]
        ttfts = [r.ttft for r in measured if r.ttft is not None]
        tpots = [r.tpot for r in measured if r.tpot is not None]
        elapsed = max(t - (t_window_start or 0.0), 1e-9)
        mean_tpot = statistics.mean(tpots) if tpots else 0.0
        return SimMetrics(
            ttft_ms=1e3 * statistics.mean(ttfts) if ttfts else 0.0,
            tpot_ms=1e3 * mean_tpot,
            throughput_tok_s=gen_window / elapsed,
            tokens_per_s_per_user=(1.0 / mean_tpot) if mean_tpot else 0.0,
            completed=len(measured),
            steps=steps,
            per_request=[(r.ttft, r.tpot) for r in measured
                         if r.ttft is not None],
        )

    # ------------------------------------------------------------------
    def replay(self, trace, slo=None,
               max_steps: int = 200_000) -> ReplayMetrics:
        """Open-loop replay of a workload trace.

        ``trace`` is a :class:`repro.workloads.trace.WorkloadTrace` (or
        any sequence of records with ``arrival_s``/``isl``/``osl`` and
        optional ``tenant``/``priority``).  Requests are admitted the
        first iteration boundary after their arrival time; when the
        engine sits idle the clock jumps to the next arrival.  Queueing
        delay is part of TTFT (TTFT = first token time − *arrival*), so
        a bursty trace degrades tail percentiles even when steady-state
        throughput looks identical.

        ``slo`` (a :class:`repro.workloads.slo.SLOSpec`-like object)
        turns on goodput accounting: rejected and unfinished requests
        count as SLO misses.
        """
        tracer = get_tracer()
        with tracer.span("serving.replay") as sp:
            metrics, completed, rejected = self._replay(trace, slo,
                                                        max_steps)
            # the flight recorder writes per-request span trees after
            # the simulation body, anchored at this span's start — it
            # can never perturb the iteration sequence
            emit_request_spans(tracer, completed, rejected,
                               base=sp.v_start)
            # advance the tracer's virtual clock by the simulated makespan
            # so the span's v_start/v_end bracket sim time, not wall time
            tracer.virtual_time = sp.v_start + metrics.duration_s
            sp.set(n_requests=metrics.n_requests, steps=metrics.steps,
                   completed=metrics.completed, rejected=metrics.rejected)
        m = get_metrics()
        if m is not None:
            m.inc("repro_replay_iterations_total", metrics.steps)
            m.inc("repro_replay_admissions_total",
                  metrics.n_requests - metrics.rejected)
            m.inc("repro_replay_rejections_total", metrics.rejected)
            m.inc("repro_replay_completions_total", metrics.completed)
            if metrics.slo_attainment is not None:
                m.set_gauge("repro_replay_slo_attainment",
                            metrics.slo_attainment, sim="serving")
        return metrics

    def _replay(self, trace, slo, max_steps: int):
        records = list(getattr(trace, "requests", trace))
        sched = ContinuousBatchingScheduler(self.sched_cfg)
        t = 0.0
        i = 0
        rejected_reqs: List[Request] = []
        done: List[Request] = []
        steps = 0
        gen_total = 0
        depth_sum = 0
        depth_max = 0

        def admit_arrived():
            nonlocal i
            while i < len(records) and records[i].arrival_s <= t:
                r = records[i]
                req = Request(rid=i, isl=r.isl, osl=r.osl,
                              arrival=r.arrival_s,
                              tenant=getattr(r, "tenant", "default"),
                              priority=getattr(r, "priority", 0))
                if not sched.add(req):
                    rejected_reqs.append(req)
                i += 1

        admit_arrived()
        while (i < len(records) or sched.active > 0) and steps < max_steps:
            out = run_iteration(sched, self.latency_fn, t)
            if out is None:
                if i < len(records):
                    # engine idle, arrivals pending: jump to the next one
                    t = max(t, records[i].arrival_s)
                    admit_arrived()
                    continue
                break
            depth_sum += out.waiting_depth
            depth_max = max(depth_max, out.waiting_depth)
            t = out.t
            steps += 1
            gen_total += out.gen_tokens
            done.extend(out.finished)
            admit_arrived()

        completed = [r for r in done if r.ttft is not None]
        rejected = len(rejected_reqs)
        unfinished = len(records) - rejected - len(completed)
        truncated = steps >= max_steps \
            and (i < len(records) or sched.active > 0)
        ttfts_ms = [1e3 * r.ttft for r in completed]
        tpots_ms = [1e3 * r.tpot for r in completed if r.tpot is not None]
        # degenerate traces — empty, or every request bounced off
        # max_queue — take explicit zero branches rather than hiding a
        # division behind max(..., 1): the metrics stay finite and a
        # capacity rung replaying such a trace reads as zero goodput,
        # never NaN
        metrics = ReplayMetrics(
            n_requests=len(records),
            completed=len(completed),
            rejected=rejected,
            unfinished=unfinished,
            steps=steps,
            duration_s=t,
            throughput_tok_s=gen_total / t if t > 0 else 0.0,
            ttft_ms=_pctl_dict(ttfts_ms),
            tpot_ms=_pctl_dict(tpots_ms),
            queue_depth_mean=depth_sum / steps if steps else 0.0,
            queue_depth_max=depth_max,
            truncated=truncated,
            per_request=[(r.tenant, r.ttft, r.tpot) for r in completed],
            histograms=latency_histograms(completed, sim="serving"),
        )
        if slo is not None:
            attaining = [r for r in completed
                         if slo.request_meets(r.ttft, r.tpot)]
            metrics.slo = {"ttft_p99_ms": slo.ttft_p99_ms,
                           "tpot_p99_ms": slo.tpot_p99_ms}
            metrics.slo_attainment = (len(attaining) / len(records)
                                      if records else 0.0)
            metrics.goodput_tok_s = (sum(r.osl for r in attaining) / t
                                     if t > 0 else 0.0)
        return metrics, completed, rejected_reqs


LatencyFn = Callable[[StepSpec], float]
