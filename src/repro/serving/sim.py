"""Discrete-event serving simulator — the fidelity ground truth.

Plays the role real silicon plays in the paper's §5 evaluation: it executes
the *same* continuous-batching scheduler as the engine, iteration by
iteration, advancing a virtual clock by a per-iteration latency obtained
from an operator-level latency callback (the perf DB).  Algorithm 2's
closed-form estimate is then validated against this step-accurate
execution (benchmarks/fig6_fidelity.py), reproducing the paper's MAPE
methodology without GPUs.
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import Callable, Dict, List, Optional, Tuple

from repro.serving.request import IterationPlan, Request
from repro.serving.scheduler import ContinuousBatchingScheduler, SchedulerConfig


@dataclasses.dataclass(frozen=True)
class StepSpec:
    """Shape of one iteration handed to the latency model."""
    prefill: Tuple[Tuple[int, int], ...]   # (chunk_len, past_len) per chunk
    decode: Tuple[int, ...]                # kv length per decode row


@dataclasses.dataclass
class SimMetrics:
    ttft_ms: float
    tpot_ms: float
    throughput_tok_s: float                # generated tokens / wall
    tokens_per_s_per_user: float
    completed: int
    steps: int
    per_request: List[Tuple[float, float]]  # (ttft_s, tpot_s)


LatencyFn = Callable[[StepSpec], float]


class ServingSimulator:
    def __init__(self, sched_cfg: SchedulerConfig, latency_fn: LatencyFn):
        self.sched_cfg = sched_cfg
        self.latency_fn = latency_fn

    def run(self, isl: int, osl: int, concurrency: int,
            max_requests: int = 64, warmup: int = 8) -> SimMetrics:
        """Closed-loop at fixed concurrency (the paper's steady-state view)."""
        sched = ContinuousBatchingScheduler(self.sched_cfg)
        t = 0.0
        rid = 0
        done: List[Request] = []

        def inject():
            nonlocal rid
            req = Request(rid=rid, isl=isl, osl=osl, arrival=t)
            sched.add(req)
            rid += 1

        for _ in range(min(concurrency, max_requests + warmup)):
            inject()

        steps = 0
        gen_window = 0
        t_window_start: Optional[float] = None
        while len(done) < max_requests + warmup and sched.active > 0:
            plan = sched.plan(t)
            if plan.empty:
                break
            spec = StepSpec(
                prefill=tuple((c.length, c.start) for c in plan.prefill),
                decode=tuple(r.isl + r.generated for r in plan.decode),
            )
            t += self.latency_fn(spec)
            steps += 1
            if len(done) >= warmup:
                if t_window_start is None:
                    t_window_start = t
                gen_window += plan.gen_tokens + sum(
                    1 for c in plan.prefill
                    if c.start + c.length >= c.req.isl)
            finished = sched.commit(plan, t)
            done.extend(finished)
            for _ in finished:
                if rid < max_requests + warmup:
                    inject()

        measured = done[warmup:]
        ttfts = [r.ttft for r in measured if r.ttft is not None]
        tpots = [r.tpot for r in measured if r.tpot is not None]
        elapsed = max(t - (t_window_start or 0.0), 1e-9)
        mean_tpot = statistics.mean(tpots) if tpots else 0.0
        return SimMetrics(
            ttft_ms=1e3 * statistics.mean(ttfts) if ttfts else 0.0,
            tpot_ms=1e3 * mean_tpot,
            throughput_tok_s=gen_window / elapsed,
            tokens_per_s_per_user=(1.0 / mean_tpot) if mean_tpot else 0.0,
            completed=len(measured),
            steps=steps,
            per_request=[(r.ttft or 0.0, r.tpot or 0.0) for r in measured],
        )
