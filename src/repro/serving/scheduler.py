"""Iteration-level continuous-batching scheduler.

One scheduler implementation drives BOTH the real JAX engine and the
discrete-event simulator, so the simulator is an honest ground truth for the
paper's closed-form Algorithm 2: they share admission, chunking, and slot
policies and differ only in how an iteration's latency is obtained
(measured vs. perf-DB query).

Modeled runtime flags (the paper's framework-specific knobs):
  max_batch            decode slot count (engine batch dimension)
  max_num_tokens       per-iteration context-token capacity (C_ctx)
  chunked_prefill      split prompts into max_num_tokens-sized chunks
  prefill_priority     schedule prefill before decode when contending
  priority_admission   order the waiting queue by request priority
                       (higher first, FIFO within a priority class)
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional

from repro.serving.request import IterationPlan, Phase, PrefillChunk, Request


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    max_batch: int = 64
    max_num_tokens: int = 8192          # C_ctx
    chunked_prefill: bool = True
    prefill_priority: bool = True       # TRT-LLM-style context-first
    max_queue: int = 100_000
    priority_admission: bool = False    # multi-tenant priority ordering


class ContinuousBatchingScheduler:
    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.waiting: Deque[Request] = deque()
        self.prefilling: List[Request] = []
        self.decoding: List[Request] = []
        self._free_slots = list(range(cfg.max_batch))[::-1]

    # ------------------------------------------------------------------
    def add(self, req: Request) -> bool:
        if len(self.waiting) >= self.cfg.max_queue:
            return False
        req.phase = Phase.WAITING
        if self.cfg.priority_admission:
            # keep the queue sorted by descending priority, FIFO within a
            # class: insert before the first strictly-lower-priority entry
            for i, other in enumerate(self.waiting):
                if other.priority < req.priority:
                    self.waiting.insert(i, req)
                    return True
        self.waiting.append(req)
        return True

    @property
    def active(self) -> int:
        return len(self.waiting) + len(self.prefilling) + len(self.decoding)

    # ------------------------------------------------------------------
    def plan(self, now: float) -> IterationPlan:
        """Build the next iteration: fill C_ctx with prefill chunks, give the
        remaining slots to decode."""
        cfg = self.cfg
        budget = cfg.max_num_tokens
        chunks: List[PrefillChunk] = []

        # 1. continue partially-prefilled requests first (chunked mode)
        for req in list(self.prefilling):
            if budget <= 0:
                break
            take = min(req.isl - req.prefill_done, budget)
            if take > 0:
                chunks.append(PrefillChunk(req, req.prefill_done, take))
                budget -= take

        # 2. admit waiting requests while slots and token budget remain
        while self.waiting and self._free_slots and budget > 0:
            req = self.waiting[0]
            take = min(req.isl, budget) if cfg.chunked_prefill else req.isl
            if take > budget and not (budget == cfg.max_num_tokens
                                      and not cfg.chunked_prefill):
                break  # whole-prompt scheduling: wait for a freer iteration
            self.waiting.popleft()
            req.slot = self._free_slots.pop()
            req.phase = Phase.PREFILL
            if req.t_first_sched is None:
                req.t_first_sched = now
            self.prefilling.append(req)
            chunks.append(PrefillChunk(req, 0, take))
            budget -= take

        decode = list(self.decoding)
        return IterationPlan(prefill=chunks, decode=decode)

    # ------------------------------------------------------------------
    def commit(self, plan: IterationPlan, now: float) -> List[Request]:
        """Apply an executed iteration's effects; returns finished requests."""
        for chunk in plan.prefill:
            req = chunk.req
            req.prefill_done += chunk.length
            if req.prefill_done >= req.isl:
                # prefill complete -> first token produced this iteration
                req.phase = Phase.DECODE
                req.generated = 1
                if req.t_first_token is None:
                    req.t_first_token = now
                self.prefilling.remove(req)
                self.decoding.append(req)

        finished: List[Request] = []
        for req in plan.decode:
            req.generated += 1
            if req.generated >= req.osl:
                req.phase = Phase.DONE
                req.t_finish = now
                self.decoding.remove(req)
                self._free_slots.append(req.slot)
                finished.append(req)
        # a request that finishes prefill with osl == 1 is also done
        for req in list(self.decoding):
            if req.osl <= 1 and req.generated >= 1:
                req.phase = Phase.DONE
                req.t_finish = now
                self.decoding.remove(req)
                self._free_slots.append(req.slot)
                finished.append(req)
        return finished
