"""Request lifecycle types shared by the real engine and the simulator."""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional


class Phase(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"


@dataclasses.dataclass
class Request:
    rid: int
    isl: int                              # input sequence length
    osl: int                              # output sequence length target
    arrival: float = 0.0                  # seconds (virtual or wall)
    prompt: Optional[List[int]] = None    # real tokens (engine) or None (sim)
    tenant: str = "default"
    priority: int = 0                     # higher value admitted first

    # mutable lifecycle state
    phase: Phase = Phase.WAITING
    prefill_done: int = 0                 # prompt tokens processed so far
    generated: int = 0
    slot: int = -1                        # engine batch slot
    out_tokens: List[int] = dataclasses.field(default_factory=list)

    # metrics
    t_first_sched: Optional[float] = None
    t_first_token: Optional[float] = None
    t_finish: Optional[float] = None

    @property
    def ttft(self) -> Optional[float]:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.arrival

    @property
    def tpot(self) -> Optional[float]:
        if self.t_finish is None or self.t_first_token is None or self.osl <= 1:
            return None
        return (self.t_finish - self.t_first_token) / (self.osl - 1)


@dataclasses.dataclass
class PrefillChunk:
    req: Request
    start: int
    length: int


@dataclasses.dataclass
class IterationPlan:
    """What one engine iteration executes (the 'mixed step' of Alg. 2)."""
    prefill: List[PrefillChunk]
    decode: List[Request]

    @property
    def ctx_tokens(self) -> int:
        return sum(c.length for c in self.prefill)

    @property
    def gen_tokens(self) -> int:
        return len(self.decode)

    @property
    def empty(self) -> bool:
        return not self.prefill and not self.decode
