"""Real JAX serving engine (the "repro-jax" backend).

Continuous batching over fixed decode slots with a ring-buffer KV cache:

  - ONE compiled decode step for the whole slot array (fixed shapes +
    donated cache = the TPU-idiomatic analogue of CUDA-graph capture;
    flag: ``decode_bucketing``),
  - whole-prompt prefill compiled per distinct prompt length (the engine
    serves real tokens; the simulator models chunked prefill),
  - per-row positions so slots at different depths decode together,
  - greedy sampling; wall-clock TTFT/TPOT per request.

The configurator's Generator emits a ``LaunchConfig`` this engine consumes
directly (see repro/core/generator.py) — the paper's technique wired in as
a first-class feature.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs.base import ModelConfig
from repro.serving.request import Phase, Request
from repro.serving.scheduler import ContinuousBatchingScheduler, SchedulerConfig


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 8                 # decode slots
    max_seq: int = 256                 # KV allocation per slot
    kv_cache_hbm_fraction: float = 0.9  # resolved by the Generator
    decode_bucketing: bool = True      # fixed-shape compiled decode step
    max_num_tokens: int = 8192


class Engine:
    def __init__(self, cfg: ModelConfig, params: Any, ecfg: EngineConfig):
        if cfg.family not in ("dense", "vlm", "moe", "hybrid", "ssm"):
            raise ValueError(f"engine does not serve family {cfg.family!r}")
        self.cfg = cfg
        self.ecfg = ecfg
        self.params = params
        self.sched = ContinuousBatchingScheduler(SchedulerConfig(
            max_batch=ecfg.max_batch, max_num_tokens=ecfg.max_num_tokens,
            chunked_prefill=False))
        mod = models.module_for(cfg)
        W = mod.cache_width(cfg, ecfg.max_seq) if hasattr(mod, "cache_width") \
            else ecfg.max_seq
        self._W = W
        dt = models.param_dtype(cfg)
        B = ecfg.max_batch
        if cfg.family in ("dense", "vlm", "moe"):
            L, K, D = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
            self.cache = {
                "k": jnp.zeros((L, B, W, K, D), dt),
                "v": jnp.zeros((L, B, W, K, D), dt),
                "pos": jnp.zeros((B,), jnp.int32),
            }
        else:
            raise NotImplementedError(
                "batched slots for recurrent families use the static path")
        self._pos_host = np.zeros(B, np.int32)
        self._last_tok = np.zeros(B, np.int32)
        self._decode_fn = jax.jit(
            functools.partial(models.decode_step, cfg=self.cfg),
            static_argnames=(), donate_argnames=("cache",))
        self._prefill_cache: Dict[int, Any] = {}

    # ------------------------------------------------------------------
    def _prefill_fn(self, isl: int):
        if isl not in self._prefill_cache:
            self._prefill_cache[isl] = jax.jit(
                functools.partial(models.prefill, cfg=self.cfg,
                                  max_len=self._W))
        return self._prefill_cache[isl]

    # ------------------------------------------------------------------
    def add_request(self, req: Request) -> None:
        assert req.prompt is not None and len(req.prompt) == req.isl
        self.sched.add(req)

    def _run_prefill(self, req: Request) -> int:
        toks = jnp.asarray(np.asarray(req.prompt, np.int32)[None])
        logits, cache = self._prefill_fn(req.isl)(self.params, tokens=toks)
        slot = req.slot
        self.cache["k"] = self.cache["k"].at[:, slot].set(cache["k"][:, 0])
        self.cache["v"] = self.cache["v"].at[:, slot].set(cache["v"][:, 0])
        self._pos_host[slot] = req.isl
        tok = int(jnp.argmax(logits[0, -1]))
        self._last_tok[slot] = tok
        req.out_tokens.append(tok)
        return tok

    def _run_decode(self, active: List[Request]) -> None:
        self.cache["pos"] = jnp.asarray(self._pos_host)
        tokens = jnp.asarray(self._last_tok[:, None])
        logits, self.cache = self._decode_fn(
            params=self.params, token=tokens, cache=self.cache)
        logits.block_until_ready()
        new = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        for req in active:
            self._pos_host[req.slot] += 1
            self._last_tok[req.slot] = new[req.slot]
            req.out_tokens.append(int(new[req.slot]))

    # ------------------------------------------------------------------
    def step(self) -> List[Request]:
        """One engine iteration; returns requests finished this step."""
        now = time.perf_counter()
        plan = self.sched.plan(now)
        if plan.empty:
            return []
        for chunk in plan.prefill:     # whole prompts (chunked=False)
            self._run_prefill(chunk.req)
        if plan.decode:
            self._run_decode(plan.decode)
        now = time.perf_counter()
        return self.sched.commit(plan, now)

    def run_until_drained(self, max_steps: int = 100_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_steps):
            done.extend(self.step())
            if self.sched.active == 0:
                break
        return done
