"""Speculative-decoding extension (beyond-paper: §7 lists it as future
work).

Models draft-and-verify decoding on top of the operator database:

  - the DRAFT model runs γ autoregressive steps,
  - the TARGET model verifies γ+1 tokens in ONE step (a γ+1-token
    "mini-prefill" against the full KV cache),
  - with per-token acceptance rate a, the expected accepted tokens per
    round is E[n] = (1 - a^{γ+1}) / (1 - a)  (Leviathan et al. 2023),

so TPOT_spec = (γ·T_draft + T_verify(γ+1)) / E[n].  Both step latencies
come from the same PerfDatabase the rest of the configurator uses, so the
search composes: ``best_gamma`` sweeps γ under the workload's SLA.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.configs import get_config
from repro.core.config import ParallelismConfig, RuntimeFlags, WorkloadDescriptor
from repro.core.perf_database import PerfDatabase
from repro.core.session import InferenceSession
from repro.serving.sim import StepSpec


def expected_accepted(gamma: int, acceptance: float) -> float:
    """E[tokens emitted per draft-verify round] (includes the bonus token)."""
    a = min(max(acceptance, 0.0), 0.9999)
    return (1.0 - a ** (gamma + 1)) / (1.0 - a)


@dataclasses.dataclass
class SpecDecodeProjection:
    gamma: int
    tpot_ms: float                 # effective per-token latency
    tokens_per_s_user: float
    speedup_vs_autoregressive: float
    draft_step_ms: float
    verify_step_ms: float
    accepted_per_round: float


class SpeculativeEstimator:
    def __init__(self, workload: WorkloadDescriptor, draft_model: str,
                 db: Optional[PerfDatabase] = None):
        self.w = workload
        self.target = InferenceSession(workload, db)
        draft_w = dataclasses.replace(workload, model=draft_model)
        self.draft = InferenceSession(draft_w, self.target.db)

    def evaluate(self, par: ParallelismConfig, batch: int, gamma: int,
                 acceptance: float,
                 flags: RuntimeFlags = RuntimeFlags()) -> SpecDecodeProjection:
        kv = self.w.isl + self.w.osl // 2
        t_draft = self.draft.spec_latency_ms(
            par, StepSpec(prefill=(), decode=(kv,) * batch), flags)
        # verification: γ+1 query tokens per sequence against the cache —
        # a chunked-prefill-shaped step (compute-denser than decode)
        t_verify = self.target.spec_latency_ms(
            par, StepSpec(prefill=tuple((gamma + 1, kv)
                                        for _ in range(batch)),
                          decode=()), flags)
        t_ar = self.target.spec_latency_ms(
            par, StepSpec(prefill=(), decode=(kv,) * batch), flags)
        acc = expected_accepted(gamma, acceptance)
        tpot = (gamma * t_draft + t_verify) / acc
        return SpecDecodeProjection(
            gamma=gamma, tpot_ms=tpot,
            tokens_per_s_user=1000.0 / tpot if tpot else float("inf"),
            speedup_vs_autoregressive=t_ar / tpot if tpot else 0.0,
            draft_step_ms=t_draft, verify_step_ms=t_verify,
            accepted_per_round=acc)

    def best_gamma(self, par: ParallelismConfig, batch: int,
                   acceptance: float, max_gamma: int = 8
                   ) -> Tuple[SpecDecodeProjection, list]:
        projs = [self.evaluate(par, batch, g, acceptance)
                 for g in range(1, max_gamma + 1)]
        return min(projs, key=lambda p: p.tpot_ms), projs
