"""Pareto analyzer (§4.1): filter SLA-valid projections, compute the
throughput-vs-speed Pareto frontier, rank the winners.

Two implementations of the same frontier: batch :func:`frontier` (sort the
full list once) and the online :class:`FrontierAccumulator` (maintain the
non-dominated set as projections stream in, O(frontier) per insert).  The
streaming search path uses the accumulator; the batch function stays as the
independent oracle the property tests compare it against.
"""
from __future__ import annotations

import bisect
from typing import Iterable, List, Optional, Sequence, Tuple

from repro.core.config import Projection, SLA


def sla_filter(projs: Sequence[Projection], sla: SLA) -> List[Projection]:
    return [p for p in projs if p.meets(sla)]


def frontier(projs: Sequence[Projection]) -> List[Projection]:
    """Non-dominated set over (tokens/s/user ↑, tokens/s/chip ↑),
    sorted by speed descending."""
    pts = sorted(projs, key=lambda p: (-p.tokens_per_s_user,
                                       -p.tokens_per_s_per_chip))
    out: List[Projection] = []
    best_thru = -1.0
    for p in pts:
        if p.tokens_per_s_per_chip > best_thru:
            out.append(p)
            best_thru = p.tokens_per_s_per_chip
    return out


class FrontierAccumulator:
    """Online Pareto frontier over (tokens/s/user ↑, tokens/s/chip ↑).

    Invariant: the internal list is sorted by speed strictly descending,
    which forces per-chip throughput strictly ascending.  ``add`` locates
    the insertion point by bisection, rejects dominated/duplicate points,
    and evicts the contiguous run of points the newcomer dominates — so an
    insert costs O(log f) search plus O(evicted) removals, never a re-sort
    of everything seen so far.  Fed any permutation of a projection list,
    the final set equals batch :func:`frontier` of that list (first-seen
    instance wins among (speed, throughput) duplicates, matching the
    stable batch sort).
    """

    def __init__(self, projs: Optional[Iterable[Projection]] = None):
        self._neg_speeds: List[float] = []    # negated ⇒ ascending for bisect
        self._points: List[Projection] = []   # speed desc, throughput asc
        for p in projs or ():
            self.add(p)

    def __len__(self) -> int:
        return len(self._points)

    def add(self, p: Projection) -> bool:
        """Insert one projection; True iff it joined the frontier."""
        speed, thru = p.tokens_per_s_user, p.tokens_per_s_per_chip
        i = bisect.bisect_left(self._neg_speeds, -speed)
        # points[:i] are strictly faster; the slowest of them carries the
        # highest throughput, so it alone decides domination from the left
        if i > 0 and self._points[i - 1].tokens_per_s_per_chip >= thru:
            return False
        if i < len(self._points) \
                and self._points[i].tokens_per_s_user == speed:
            if self._points[i].tokens_per_s_per_chip >= thru:
                return False          # dominated at equal speed (or duplicate)
            del self._neg_speeds[i], self._points[i]
        j = i                         # evict the run p now dominates
        while j < len(self._points) \
                and self._points[j].tokens_per_s_per_chip <= thru:
            j += 1
        del self._neg_speeds[i:j], self._points[i:j]
        self._neg_speeds.insert(i, -speed)
        self._points.insert(i, p)
        return True

    def frontier(self) -> List[Projection]:
        """Current non-dominated set, sorted by speed descending (the same
        order batch :func:`frontier` emits)."""
        return list(self._points)

    def dominates(self, p: Projection) -> bool:
        """Would ``add(p)`` be rejected? (Read-only domination probe.)"""
        speed, thru = p.tokens_per_s_user, p.tokens_per_s_per_chip
        i = bisect.bisect_left(self._neg_speeds, -speed)
        if i > 0 and self._points[i - 1].tokens_per_s_per_chip >= thru:
            return True
        return (i < len(self._points)
                and self._points[i].tokens_per_s_user == speed
                and self._points[i].tokens_per_s_per_chip >= thru)


def top_k(projs: Sequence[Projection], sla: SLA, k: int = 5) -> List[Projection]:
    """Highest per-chip throughput among SLA-compliant configs."""
    ok = sla_filter(projs, sla)
    return sorted(ok, key=lambda p: -p.tokens_per_s_per_chip)[:k]


def best(projs: Sequence[Projection], sla: SLA) -> Optional[Projection]:
    ranked = top_k(projs, sla, 1)
    return ranked[0] if ranked else None
