"""Pareto analyzer (§4.1): filter SLA-valid projections, compute the
throughput-vs-speed Pareto frontier, rank the winners."""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from repro.core.config import Projection, SLA


def sla_filter(projs: Sequence[Projection], sla: SLA) -> List[Projection]:
    return [p for p in projs if p.meets(sla)]


def frontier(projs: Sequence[Projection]) -> List[Projection]:
    """Non-dominated set over (tokens/s/user ↑, tokens/s/chip ↑),
    sorted by speed descending."""
    pts = sorted(projs, key=lambda p: (-p.tokens_per_s_user,
                                       -p.tokens_per_s_per_chip))
    out: List[Projection] = []
    best_thru = -1.0
    for p in pts:
        if p.tokens_per_s_per_chip > best_thru:
            out.append(p)
            best_thru = p.tokens_per_s_per_chip
    return out


def top_k(projs: Sequence[Projection], sla: SLA, k: int = 5) -> List[Projection]:
    """Highest per-chip throughput among SLA-compliant configs."""
    ok = sla_filter(projs, sla)
    return sorted(ok, key=lambda p: -p.tokens_per_s_per_chip)[:k]


def best(projs: Sequence[Projection], sla: SLA) -> Optional[Projection]:
    ranked = top_k(projs, sla, 1)
    return ranked[0] if ranked else None
