"""Iteration -> operator decomposition (§4.3).

An inference iteration is a fixed operator sequence repeated per layer;
parallelism rescales operator shapes and inserts well-defined collectives
(Fig. 4).  ``iteration_ops`` builds the operator list for one iteration
described by a ``StepSpec`` (prefill chunks + decode rows — the same spec
the discrete-event simulator emits), under a ParallelismConfig, for any
architecture family in the registry.

Backend differences (§4.3: "the exact pair [of EP collectives] depends on
the inference engine backend"):
  repro-jax : GSPMD-style all-gather dispatch + reduce-scatter combine
              (matches what our real lowering emits)
  trtllm    : all-to-all dispatch/combine
  sglang    : all-to-all dispatch/combine
  vllm      : all-gather + reduce-scatter
"""
from __future__ import annotations

import math
from typing import List, Optional

from repro.configs.base import ModelConfig
from repro.core import operators as ops
from repro.core import powerlaw
from repro.core.config import ParallelismConfig
from repro.serving.sim import StepSpec

EP_A2A_BACKENDS = {"trtllm", "sglang"}


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# per-layer operator builders (token counts are per pipeline microbatch)
# ---------------------------------------------------------------------------

def _attn_ops(cfg: ModelConfig, par: ParallelismConfig, spec: StepSpec,
              dtype: str, window: int, mb: int) -> List:
    """QKV/out GEMMs + fused attention for one layer."""
    tp = par.tp
    hd = cfg.head_dim
    h_loc = _ceil(cfg.num_heads, tp)
    kv_loc = _ceil(cfg.num_kv_heads, tp) if cfg.num_kv_heads >= tp else 1
    T = _tokens(spec, mb)
    out: List = []
    if T == 0:
        return out
    out.append(ops.GEMM(T, (h_loc + 2 * kv_loc) * hd, cfg.d_model, dtype))
    for (clen, past) in spec.prefill[:: max(mb, 1)] if mb > 1 else spec.prefill:
        out.append(ops.Attention(
            "prefill", 1, clen, past + clen, h_loc, kv_loc, hd,
            cfg.attention_kind, window, dtype, q_offset=past))
    dec = spec.decode[:: mb] if mb > 1 else spec.decode
    if dec:
        kv_mean = int(sum(dec) / len(dec))
        out.append(ops.Attention(
            "decode", len(dec), 1, kv_mean, h_loc, kv_loc, hd,
            cfg.attention_kind, window, dtype))
        # KV write-out for the new tokens
        out.append(ops.MemOp(len(dec) * 2 * kv_loc * hd * ops.BYTES[dtype]))
    out.append(ops.GEMM(T, cfg.d_model, h_loc * hd, dtype))
    if tp > 1:
        out.append(ops.Comm("all_reduce",
                            T * cfg.d_model * ops.BYTES[dtype], tp))
    return out


def _dense_ffn_ops(cfg, par, T, dtype, d_ff=None) -> List:
    tp = par.tp
    f_loc = _ceil(d_ff or cfg.d_ff, tp)
    out = [
        ops.GEMM(T, 2 * f_loc, cfg.d_model, dtype),       # gate+up fused
        ops.GEMM(T, cfg.d_model, f_loc, dtype),           # down
    ]
    if tp > 1:
        out.append(ops.Comm("all_reduce", T * cfg.d_model * ops.BYTES[dtype], tp))
    return out


def _moe_ops(cfg, par, T, dtype, alpha, backend, seed) -> List:
    tp, ep = par.tp, min(par.ep, par.tp)
    b = ops.BYTES[dtype]
    out: List = [ops.GEMM(T, cfg.num_experts, cfg.d_model, dtype)]  # router
    # dispatch + combine
    payload = T * cfg.top_k * cfg.d_model * b / max(ep, 1)
    if ep > 1:
        kind = "all_to_all" if backend in EP_A2A_BACKENDS else "all_gather"
        out.append(ops.Comm(kind, payload, ep))
    hot = powerlaw.hot_rank_tokens(T, cfg.top_k, cfg.num_experts, ep,
                                   alpha, seed)
    tp_in_expert = max(tp // ep, 1)
    out.append(ops.MoEOp(
        tokens=T, d_model=cfg.d_model,
        d_ff=_ceil(cfg.moe_d_ff, tp_in_expert),
        num_experts=cfg.num_experts, top_k=cfg.top_k, ep=ep,
        hot_rank_tokens=hot, dtype=dtype))
    if cfg.n_shared_experts:
        out += _dense_ffn_ops(cfg, par, T, dtype,
                              d_ff=cfg.n_shared_experts * cfg.moe_d_ff)[:-1]
    if ep > 1:
        kind = "all_to_all" if backend in EP_A2A_BACKENDS else "reduce_scatter"
        out.append(ops.Comm(kind, payload, ep))
    if tp > 1:
        out.append(ops.Comm("all_reduce", T * cfg.d_model * b, tp))
    return out


def _rec_ops(cfg, par, spec: StepSpec, dtype, mb, kind: str) -> List:
    """RG-LRU temporal block (in/gate proj, conv, scan, out proj)."""
    tp = par.tp
    T = _tokens(spec, mb)
    if T == 0:
        return []
    w_loc = _ceil(cfg.lru_width, tp)
    b = ops.BYTES[dtype]
    batch = max(len(spec.decode[:: mb] if mb > 1 else spec.decode), 1) \
        if not spec.prefill else 1
    seq = T if spec.prefill else 1
    out = [
        ops.GEMM(T, 2 * w_loc, cfg.d_model, dtype),
        ops.MemOp(T * w_loc * b * cfg.conv_width),
        ops.RecurrentOp(kind, batch, seq, w_loc, cfg.num_heads, dtype),
        ops.GEMM(T, cfg.d_model, w_loc, dtype),
    ]
    if tp > 1:
        out.append(ops.Comm("all_reduce", T * cfg.d_model * b, tp))
    return out


def _mlstm_ops(cfg, par, spec, dtype, mb) -> List:
    from repro.models.xlstm import up_dim
    tp = par.tp
    T = _tokens(spec, mb)
    if T == 0:
        return []
    u = up_dim(cfg)
    u_loc = _ceil(u, tp)
    b = ops.BYTES[dtype]
    batch = max(len(spec.decode), 1) if not spec.prefill else 1
    seq = T if spec.prefill else 1
    out = [
        ops.GEMM(T, 2 * u_loc, cfg.d_model, dtype),       # up + gate
        ops.MemOp(T * u_loc * b * cfg.conv_width),
        ops.GEMM(T, 3 * u_loc, u, dtype),                 # q,k,v
        ops.RecurrentOp("mlstm", batch, seq, u_loc, cfg.num_heads, dtype),
        ops.GEMM(T, cfg.d_model, u_loc, dtype),
    ]
    if tp > 1:
        out.append(ops.Comm("all_reduce", T * cfg.d_model * b, tp))
    return out


def _slstm_ops(cfg, par, spec, dtype, mb) -> List:
    tp = par.tp
    T = _tokens(spec, mb)
    if T == 0:
        return []
    d = cfg.d_model
    b = ops.BYTES[dtype]
    f = int(d * cfg.slstm_proj_factor)
    batch = max(len(spec.decode), 1) if not spec.prefill else 1
    seq = T if spec.prefill else 1
    out = [
        ops.GEMM(T, _ceil(4 * d, tp), d, dtype),
        ops.RecurrentOp("slstm", batch, seq, _ceil(d, tp), cfg.num_heads, dtype),
        ops.GEMM(T, _ceil(2 * f, tp), d, dtype),
        ops.GEMM(T, d, _ceil(f, tp), dtype),
    ]
    if tp > 1:
        out.append(ops.Comm("all_reduce", T * d * b, tp))
    return out


def _tokens(spec: StepSpec, mb: int) -> int:
    t = sum(c for c, _ in spec.prefill) + len(spec.decode)
    return _ceil(t, mb) if mb > 1 else t


# ---------------------------------------------------------------------------
# whole-iteration decomposition
# ---------------------------------------------------------------------------

def iteration_ops(cfg: ModelConfig, par: ParallelismConfig, spec: StepSpec,
                  *, alpha: float = 1.2, backend: str = "repro-jax",
                  dtype: str = "bf16", seed: int = 0) -> List:
    """Weighted (operator, count) list for ONE iteration (one pipeline
    microbatch's full pass + inter-stage P2P).  Identical layers share one
    operator entry with a count — that is why per-config search time stays
    ~constant in model size (paper Table 1: ~1.5 ms/config regardless of
    parameter count).  Latency = PerfDatabase.sequence_latency(result)."""
    mb = par.pp                       # microbatch split factor
    T = _tokens(spec, mb)
    if T == 0:
        return []
    b = ops.BYTES[dtype]
    out: List = [(ops.Embedding(T, cfg.vocab_size, cfg.d_model, dtype), 1)]
    window = cfg.sliding_window

    # encoder pass (whisper): runs once per request, charged to the
    # iteration where the request's first chunk appears
    if cfg.is_encoder_decoder:
        new_reqs = sum(1 for c, past in spec.prefill if past == 0)
        if new_reqs:
            F = cfg.num_source_positions * new_reqs
            enc_spec = StepSpec(prefill=((F, 0),), decode=())
            enc_layer = (_attn_ops(cfg, par, enc_spec, dtype, 0, 1)
                         + _dense_ffn_ops(cfg, par, F, dtype))
            out.extend((op, cfg.encoder_layers) for op in enc_layer)
            # cross-KV projection for every decoder layer
            out.append((ops.GEMM(
                F * cfg.num_layers,
                2 * _ceil(cfg.num_heads, par.tp) * cfg.head_dim,
                cfg.d_model, dtype), 1))

    # Layers of the same kind produce identical operator lists -> build each
    # kind ONCE and emit (op, count) pairs; keeps per-config search cost at
    # the paper's ~1.5 ms scale.
    def emit(layer_ops: List, count: int):
        out.extend((op, count) for op in layer_ops)

    if cfg.family == "hybrid":
        n_attn = sum(1 for k in cfg.block_pattern if k == "attn")
        n_rec = cfg.num_layers - n_attn
        emit(_rec_ops(cfg, par, spec, dtype, mb, "rglru"), n_rec)
        emit(_attn_ops(cfg, par, spec, dtype, cfg.local_window, mb), n_attn)
        emit(_dense_ffn_ops(cfg, par, T, dtype), cfg.num_layers)
    elif cfg.family == "ssm":
        n_m = sum(1 for k in cfg.block_pattern if k == "m")
        emit(_mlstm_ops(cfg, par, spec, dtype, mb), n_m)
        emit(_slstm_ops(cfg, par, spec, dtype, mb), cfg.num_layers - n_m)
    else:
        emit(_attn_ops(cfg, par, spec, dtype, window, mb), cfg.num_layers)
        if cfg.is_encoder_decoder:
            # cross attention (KV = encoder frames, precomputed)
            h_loc = _ceil(cfg.num_heads, par.tp)
            emit([ops.GEMM(T, h_loc * cfg.head_dim, cfg.d_model, dtype),
                  ops.Attention(
                      "decode" if not spec.prefill else "prefill",
                      max(len(spec.decode), 1), 1 if not spec.prefill else T,
                      cfg.num_source_positions, h_loc, h_loc, cfg.head_dim,
                      "mha", 0, dtype),
                  ops.GEMM(T, cfg.d_model, h_loc * cfg.head_dim, dtype)],
                 cfg.num_layers)
        if cfg.num_experts:
            emit(_moe_ops(cfg, par, T, dtype, alpha, backend, seed),
                 cfg.num_layers)
        else:
            emit(_dense_ffn_ops(cfg, par, T, dtype), cfg.num_layers)

    # LM head for rows that emit a token this iteration
    n_emit = len(spec.decode) + sum(1 for _ in spec.prefill)
    if n_emit:
        v_loc = _ceil(cfg.vocab_size, par.tp)
        out.append((ops.GEMM(n_emit, v_loc, cfg.d_model, dtype), 1))
        if par.tp > 1:
            out.append((ops.Comm("all_gather", n_emit * v_loc * 4, par.tp), 1))

    # pipeline-parallel inter-stage transfers
    if par.pp > 1:
        out.append((ops.Comm("p2p", T * cfg.d_model * b, 2), par.pp - 1))
    return out


# ---------------------------------------------------------------------------
# memory model (per chip) — used by TaskRunner pruning and the Generator's
# kv_cache_mem_fraction resolution
# ---------------------------------------------------------------------------

def param_bytes_per_chip(cfg: ModelConfig, par: ParallelismConfig,
                         dtype: str = "bf16") -> float:
    b = ops.BYTES[dtype]
    total = cfg.param_count() * b
    if cfg.num_experts:
        expert = cfg.num_layers * cfg.num_experts * 3 * cfg.d_model * cfg.moe_d_ff * b
        dense = total - expert
        ep = min(par.ep, par.tp)
        shard = expert / max(ep * max(par.tp // ep, 1), 1)
        return (dense / par.tp + shard) / par.pp
    return total / (par.tp * par.pp)


def kv_bytes_per_chip(cfg: ModelConfig, par: ParallelismConfig, batch: int,
                      seq: int, dtype: str = "bf16") -> float:
    b = ops.BYTES[dtype]
    if cfg.family == "ssm":
        from repro.models.xlstm import up_dim
        u = up_dim(cfg)
        per_tok_indep = cfg.num_layers / 2 * (u // cfg.num_heads * u + 4 * cfg.d_model)
        return batch * per_tok_indep * 4 / (par.tp * par.pp)
    kv_loc = max(_ceil(cfg.num_kv_heads, par.tp), 1)
    total = 0.0
    for li in range(cfg.num_layers):
        kind = cfg.block_pattern[li] if cfg.block_pattern else "attn"
        W = cfg.kv_cache_len(seq, kind)
        if kind == "rec":
            total += cfg.lru_width * 4 + cfg.lru_width * cfg.conv_width * b
        else:
            total += 2 * W * kv_loc * cfg.head_dim * b
    if cfg.is_encoder_decoder:
        total += (cfg.num_layers * 2 * cfg.num_source_positions
                  * _ceil(cfg.num_heads, par.tp) * cfg.head_dim * b)
    return batch * total / par.pp


def activation_bytes_per_chip(cfg: ModelConfig, par: ParallelismConfig,
                              max_tokens: int, dtype: str = "bf16") -> float:
    b = ops.BYTES[dtype]
    width = max(cfg.d_ff or cfg.d_model, cfg.moe_d_ff * cfg.top_k if cfg.num_experts else 0)
    return max_tokens * (cfg.d_model + _ceil(2 * width, par.tp)) * b * 2


def fits_memory(cfg: ModelConfig, par: ParallelismConfig, batch: int,
                seq: int, platform, flags=None, dtype: str = "bf16"):
    """Returns (fits, bytes_per_chip)."""
    kv_frac = flags.kv_cache_mem_fraction if flags else 0.9
    p = param_bytes_per_chip(cfg, par, dtype)
    a = activation_bytes_per_chip(cfg, par,
                                  flags.max_num_tokens if flags else 8192, dtype)
    k = kv_bytes_per_chip(cfg, par, batch, seq, dtype)
    free_for_kv = (platform.hbm_capacity - p - a) * kv_frac
    return k <= max(free_for_kv, 0.0), p + a + k
