"""Iteration -> operator decomposition (§4.3).

An inference iteration is a fixed operator sequence repeated per layer;
parallelism rescales operator shapes and inserts well-defined collectives
(Fig. 4).  ``iteration_ops`` builds the operator list for one iteration
described by a ``StepSpec`` (prefill chunks + decode rows — the same spec
the discrete-event simulator emits), under a ParallelismConfig, for any
architecture family in the registry.

Backend differences (§4.3: "the exact pair [of EP collectives] depends on
the inference engine backend"):
  repro-jax : GSPMD-style all-gather dispatch + reduce-scatter combine
              (matches what our real lowering emits)
  trtllm    : all-to-all dispatch/combine
  sglang    : all-to-all dispatch/combine
  vllm      : all-gather + reduce-scatter
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import operators as ops
from repro.core import powerlaw
from repro.core.config import ParallelismConfig
from repro.serving.sim import StepSpec

EP_A2A_BACKENDS = {"trtllm", "sglang"}


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# per-layer operator builders (token counts are per pipeline microbatch)
# ---------------------------------------------------------------------------

def _attn_ops(cfg: ModelConfig, par: ParallelismConfig, spec: StepSpec,
              dtype: str, window: int, mb: int) -> List:
    """QKV/out GEMMs + fused attention for one layer."""
    tp = par.tp
    hd = cfg.head_dim
    h_loc = _ceil(cfg.num_heads, tp)
    kv_loc = _ceil(cfg.num_kv_heads, tp) if cfg.num_kv_heads >= tp else 1
    T = _tokens(spec, mb)
    out: List = []
    if T == 0:
        return out
    out.append(ops.GEMM(T, (h_loc + 2 * kv_loc) * hd, cfg.d_model, dtype))
    for (clen, past) in spec.prefill[:: max(mb, 1)] if mb > 1 else spec.prefill:
        out.append(ops.Attention(
            "prefill", 1, clen, past + clen, h_loc, kv_loc, hd,
            cfg.attention_kind, window, dtype, q_offset=past))
    dec = spec.decode[:: mb] if mb > 1 else spec.decode
    if dec:
        kv_mean = int(sum(dec) / len(dec))
        out.append(ops.Attention(
            "decode", len(dec), 1, kv_mean, h_loc, kv_loc, hd,
            cfg.attention_kind, window, dtype))
        # KV write-out for the new tokens
        out.append(ops.MemOp(len(dec) * 2 * kv_loc * hd * ops.BYTES[dtype]))
    out.append(ops.GEMM(T, cfg.d_model, h_loc * hd, dtype))
    if tp > 1:
        out.append(ops.Comm("all_reduce",
                            T * cfg.d_model * ops.BYTES[dtype], tp))
    return out


def _dense_ffn_ops(cfg, par, T, dtype, d_ff=None) -> List:
    tp = par.tp
    f_loc = _ceil(d_ff or cfg.d_ff, tp)
    out = [
        ops.GEMM(T, 2 * f_loc, cfg.d_model, dtype),       # gate+up fused
        ops.GEMM(T, cfg.d_model, f_loc, dtype),           # down
    ]
    if tp > 1:
        out.append(ops.Comm("all_reduce", T * cfg.d_model * ops.BYTES[dtype], tp))
    return out


def _moe_ops(cfg, par, T, dtype, alpha, backend, seed) -> List:
    tp, ep = par.tp, min(par.ep, par.tp)
    b = ops.BYTES[dtype]
    out: List = [ops.GEMM(T, cfg.num_experts, cfg.d_model, dtype)]  # router
    # dispatch + combine.  Comm convention (see ops.Comm): gather/scatter
    # collectives take the FULL logical token tensor — the collective model
    # applies the (n-1)/n sharding itself — while all-to-all takes the
    # per-chip payload each rank actually sends.
    a2a = backend in EP_A2A_BACKENDS
    payload = T * cfg.top_k * cfg.d_model * b
    if a2a:
        payload = payload / max(ep, 1)
    if ep > 1:
        kind = "all_to_all" if a2a else "all_gather"
        out.append(ops.Comm(kind, payload, ep))
    hot = powerlaw.hot_rank_tokens(T, cfg.top_k, cfg.num_experts, ep,
                                   alpha, seed)
    tp_in_expert = max(tp // ep, 1)
    out.append(ops.MoEOp(
        tokens=T, d_model=cfg.d_model,
        d_ff=_ceil(cfg.moe_d_ff, tp_in_expert),
        num_experts=cfg.num_experts, top_k=cfg.top_k, ep=ep,
        hot_rank_tokens=hot, dtype=dtype))
    if cfg.n_shared_experts:
        out += _dense_ffn_ops(cfg, par, T, dtype,
                              d_ff=cfg.n_shared_experts * cfg.moe_d_ff)[:-1]
    if ep > 1:
        kind = "all_to_all" if a2a else "reduce_scatter"
        out.append(ops.Comm(kind, payload, ep))
    if tp > 1:
        out.append(ops.Comm("all_reduce", T * cfg.d_model * b, tp))
    return out


def _rec_ops(cfg, par, spec: StepSpec, dtype, mb, kind: str) -> List:
    """RG-LRU temporal block (in/gate proj, conv, scan, out proj)."""
    tp = par.tp
    T = _tokens(spec, mb)
    if T == 0:
        return []
    w_loc = _ceil(cfg.lru_width, tp)
    b = ops.BYTES[dtype]
    batch = max(len(spec.decode[:: mb] if mb > 1 else spec.decode), 1) \
        if not spec.prefill else 1
    seq = T if spec.prefill else 1
    out = [
        ops.GEMM(T, 2 * w_loc, cfg.d_model, dtype),
        ops.MemOp(T * w_loc * b * cfg.conv_width),
        ops.RecurrentOp(kind, batch, seq, w_loc, cfg.num_heads, dtype),
        ops.GEMM(T, cfg.d_model, w_loc, dtype),
    ]
    if tp > 1:
        out.append(ops.Comm("all_reduce", T * cfg.d_model * b, tp))
    return out


def _mlstm_ops(cfg, par, spec, dtype, mb) -> List:
    from repro.models.xlstm import up_dim
    tp = par.tp
    T = _tokens(spec, mb)
    if T == 0:
        return []
    u = up_dim(cfg)
    u_loc = _ceil(u, tp)
    b = ops.BYTES[dtype]
    batch = max(len(spec.decode), 1) if not spec.prefill else 1
    seq = T if spec.prefill else 1
    out = [
        ops.GEMM(T, 2 * u_loc, cfg.d_model, dtype),       # up + gate
        ops.MemOp(T * u_loc * b * cfg.conv_width),
        ops.GEMM(T, 3 * u_loc, u, dtype),                 # q,k,v
        ops.RecurrentOp("mlstm", batch, seq, u_loc, cfg.num_heads, dtype),
        ops.GEMM(T, cfg.d_model, u_loc, dtype),
    ]
    if tp > 1:
        out.append(ops.Comm("all_reduce", T * cfg.d_model * b, tp))
    return out


def _slstm_ops(cfg, par, spec, dtype, mb) -> List:
    tp = par.tp
    T = _tokens(spec, mb)
    if T == 0:
        return []
    d = cfg.d_model
    b = ops.BYTES[dtype]
    f = int(d * cfg.slstm_proj_factor)
    batch = max(len(spec.decode), 1) if not spec.prefill else 1
    seq = T if spec.prefill else 1
    out = [
        ops.GEMM(T, _ceil(4 * d, tp), d, dtype),
        ops.RecurrentOp("slstm", batch, seq, _ceil(d, tp), cfg.num_heads, dtype),
        ops.GEMM(T, _ceil(2 * f, tp), d, dtype),
        ops.GEMM(T, d, _ceil(f, tp), dtype),
    ]
    if tp > 1:
        out.append(ops.Comm("all_reduce", T * d * b, tp))
    return out


def _tokens(spec: StepSpec, mb: int) -> int:
    t = sum(c for c, _ in spec.prefill) + len(spec.decode)
    return _ceil(t, mb) if mb > 1 else t


# ---------------------------------------------------------------------------
# whole-iteration decomposition
# ---------------------------------------------------------------------------

def iteration_ops(cfg: ModelConfig, par: ParallelismConfig, spec: StepSpec,
                  *, alpha: float = 1.2, backend: str = "repro-jax",
                  dtype: str = "bf16", seed: int = 0) -> List:
    """Weighted (operator, count) list for ONE iteration (one pipeline
    microbatch's full pass + inter-stage P2P).  Identical layers share one
    operator entry with a count — that is why per-config search time stays
    ~constant in model size (paper Table 1: ~1.5 ms/config regardless of
    parameter count).  Latency = PerfDatabase.sequence_latency(result)."""
    mb = par.pp                       # microbatch split factor
    T = _tokens(spec, mb)
    if T == 0:
        return []
    b = ops.BYTES[dtype]
    out: List = [(ops.Embedding(T, cfg.vocab_size, cfg.d_model, dtype), 1)]
    window = cfg.sliding_window

    # encoder pass (whisper): runs once per request, charged to the
    # iteration where the request's first chunk appears
    if cfg.is_encoder_decoder:
        new_reqs = sum(1 for c, past in spec.prefill if past == 0)
        if new_reqs:
            F = cfg.num_source_positions * new_reqs
            enc_spec = StepSpec(prefill=((F, 0),), decode=())
            enc_layer = (_attn_ops(cfg, par, enc_spec, dtype, 0, 1)
                         + _dense_ffn_ops(cfg, par, F, dtype))
            out.extend((op, cfg.encoder_layers) for op in enc_layer)
            # cross-KV projection for every decoder layer
            out.append((ops.GEMM(
                F * cfg.num_layers,
                2 * _ceil(cfg.num_heads, par.tp) * cfg.head_dim,
                cfg.d_model, dtype), 1))

    # Layers of the same kind produce identical operator lists -> build each
    # kind ONCE and emit (op, count) pairs; keeps per-config search cost at
    # the paper's ~1.5 ms scale.
    def emit(layer_ops: List, count: int):
        out.extend((op, count) for op in layer_ops)

    if cfg.family == "hybrid":
        n_attn = sum(1 for k in cfg.block_pattern if k == "attn")
        n_rec = cfg.num_layers - n_attn
        emit(_rec_ops(cfg, par, spec, dtype, mb, "rglru"), n_rec)
        emit(_attn_ops(cfg, par, spec, dtype, cfg.local_window, mb), n_attn)
        emit(_dense_ffn_ops(cfg, par, T, dtype), cfg.num_layers)
    elif cfg.family == "ssm":
        n_m = sum(1 for k in cfg.block_pattern if k == "m")
        emit(_mlstm_ops(cfg, par, spec, dtype, mb), n_m)
        emit(_slstm_ops(cfg, par, spec, dtype, mb), cfg.num_layers - n_m)
    else:
        emit(_attn_ops(cfg, par, spec, dtype, window, mb), cfg.num_layers)
        if cfg.is_encoder_decoder:
            # cross attention (KV = encoder frames, precomputed)
            h_loc = _ceil(cfg.num_heads, par.tp)
            emit([ops.GEMM(T, h_loc * cfg.head_dim, cfg.d_model, dtype),
                  ops.Attention(
                      "decode" if not spec.prefill else "prefill",
                      max(len(spec.decode), 1), 1 if not spec.prefill else T,
                      cfg.num_source_positions, h_loc, h_loc, cfg.head_dim,
                      "mha", 0, dtype),
                  ops.GEMM(T, cfg.d_model, h_loc * cfg.head_dim, dtype)],
                 cfg.num_layers)
        if cfg.num_experts:
            emit(_moe_ops(cfg, par, T, dtype, alpha, backend, seed),
                 cfg.num_layers)
        else:
            emit(_dense_ffn_ops(cfg, par, T, dtype), cfg.num_layers)

    # LM head for rows that emit a token this iteration
    n_emit = len(spec.decode) + sum(1 for _ in spec.prefill)
    if n_emit:
        v_loc = _ceil(cfg.vocab_size, par.tp)
        out.append((ops.GEMM(n_emit, v_loc, cfg.d_model, dtype), 1))
        if par.tp > 1:
            # full fp32 logits tensor (tp·v_loc covers the padded vocab) —
            # all_gather takes the full tensor per the Comm convention
            out.append((ops.Comm("all_gather", n_emit * v_loc * par.tp * 4,
                                 par.tp), 1))

    # pipeline-parallel inter-stage transfers
    if par.pp > 1:
        out.append((ops.Comm("p2p", T * cfg.d_model * b, 2), par.pp - 1))
    return out


# ---------------------------------------------------------------------------
# batch encoding — struct-of-arrays lowering for
# PerfDatabase.sequence_latency_batch (the fused whole-space pricing kernel)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GridRows:
    """All rows of one candidate batch that price through one OpGrid.

    Coordinates are deduplicated at encode time: ``coords`` holds only
    the U distinct query points, and ``ridx`` maps each of the R logical
    rows back to its coordinate.  Candidate spaces revisit the same
    shapes constantly (2.5-6.5x duplication on the Table-1 spaces), so
    the interpolation kernel runs on U rows while the per-item
    ``bincount`` still sees all R.
    """
    rep_op: object          # representative operator — resolves/builds the grid
    family: str             # calibration family (ops.op_family name)
    coords: np.ndarray      # [U, ndim] float64 distinct grid query coordinates
    mult: np.ndarray        # [R] float64 multiplicity (layer count × batch fold)
    item: np.ndarray        # [R] int64 owning item index
    ridx: np.ndarray        # [R] int64 index into coords for each logical row


SOL_MEM = 0                 # HBM stream (MemOp): value = bytes moved
SOL_EMBED = 1               # embedding gather:   value = bytes moved


@dataclasses.dataclass
class SolRows:
    """Speed-of-light rows — the unprofiled ops the scalar path sends to
    ``analytical.latency`` directly (no grid, no calibration correction)."""
    kind: np.ndarray        # [S] int8 (SOL_MEM | SOL_EMBED)
    value: np.ndarray       # [S] float64 bytes moved
    mult: np.ndarray        # [S] float64 multiplicity
    item: np.ndarray        # [S] int64 owning item index


@dataclasses.dataclass
class OpBatch:
    """One candidate batch, lowered to per-grid stacked arrays."""
    n_items: int
    grid_rows: List[GridRows]
    sol_rows: Optional[SolRows]

    @property
    def n_rows(self) -> int:
        rows = sum(len(g.item) for g in self.grid_rows)
        return rows + (len(self.sol_rows.item) if self.sol_rows else 0)


class _BatchAcc:
    """Mutable row accumulator the per-item encoders append into."""
    __slots__ = ("groups", "_sol")

    def __init__(self):
        self.groups: Dict[Tuple, Tuple] = {}
        self._sol = ([], [], [], [])            # kind, value, mult, item

    def gemm(self, dtype, m, n, k, mult, it):
        key = ("gemm", dtype)
        g = self.groups.get(key)
        if g is None:
            g = (ops.GEMM(1, 1, 1, dtype), "gemm", [], [], [])
            self.groups[key] = g
        g[2].append((m, n, k)); g[3].append(mult); g[4].append(it)

    def attn(self, phase, akind, h_loc, kv_loc, hd, dtype, coords, mult, it):
        key = ("attn", phase, akind, h_loc, kv_loc, hd, dtype)
        g = self.groups.get(key)
        if g is None:
            rep = ops.Attention(phase, 1, 1, 1, h_loc, kv_loc, hd,
                                akind, 0, dtype)
            fam = "attn_prefill" if phase == "prefill" else "attn_decode"
            g = (rep, fam, [], [], [])
            self.groups[key] = g
        g[2].append(coords); g[3].append(mult); g[4].append(it)

    def moe(self, d_model, d_ff, n_exp, top_k, ep, dtype, coords, mult, it):
        key = ("moe", d_model, d_ff, n_exp, ep, dtype)
        g = self.groups.get(key)
        if g is None:
            rep = ops.MoEOp(tokens=1, d_model=d_model, d_ff=d_ff,
                            num_experts=n_exp, top_k=top_k, ep=ep,
                            dtype=dtype)
            g = (rep, "moe", [], [], [])
            self.groups[key] = g
        g[2].append(coords); g[3].append(mult); g[4].append(it)

    def rec(self, rkind, width, heads, dtype, coords, mult, it):
        key = ("recurrent", rkind, width, heads, dtype)
        g = self.groups.get(key)
        if g is None:
            g = (ops.RecurrentOp(rkind, 1, 1, width, heads, dtype),
                 "recurrent", [], [], [])
            self.groups[key] = g
        g[2].append(coords); g[3].append(mult); g[4].append(it)

    def comm(self, ckind, n_chips, nbytes, mult, it):
        if n_chips <= 1:            # scalar path prices these at exactly 0
            return
        key = ("comm", ckind, n_chips)
        g = self.groups.get(key)
        if g is None:
            g = (ops.Comm(ckind, 1.0, n_chips), "comm", [], [], [])
            self.groups[key] = g
        g[2].append((max(nbytes, 1.0),)); g[3].append(mult); g[4].append(it)

    def sol(self, kind, value, mult, it):
        s = self._sol
        s[0].append(kind); s[1].append(value); s[2].append(mult); s[3].append(it)


def _enc_attn(cfg, par, spec, dtype, window, mb, count, T, it, acc):
    tp = par.tp
    hd = cfg.head_dim
    h_loc = _ceil(cfg.num_heads, tp)
    kv_loc = _ceil(cfg.num_kv_heads, tp) if cfg.num_kv_heads >= tp else 1
    b = ops.BYTES[dtype]
    d = cfg.d_model
    akind = cfg.attention_kind
    acc.gemm(dtype, T, (h_loc + 2 * kv_loc) * hd, d, count, it)
    prefill = spec.prefill[:: max(mb, 1)] if mb > 1 else spec.prefill
    if prefill:
        # RLE over identical chunks: each run is one row with multiplicity
        # run_length × count (mode specs repeat the same chunk per request)
        run, run_n = prefill[0], 0
        for ch in prefill:
            if ch == run:
                run_n += 1
                continue
            clen, past = run
            kv = past + clen
            if window:
                kv = min(kv, window)
            acc.attn("prefill", akind, h_loc, kv_loc, hd, dtype,
                     (clen, max(kv, 1)), run_n * count, it)
            run, run_n = ch, 1
        clen, past = run
        kv = past + clen
        if window:
            kv = min(kv, window)
        acc.attn("prefill", akind, h_loc, kv_loc, hd, dtype,
                 (clen, max(kv, 1)), run_n * count, it)
    dec = spec.decode[:: mb] if mb > 1 else spec.decode
    if dec:
        kv = int(sum(dec) / len(dec))
        if window:
            kv = min(kv, window)
        acc.attn("decode", akind, h_loc, kv_loc, hd, dtype,
                 (len(dec), max(kv, 1)), count, it)
        acc.sol(SOL_MEM, len(dec) * 2 * kv_loc * hd * b, count, it)
    acc.gemm(dtype, T, d, h_loc * hd, count, it)
    if tp > 1:
        acc.comm("all_reduce", tp, T * d * b, count, it)


def _enc_ffn(cfg, par, dtype, count, T, it, acc, d_ff=None):
    tp = par.tp
    d = cfg.d_model
    f_loc = _ceil(d_ff or cfg.d_ff, tp)
    acc.gemm(dtype, T, 2 * f_loc, d, count, it)
    acc.gemm(dtype, T, d, f_loc, count, it)
    if tp > 1:
        acc.comm("all_reduce", tp, T * d * ops.BYTES[dtype], count, it)


def _enc_moe(cfg, par, dtype, alpha, backend, seed, count, T, it, acc):
    tp, ep = par.tp, min(par.ep, par.tp)
    b = ops.BYTES[dtype]
    d = cfg.d_model
    acc.gemm(dtype, T, cfg.num_experts, d, count, it)        # router
    a2a = backend in EP_A2A_BACKENDS
    payload = T * cfg.top_k * d * b
    if a2a:
        payload = payload / max(ep, 1)
    if ep > 1:
        acc.comm("all_to_all" if a2a else "all_gather", ep, payload,
                 count, it)
    hot = powerlaw.hot_rank_tokens(T, cfg.top_k, cfg.num_experts, ep,
                                   alpha, seed)
    acc.moe(d, _ceil(cfg.moe_d_ff, max(tp // ep, 1)), cfg.num_experts,
            cfg.top_k, ep, dtype, (max(hot, 1),), count, it)
    if cfg.n_shared_experts:
        # mirrors _moe_ops's `_dense_ffn_ops(...)[:-1]`: gate+up always,
        # down-proj only when the dropped trailing entry is the all_reduce
        sf_loc = _ceil(cfg.n_shared_experts * cfg.moe_d_ff, tp)
        acc.gemm(dtype, T, 2 * sf_loc, d, count, it)
        if tp > 1:
            acc.gemm(dtype, T, d, sf_loc, count, it)
    if ep > 1:
        acc.comm("all_to_all" if a2a else "reduce_scatter", ep, payload,
                 count, it)
    if tp > 1:
        acc.comm("all_reduce", tp, T * d * b, count, it)


def _enc_rec(cfg, par, spec, dtype, mb, count, T, it, acc):
    tp = par.tp
    b = ops.BYTES[dtype]
    d = cfg.d_model
    w_loc = _ceil(cfg.lru_width, tp)
    dec = spec.decode[:: mb] if mb > 1 else spec.decode
    batch = max(len(dec), 1) if not spec.prefill else 1
    seq = T if spec.prefill else 1
    acc.gemm(dtype, T, 2 * w_loc, d, count, it)
    acc.sol(SOL_MEM, T * w_loc * b * cfg.conv_width, count, it)
    acc.rec("rglru", w_loc, cfg.num_heads, dtype, (max(seq, 1),),
            count * batch, it)
    acc.gemm(dtype, T, d, w_loc, count, it)
    if tp > 1:
        acc.comm("all_reduce", tp, T * d * b, count, it)


def _enc_mlstm(cfg, par, spec, dtype, count, T, it, acc):
    from repro.models.xlstm import up_dim
    tp = par.tp
    b = ops.BYTES[dtype]
    d = cfg.d_model
    u = up_dim(cfg)
    u_loc = _ceil(u, tp)
    batch = max(len(spec.decode), 1) if not spec.prefill else 1
    seq = T if spec.prefill else 1
    acc.gemm(dtype, T, 2 * u_loc, d, count, it)
    acc.sol(SOL_MEM, T * u_loc * b * cfg.conv_width, count, it)
    acc.gemm(dtype, T, 3 * u_loc, u, count, it)
    acc.rec("mlstm", u_loc, cfg.num_heads, dtype, (max(seq, 1),),
            count * batch, it)
    acc.gemm(dtype, T, d, u_loc, count, it)
    if tp > 1:
        acc.comm("all_reduce", tp, T * d * b, count, it)


def _enc_slstm(cfg, par, spec, dtype, count, T, it, acc):
    tp = par.tp
    b = ops.BYTES[dtype]
    d = cfg.d_model
    f = int(d * cfg.slstm_proj_factor)
    batch = max(len(spec.decode), 1) if not spec.prefill else 1
    seq = T if spec.prefill else 1
    acc.gemm(dtype, T, _ceil(4 * d, tp), d, count, it)
    acc.rec("slstm", _ceil(d, tp), cfg.num_heads, dtype, (max(seq, 1),),
            count * batch, it)
    acc.gemm(dtype, T, _ceil(2 * f, tp), d, count, it)
    acc.gemm(dtype, T, d, _ceil(f, tp), count, it)
    if tp > 1:
        acc.comm("all_reduce", tp, T * d * b, count, it)


def _encode_item(cfg, par, spec, dtype, alpha, backend, seed, it, acc):
    mb = par.pp
    T = _tokens(spec, mb)
    if T == 0:
        return
    b = ops.BYTES[dtype]
    d = cfg.d_model
    acc.sol(SOL_EMBED, b * T * d * 2, 1, it)
    if cfg.family == "hybrid":
        n_attn = sum(1 for k in cfg.block_pattern if k == "attn")
        _enc_rec(cfg, par, spec, dtype, mb, cfg.num_layers - n_attn, T,
                 it, acc)
        _enc_attn(cfg, par, spec, dtype, cfg.local_window, mb, n_attn, T,
                  it, acc)
        _enc_ffn(cfg, par, dtype, cfg.num_layers, T, it, acc)
    elif cfg.family == "ssm":
        n_m = sum(1 for k in cfg.block_pattern if k == "m")
        _enc_mlstm(cfg, par, spec, dtype, n_m, T, it, acc)
        _enc_slstm(cfg, par, spec, dtype, cfg.num_layers - n_m, T, it, acc)
    else:
        _enc_attn(cfg, par, spec, dtype, cfg.sliding_window, mb,
                  cfg.num_layers, T, it, acc)
        if cfg.num_experts:
            _enc_moe(cfg, par, dtype, alpha, backend, seed, cfg.num_layers,
                     T, it, acc)
        else:
            _enc_ffn(cfg, par, dtype, cfg.num_layers, T, it, acc)
    n_emit = len(spec.decode) + len(spec.prefill)
    if n_emit:
        v_loc = _ceil(cfg.vocab_size, par.tp)
        acc.gemm(dtype, n_emit, v_loc, d, 1, it)
        if par.tp > 1:
            acc.comm("all_gather", par.tp, n_emit * v_loc * par.tp * 4,
                     1, it)
    if par.pp > 1:
        acc.comm("p2p", 2, T * d * b, par.pp - 1, it)


def encode_iteration_batch(items: Sequence[Tuple], *, alpha: float = 1.2,
                           backend: str = "repro-jax", dtype: str = "bf16",
                           seed: int = 0) -> Optional[OpBatch]:
    """Lower ``(cfg, par, spec)`` triples into one :class:`OpBatch`.

    Emits exactly the operator sites :func:`iteration_ops` would, as
    per-grid stacked coordinate/multiplicity/owner arrays (identical
    prefill chunks are run-length collapsed — the scalar path memoizes
    them away; here they fold into one row's multiplicity).  Returns
    ``None`` when any item needs the scalar path (encoder-decoder models,
    whose per-request encoder pass has no stacked form yet).
    """
    acc = _BatchAcc()
    for it, (cfg, par, spec) in enumerate(items):
        if cfg.is_encoder_decoder:
            return None
        _encode_item(cfg, par, spec, dtype, alpha, backend, seed, it, acc)
    grid_rows = []
    for rep, family, coords, mult, item in acc.groups.values():
        uniq: Dict[Tuple, int] = {}
        ridx = [uniq.setdefault(c, len(uniq)) for c in coords]
        grid_rows.append(GridRows(
            rep, family,
            np.asarray(list(uniq), np.float64),
            np.asarray(mult, np.float64),
            np.asarray(item, np.int64),
            np.asarray(ridx, np.int64)))
    kind, value, mult, item = acc._sol
    sol = SolRows(np.asarray(kind, np.int8),
                  np.asarray(value, np.float64),
                  np.asarray(mult, np.float64),
                  np.asarray(item, np.int64))
    return OpBatch(n_items=len(items), grid_rows=grid_rows, sol_rows=sol)


# ---------------------------------------------------------------------------
# memory model (per chip) — used by TaskRunner pruning and the Generator's
# kv_cache_mem_fraction resolution
# ---------------------------------------------------------------------------

def param_bytes_per_chip(cfg: ModelConfig, par: ParallelismConfig,
                         dtype: str = "bf16") -> float:
    b = ops.BYTES[dtype]
    total = cfg.param_count() * b
    if cfg.num_experts:
        expert = cfg.num_layers * cfg.num_experts * 3 * cfg.d_model * cfg.moe_d_ff * b
        dense = total - expert
        ep = min(par.ep, par.tp)
        shard = expert / max(ep * max(par.tp // ep, 1), 1)
        return (dense / par.tp + shard) / par.pp
    return total / (par.tp * par.pp)


def kv_bytes_per_chip(cfg: ModelConfig, par: ParallelismConfig, batch: int,
                      seq: int, dtype: str = "bf16") -> float:
    b = ops.BYTES[dtype]
    if cfg.family == "ssm":
        from repro.models.xlstm import up_dim
        u = up_dim(cfg)
        per_tok_indep = cfg.num_layers / 2 * (u // cfg.num_heads * u + 4 * cfg.d_model)
        return batch * per_tok_indep * 4 / (par.tp * par.pp)
    kv_loc = max(_ceil(cfg.num_kv_heads, par.tp), 1)
    total = 0.0
    for li in range(cfg.num_layers):
        kind = cfg.block_pattern[li] if cfg.block_pattern else "attn"
        W = cfg.kv_cache_len(seq, kind)
        if kind == "rec":
            # recurrent state is tp-sharded exactly like _rec_ops computes
            # on it (w_loc = ceil(lru_width/tp)); charging the full width
            # over-counted by tp× and wrongly pruned hybrid configs
            w_loc = max(_ceil(cfg.lru_width, par.tp), 1)
            total += w_loc * 4 + w_loc * cfg.conv_width * b
        else:
            total += 2 * W * kv_loc * cfg.head_dim * b
    if cfg.is_encoder_decoder:
        total += (cfg.num_layers * 2 * cfg.num_source_positions
                  * _ceil(cfg.num_heads, par.tp) * cfg.head_dim * b)
    return batch * total / par.pp


def activation_bytes_per_chip(cfg: ModelConfig, par: ParallelismConfig,
                              max_tokens: int, dtype: str = "bf16") -> float:
    b = ops.BYTES[dtype]
    width = max(cfg.d_ff or cfg.d_model, cfg.moe_d_ff * cfg.top_k if cfg.num_experts else 0)
    return max_tokens * (cfg.d_model + _ceil(2 * width, par.tp)) * b * 2


def fits_memory(cfg: ModelConfig, par: ParallelismConfig, batch: int,
                seq: int, platform, flags=None, dtype: str = "bf16"):
    """Returns (fits, bytes_per_chip)."""
    kv_frac = flags.kv_cache_mem_fraction if flags else 0.9
    p = param_bytes_per_chip(cfg, par, dtype)
    a = activation_bytes_per_chip(cfg, par,
                                  flags.max_num_tokens if flags else 8192, dtype)
    k = kv_bytes_per_chip(cfg, par, batch, seq, dtype)
    free_for_kv = (platform.hbm_capacity - p - a) * kv_frac
    return k <= max(free_for_kv, 0.0), p + a + k
