"""The paper's three serving-mode estimators (§4.2, Algorithms 1–3).

Implemented to match the pseudocode constant-for-constant:
  Alg. 1  static        — stride-32 decode interpolation
  Alg. 2  aggregated    — mixed/generation phases, rate-matching throttle,
                          F_corr = min(2 + (T_ctx-3)/20, 4), 3-step jitter
                          offset in the TPOT weighting
  Alg. 3  disaggregated — α_pre=0.9, α_dec=0.92, β_TTFT=1.8, x∈[1,32],
                          y∈[1,64] rate matching maximizing per-chip
                          throughput

All latencies in milliseconds (the paper's unit).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

# Paper constants
STRIDE = 32
ALPHA_PRE = 0.9
ALPHA_DEC = 0.92
BETA_TTFT = 1.8
F_CORR_CAP = 4.0


# ---------------------------------------------------------------------------
# Algorithm 1 — static mode
# ---------------------------------------------------------------------------

def static_mode(get_step_latency_ms: Callable[[int, int, str], float],
                isl: int, osl: int, batch: int, prefix: int = 0,
                stride: int = STRIDE) -> Tuple[float, float]:
    """Returns (TTFT_ms, TPOT_ms)."""
    isl_eff = isl - prefix
    ttft = get_step_latency_ms(batch, isl_eff, "prefill")
    t_gen = 0.0
    if osl > 1:
        k = 0
        while k < osl - 1:
            s_seq = isl + k + 1
            t_step = get_step_latency_ms(batch, s_seq, "decode")
            r = min(stride, osl - 1 - k)
            t_gen += t_step * r
            k += stride
        tpot = t_gen / (osl - 1)
    else:
        tpot = 0.0
    return ttft, tpot


# ---------------------------------------------------------------------------
# Algorithm 2 — aggregated (continuous batching) mode
# ---------------------------------------------------------------------------

def aggregated_mode(get_mix_lat_ms: Callable[[int, int, int, int], float],
                    get_gen_lat_ms: Callable[[int, int, int], float],
                    isl: int, osl: int, batch: int,
                    c_ctx: int, f_corr_base: float = 2.0) -> Tuple[float, float]:
    """Returns (TTFT_ms, TPOT_ms).  c_ctx = per-iteration context capacity."""
    t_total_ctx = math.ceil(isl * batch / c_ctx)
    # Paper line 9/15/22 sets N_ctx <- C_ctx (saturated steady state).  When
    # the whole context backlog is smaller than C_ctx the scheduler can only
    # fill ceil(ISL*B / T_total_ctx) tokens per mixed step; without this
    # correction the estimator prices phantom context tokens and TTFT
    # explodes for small workloads (documented deviation, EXPERIMENTS.md).
    fill = min(c_ctx, math.ceil(isl * batch / t_total_ctx))

    if batch > 1:
        if t_total_ctx >= osl:
            # context dominates: throttle decode streams (rate matching)
            t_mix = t_total_ctx
            t_gen = 0
            n_ctx = fill
            n_gen = max(1, int(batch / (t_total_ctx / osl)))
        else:
            t_mix = t_total_ctx
            t_gen = osl - t_mix
            n_ctx = fill
            n_gen = max(1, batch - math.ceil(fill / isl))    # paper: assert >= 1
    else:
        t_mix, t_gen = 1, osl - 1
        n_ctx, n_gen = min(c_ctx, isl), 0

    l_mix = get_mix_lat_ms(n_ctx, n_gen, isl, osl)
    l_gen = get_gen_lat_ms(batch, isl, osl)

    f_corr = min(f_corr_base + (t_total_ctx - 3) / 20.0, F_CORR_CAP)
    f_corr = max(f_corr, 0.5)
    ttft = l_mix * math.ceil(isl / c_ctx) * f_corr

    t_mix_p = max(1, t_mix - 3)                              # jitter offset
    if batch > 1:
        tpot = (l_mix * t_mix_p + l_gen * t_gen) / (t_mix_p + t_gen)
    else:
        tpot = l_gen
    return ttft, tpot


# ---------------------------------------------------------------------------
# Algorithm 3 — disaggregated mode
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PoolCandidate:
    """One evaluated static candidate for a prefill or decode pool."""
    config: object                  # CandidateConfig
    chips: int
    latency_ms: float               # prefill: TTFT; decode: TPOT
    req_throughput: float           # requests/s of ONE instance


@dataclasses.dataclass
class DisaggBest:
    prefill: PoolCandidate
    decode: PoolCandidate
    x: int
    y: int
    ttft_ms: float
    tpot_ms: float
    total_chips: int
    req_per_s: float
    tokens_per_s_per_chip: float


def disaggregated_mode(prefill_cands: Sequence[PoolCandidate],
                       decode_cands: Sequence[PoolCandidate],
                       ttft_limit_ms: float, tpot_limit_ms: float,
                       valid_totals: Iterable[int], osl: int,
                       x_range: Tuple[int, int] = (1, 32),
                       y_range: Tuple[int, int] = (1, 64),
                       beta_ttft: float = BETA_TTFT,
                       keep_all: bool = False,
                       progress_cb: Optional[Callable[[int], bool]] = None):
    """Rate matching over (x)P(y)D composites.  Returns (best, all) where
    all is populated when keep_all (for Pareto plots).

    ``progress_cb`` (streaming early exit) is consulted with the number of
    composites evaluated so far, once per (decode, prefill, x) slice; a
    True return preempts the matching and the best composite found so far
    is returned.  The full grid can be hundreds of thousands of
    composites, so without this hook a ``deadline_s`` policy could not
    bound disaggregated search cost.
    """
    valid = set(valid_totals)
    cp = [c for c in prefill_cands if c.latency_ms * beta_ttft <= ttft_limit_ms]
    cd = [c for c in decode_cands if c.latency_ms <= tpot_limit_ms]
    best: Optional[DisaggBest] = None
    everything: List[DisaggBest] = []
    n_seen = 0
    for dec in cd:
        for pre in cp:
            for x in range(x_range[0], x_range[1] + 1):
                if progress_cb is not None and progress_cb(n_seen):
                    return best, everything
                g_pre = x * pre.chips
                if g_pre > max(valid):
                    break
                for y in range(y_range[0], y_range[1] + 1):
                    n_seen += 1
                    g_total = g_pre + y * dec.chips
                    if g_total not in valid:
                        if g_total > max(valid):
                            break
                        continue
                    r_pre = pre.req_throughput * x * ALPHA_PRE
                    r_dec = dec.req_throughput * y * ALPHA_DEC
                    r_sys = min(r_pre, r_dec)
                    thru_chip = r_sys * osl / g_total
                    cand = DisaggBest(
                        prefill=pre, decode=dec, x=x, y=y,
                        ttft_ms=pre.latency_ms * beta_ttft,
                        tpot_ms=dec.latency_ms,
                        total_chips=g_total, req_per_s=r_sys,
                        tokens_per_s_per_chip=thru_chip)
                    if keep_all:
                        everything.append(cand)
                    if best is None or thru_chip > best.tokens_per_s_per_chip:
                        best = cand
    return best, everything
