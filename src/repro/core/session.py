"""InferenceSession (§4.1): estimates TTFT/TPOT/throughput for one candidate
serving configuration by composing iteration-level modeling (decompose) with
operator latencies from the PerfDatabase, through the mode algorithms.

Throughput follows the paper's steady-state request view:

    GenerationSpeed   = 1000 / TPOT                               (eq. 1)
    SystemThroughput  = 1000/(TTFT + (OSL-1)*TPOT) * B * OSL / N  (eq. 2)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.core import decompose, modes
from repro.core.backends.base import get_backend
from repro.core.config import (CandidateConfig, ParallelismConfig, Projection,
                               RuntimeFlags, SLA, WorkloadDescriptor)
from repro.core.hardware import get_platform
from repro.core.perf_database import PerfDatabase
from repro.obs.metrics import get_metrics
from repro.obs.trace import get_tracer
from repro.serving.sim import StepSpec


class InferenceSession:
    def __init__(self, workload: WorkloadDescriptor,
                 db: Optional[PerfDatabase] = None,
                 cfg: Optional[ModelConfig] = None):
        self.w = workload
        # cfg override supports unregistered variants (e.g. the reduced
        # models the CPU-silicon fidelity benchmark serves for real)
        self.cfg: ModelConfig = cfg or get_config(workload.model)
        self.platform = (db.platform if db is not None
                         else get_platform(workload.cluster.platform))
        self.db = db or PerfDatabase(self.platform, workload.backend)
        self.backend = get_backend(workload.backend)
        # batch pricing state: _price_hook intercepts spec_latency_ms during
        # the record/replay passes of the batched cursor; _price_memo caches
        # fused-kernel answers per (parallelism, spec) across the session
        self._price_hook: Optional[Callable] = None
        self._price_memo: Dict = {}
        self._price_epoch: Optional[int] = None

    # ------------------------------------------------------------------
    # iteration latencies (ms) — the GETSTEPLATENCY / GETMIXLAT /
    # GETGENLAT oracles of Algorithms 1–2
    # ------------------------------------------------------------------
    def spec_latency_ms(self, par: ParallelismConfig, spec: StepSpec,
                        flags: RuntimeFlags) -> float:
        hook = self._price_hook
        if hook is not None:
            return hook(par, spec, flags)
        if self.backend.sequential_prefill and len(spec.prefill) > 1:
            # engine launches one kernel per prompt: no cross-prompt GEMM
            # batching — price each chunk as its own mini-iteration
            t = 0.0
            for chunk in spec.prefill:
                t += self.spec_latency_ms(
                    par, StepSpec(prefill=(chunk,), decode=()), flags)
            if spec.decode:
                t += self.spec_latency_ms(
                    par, StepSpec(prefill=(), decode=spec.decode), flags)
            return t
        op_list = decompose.iteration_ops(
            self.cfg, par, spec, alpha=self.w.moe_alpha,
            backend=self.w.backend, dtype=self.w.dtype)
        t = self.db.sequence_latency(op_list)
        t += self.backend.iteration_overhead(
            len(spec.prefill), len(spec.decode), flags.enable_graph_capture)
        return 1e3 * t

    def step_latency_ms(self, par: ParallelismConfig, flags: RuntimeFlags,
                        batch: int, seq: int, phase: str) -> float:
        if phase == "prefill":
            spec = StepSpec(prefill=tuple((seq, 0) for _ in range(batch)),
                            decode=())
        else:
            spec = StepSpec(prefill=(), decode=(seq,) * batch)
        return self.spec_latency_ms(par, spec, flags)

    def mix_lat_ms(self, par, flags, n_ctx: int, n_gen: int,
                   isl: int, osl: int) -> float:
        chunks: List[Tuple[int, int]] = []
        remaining = n_ctx
        while remaining > 0:
            take = min(isl, remaining)
            chunks.append((take, 0))
            remaining -= take
        kv = isl + osl // 2
        spec = StepSpec(prefill=tuple(chunks), decode=(kv,) * n_gen)
        return self.spec_latency_ms(par, spec, flags)

    def gen_lat_ms(self, par, flags, batch: int, isl: int, osl: int) -> float:
        kv = isl + osl // 2
        return self.spec_latency_ms(
            par, StepSpec(prefill=(), decode=(kv,) * batch), flags)

    # ------------------------------------------------------------------
    # batched pricing (record → fused kernel → replay)
    # ------------------------------------------------------------------
    def batch_pricing_ok(self) -> bool:
        """Whether this session's specs can price through the fused batch
        kernel: grid-backed database and a stackable architecture (the
        encoder-decoder per-request pass still walks the scalar path)."""
        return bool(self.db.use_grid) and not self.cfg.is_encoder_decoder

    def record_specs(self, fn) -> Tuple[object, List[Tuple]]:
        """Run ``fn()`` with spec pricing stubbed to 0.0, returning
        ``(result, atoms)`` where ``atoms`` is every (par, spec, flags)
        ``spec_latency_ms`` would have priced, in call order.  Mode
        algorithms have latency-independent control flow, so the recorded
        atom sequence equals the real one."""
        atoms: List[Tuple] = []

        def hook(par, spec, flags):
            atoms.append((par, spec, flags))
            return 0.0

        self._price_hook = hook
        try:
            return fn(), atoms
        finally:
            self._price_hook = None

    def replay_specs(self, fn, values: List[float]):
        """Run ``fn()`` with ``spec_latency_ms`` answered from ``values``
        (the batch-priced latencies, in the recorded atom order)."""
        it = iter(values)
        self._price_hook = lambda par, spec, flags, _it=it: next(_it)
        try:
            return fn()
        finally:
            self._price_hook = None

    def price_specs(self, atoms: List[Tuple],
                    backend_kernel: str = "np") -> List[float]:
        """Price recorded (par, spec, flags) atoms through
        ``PerfDatabase.sequence_latency_batch``, returning per-atom
        latencies in ms.  Semantics mirror ``spec_latency_ms`` exactly:
        sequential-prefill backends split multi-prompt specs, the backend
        iteration overhead is added per (sub-)spec, and repeated
        (parallelism, spec) pairs are memoized for the session (counted as
        sequence-memo hits, like the scalar path's)."""
        if self._price_epoch != self.db._epoch:
            self._price_memo.clear()
            self._price_epoch = self.db._epoch
        flat: List[Tuple[int, ParallelismConfig, StepSpec]] = []
        split = self.backend.sequential_prefill
        for i, (par, spec, flags) in enumerate(atoms):
            if split and len(spec.prefill) > 1:
                for chunk in spec.prefill:
                    flat.append((i, par,
                                 StepSpec(prefill=(chunk,), decode=())))
                if spec.decode:
                    flat.append((i, par,
                                 StepSpec(prefill=(), decode=spec.decode)))
            else:
                flat.append((i, par, spec))
        memo = self._price_memo
        to_price: List[Tuple] = []
        seen: Dict[Tuple, bool] = {}
        hits = 0
        for _, par, spec in flat:
            key = (par.tp, par.pp, par.ep, par.dp, spec)
            if key in memo or key in seen:
                hits += 1
                continue
            seen[key] = True
            to_price.append((key, par, spec))
        local: Dict[Tuple, float] = {}
        if to_price:
            tracer = get_tracer()
            with tracer.span("price.encode", atoms=len(to_price)):
                batch = decompose.encode_iteration_batch(
                    [(self.cfg, par, spec) for _, par, spec in to_price],
                    alpha=self.w.moe_alpha, backend=self.w.backend,
                    dtype=self.w.dtype)
            if batch is None:            # scalar fallback (encoder-decoder)
                for key, par, spec in to_price:
                    op_list = decompose.iteration_ops(
                        self.cfg, par, spec, alpha=self.w.moe_alpha,
                        backend=self.w.backend, dtype=self.w.dtype)
                    local[key] = self.db.sequence_latency(op_list)
            else:
                with tracer.span("price.kernel", atoms=batch.n_items,
                                 rows=batch.n_rows):
                    vals = self.db.sequence_latency_batch(
                        batch, backend=backend_kernel)
                for (key, _, _), v in zip(to_price, vals):
                    local[key] = float(v)
            if len(memo) < 500_000:
                memo.update(local)
        if hits:
            self.db.stats.seq_queries += hits
            self.db.stats.seq_hits += hits
            m = get_metrics()
            if m is not None:
                m.inc("repro_db_seq_total", hits, mode="batched")
                m.inc("repro_db_seq_hits_total", hits, mode="batched")
        out = [0.0] * len(atoms)
        for i, par, spec in flat:
            key = (par.tp, par.pp, par.ep, par.dp, spec)
            raw = memo.get(key)
            if raw is None:
                raw = local[key]
            flags = atoms[i][2]
            t = raw + self.backend.iteration_overhead(
                len(spec.prefill), len(spec.decode),
                flags.enable_graph_capture)
            out[i] += 1e3 * t
        return out

    # ------------------------------------------------------------------
    # candidate evaluation
    # ------------------------------------------------------------------
    def _throughput(self, ttft_ms: float, tpot_ms: float, batch: int,
                    chips: int) -> float:
        osl = self.w.osl
        denom = ttft_ms + (osl - 1) * tpot_ms
        if denom <= 0:
            return 0.0
        return 1000.0 / denom * batch * osl / chips

    def _mem_ok(self, cand: CandidateConfig) -> Tuple[bool, float]:
        return decompose.fits_memory(
            self.cfg, cand.parallel, cand.batch_size,
            self.w.isl + self.w.osl, self.platform, cand.flags, self.w.dtype)

    def evaluate_static(self, cand: CandidateConfig, *, _mem=None,
                        _plan_only: bool = False) -> Optional[Projection]:
        ok, mem = self._mem_ok(cand) if _mem is None else _mem
        if not ok:
            return None
        ttft, tpot = modes.static_mode(
            lambda b, s, ph: self.step_latency_ms(cand.parallel, cand.flags,
                                                  b, s, ph),
            self.w.isl, self.w.osl, cand.batch_size, self.w.prefix_len)
        if _plan_only:
            return True
        chips = cand.parallel.chips_per_instance
        return Projection(
            ttft_ms=ttft, tpot_ms=tpot,
            tokens_per_s_user=1000.0 / tpot if tpot else float("inf"),
            tokens_per_s_per_chip=self._throughput(ttft, tpot,
                                                   cand.batch_size, chips),
            chips=chips, batch_size=cand.batch_size, mode="static",
            config={"parallel": dataclasses.asdict(cand.parallel),
                    "flags": dataclasses.asdict(cand.flags),
                    "describe": cand.describe()},
            mem_bytes_per_chip=mem)

    def evaluate_aggregated(self, cand: CandidateConfig, *, _mem=None,
                            _plan_only: bool = False) -> Optional[Projection]:
        ok, mem = self._mem_ok(cand) if _mem is None else _mem
        if not ok:
            return None
        c_ctx = (cand.flags.max_num_tokens if cand.flags.enable_chunked_context
                 else max(cand.flags.max_num_tokens, self.w.isl))
        ttft, tpot = modes.aggregated_mode(
            lambda nc, ng, i, o: self.mix_lat_ms(cand.parallel, cand.flags,
                                                 nc, ng, i, o),
            lambda b, i, o: self.gen_lat_ms(cand.parallel, cand.flags, b, i, o),
            self.w.isl, self.w.osl, cand.batch_size, c_ctx,
            f_corr_base=self.backend.f_corr_base)
        if _plan_only:
            return True
        chips = cand.parallel.chips_per_instance
        return Projection(
            ttft_ms=ttft, tpot_ms=tpot,
            tokens_per_s_user=1000.0 / tpot if tpot else float("inf"),
            tokens_per_s_per_chip=self._throughput(ttft, tpot,
                                                   cand.batch_size, chips),
            chips=chips, batch_size=cand.batch_size, mode="aggregated",
            config={"parallel": dataclasses.asdict(cand.parallel),
                    "flags": dataclasses.asdict(cand.flags),
                    "describe": cand.describe()},
            mem_bytes_per_chip=mem)

    # -- disaggregated pool candidates ----------------------------------
    def prefill_pool_candidate(self, cand: CandidateConfig
                               ) -> Optional[modes.PoolCandidate]:
        """Prefill instance: batches of cand.batch prompts, latency = TTFT."""
        ok, _ = self._mem_ok(dataclasses.replace(cand, batch_size=cand.batch_size))
        if not ok:
            return None
        lat = self.step_latency_ms(cand.parallel, cand.flags,
                                   cand.batch_size, self.w.isl, "prefill")
        rate = cand.batch_size / (lat / 1e3)        # requests/s
        return modes.PoolCandidate(config=cand,
                                   chips=cand.parallel.chips_per_instance,
                                   latency_ms=lat, req_throughput=rate)

    def decode_pool_candidate(self, cand: CandidateConfig
                              ) -> Optional[modes.PoolCandidate]:
        ok, _ = self._mem_ok(cand)
        if not ok:
            return None
        _, tpot = modes.static_mode(
            lambda b, s, ph: self.step_latency_ms(cand.parallel, cand.flags,
                                                  b, s, ph),
            self.w.isl, self.w.osl, cand.batch_size)
        if tpot <= 0:
            return None
        # one instance completes batch requests every (osl-1)*tpot
        rate = cand.batch_size / (max(self.w.osl - 1, 1) * tpot / 1e3)
        return modes.PoolCandidate(config=cand,
                                   chips=cand.parallel.chips_per_instance,
                                   latency_ms=tpot, req_throughput=rate)
