"""InferenceSession (§4.1): estimates TTFT/TPOT/throughput for one candidate
serving configuration by composing iteration-level modeling (decompose) with
operator latencies from the PerfDatabase, through the mode algorithms.

Throughput follows the paper's steady-state request view:

    GenerationSpeed   = 1000 / TPOT                               (eq. 1)
    SystemThroughput  = 1000/(TTFT + (OSL-1)*TPOT) * B * OSL / N  (eq. 2)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.core import decompose, modes
from repro.core.backends.base import get_backend
from repro.core.config import (CandidateConfig, ParallelismConfig, Projection,
                               RuntimeFlags, SLA, WorkloadDescriptor)
from repro.core.hardware import get_platform
from repro.core.perf_database import PerfDatabase
from repro.serving.sim import StepSpec


class InferenceSession:
    def __init__(self, workload: WorkloadDescriptor,
                 db: Optional[PerfDatabase] = None,
                 cfg: Optional[ModelConfig] = None):
        self.w = workload
        # cfg override supports unregistered variants (e.g. the reduced
        # models the CPU-silicon fidelity benchmark serves for real)
        self.cfg: ModelConfig = cfg or get_config(workload.model)
        self.platform = (db.platform if db is not None
                         else get_platform(workload.cluster.platform))
        self.db = db or PerfDatabase(self.platform, workload.backend)
        self.backend = get_backend(workload.backend)

    # ------------------------------------------------------------------
    # iteration latencies (ms) — the GETSTEPLATENCY / GETMIXLAT /
    # GETGENLAT oracles of Algorithms 1–2
    # ------------------------------------------------------------------
    def spec_latency_ms(self, par: ParallelismConfig, spec: StepSpec,
                        flags: RuntimeFlags) -> float:
        if self.backend.sequential_prefill and len(spec.prefill) > 1:
            # engine launches one kernel per prompt: no cross-prompt GEMM
            # batching — price each chunk as its own mini-iteration
            t = 0.0
            for chunk in spec.prefill:
                t += self.spec_latency_ms(
                    par, StepSpec(prefill=(chunk,), decode=()), flags)
            if spec.decode:
                t += self.spec_latency_ms(
                    par, StepSpec(prefill=(), decode=spec.decode), flags)
            return t
        op_list = decompose.iteration_ops(
            self.cfg, par, spec, alpha=self.w.moe_alpha,
            backend=self.w.backend, dtype=self.w.dtype)
        t = self.db.sequence_latency(op_list)
        t += self.backend.iteration_overhead(
            len(spec.prefill), len(spec.decode), flags.enable_graph_capture)
        return 1e3 * t

    def step_latency_ms(self, par: ParallelismConfig, flags: RuntimeFlags,
                        batch: int, seq: int, phase: str) -> float:
        if phase == "prefill":
            spec = StepSpec(prefill=tuple((seq, 0) for _ in range(batch)),
                            decode=())
        else:
            spec = StepSpec(prefill=(), decode=(seq,) * batch)
        return self.spec_latency_ms(par, spec, flags)

    def mix_lat_ms(self, par, flags, n_ctx: int, n_gen: int,
                   isl: int, osl: int) -> float:
        chunks: List[Tuple[int, int]] = []
        remaining = n_ctx
        while remaining > 0:
            take = min(isl, remaining)
            chunks.append((take, 0))
            remaining -= take
        kv = isl + osl // 2
        spec = StepSpec(prefill=tuple(chunks), decode=(kv,) * n_gen)
        return self.spec_latency_ms(par, spec, flags)

    def gen_lat_ms(self, par, flags, batch: int, isl: int, osl: int) -> float:
        kv = isl + osl // 2
        return self.spec_latency_ms(
            par, StepSpec(prefill=(), decode=(kv,) * batch), flags)

    # ------------------------------------------------------------------
    # candidate evaluation
    # ------------------------------------------------------------------
    def _throughput(self, ttft_ms: float, tpot_ms: float, batch: int,
                    chips: int) -> float:
        osl = self.w.osl
        denom = ttft_ms + (osl - 1) * tpot_ms
        if denom <= 0:
            return 0.0
        return 1000.0 / denom * batch * osl / chips

    def _mem_ok(self, cand: CandidateConfig) -> Tuple[bool, float]:
        return decompose.fits_memory(
            self.cfg, cand.parallel, cand.batch_size,
            self.w.isl + self.w.osl, self.platform, cand.flags, self.w.dtype)

    def evaluate_static(self, cand: CandidateConfig) -> Optional[Projection]:
        ok, mem = self._mem_ok(cand)
        if not ok:
            return None
        ttft, tpot = modes.static_mode(
            lambda b, s, ph: self.step_latency_ms(cand.parallel, cand.flags,
                                                  b, s, ph),
            self.w.isl, self.w.osl, cand.batch_size, self.w.prefix_len)
        chips = cand.parallel.chips_per_instance
        return Projection(
            ttft_ms=ttft, tpot_ms=tpot,
            tokens_per_s_user=1000.0 / tpot if tpot else float("inf"),
            tokens_per_s_per_chip=self._throughput(ttft, tpot,
                                                   cand.batch_size, chips),
            chips=chips, batch_size=cand.batch_size, mode="static",
            config={"parallel": dataclasses.asdict(cand.parallel),
                    "flags": dataclasses.asdict(cand.flags),
                    "describe": cand.describe()},
            mem_bytes_per_chip=mem)

    def evaluate_aggregated(self, cand: CandidateConfig) -> Optional[Projection]:
        ok, mem = self._mem_ok(cand)
        if not ok:
            return None
        c_ctx = (cand.flags.max_num_tokens if cand.flags.enable_chunked_context
                 else max(cand.flags.max_num_tokens, self.w.isl))
        ttft, tpot = modes.aggregated_mode(
            lambda nc, ng, i, o: self.mix_lat_ms(cand.parallel, cand.flags,
                                                 nc, ng, i, o),
            lambda b, i, o: self.gen_lat_ms(cand.parallel, cand.flags, b, i, o),
            self.w.isl, self.w.osl, cand.batch_size, c_ctx,
            f_corr_base=self.backend.f_corr_base)
        chips = cand.parallel.chips_per_instance
        return Projection(
            ttft_ms=ttft, tpot_ms=tpot,
            tokens_per_s_user=1000.0 / tpot if tpot else float("inf"),
            tokens_per_s_per_chip=self._throughput(ttft, tpot,
                                                   cand.batch_size, chips),
            chips=chips, batch_size=cand.batch_size, mode="aggregated",
            config={"parallel": dataclasses.asdict(cand.parallel),
                    "flags": dataclasses.asdict(cand.flags),
                    "describe": cand.describe()},
            mem_bytes_per_chip=mem)

    # -- disaggregated pool candidates ----------------------------------
    def prefill_pool_candidate(self, cand: CandidateConfig
                               ) -> Optional[modes.PoolCandidate]:
        """Prefill instance: batches of cand.batch prompts, latency = TTFT."""
        ok, _ = self._mem_ok(dataclasses.replace(cand, batch_size=cand.batch_size))
        if not ok:
            return None
        lat = self.step_latency_ms(cand.parallel, cand.flags,
                                   cand.batch_size, self.w.isl, "prefill")
        rate = cand.batch_size / (lat / 1e3)        # requests/s
        return modes.PoolCandidate(config=cand,
                                   chips=cand.parallel.chips_per_instance,
                                   latency_ms=lat, req_throughput=rate)

    def decode_pool_candidate(self, cand: CandidateConfig
                              ) -> Optional[modes.PoolCandidate]:
        ok, _ = self._mem_ok(cand)
        if not ok:
            return None
        _, tpot = modes.static_mode(
            lambda b, s, ph: self.step_latency_ms(cand.parallel, cand.flags,
                                                  b, s, ph),
            self.w.isl, self.w.osl, cand.batch_size)
        if tpot <= 0:
            return None
        # one instance completes batch requests every (osl-1)*tpot
        rate = cand.batch_size / (max(self.w.osl - 1, 1) * tpot / 1e3)
        return modes.PoolCandidate(config=cand,
                                   chips=cand.parallel.chips_per_instance,
                                   latency_ms=tpot, req_throughput=rate)
