"""AIConfigurator core — the paper's contribution.

The public, stable entry point is the ``repro.api`` facade::

    from repro.api import Configurator

    report = (Configurator.for_model("qwen3-32b")
              .traffic(isl=4000, osl=500)
              .sla(ttft_ms=1200, min_tokens_per_s_user=60)
              .cluster(chips=8)
              .search())

This package holds the building blocks underneath it (used directly when
composing custom pipelines):

    from repro.core import (WorkloadDescriptor, SLA, ClusterSpec, TaskRunner,
                            PerfDatabase, generate)

    w = WorkloadDescriptor(model="qwen3-32b", isl=4000, osl=500,
                           sla=SLA(ttft_ms=1200, min_tokens_per_s_user=60),
                           cluster=ClusterSpec(n_chips=8))
    result = TaskRunner(w).run()
    launch = generate(w, result.best)
"""
from repro.core.config import (CandidateConfig, ClusterSpec, DisaggConfig,
                               ParallelismConfig, Projection, RuntimeFlags,
                               SLA, WorkloadDescriptor)
from repro.core.generator import LaunchConfig, generate
from repro.core.hardware import PLATFORMS, Platform, get_platform
from repro.core.perf_database import PerfDatabase
from repro.core.session import InferenceSession
from repro.core.task_runner import SearchResult, TaskRunner

__all__ = [
    "CandidateConfig", "ClusterSpec", "DisaggConfig", "ParallelismConfig",
    "Projection", "RuntimeFlags", "SLA", "WorkloadDescriptor",
    "LaunchConfig", "generate", "PLATFORMS", "Platform", "get_platform",
    "PerfDatabase", "InferenceSession", "SearchResult", "TaskRunner",
]
