"""PerfDatabase (§4.4): per-operator latency grids + interpolation +
speed-of-light fallback, per (hardware platform × framework backend).

Data collection sweeps the operator parameter grids the paper profiles
(batch, sequence, GEMM dims, message sizes) and stores latencies from the
calibrated analytical executor (the silicon stand-in; see analytical.py).
Queries snap onto the grid with multilinear interpolation in log space —
exactly the paper's "interpolation of real system data".  Operators outside
any grid fall back to Speed-of-Light estimation (§4.4 'Data Collection').

Grids for shape-rich operators (attention, MoE, recurrent) are built lazily
per head-config/expert-config the first time a model needs them — mirroring
the paper's per-model coverage ("popular open-weights models").

The database can be exported/imported as JSON so the "offline" artifact is
a real file (src/repro/core/data/<platform>_<backend>.json).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import analytical
from repro.core import operators as ops
from repro.core.hardware import Platform, get_platform
from repro.obs.metrics import get_metrics

# Grid axes ------------------------------------------------------------------

_POW2 = lambda lo, hi: [2 ** i for i in range(int(math.log2(lo)), int(math.log2(hi)) + 1)]

GEMM_M = _POW2(1, 1 << 20)
GEMM_N = _POW2(128, 1 << 15)
GEMM_K = _POW2(128, 1 << 15)
ATTN_BATCH = _POW2(1, 512)
ATTN_SEQ = _POW2(16, 1 << 20)
MOE_TOKENS = _POW2(1, 1 << 20)
COMM_BYTES = _POW2(1 << 10, 1 << 34)
REC_TOKENS = _POW2(1, 1 << 20)


class OpGrid:
    """N-dimensional latency table with multilinear interpolation in
    log(parameter) space.  Exact on grid hits; clamped at the edges."""

    def __init__(self, axes: Sequence[Sequence[float]], table: np.ndarray):
        self.axes = [np.asarray(a, np.float64) for a in axes]
        self.table = np.asarray(table, np.float64)
        assert self.table.shape == tuple(len(a) for a in self.axes)

    @classmethod
    def build(cls, axes: Sequence[Sequence[float]], fn) -> "OpGrid":
        shape = tuple(len(a) for a in axes)
        table = np.empty(shape, np.float64)
        for idx in np.ndindex(shape):
            coords = [axes[d][i] for d, i in enumerate(idx)]
            table[idx] = fn(*coords)
        return cls(axes, table)

    # -- batched interpolation ----------------------------------------------
    def _batch_tables(self):
        """Precomputed log-space views the vectorized kernels read."""
        cached = getattr(self, "_batch_cache", None)
        if cached is None:
            log_axes = [np.log(a) for a in self.axes]
            log_table = np.log(np.maximum(self.table.ravel(), 1e-12))
            strides = [int(s) // self.table.itemsize
                       for s in self.table.strides]
            # exact power-of-two axes (every analytical grid) bracket via a
            # single log2 — no searchsorted, no per-row log-axis gathers
            pow2 = [math.log2(a[0])
                    if len(a) > 1 and a[0] > 0
                    and bool(np.all(a[1:] == 2.0 * a[:-1])) else None
                    for a in self.axes]
            ndim = len(self.axes)
            sv = np.asarray(strides, np.int64)
            bits = (np.arange(1 << ndim)[:, None] >> np.arange(ndim)) & 1
            corner_off = bits @ sv                      # [2^ndim] flat offsets
            cached = (log_axes, log_table, strides, pow2, sv, corner_off)
            self._batch_cache = cached
        return cached

    def _corner_setup(self, coords):
        """Shared prologue of the vectorized kernels: clamp, bracket and
        weight every coordinate, then flat-index ALL 2^ndim corners.
        Returns ``(flat [B, 2^ndim], wts [B, ndim])`` — corner ``c``'s bit
        ``d`` selects the hi neighbor along dim ``d``."""
        coords = np.asarray(coords, np.float64)
        if coords.ndim == 1:
            coords = coords[None, :]
        n_batch, ndim = coords.shape
        log_axes, _, _, pow2, sv, corner_off = self._batch_tables()
        lo = np.empty((n_batch, ndim), np.int64)
        wts = np.empty((n_batch, ndim), np.float64)
        for d, a in enumerate(self.axes):
            c = np.minimum(np.maximum(coords[:, d], a[0]), a[-1])
            if pow2[d] is not None:
                # axis is a[0] * 2^k: the bracket index is floor(log2)
                l2 = np.log2(c) - pow2[d]
                j = np.minimum(l2.astype(np.int64), len(a) - 2)
                w = l2 - j
            else:
                j = np.searchsorted(a, c, side="right") - 1
                j = np.clip(j, 0, len(a) - 2)
                la = log_axes[d]
                w = ((np.log(np.maximum(c, 1e-12)) - la[j])
                     / (la[j + 1] - la[j]))
            lo[:, d] = j
            wts[:, d] = np.minimum(np.maximum(w, 0.0), 1.0)
        flat = (lo @ sv)[:, None] + corner_off[None, :]
        return flat, wts

    @staticmethod
    def _reduce_corners(vals, wts) -> np.ndarray:
        """Dimension-wise linear reduction of gathered log-space corner
        values ``vals[B, 2^ndim]`` down to ``exp(interpolated)``."""
        ndim = wts.shape[1]
        for d in range(ndim):
            w = wts[:, d:d + 1]
            vals = vals[:, ::2] * (1.0 - w) + vals[:, 1::2] * w
        return np.exp(vals[:, 0])

    def query_batch(self, coords) -> np.ndarray:
        """Vectorized :meth:`query`: interpolate ``coords[B, ndim]`` in one
        shot.  Same clamping, corner weights, and log-space blend as the
        scalar path — answers agree to float64 rounding."""
        flat, wts = self._corner_setup(coords)
        log_table = self._batch_tables()[1]
        return self._reduce_corners(log_table[flat], wts)

    def query_batch_jax(self, coords) -> np.ndarray:
        """jnp/``jit`` variant of :meth:`query_batch` (one compiled kernel
        per grid, cached on the instance).  Enable x64 via
        ``repro.core.jaxenv`` for float64 parity with the numpy path."""
        import jax
        import jax.numpy as jnp

        fn = getattr(self, "_jax_fn", None)
        if fn is None:
            axes = tuple(jnp.asarray(a) for a in self.axes)
            log_axes = tuple(jnp.log(a) for a in axes)
            strides = self._batch_tables()[2]
            log_table = jnp.asarray(
                np.log(np.maximum(self.table.ravel(), 1e-12)))
            lens = tuple(len(a) for a in self.axes)
            ndim = len(self.axes)

            @jax.jit
            def fn(coords):
                n_batch = coords.shape[0]
                lo, wts = [], []
                for d in range(ndim):
                    a, la = axes[d], log_axes[d]
                    c = jnp.clip(coords[:, d], a[0], a[-1])
                    j = jnp.clip(jnp.searchsorted(a, c, side="right") - 1,
                                 0, lens[d] - 2)
                    w = ((jnp.log(jnp.maximum(c, 1e-12)) - la[j])
                         / (la[j + 1] - la[j]))
                    lo.append(j)
                    wts.append(jnp.clip(w, 0.0, 1.0))
                acc = jnp.zeros(n_batch)
                for corner in range(1 << ndim):
                    wgt = jnp.ones(n_batch)
                    flat = jnp.zeros(n_batch, jnp.int32)
                    for d in range(ndim):
                        hi = (corner >> d) & 1
                        wgt = wgt * (wts[d] if hi else 1.0 - wts[d])
                        flat = flat + (lo[d] + hi) * strides[d]
                    acc = acc + wgt * log_table[flat]
                return jnp.exp(acc)

            self._jax_fn = fn
        out = fn(jnp.asarray(np.asarray(coords, np.float64)))
        return np.asarray(out, np.float64)

    def query(self, coords: Sequence[float]) -> float:
        """Multilinear interpolation in log-space of coords AND latency."""
        lo_idx, weights = [], []
        for a, c in zip(self.axes, coords):
            c = min(max(c, a[0]), a[-1])
            j = int(np.searchsorted(a, c, side="right")) - 1
            j = min(max(j, 0), len(a) - 2)
            la, lb, lc = math.log(a[j]), math.log(a[j + 1]), math.log(max(c, 1e-12))
            w = (lc - la) / (lb - la)
            lo_idx.append(j)
            weights.append(min(max(w, 0.0), 1.0))
        acc = 0.0
        for corner in range(1 << len(coords)):
            wgt, idx = 1.0, []
            for d in range(len(coords)):
                hi = (corner >> d) & 1
                wgt *= weights[d] if hi else (1.0 - weights[d])
                idx.append(lo_idx[d] + hi)
            if wgt > 0:
                acc += wgt * math.log(max(self.table[tuple(idx)], 1e-12))
        return math.exp(acc)

    def to_json(self) -> Dict:
        return {"axes": [a.tolist() for a in self.axes],
                "table": self.table.ravel().tolist()}

    @staticmethod
    def query_stacked(grids: Sequence["OpGrid"], coords: np.ndarray,
                      gid: np.ndarray) -> np.ndarray:
        """Interpolate rows against a STACK of same-axes grids in one pass.

        ``gid[i]`` selects which grid row ``i`` reads; all grids must share
        axes (true per operator family by construction — every attention
        grid spans the same sequence axes, every comm grid the same bytes
        axis, ...).  Per-row arithmetic is identical to
        :meth:`query_batch`, so fusing G single-grid calls into one
        stacked call changes wall-clock, not answers."""
        g0 = grids[0]
        if len(grids) == 1:
            return g0.query_batch(coords)
        stack = np.stack([g._batch_tables()[1] for g in grids])   # [G, V]
        flat_tables = stack.ravel()
        flat, wts = g0._corner_setup(coords)
        flat = flat + (gid.astype(np.int64) * stack.shape[1])[:, None]
        return OpGrid._reduce_corners(flat_tables[flat], wts)

    @classmethod
    def from_json(cls, d: Dict) -> "OpGrid":
        axes = d["axes"]
        shape = tuple(len(a) for a in axes)
        return cls(axes, np.asarray(d["table"]).reshape(shape))


# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DatabaseStats:
    grid_hits: int = 0
    sol_fallbacks: int = 0
    grids_built: int = 0
    seq_hits: int = 0
    seq_queries: int = 0   # every sequence_latency call (memoized or not) —
                           # the probe streaming-search tests count pricing by


class PerfDatabase:
    """Operator latency oracle for one (platform, backend).

    When a calibration artifact (repro.calibrate) is applied, grid-backed
    queries pass through a per-operator-family correction layer
    (``corrected = scale · analytical^exponent``, fitted from measured
    kernel runs) — the grids themselves stay analytical so corrections are
    swappable, and the active calibration is part of ``fingerprint()``.
    """

    def __init__(self, platform: str | Platform = "tpu_v5e",
                 backend: str = "repro-jax", use_grid: bool = True,
                 calibration=None):
        self.platform = (platform if isinstance(platform, Platform)
                         else get_platform(platform))
        self.backend = backend
        self.use_grid = use_grid
        self._grids: Dict[Tuple, OpGrid] = {}
        self._memo: Dict = {}
        self._seq_memo: Dict[Tuple, float] = {}
        self._corrections: Dict[str, Tuple[float, float]] = {}
        self._calibration_id: Optional[Dict] = None
        self._epoch = 0   # bumps whenever answers change (recalibration) so
        #                   callers holding derived caches can invalidate
        self.stats = DatabaseStats()
        if use_grid:
            self._collect_static()
        if calibration is not None:
            self.apply_calibration(calibration)

    # -- offline collection -------------------------------------------------
    def _measure(self, op) -> float:
        """Profiling stand-in (real hardware in the paper)."""
        return analytical.latency(self.platform, op)

    def _collect_static(self) -> None:
        """Eagerly build the model-independent grids (GEMM, comm).

        Collection prices the whole coordinate mesh through the vectorized
        table builders (analytical.gemm_table & friends) instead of one
        ``_measure`` call per cell — the 21×9×9 GEMM grid costs one numpy
        expression, not 1701 Python walks.
        """
        for dtype in ("bf16", "fp8"):
            key = ("gemm", dtype)
            self._grids[key] = OpGrid(
                (GEMM_M, GEMM_N, GEMM_K),
                analytical.gemm_table(self.platform, GEMM_M, GEMM_N, GEMM_K,
                                      dtype))
            self.stats.grids_built += 1

    def _comm_grid(self, kind: str, n_chips: int, inter_pod: bool) -> OpGrid:
        key = ("comm", kind, n_chips, inter_pod)
        if key not in self._grids:
            self._grids[key] = OpGrid(
                (COMM_BYTES,),
                analytical.comm_table(self.platform, kind, n_chips,
                                      inter_pod, COMM_BYTES))
            self.stats.grids_built += 1
        return self._grids[key]

    def _attn_grid(self, a: ops.Attention) -> OpGrid:
        key = ("attn", a.phase, a.kind, a.heads, a.kv_heads, a.head_dim, a.dtype)
        if key not in self._grids:
            if a.phase == "prefill":
                tmpl = dataclasses.replace(a, batch=1, q_len=1, kv_len=1,
                                           q_offset=0, window=0)
                table = analytical.attn_prefill_table(
                    self.platform, tmpl, ATTN_SEQ, ATTN_SEQ)
                self._grids[key] = OpGrid((ATTN_SEQ, ATTN_SEQ), table)
            else:
                tmpl = dataclasses.replace(a, q_len=1, kv_len=1, window=0)
                table = analytical.attn_decode_table(
                    self.platform, tmpl, ATTN_BATCH, ATTN_SEQ)
                self._grids[key] = OpGrid((ATTN_BATCH, ATTN_SEQ), table)
            self.stats.grids_built += 1
        return self._grids[key]

    def _moe_grid(self, m: ops.MoEOp) -> OpGrid:
        key = ("moe", m.d_model, m.d_ff, m.num_experts, m.ep, m.dtype)
        if key not in self._grids:
            table = analytical.moe_table(self.platform, m, MOE_TOKENS)
            self._grids[key] = OpGrid((MOE_TOKENS,), table)
            self.stats.grids_built += 1
        return self._grids[key]

    def _rec_grid(self, r: ops.RecurrentOp) -> OpGrid:
        key = ("recurrent", r.kind, r.width, r.heads, r.dtype)
        if key not in self._grids:
            tmpl = dataclasses.replace(r, batch=1, seq=1)
            table = analytical.recurrent_table(self.platform, tmpl,
                                               REC_TOKENS)
            self._grids[key] = OpGrid((REC_TOKENS,), table)
            self.stats.grids_built += 1
        return self._grids[key]

    # -- calibration ---------------------------------------------------------
    def apply_calibration(self, artifact) -> "PerfDatabase":
        """Install a measured-kernel correction layer (a
        :class:`repro.calibrate.CalibrationArtifact` or any object exposing
        ``platform``/``backend``/``corrections()``/``identity()``).

        The artifact must have been calibrated for this database's
        (platform, backend) — silently applying foreign silicon's
        corrections would defeat the provenance the artifact exists for.
        Memoized latencies are invalidated because every grid-backed
        answer changes.
        """
        if artifact.platform != self.platform.name \
                or artifact.backend != self.backend:
            raise ValueError(
                f"calibration artifact is for "
                f"({artifact.platform}, {artifact.backend}); this database "
                f"is ({self.platform.name}, {self.backend})")
        self._corrections = dict(artifact.corrections())
        self._calibration_id = artifact.identity()
        self._memo.clear()
        self._seq_memo.clear()
        self._epoch += 1
        return self

    def load_calibration(self, path: str) -> "PerfDatabase":
        from repro.calibrate.artifact import CalibrationArtifact
        return self.apply_calibration(CalibrationArtifact.load(path))

    def _correct(self, family: str, t: float) -> float:
        c = self._corrections.get(family)
        if c is None:
            return t
        scale, exponent = c
        return scale * max(t, 1e-12) ** exponent

    def _correct_batch(self, family: str, t: np.ndarray) -> np.ndarray:
        c = self._corrections.get(family)
        if c is None:
            return t
        scale, exponent = c
        return scale * np.maximum(t, 1e-12) ** exponent

    # -- queries -------------------------------------------------------------
    def op_latency(self, op) -> float:
        try:
            cached = self._memo.get(op)
        except TypeError:  # unhashable custom op: price it uncached
            return self._op_latency_uncached(op)
        if cached is not None:
            return cached
        t = self._op_latency_uncached(op)
        if len(self._memo) < 1_000_000:
            self._memo[op] = t
        return t

    def _obs_op(self, family: str, path: str, n: float = 1.0,
                mode: str = "scalar") -> None:
        """Per-family query accounting into the installed MetricsRegistry
        (one `get_metrics()` check at each call site keeps the disabled
        path free).  `path` distinguishes grid interpolation from the
        roofline fallback; a fitted calibration correction upgrades
        "grid" to "grid_corrected"."""
        m = get_metrics()
        if m is None:
            return
        if path == "grid" and family in self._corrections:
            path = "grid_corrected"
        m.inc("repro_db_ops_total", n, family=family, path=path, mode=mode)

    def _op_latency_uncached(self, op) -> float:
        if not self.use_grid:
            self.stats.sol_fallbacks += 1
            self._obs_op(ops.op_family(op), "sol")
            return analytical.sol_latency(self.platform, op)

        # grid-backed paths apply the calibration correction to the grid
        # value itself (the quantity the measurement harness sampled:
        # prefill attention and recurrence are measured per batch row, so
        # the batch fold multiplies the corrected cell); family names come
        # from ops.op_family, the one mapping the calibration pipeline
        # fits and keys corrections by
        if isinstance(op, ops.GEMM):
            g = self._grids.get(("gemm", op.dtype))
            if g is None:
                self.stats.sol_fallbacks += 1
                self._obs_op("gemm", "sol")
                return analytical.sol_latency(self.platform, op)
            self.stats.grid_hits += 1
            self._obs_op("gemm", "grid")
            return self._correct(ops.op_family(op),
                                 g.query((op.m, op.n, op.k)))

        if isinstance(op, ops.Attention):
            grid = self._attn_grid(op)
            self.stats.grid_hits += 1
            kv = op.effective_kv()
            family = ops.op_family(op)
            self._obs_op(family, "grid")
            if op.phase == "prefill":
                # batch folds linearly (flash tiles over batch)
                return op.batch * self._correct(
                    family, grid.query((op.q_len, max(kv, 1))))
            return self._correct(
                family, grid.query((op.batch, max(kv, 1))))

        if isinstance(op, ops.MoEOp):
            grid = self._moe_grid(op)
            self.stats.grid_hits += 1
            self._obs_op("moe", "grid")
            return self._correct(
                ops.op_family(op), grid.query((max(op.rank_tokens(), 1),)))

        if isinstance(op, ops.RecurrentOp):
            grid = self._rec_grid(op)
            self.stats.grid_hits += 1
            self._obs_op("recurrent", "grid")
            return op.batch * self._correct(
                ops.op_family(op), grid.query((max(op.seq, 1),)))

        if isinstance(op, ops.Comm):
            if op.n_chips <= 1:
                return 0.0
            grid = self._comm_grid(op.kind, op.n_chips, op.inter_pod)
            self.stats.grid_hits += 1
            self._obs_op("comm", "grid")
            return self._correct(
                ops.op_family(op),
                grid.query((max(op.bytes_per_chip, 1.0),)))

        # embedding / mem ops: speed-of-light path (paper: unprofiled ops)
        self.stats.sol_fallbacks += 1
        self._obs_op(ops.op_family(op), "sol")
        return analytical.latency(self.platform, op)

    def sequence_latency(self, op_list: List) -> float:
        """Accepts plain operators or (operator, count) pairs.

        Whole op-sequences are memoized on top of the per-operator memo:
        candidate sweeps re-derive identical iteration decompositions
        constantly (same parallelism at a different batch, repeated
        searches over one database), so a warm database answers them
        without re-walking the operator list.
        """
        self.stats.seq_queries += 1
        m = get_metrics()
        if m is not None:
            m.inc("repro_db_seq_total", mode="scalar")
        key: Optional[Tuple] = None
        try:
            key = tuple(op_list)
            cached = self._seq_memo.get(key)
        except TypeError:  # unhashable custom op: skip sequence memo
            key = None
            cached = None
        if cached is not None:
            self.stats.seq_hits += 1
            if m is not None:
                m.inc("repro_db_seq_hits_total", mode="scalar")
            return cached
        total = 0.0
        for item in op_list:
            if isinstance(item, tuple):
                op, count = item
                total += count * self.op_latency(op)
            else:
                total += self.op_latency(item)
        if key is not None and len(self._seq_memo) < 500_000:
            self._seq_memo[key] = total
        return total

    def sequence_latency_batch(self, batch, backend: str = "np") -> np.ndarray:
        """Price a whole candidate batch in one fused pass.

        ``batch`` is a struct-of-arrays encoding from
        :func:`repro.core.decompose.encode_iteration_batch`: per-grid
        coordinate/multiplicity/owner arrays plus speed-of-light rows.
        Each grid group runs one vectorized interpolation
        (:meth:`OpGrid.query_batch`, or the jit'd jnp kernel when
        ``backend="jax"``), corrections apply per calibration family, and
        per-item sums come back via ``np.bincount`` — no per-operator
        Python walk.  Stats move exactly like ``n`` scalar
        ``sequence_latency`` calls pricing every operator uncached.
        """
        n = batch.n_items
        total = np.zeros(n, np.float64)
        self.stats.seq_queries += n
        m = get_metrics()
        if m is not None:
            m.inc("repro_db_seq_total", n, mode="batched")
        # bucket groups by operator family — every grid of a family shares
        # axes, so a whole family prices in ONE stacked interpolation pass
        # (per-grid numpy overhead is what separates ~20x from ~100x here)
        buckets: Dict[Tuple, List] = {}
        for rows in batch.grid_rows:
            op = rows.rep_op
            if isinstance(op, ops.GEMM):
                grid = self._grids.get(("gemm", op.dtype))
                if grid is None:
                    # unprofiled dtype: vectorized speed-of-light, the same
                    # roofline the scalar path falls back to (no correction)
                    m = rows.coords[:, 0]
                    nn = rows.coords[:, 1]
                    k = rows.coords[:, 2]
                    b = ops.BYTES[op.dtype]
                    t_c = (2.0 * m * nn * k) / self.platform.matmul_peak(
                        op.dtype)
                    t_m = (b * (m * k + k * nn + m * nn)) / self.platform.hbm_bw
                    vals = np.maximum(t_c, t_m)[rows.ridx]
                    self.stats.sol_fallbacks += len(rows.item)
                    self._obs_op("gemm", "sol", len(rows.item),
                                 mode="batched")
                    total += np.bincount(rows.item,
                                         weights=rows.mult * vals,
                                         minlength=n)
                    continue
                sig = ("gemm",)
            elif isinstance(op, ops.Attention):
                grid = self._attn_grid(op)
                sig = ("attn", op.phase)
            elif isinstance(op, ops.MoEOp):
                grid = self._moe_grid(op)
                sig = ("moe",)
            elif isinstance(op, ops.RecurrentOp):
                grid = self._rec_grid(op)
                sig = ("rec",)
            elif isinstance(op, ops.Comm):
                grid = self._comm_grid(op.kind, op.n_chips, op.inter_pod)
                sig = ("comm",)
            else:
                raise TypeError(f"no grid family for {type(op).__name__}")
            buckets.setdefault(sig, []).append((grid, rows))
        for group in buckets.values():
            family = group[0][1].family
            if backend == "jax":
                for grid, rows in group:
                    vals = self._correct_batch(
                        family, grid.query_batch_jax(rows.coords))[rows.ridx]
                    self.stats.grid_hits += len(rows.item)
                    self._obs_op(family, "grid", len(rows.item),
                                 mode="batched")
                    total += np.bincount(rows.item,
                                         weights=rows.mult * vals,
                                         minlength=n)
                continue
            if len(group) == 1:
                grid, rows = group[0]
                vals = self._correct_batch(
                    family, grid.query_batch(rows.coords))[rows.ridx]
                item, mult = rows.item, rows.mult
            else:
                # interpolation runs on each group's distinct coords only;
                # ridx (offset per group) re-expands to the logical rows
                coords = np.concatenate([r.coords for _, r in group])
                gid = np.repeat(np.arange(len(group)),
                                [len(r.coords) for _, r in group])
                off = np.cumsum([0] + [len(r.coords) for _, r in group[:-1]])
                ridx = np.concatenate(
                    [r.ridx + o for (_, r), o in zip(group, off)])
                vals = OpGrid.query_stacked([g for g, _ in group],
                                            coords, gid)
                vals = self._correct_batch(family, vals)[ridx]
                item = np.concatenate([r.item for _, r in group])
                mult = np.concatenate([r.mult for _, r in group])
            self.stats.grid_hits += len(item)
            self._obs_op(family, "grid", len(item), mode="batched")
            total += np.bincount(item, weights=mult * vals, minlength=n)
        sol = batch.sol_rows
        if sol is not None and len(sol.item):
            p = self.platform
            t = np.where(
                sol.kind == 0,
                sol.value / (p.hbm_bw * analytical.HBM_STREAM_EFF)
                + p.launch_overhead,
                sol.value / (p.hbm_bw * analytical.GATHER_EFF)
                + p.launch_overhead)
            self.stats.sol_fallbacks += len(sol.item)
            n_mem = int(np.count_nonzero(sol.kind == 0))
            if n_mem:
                self._obs_op("mem", "sol", n_mem, mode="batched")
            if len(sol.item) - n_mem:
                self._obs_op("embedding", "sol", len(sol.item) - n_mem,
                             mode="batched")
            total += np.bincount(sol.item, weights=sol.mult * t, minlength=n)
        return total

    # -- identity --------------------------------------------------------------
    def fingerprint(self) -> Dict:
        """Stable identity of this database's contents: platform/backend
        plus a digest over every grid's axes and latency table.

        Grids are built deterministically (eager GEMM/comm at
        construction, shape-keyed lazy grids on first use), so two
        databases that served the same workload on the same
        (platform, backend) fingerprint identically across runs, while
        any change to platform, backend, or collected latencies changes
        the digest — the auditability hook SearchReport v2 carries.
        """
        h = hashlib.sha256()
        for key in sorted(self._grids, key=repr):
            g = self._grids[key]
            h.update(repr(key).encode())
            for a in g.axes:
                h.update(np.ascontiguousarray(a).tobytes())
            h.update(np.ascontiguousarray(g.table).tobytes())
        return {"platform": self.platform.name, "backend": self.backend,
                "n_grids": len(self._grids),
                "grid_hash": h.hexdigest()[:16],
                "calibration": self._calibration_id}

    # -- persistence ----------------------------------------------------------
    def save(self, path: Optional[str] = None) -> str:
        path = path or os.path.join(
            os.path.dirname(__file__), "data",
            f"{self.platform.name}_{self.backend}.json")
        os.makedirs(os.path.dirname(path), exist_ok=True)
        blob = {"platform": self.platform.name, "backend": self.backend,
                "grids": {json.dumps(k): g.to_json()
                          for k, g in self._grids.items()}}
        if self._corrections:
            blob["calibration"] = {"corrections": self._corrections,
                                   "identity": self._calibration_id}
        with open(path, "w") as f:
            json.dump(blob, f)
        return path

    @classmethod
    def load(cls, path: str) -> "PerfDatabase":
        with open(path) as f:
            blob = json.load(f)
        db = cls(blob["platform"], blob["backend"], use_grid=False)
        db.use_grid = True
        db._grids = {tuple(json.loads(k)): OpGrid.from_json(g)
                     for k, g in blob["grids"].items()}
        cal = blob.get("calibration")
        if cal:
            db._corrections = {f: tuple(c)
                               for f, c in cal["corrections"].items()}
            db._calibration_id = cal["identity"]
        return db
