"""Backend abstraction (§4: "unified backend abstraction").

Each backend contributes: scheduling-overhead constants (the
framework-specific dynamics the paper insists generic models miss), default
runtime-flag values, memory-overhead factors, flag vocabulary for the
Generator, its EP collective pattern (consumed by decompose via the backend
name), and a declared capability set the Configurator validates against.

Backends plug in through the decorator registry — no core edits needed:

    from repro.core.backends.base import BackendProfile, register_backend

    @register_backend("my-engine", capabilities=("aggregated",))
    def _my_engine() -> BackendProfile:
        return BackendProfile(name="my-engine", ...)

Registration is explicit and duplicate names are rejected; the built-in
profiles (``repro.core.backends.profiles``) are loaded lazily the first
time any lookup runs, so importing this module has no side effects and
callers never need the old ``import profiles  # noqa: F401`` trick.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, FrozenSet, Iterable, Optional, Tuple, Union

#: Serving modes a workload can request (WorkloadDescriptor.modes).
SERVING_MODES = ("static", "aggregated", "disaggregated")

#: Everything a backend may declare support for: serving modes plus
#: cross-cutting features.
KNOWN_CAPABILITIES = frozenset(SERVING_MODES) | {"speculative"}


@dataclasses.dataclass(frozen=True)
class BackendProfile:
    name: str
    # host/scheduler overhead added to every iteration (s)
    step_overhead: float
    # extra per prefill chunk scheduled in an iteration (s)
    chunk_overhead: float
    # fraction of HBM reserved by the runtime itself
    runtime_mem_overhead: float
    # default per-iteration token capacity
    default_max_num_tokens: int
    # graph-capture analogue removes this much of step_overhead for decode
    graph_capture_saving: float
    # base of the paper's piecewise-linear TTFT correction F_corr
    # (= min(base + (T_ctx - 3)/20, 4)); empirical per framework (§4.2.2)
    f_corr_base: float = 2.0
    # engine runs each prompt's prefill as a SEPARATE kernel launch instead
    # of batching context tokens into one iteration (repro-jax engine on
    # CPU does; TRT-LLM-style engines don't) — prices chunks sequentially
    sequential_prefill: bool = False
    # flag vocabulary: canonical knob -> backend flag string
    flags: Dict[str, str] = dataclasses.field(default_factory=dict)
    launcher: str = "custom"
    # serving modes this backend supports (filled from the registry entry
    # when registered via @register_backend(..., capabilities=...))
    capabilities: FrozenSet[str] = KNOWN_CAPABILITIES

    def iteration_overhead(self, n_chunks: int, decode_rows: int,
                           graph_capture: bool) -> float:
        ov = self.step_overhead + n_chunks * self.chunk_overhead
        if graph_capture and decode_rows and not n_chunks:
            ov -= self.graph_capture_saving * self.step_overhead
        return max(ov, 1e-6)

    def supports(self, capability: str) -> bool:
        return capability in self.capabilities


ProfileSource = Union[BackendProfile, Callable[[], BackendProfile]]


@dataclasses.dataclass
class _Entry:
    source: ProfileSource
    capabilities: FrozenSet[str]
    resolved: Optional[BackendProfile] = None


_REGISTRY: Dict[str, _Entry] = {}
_BUILTINS_LOADED = False


def _ensure_builtins() -> None:
    """Load the in-tree profiles exactly once, on first lookup."""
    global _BUILTINS_LOADED
    if not _BUILTINS_LOADED:
        _BUILTINS_LOADED = True
        from repro.core.backends import profiles  # noqa: F401


def register_backend(name: str, *,
                     capabilities: Iterable[str] = KNOWN_CAPABILITIES,
                     override: bool = False):
    """Decorator registering a backend under ``name``.

    Accepts either a zero-arg factory returning a :class:`BackendProfile`
    (resolved lazily on first :func:`get_backend`) or a ready profile
    instance.  Duplicate names raise ``ValueError`` unless ``override=True``
    (used by calibration flows that legitimately re-register).
    """
    caps = frozenset(capabilities)
    unknown = caps - KNOWN_CAPABILITIES
    if unknown:
        raise ValueError(
            f"unknown capabilities {sorted(unknown)} for backend {name!r}; "
            f"known: {sorted(KNOWN_CAPABILITIES)}")

    def deco(source: ProfileSource) -> ProfileSource:
        if name in _REGISTRY and not override:
            raise ValueError(
                f"backend {name!r} is already registered; pass "
                f"override=True to replace it")
        _REGISTRY[name] = _Entry(source=source, capabilities=caps)
        return source

    return deco


def register(profile: BackendProfile,
             capabilities: Optional[Iterable[str]] = None
             ) -> BackendProfile:
    """Legacy instance-registration helper (kept for calibration flows);
    silently replaces an existing entry of the same name.  Unless new
    capabilities are given explicitly, a re-registration keeps the
    capabilities the backend originally declared."""
    if capabilities is None:
        prior = _REGISTRY.get(profile.name)
        capabilities = (prior.capabilities if prior is not None
                        else KNOWN_CAPABILITIES)
    register_backend(profile.name, capabilities=capabilities,
                     override=True)(profile)
    return profile


def unregister_backend(name: str) -> None:
    _REGISTRY.pop(name, None)


def get_backend(name: str) -> BackendProfile:
    _ensure_builtins()
    try:
        entry = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown backend {name!r}; known: {sorted(_REGISTRY)}")
    if entry.resolved is None:
        prof = entry.source() if callable(entry.source) else entry.source
        if prof.capabilities != entry.capabilities:
            prof = dataclasses.replace(prof, capabilities=entry.capabilities)
        entry.resolved = prof
    return entry.resolved


def backend_capabilities(name: str) -> FrozenSet[str]:
    return get_backend(name).capabilities


def all_backends() -> Tuple[str, ...]:
    _ensure_builtins()
    return tuple(sorted(_REGISTRY))
