"""Backend abstraction (§4: "unified backend abstraction").

Each backend contributes: scheduling-overhead constants (the
framework-specific dynamics the paper insists generic models miss), default
runtime-flag values, memory-overhead factors, flag vocabulary for the
Generator, and its EP collective pattern (consumed by decompose via the
backend name).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass(frozen=True)
class BackendProfile:
    name: str
    # host/scheduler overhead added to every iteration (s)
    step_overhead: float
    # extra per prefill chunk scheduled in an iteration (s)
    chunk_overhead: float
    # fraction of HBM reserved by the runtime itself
    runtime_mem_overhead: float
    # default per-iteration token capacity
    default_max_num_tokens: int
    # graph-capture analogue removes this much of step_overhead for decode
    graph_capture_saving: float
    # base of the paper's piecewise-linear TTFT correction F_corr
    # (= min(base + (T_ctx - 3)/20, 4)); empirical per framework (§4.2.2)
    f_corr_base: float = 2.0
    # engine runs each prompt's prefill as a SEPARATE kernel launch instead
    # of batching context tokens into one iteration (repro-jax engine on
    # CPU does; TRT-LLM-style engines don't) — prices chunks sequentially
    sequential_prefill: bool = False
    # flag vocabulary: canonical knob -> backend flag string
    flags: Dict[str, str] = dataclasses.field(default_factory=dict)
    launcher: str = "custom"

    def iteration_overhead(self, n_chunks: int, decode_rows: int,
                           graph_capture: bool) -> float:
        ov = self.step_overhead + n_chunks * self.chunk_overhead
        if graph_capture and decode_rows and not n_chunks:
            ov -= self.graph_capture_saving * self.step_overhead
        return max(ov, 1e-6)


_REGISTRY: Dict[str, BackendProfile] = {}


def register(profile: BackendProfile) -> BackendProfile:
    _REGISTRY[profile.name] = profile
    return profile


def get_backend(name: str) -> BackendProfile:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown backend {name!r}; known: {sorted(_REGISTRY)}")


def all_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))
