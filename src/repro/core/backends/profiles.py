"""Concrete backend profiles.

repro-jax is our real, executable JAX engine (its overhead constants can be
re-calibrated from wall-clock engine runs — see
benchmarks/fig6_fidelity.py).  trtllm/vllm/sglang model the production
frameworks' scheduling dynamics: static-graph low-overhead stepping
(TRT-LLM), Python-scheduler overhead (vLLM), Triton-launch middle ground
(SGLang).  Flag vocabularies feed the Generator.

Each profile registers through the ``@register_backend`` decorator — the
same entrypoint third-party backends use — and is resolved lazily by
``get_backend``; this module is imported by the registry itself on first
lookup, never as an import-time side effect of unrelated modules.
"""
from __future__ import annotations

from repro.core.backends.base import (KNOWN_CAPABILITIES, BackendProfile,
                                      get_backend, register_backend)


@register_backend("repro-jax", capabilities=KNOWN_CAPABILITIES)
def _repro_jax() -> BackendProfile:
    return BackendProfile(
        name="repro-jax",
        step_overhead=120e-6,          # python dispatch + host sync
        chunk_overhead=40e-6,          # per-prompt prefill dispatch
        runtime_mem_overhead=0.04,
        default_max_num_tokens=8192,
        graph_capture_saving=0.6,      # donated fixed-shape decode step
        # our engine admits requests into the next iteration immediately (no
        # TRT-LLM-style admission queue), so the TTFT correction base is ~1
        f_corr_base=1.0,
        flags={
            "max_num_tokens": "--max-num-tokens",
            "kv_cache_mem_fraction": "--kv-cache-hbm-fraction",
            "enable_chunked_context": "--chunked-prefill",
            "enable_graph_capture": "--decode-bucketing",
        },
        launcher="python -m repro.launch.serve",
    )


@register_backend("trtllm", capabilities=KNOWN_CAPABILITIES)
def _trtllm() -> BackendProfile:
    return BackendProfile(
        name="trtllm",
        step_overhead=30e-6,           # static engine, C++ runtime
        chunk_overhead=15e-6,
        runtime_mem_overhead=0.08,     # engine workspace
        default_max_num_tokens=8192,
        graph_capture_saving=0.8,
        flags={
            "max_num_tokens": "--max_num_tokens",
            "kv_cache_mem_fraction": "--kv_cache_free_gpu_mem_fraction",
            "enable_chunked_context": "--enable_chunked_context",
            "enable_graph_capture": "--enable_cuda_graph",
        },
        launcher="trtllm-serve",
    )


@register_backend("vllm", capabilities=KNOWN_CAPABILITIES)
def _vllm() -> BackendProfile:
    return BackendProfile(
        name="vllm",
        step_overhead=150e-6,          # python scheduler
        chunk_overhead=30e-6,
        runtime_mem_overhead=0.05,
        default_max_num_tokens=8192,
        graph_capture_saving=0.7,
        flags={
            "max_num_tokens": "--max-num-batched-tokens",
            "kv_cache_mem_fraction": "--gpu-memory-utilization",
            "enable_chunked_context": "--enable-chunked-prefill",
            "enable_graph_capture": "--compilation-config",
        },
        launcher="vllm serve",
    )


@register_backend("sglang", capabilities=KNOWN_CAPABILITIES)
def _sglang() -> BackendProfile:
    return BackendProfile(
        name="sglang",
        step_overhead=60e-6,
        chunk_overhead=25e-6,
        runtime_mem_overhead=0.06,
        default_max_num_tokens=8192,
        graph_capture_saving=0.75,
        flags={
            "max_num_tokens": "--max-prefill-tokens",
            "kv_cache_mem_fraction": "--mem-fraction-static",
            "enable_chunked_context": "--chunked-prefill-size",
            "enable_graph_capture": "--cuda-graph-max-bs",
        },
        launcher="python -m sglang.launch_server",
    )


# resolved singletons for direct import (calibration, tests)
REPRO_JAX = get_backend("repro-jax")
TRTLLM = get_backend("trtllm")
VLLM = get_backend("vllm")
SGLANG = get_backend("sglang")
