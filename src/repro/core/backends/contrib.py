"""Contrib backend plugins — out-of-tree-style profiles that are NOT part
of the builtin registry.

This module is the reference for how a third-party serving engine joins
the configurator: import it (nothing else), and ``@register_backend``
puts a lazily-resolved factory in the registry with an explicit,
restricted capability set the Configurator gates workloads against.  The
builtin loader never imports this module, so ``disagg-router`` only
exists for processes that opted in — exactly the plugin contract.

    import repro.core.backends.contrib  # noqa: F401  (registers)

    Configurator.for_model(...).backend("disagg-router") \\
        .modes("disaggregated")        # ok
        .modes("aggregated")           # ValueError: capability gated
"""
from __future__ import annotations

from repro.core.backends.base import BackendProfile, register_backend


@register_backend("disagg-router", capabilities=("disaggregated",))
def _disagg_router() -> BackendProfile:
    """A prefill/decode-disaggregated router deployment: requests always
    cross a router hop into separate pools, so there is no aggregated or
    static mode to declare — only ``disaggregated``.  The router adds a
    fixed per-iteration dispatch cost on top of an otherwise TRT-class
    C++ data plane."""
    return BackendProfile(
        name="disagg-router",
        step_overhead=45e-6,           # C++ pool step + router dispatch
        chunk_overhead=20e-6,
        runtime_mem_overhead=0.07,     # router buffers + engine workspace
        default_max_num_tokens=16384,  # prefill pools batch aggressively
        graph_capture_saving=0.75,
        f_corr_base=1.8,               # admission queue ahead of prefill
        flags={
            "max_num_tokens": "--max-pool-tokens",
            "kv_cache_mem_fraction": "--kv-cache-fraction",
            "enable_chunked_context": "--chunked-prefill",
            "enable_graph_capture": "--decode-graphs",
        },
        launcher="python -m disagg_router.serve",
    )
