"""Generator (§4.1): converts a Pareto-selected Projection into a
version-compatible launch artifact for the chosen backend, resolving the
optimal runtime flags (graph capture, KV-cache memory fraction, max token
capacity) from the memory model.

For the repro-jax backend the artifact is directly consumable by
``python -m repro.launch.serve`` (and by serving.engine.EngineConfig) —
the configurator's output drives the real engine end-to-end.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

from repro.configs import get_config
from repro.core import decompose
from repro.core.backends.base import get_backend
from repro.core.config import Projection, RuntimeFlags, ParallelismConfig, WorkloadDescriptor
from repro.core.hardware import get_platform


def resolve_kv_fraction(workload: WorkloadDescriptor,
                        par: ParallelismConfig, batch: int,
                        max_num_tokens: Optional[int] = None) -> float:
    """Pick the KV fraction that exactly covers the needed cache + margin.

    ``max_num_tokens`` must be the candidate's actual RuntimeFlags value so
    the activation budget here agrees with the ``fits_memory`` model the
    search applied; defaults to the backend's default token capacity.
    """
    cfg = get_config(workload.model)
    platform = get_platform(workload.cluster.platform)
    backend = get_backend(workload.backend)
    if max_num_tokens is None:
        max_num_tokens = backend.default_max_num_tokens
    p = decompose.param_bytes_per_chip(cfg, par, workload.dtype)
    a = decompose.activation_bytes_per_chip(cfg, par, max_num_tokens,
                                            workload.dtype)
    need = decompose.kv_bytes_per_chip(cfg, par, batch,
                                       workload.isl + workload.osl,
                                       workload.dtype)
    free = platform.hbm_capacity * (1 - backend.runtime_mem_overhead) - p - a
    if free <= 0:
        return 0.9
    frac = min(0.95, 1.1 * need / free)          # 10% headroom
    return round(max(frac, 0.05), 3)


def _parallel_of(d: Dict) -> ParallelismConfig:
    return ParallelismConfig(**{k: d[k] for k in ("tp", "pp", "ep", "dp")})


@dataclasses.dataclass
class LaunchConfig:
    backend: str
    command: str
    env: Dict[str, str]
    raw: Dict

    def to_json(self) -> str:
        return json.dumps(self.raw, indent=2)


def generate(workload: WorkloadDescriptor, proj: Projection) -> LaunchConfig:
    backend = get_backend(workload.backend)
    if proj.mode == "disaggregated":
        return _generate_disagg(workload, proj, backend)
    par = _parallel_of(proj.config["parallel"])
    flags = proj.config.get("flags", dataclasses.asdict(RuntimeFlags()))
    kv_frac = resolve_kv_fraction(workload, par, proj.batch_size,
                                  max_num_tokens=flags["max_num_tokens"])
    knobs = {
        "max_num_tokens": flags["max_num_tokens"],
        "kv_cache_mem_fraction": kv_frac,
        "enable_chunked_context": flags["enable_chunked_context"],
        "enable_graph_capture": flags["enable_graph_capture"],
    }
    parts = [backend.launcher, f"--model {workload.model}",
             f"--tp {par.tp}", f"--pp {par.pp}"]
    if par.ep > 1:
        parts.append(f"--ep {par.ep}")
    parts.append(f"--max-batch {proj.batch_size}")
    for knob, val in knobs.items():
        flag = backend.flags.get(knob)
        if flag is None:
            continue
        if isinstance(val, bool):
            if val:
                parts.append(flag)
        else:
            parts.append(f"{flag} {val}")
    raw = {
        "backend": backend.name, "mode": proj.mode,
        "model": workload.model,
        "parallel": dataclasses.asdict(par),
        "batch_size": proj.batch_size,
        "runtime_flags": knobs,
        "projection": {
            "ttft_ms": proj.ttft_ms, "tpot_ms": proj.tpot_ms,
            "tokens_per_s_per_chip": proj.tokens_per_s_per_chip,
        },
    }
    return LaunchConfig(backend=backend.name, command=" ".join(parts),
                        env={}, raw=raw)


def _generate_disagg(workload, proj, backend) -> LaunchConfig:
    pre, dec = proj.config["prefill"], proj.config["decode"]
    pre_par, dec_par = _parallel_of(pre["parallel"]), _parallel_of(dec["parallel"])
    kv_frac = resolve_kv_fraction(workload, dec_par, dec["batch"])
    raw = {
        "backend": backend.name, "mode": "disaggregated",
        "model": workload.model,
        "prefill_workers": {"count": pre["x"],
                            "parallel": dataclasses.asdict(pre_par),
                            "batch_size": pre["batch"]},
        "decode_workers": {"count": dec["y"],
                           "parallel": dataclasses.asdict(dec_par),
                           "batch_size": dec["batch"],
                           "kv_cache_mem_fraction": kv_frac},
        "projection": {"ttft_ms": proj.ttft_ms, "tpot_ms": proj.tpot_ms,
                       "tokens_per_s_per_chip": proj.tokens_per_s_per_chip},
    }
    cmd = (f"{backend.launcher} --model {workload.model} --disaggregated "
           f"--prefill {pre['x']}xTP{pre_par.tp} "
           f"--decode {dec['y']}xTP{dec_par.tp} "
           f"--decode-batch {dec['batch']} "
           f"{backend.flags.get('kv_cache_mem_fraction', '--kv-frac')} {kv_frac}")
    return LaunchConfig(backend=backend.name, command=cmd, env={}, raw=raw)
