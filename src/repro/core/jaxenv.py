"""jax/array-backend environment plumbing for the batched pricing path.

The whole-space pricing kernel (OpGrid.query_batch +
PerfDatabase.sequence_latency_batch) runs on numpy by default and on
jax.numpy under ``jit`` when asked to.  This module owns the env-var
surface that selects the path and the ``jax.config`` knobs the jnp
variant needs (x64 precision, platform, host device count) — the same
helpers research codebases ship for reproducible jax setup.

Environment variables
---------------------
REPRO_BATCHED_PRICING   "0"/"false" forces the scalar per-candidate path
                        (default: batched pricing on)
REPRO_PRICING_BACKEND   "np" (default) or "jax" — array backend for the
                        fused interpolation kernel
REPRO_PRICING_CHUNK     candidates per pricing batch in the streaming
                        cursor (default 64; must stay small enough that
                        early-exit consumers skip real work)
REPRO_JAX_X64           when set truthy, enable 64-bit jax arrays before
                        the first jax pricing call
REPRO_JAX_PLATFORM      force jax_platform_name (e.g. "cpu")
REPRO_HOST_DEVICES      --xla_force_host_platform_device_count value
"""
from __future__ import annotations

import os

_FALSY = {"0", "false", "no", "off", ""}

DEFAULT_CHUNK = 64


def _env_flag(name: str, default: bool) -> bool:
    raw = os.environ.get(name)
    if raw is None:
        return default
    return raw.strip().lower() not in _FALSY


def batched_pricing_default() -> bool:
    """Whether iter_search should price through the batched cursor."""
    return _env_flag("REPRO_BATCHED_PRICING", True)


def pricing_backend() -> str:
    """Array backend for the fused pricing kernel: 'np' or 'jax'."""
    raw = os.environ.get("REPRO_PRICING_BACKEND", "np").strip().lower()
    return "jax" if raw in ("jax", "jnp") else "np"


def pricing_chunk(default: int = DEFAULT_CHUNK) -> int:
    """Candidates per pricing batch in the streaming cursor."""
    try:
        n = int(os.environ.get("REPRO_PRICING_CHUNK", default))
    except (TypeError, ValueError):
        return default
    return max(n, 1)


# ---------------------------------------------------------------------------
# jax.config knobs (imported lazily so numpy-only runs never touch jax)
# ---------------------------------------------------------------------------

def enable_x64(use_x64: bool = True) -> None:
    """Set the default jax float precision to 64 (or back to 32) bits."""
    import jax
    jax.config.update("jax_enable_x64", bool(use_x64))


def x64_enabled() -> bool:
    import jax
    return bool(jax.config.read("jax_enable_x64"))


def set_platform(platform: str = "cpu") -> None:
    """Pin the jax platform; only effective before the first jax op."""
    import jax
    jax.config.update("jax_platform_name", platform)


def set_host_device_count(n: int) -> None:
    """Expose ``n`` host devices; only effective before jax initializes."""
    flags = os.environ.get("XLA_FLAGS", "")
    kept = [f for f in flags.split()
            if not f.startswith("--xla_force_host_platform_device_count")]
    kept.append(f"--xla_force_host_platform_device_count={int(n)}")
    os.environ["XLA_FLAGS"] = " ".join(kept)


def configure_from_env() -> None:
    """Apply REPRO_JAX_* / REPRO_HOST_DEVICES before a jax pricing run."""
    if os.environ.get("REPRO_HOST_DEVICES"):
        set_host_device_count(int(os.environ["REPRO_HOST_DEVICES"]))
    if os.environ.get("REPRO_JAX_PLATFORM"):
        set_platform(os.environ["REPRO_JAX_PLATFORM"])
    if _env_flag("REPRO_JAX_X64", False):
        enable_x64(True)
