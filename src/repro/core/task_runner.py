"""TaskRunner (§4.1): builds the candidate search space from a workload
descriptor, drives InferenceSession over every candidate, hands the results
to the Pareto analyzer, and reports search timing (Table 1's metric).

Candidate enumeration and pricing are generators end-to-end:
:meth:`TaskRunner.iter_search` lazily yields ``(CandidateConfig,
Projection)`` pairs as each candidate is priced against the (memoized)
PerfDatabase, and :meth:`TaskRunner.run` is just "drain the iterator into
a SearchResult" — batch and streaming search share one pricing code path,
so an early-exit consumer prices strictly fewer candidates than a full
sweep.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Dict, Iterator, List, Optional, Tuple

from repro.core import jaxenv, modes, pareto
from repro.core.config import (CandidateConfig, DisaggConfig,
                               ParallelismConfig, Projection, RuntimeFlags,
                               WorkloadDescriptor)
from repro.core.perf_database import PerfDatabase
from repro.core.session import InferenceSession
from repro.obs.metrics import get_metrics
from repro.obs.trace import get_tracer

BATCH_SWEEP = (1, 2, 4, 8, 16, 32, 64, 128, 256)
MAX_TOKENS_SWEEP = (4096, 8192, 16384)


@dataclasses.dataclass
class SearchProgress:
    """Mutable side-channel a streaming consumer shares with
    :meth:`TaskRunner.iter_search` — candidates priced so far (including
    OOM/invalid ones that yield nothing) and the disaggregated solution
    once that phase has run.

    ``abort`` is the out-of-band early-exit hook: when set (streaming
    search installs its elapsed-based policy check), it is consulted
    during the long non-yielding disaggregated phase — once per pool
    candidate priced and once per (decode, prefill, x) matching slice —
    and a True return preempts the phase, leaving the best-so-far
    composite and ``disagg_preempted`` set."""
    n_evaluated: int = 0
    n_yielded: int = 0
    disagg_best: Optional[modes.DisaggBest] = None
    disagg_done: bool = False
    abort: Optional[Callable[[], bool]] = None
    disagg_pool_evaluated: int = 0
    disagg_preempted: bool = False


@dataclasses.dataclass
class SearchResult:
    projections: List[Projection]
    best: Optional[Projection]
    frontier: List[Projection]
    n_candidates: int
    elapsed_s: float
    per_candidate_ms: float
    disagg_best: Optional[modes.DisaggBest] = None

    def summary(self) -> str:
        lines = [f"evaluated {self.n_candidates} candidates in "
                 f"{self.elapsed_s:.2f}s "
                 f"({self.per_candidate_ms:.2f} ms/config)"]
        if self.best:
            b = self.best
            lines.append(
                f"best [{b.mode}] {b.config.get('describe', '')}: "
                f"{b.tokens_per_s_per_chip:.1f} tok/s/chip @ "
                f"{b.tokens_per_s_user:.1f} tok/s/user "
                f"(TTFT {b.ttft_ms:.0f}ms)")
        return "\n".join(lines)


class TaskRunner:
    def __init__(self, workload: WorkloadDescriptor,
                 db: Optional[PerfDatabase] = None,
                 session: Optional[InferenceSession] = None):
        self.w = workload
        if session is not None and session.w is not workload \
                and session.w != workload:
            raise ValueError("session was built for a different workload")
        self.session = session or InferenceSession(workload, db)
        self.cfg = self.session.cfg

    # ------------------------------------------------------------------
    def parallelism_candidates(self, max_chips: Optional[int] = None
                               ) -> List[ParallelismConfig]:
        cluster = self.w.cluster
        limit = max_chips or cluster.n_chips
        # a pipeline stage needs at least one layer: never emit pp beyond
        # min(8, num_layers), regardless of which cap the doubling loop
        # would have tripped first on shallow models
        max_pp = min(8, max(self.cfg.num_layers, 1))
        out = []
        tp = 1
        while tp <= limit:
            pp = 1
            while tp * pp <= limit and pp <= max_pp:
                eps = [1]
                if self.cfg.num_experts:
                    eps = [e for e in (1, 2, 4, 8, 16, 32, 64)
                           if e <= tp and tp % e == 0
                           and e <= self.cfg.num_experts]
                for ep in eps:
                    out.append(ParallelismConfig(tp=tp, pp=pp, ep=ep))
                pp *= 2
            tp *= 2
        return out

    def iter_candidates(self, sweep_flags: bool = False
                        ) -> Iterator[CandidateConfig]:
        """Lazily enumerate the (parallelism × batch × flags) grid."""
        toks = MAX_TOKENS_SWEEP if sweep_flags else (
            self.session.backend.default_max_num_tokens,)
        for par, b, mt in itertools.product(
                self.parallelism_candidates(), BATCH_SWEEP, toks):
            yield CandidateConfig(
                parallel=par, batch_size=b,
                flags=RuntimeFlags(max_num_tokens=mt))

    def candidates(self, sweep_flags: bool = False) -> List[CandidateConfig]:
        return list(self.iter_candidates(sweep_flags))

    def simulator(self, cand: CandidateConfig,
                  priority_admission: bool = False,
                  max_queue: int = 100_000):
        """Discrete-event simulator for one candidate, priced by this
        runner's (memoized) session — the open-loop replay engine behind
        SLO-aware frontier re-ranking shares the PerfDatabase that
        priced the analytical search."""
        from repro.serving.scheduler import SchedulerConfig
        from repro.serving.sim import ServingSimulator
        sched_cfg = SchedulerConfig(
            max_batch=cand.batch_size,
            max_num_tokens=cand.flags.max_num_tokens,
            chunked_prefill=cand.flags.enable_chunked_context,
            priority_admission=priority_admission,
            max_queue=max_queue)
        par, flags = cand.parallel, cand.flags

        def latency_s(spec) -> float:
            return self.session.spec_latency_ms(par, spec, flags) / 1e3

        return ServingSimulator(sched_cfg, latency_s)

    def cluster_simulator(self, deployment, routing: str = "round_robin",
                          priority_admission: bool = True,
                          max_queue: int = 100_000):
        """Multi-replica cluster simulator for one
        :class:`~repro.capacity.deployment.DeploymentSpec` — N identical
        engines behind a routing policy, every replica priced by this
        runner's (memoized) session, so a whole capacity ladder shares
        the PerfDatabase that priced the analytical search."""
        from repro.capacity.cluster import ClusterSimulator
        from repro.serving.scheduler import SchedulerConfig
        cand = deployment.candidate
        sched_cfg = SchedulerConfig(
            max_batch=cand.batch_size,
            max_num_tokens=cand.flags.max_num_tokens,
            chunked_prefill=cand.flags.enable_chunked_context,
            priority_admission=priority_admission,
            max_queue=max_queue)
        par, flags = cand.parallel, cand.flags

        def latency_s(spec) -> float:
            return self.session.spec_latency_ms(par, spec, flags) / 1e3

        return ClusterSimulator(sched_cfg, latency_s,
                                replicas=deployment.replicas,
                                routing=routing)

    def autoscale_simulator(self, cand, policy,
                            routing: str = "round_robin",
                            initial_replicas=None,
                            tick_s: float = 1.0,
                            cold_start_s: float = 5.0,
                            priority_admission: bool = True,
                            max_queue: int = 100_000):
        """Autoscaling control loop for one candidate engine — the
        policy resizes a fleet of replicas (each priced by this
        runner's memoized session) on a fixed tick, so the autoscaled
        run, the static capacity ladder, and the analytical search all
        share one PerfDatabase."""
        from repro.autoscale.simulator import AutoscaleSimulator
        from repro.serving.scheduler import SchedulerConfig
        sched_cfg = SchedulerConfig(
            max_batch=cand.batch_size,
            max_num_tokens=cand.flags.max_num_tokens,
            chunked_prefill=cand.flags.enable_chunked_context,
            priority_admission=priority_admission,
            max_queue=max_queue)
        par, flags = cand.parallel, cand.flags

        def latency_s(spec) -> float:
            return self.session.spec_latency_ms(par, spec, flags) / 1e3

        return AutoscaleSimulator(
            sched_cfg, latency_s, policy, routing=routing,
            initial_replicas=initial_replicas,
            chips_per_replica=par.chips_per_instance,
            tick_s=tick_s, cold_start_s=cold_start_s)

    # ------------------------------------------------------------------
    def iter_search(self, sweep_flags: bool = False,
                    keep_all_disagg: bool = False,
                    progress: Optional[SearchProgress] = None,
                    batched: Optional[bool] = None
                    ) -> Iterator[Tuple[CandidateConfig, Projection]]:
        """Lazily price candidates, yielding ``(candidate, projection)``
        pairs as each one resolves against the PerfDatabase.

        Candidates that do not fit memory (or otherwise project to
        nothing) are counted in ``progress.n_evaluated`` but yield no
        pair.  Disaggregated composites are matched after the
        per-candidate modes; each disagg projection is yielded with its
        decode-pool candidate (the composite itself lives in
        ``projection.config``), best composite first.  Abandoning the
        iterator early (early-exit policy, ``break`` in a UI loop) skips
        all remaining pricing work.

        ``batched`` selects the fused batch-pricing cursor (record the
        chunk's spec atoms, price them in one
        ``sequence_latency_batch`` call, replay the projections);
        ``None`` defers to ``REPRO_BATCHED_PRICING`` and falls back to
        scalar whenever the database/model cannot batch.  Both paths
        yield the identical (candidate, projection) stream; the batched
        cursor prices at most one chunk (``REPRO_PRICING_CHUNK``,
        default 64 candidates) ahead of the consumer, so early exits
        still skip nearly all remaining work.
        """
        progress = progress if progress is not None else SearchProgress()
        if batched is None:
            batched = jaxenv.batched_pricing_default()
        batched = bool(batched) and self.session.batch_pricing_ok()

        if "static" in self.w.modes or "aggregated" in self.w.modes:
            if batched:
                yield from self._iter_modes_batched(sweep_flags, progress)
            else:
                m = get_metrics()
                for cand in self.iter_candidates(sweep_flags):
                    if m is not None:
                        m.inc("repro_search_candidates_enumerated_total",
                              path="scalar")
                    if "static" in self.w.modes:
                        p = self.session.evaluate_static(cand)
                        progress.n_evaluated += 1
                        if m is not None:
                            m.inc("repro_search_candidates_priced_total"
                                  if p else
                                  "repro_search_candidates_pruned_total",
                                  path="scalar", mode="static")
                        if p:
                            progress.n_yielded += 1
                            yield cand, p
                    if "aggregated" in self.w.modes:
                        p = self.session.evaluate_aggregated(cand)
                        progress.n_evaluated += 1
                        if m is not None:
                            m.inc("repro_search_candidates_priced_total"
                                  if p else
                                  "repro_search_candidates_pruned_total",
                                  path="scalar", mode="aggregated")
                        if p:
                            progress.n_yielded += 1
                            yield cand, p

        if "disaggregated" in self.w.modes:
            pool_before = progress.disagg_pool_evaluated
            with get_tracer().span("search.disagg") as sp:
                disagg_best, disagg_all = self._run_disagg(keep_all_disagg,
                                                           progress)
                sp.set(pool_evaluated=progress.disagg_pool_evaluated
                       - pool_before,
                       preempted=progress.disagg_preempted,
                       matched=disagg_best is not None)
            m = get_metrics()
            if m is not None:
                m.inc("repro_search_disagg_pool_total",
                      progress.disagg_pool_evaluated - pool_before)
            progress.disagg_best = disagg_best
            progress.disagg_done = True
            if disagg_best:
                progress.n_yielded += 1
                yield disagg_best.decode.config, \
                    self._disagg_projection(disagg_best)
            for d in disagg_all or []:
                if d is not disagg_best:
                    progress.n_yielded += 1
                    yield d.decode.config, self._disagg_projection(d)

    def _iter_modes_batched(self, sweep_flags: bool,
                            progress: SearchProgress
                            ) -> Iterator[Tuple[CandidateConfig, Projection]]:
        """Chunked record → price → replay cursor over the static and
        aggregated modes.  Per chunk: record every feasible candidate's
        spec atoms (mode algorithms have latency-independent control
        flow), price all atoms in one ``InferenceSession.price_specs``
        call (struct-of-arrays encoding + fused interpolation kernel),
        then replay each candidate against its latency slice to build
        the real Projection.  Yield order, n_evaluated accounting, and
        the projections themselves match the scalar loop."""
        chunk_n = jaxenv.pricing_chunk()
        kernel = jaxenv.pricing_backend()
        session = self.session
        mode_fns = [(m, session.evaluate_static if m == "static"
                     else session.evaluate_aggregated)
                    for m in ("static", "aggregated") if m in self.w.modes]
        cand_it = self.iter_candidates(sweep_flags)
        metrics = get_metrics()
        tracer = get_tracer()
        chunk_idx = 0
        while True:
            cands = list(itertools.islice(cand_it, chunk_n))
            if not cands:
                return
            # record pass: plan = (cand, fn, mem, atom offset, n_atoms)
            # (the whole record→price block nests under one chunk span;
            # replay spans stay outside it so no span is open at a yield)
            with tracer.span("search.chunk", index=chunk_idx,
                             candidates=len(cands)) as sp:
                plans, atoms = [], []
                with tracer.span("search.record"):
                    for cand in cands:
                        mem = session._mem_ok(cand)
                        for _mode, fn in mode_fns:
                            if not mem[0]:
                                plans.append((cand, fn, mem, 0, 0))
                                continue
                            _, rec = session.record_specs(
                                lambda _f=fn, _c=cand, _m=mem:
                                _f(_c, _mem=_m, _plan_only=True))
                            plans.append((cand, fn, mem, len(atoms),
                                          len(rec)))
                            atoms.extend(rec)
                values = session.price_specs(atoms, backend_kernel=kernel) \
                    if atoms else []
                sp.set(atoms=len(atoms))
            if metrics is not None:
                metrics.inc("repro_search_chunks_total")
                metrics.inc("repro_search_candidates_enumerated_total",
                            len(cands), path="batched")
            chunk_idx += 1
            # replay pass, in the scalar loop's candidate × mode order
            pi = -1
            try:
                for pi, (cand, fn, mem, start, n) in enumerate(plans):
                    progress.n_evaluated += 1
                    if not mem[0]:
                        if metrics is not None:
                            metrics.inc(
                                "repro_search_candidates_pruned_total",
                                path="batched")
                        continue
                    with tracer.span("search.replay"):
                        p = session.replay_specs(
                            lambda _f=fn, _c=cand, _m=mem: _f(_c, _mem=_m),
                            values[start:start + n])
                    if metrics is not None:
                        metrics.inc("repro_search_candidates_priced_total"
                                    if p else
                                    "repro_search_candidates_pruned_total",
                                    path="batched")
                    if p:
                        progress.n_yielded += 1
                        yield cand, p
            except GeneratorExit:
                # the chunk was priced whole but the consumer stopped
                # mid-replay: everything after the current plan is work
                # early exit could not skip (the cost of chunking)
                if metrics is not None and len(plans) - pi - 1 > 0:
                    metrics.inc("repro_search_chunk_overrun_total",
                                len(plans) - pi - 1)
                raise

    def run(self, sweep_flags: bool = False,
            keep_all_disagg: bool = False,
            batched: Optional[bool] = None) -> SearchResult:
        """Drain :meth:`iter_search` into a batch SearchResult (single
        pricing code path; the frontier is accumulated online)."""
        t0 = time.perf_counter()
        progress = SearchProgress()
        projs: List[Projection] = []
        acc = pareto.FrontierAccumulator()
        best: Optional[Projection] = None
        for _cand, p in self.iter_search(sweep_flags, keep_all_disagg,
                                         progress=progress, batched=batched):
            projs.append(p)
            acc.add(p)
            if p.meets(self.w.sla) and (
                    best is None
                    or p.tokens_per_s_per_chip > best.tokens_per_s_per_chip):
                best = p

        elapsed = time.perf_counter() - t0
        n_eval = progress.n_evaluated
        return SearchResult(
            projections=projs, best=best, frontier=acc.frontier(),
            n_candidates=n_eval, elapsed_s=elapsed,
            per_candidate_ms=1e3 * elapsed / max(n_eval, 1),
            disagg_best=progress.disagg_best)

    # ------------------------------------------------------------------
    def _run_disagg(self, keep_all: bool,
                    progress: Optional[SearchProgress] = None):
        # prefill pool: small batches, TP-heavy; decode pool: big batches
        progress = progress if progress is not None else SearchProgress()

        def _abort() -> bool:
            if progress.abort is not None and progress.abort():
                progress.disagg_preempted = True
                return True
            return False

        pre_pool, dec_pool = [], []
        for par in self.parallelism_candidates():
            for b in (1, 2, 4, 8):
                if _abort():
                    break
                c = self.session.prefill_pool_candidate(
                    CandidateConfig(parallel=par, batch_size=b))
                progress.n_evaluated += 1
                progress.disagg_pool_evaluated += 1
                if c:
                    pre_pool.append(c)
            for b in BATCH_SWEEP:
                if _abort():
                    break
                c = self.session.decode_pool_candidate(
                    CandidateConfig(parallel=par, batch_size=b))
                progress.n_evaluated += 1
                progress.disagg_pool_evaluated += 1
                if c:
                    dec_pool.append(c)
            if progress.disagg_preempted:
                break
        if progress.disagg_preempted:
            # the deadline already elapsed mid-pool-pricing; matching
            # would be aborted by its progress_cb on the first slice
            return None, []
        best, everything = modes.disaggregated_mode(
            pre_pool, dec_pool,
            self.w.sla.ttft_ms, self.w.sla.tpot_limit_ms(),
            valid_totals=range(1, self.w.cluster.n_chips + 1),
            osl=self.w.osl, keep_all=keep_all,
            progress_cb=(lambda _n: _abort()) if progress.abort is not None
            else None)
        return best, everything

    def _disagg_projection(self, d: modes.DisaggBest) -> Projection:
        return Projection(
            ttft_ms=d.ttft_ms, tpot_ms=d.tpot_ms,
            tokens_per_s_user=1000.0 / d.tpot_ms if d.tpot_ms else float("inf"),
            tokens_per_s_per_chip=d.tokens_per_s_per_chip,
            chips=d.total_chips,
            batch_size=d.decode.config.batch_size,
            mode="disaggregated",
            config={
                "describe": DisaggConfig(
                    prefill=d.prefill.config, decode=d.decode.config,
                    x=d.x, y=d.y).describe(),
                "prefill": {"parallel": dataclasses.asdict(d.prefill.config.parallel),
                            "batch": d.prefill.config.batch_size, "x": d.x},
                "decode": {"parallel": dataclasses.asdict(d.decode.config.parallel),
                           "batch": d.decode.config.batch_size, "y": d.y},
            })
