"""Hardware platform profiles (§4.4 "Hardware specifications").

The paper ships per-GPU-SKU profiles (Ampere..Blackwell).  Our primary
target is TPU v5e (the constants given for the roofline deliverable);
v5p and an H100-like profile are kept so the multi-platform machinery of
the PerfDatabase is real, not vestigial.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class Platform:
    name: str
    peak_flops_bf16: float          # FLOP/s per chip
    peak_flops_fp8: float
    hbm_bw: float                   # bytes/s
    hbm_capacity: float             # bytes
    link_bw: float                  # bytes/s per ICI/NVLink link (one dir)
    links_per_axis: int             # links usable along one mesh axis
    inter_pod_bw: float             # bytes/s per chip across pods / nodes
    launch_overhead: float          # seconds per kernel launch
    hop_latency: float              # seconds per interconnect hop
    # matmul tile geometry for the alignment-efficiency curve (MXU on TPU:
    # 8 sublanes x 128 lanes; SIMD CPUs are ~8x8)
    tile_m: int = 8
    tile_n: int = 128

    def matmul_peak(self, dtype: str) -> float:
        return self.peak_flops_fp8 if dtype in ("fp8", "int8") else self.peak_flops_bf16


TPU_V5E = Platform(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    peak_flops_fp8=394e12,
    hbm_bw=819e9,
    hbm_capacity=16 * 2**30,
    link_bw=50e9,
    links_per_axis=2,               # bidirectional ring on a torus axis
    inter_pod_bw=25e9,              # DCI per chip (conservative)
    launch_overhead=2e-6,
    hop_latency=1e-6,
)

TPU_V5P = Platform(
    name="tpu_v5p",
    peak_flops_bf16=459e12,
    peak_flops_fp8=918e12,
    hbm_bw=2765e9,
    hbm_capacity=95 * 2**30,
    link_bw=100e9,
    links_per_axis=2,
    inter_pod_bw=25e9,
    launch_overhead=2e-6,
    hop_latency=1e-6,
)

H100_SXM = Platform(
    name="h100_sxm",
    peak_flops_bf16=989e12,
    peak_flops_fp8=1979e12,
    hbm_bw=3350e9,
    hbm_capacity=80 * 2**30,
    link_bw=450e9,                  # NVLink aggregate per GPU
    links_per_axis=1,
    inter_pod_bw=50e9,              # IB per GPU
    launch_overhead=4e-6,
    hop_latency=2e-6,
)

PLATFORMS: Dict[str, Platform] = {
    p.name: p for p in (TPU_V5E, TPU_V5P, H100_SXM)
}


def get_platform(name: str) -> Platform:
    return PLATFORMS[name]
