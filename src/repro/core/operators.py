"""Operator taxonomy (§4.3.1): the primitives an inference iteration
decomposes into.  Every operator knows its FLOPs and bytes moved; latency
comes from the PerfDatabase (grid + interpolation) or the analytical
executor (speed-of-light fallback).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

BYTES = {"bf16": 2, "fp16": 2, "fp32": 4, "fp8": 1, "int8": 1, "int4": 0.5}


@dataclasses.dataclass(frozen=True)
class GEMM:
    """C[m,n] = A[m,k] @ B[k,n]."""
    m: int
    n: int
    k: int
    dtype: str = "bf16"

    def flops(self) -> float:
        return 2.0 * self.m * self.n * self.k

    def bytes(self) -> float:
        b = BYTES[self.dtype]
        return b * (self.m * self.k + self.k * self.n + self.m * self.n)

    def grid_key(self) -> Tuple:
        return ("gemm", self.dtype)


@dataclasses.dataclass(frozen=True)
class Attention:
    """Fused attention; phase 'prefill' (compute-bound, causal flash) or
    'decode' (memory-bound, 1 query token vs kv_len cache)."""
    phase: str                      # prefill | decode
    batch: int
    q_len: int
    kv_len: int
    heads: int
    kv_heads: int
    head_dim: int
    kind: str = "gqa"               # mha | gqa | mla
    window: int = 0                 # sliding-window clamp on kv_len
    dtype: str = "bf16"
    q_offset: int = 0               # past tokens already cached (chunked prefill)

    def effective_kv(self) -> int:
        kv = self.kv_len
        return min(kv, self.window) if self.window else kv

    def flops(self) -> float:
        kv = self.effective_kv()
        if self.phase == "prefill":
            # causal: each query attends ~ (q_offset + (i+1)) keys
            avg_kv = min(self.q_offset + (self.q_len + 1) / 2.0, kv)
            return 4.0 * self.batch * self.heads * self.q_len * avg_kv * self.head_dim
        return 4.0 * self.batch * self.heads * kv * self.head_dim

    def bytes(self) -> float:
        b = BYTES[self.dtype]
        kv = self.effective_kv()
        if self.kind == "mla":
            kv_row = 576               # compressed latent + rope dims
        else:
            kv_row = 2 * self.kv_heads * self.head_dim
        io = self.batch * self.q_len * self.heads * self.head_dim * 2  # q + out
        cache = self.batch * kv * kv_row
        return b * (io + cache)

    def grid_key(self) -> Tuple:
        return ("attn", self.phase, self.kind, self.dtype)


@dataclasses.dataclass(frozen=True)
class MoEOp:
    """Grouped expert FFN with dispatch/combine.  ``loads`` is the per-rank
    token count after power-law skew + EP placement: latency follows the
    hottest rank (§4.4.1 'tail latency ... determines overall throughput')."""
    tokens: int                     # tokens entering the MoE layer (global)
    d_model: int
    d_ff: int
    num_experts: int
    top_k: int
    ep: int = 1                     # expert-parallel ways
    hot_rank_tokens: Optional[int] = None   # tokens on the hottest EP rank
    dtype: str = "bf16"

    def rank_tokens(self) -> float:
        if self.hot_rank_tokens is not None:
            return self.hot_rank_tokens
        return self.tokens * self.top_k / self.ep

    def flops(self) -> float:
        # hottest rank: 3 GEMMs (gate/up/down) over its token load
        return 2.0 * 3 * self.rank_tokens() * self.d_model * self.d_ff

    def bytes(self) -> float:
        b = BYTES[self.dtype]
        w = 3 * (self.num_experts / self.ep) * self.d_model * self.d_ff
        acts = self.rank_tokens() * (2 * self.d_model + 2 * self.d_ff)
        return b * (w + acts)

    def grid_key(self) -> Tuple:
        return ("moe", self.dtype)


@dataclasses.dataclass(frozen=True)
class RecurrentOp:
    """RG-LRU / mLSTM / sLSTM temporal mixing — memory-bound elementwise
    recurrence + small per-step GEMMs (state update)."""
    kind: str                       # rglru | mlstm | slstm
    batch: int
    seq: int                        # tokens processed (1 for decode)
    width: int                      # recurrence width
    heads: int = 1
    dtype: str = "bf16"

    def flops(self) -> float:
        per_tok = 8.0 * self.width
        if self.kind == "mlstm":
            dh = self.width // max(self.heads, 1)
            per_tok += 4.0 * self.heads * dh * dh     # matrix memory update
        if self.kind == "slstm":
            dh = self.width // max(self.heads, 1)
            per_tok += 2.0 * self.heads * dh * 4 * dh  # recurrent R matmul
        return self.batch * self.seq * per_tok

    def bytes(self) -> float:
        b = BYTES[self.dtype]
        state = self.width
        if self.kind == "mlstm":
            dh = self.width // max(self.heads, 1)
            state += self.heads * dh * dh
        return b * self.batch * (self.seq * 4 * self.width + 2 * state * 4)

    def grid_key(self) -> Tuple:
        return ("recurrent", self.kind, self.dtype)


@dataclasses.dataclass(frozen=True)
class Comm:
    """Collective / point-to-point communication.

    ``bytes_per_chip`` convention (what every call site must pass):

    * ``all_reduce`` / ``all_gather`` / ``reduce_scatter`` — the **full
      logical tensor** being reduced/gathered.  The ring-collective cost
      model scales it by ``(n-1)/n`` (×2 for all_reduce) itself, so
      passing a pre-sharded payload double-discounts.
    * ``all_to_all`` / ``p2p`` — the **per-chip payload actually sent**
      by one rank; no further sharding is applied by the model.
    """
    kind: str                       # all_reduce | all_gather | reduce_scatter
    #                                 | all_to_all | p2p
    bytes_per_chip: float
    n_chips: int
    inter_pod: bool = False         # crosses the pod/node boundary

    def flops(self) -> float:
        return 0.0

    def bytes(self) -> float:
        return self.bytes_per_chip

    def grid_key(self) -> Tuple:
        return ("comm", self.kind, self.inter_pod)


@dataclasses.dataclass(frozen=True)
class Embedding:
    tokens: int
    vocab: int
    d_model: int
    dtype: str = "bf16"

    def flops(self) -> float:
        return 0.0

    def bytes(self) -> float:
        return BYTES[self.dtype] * self.tokens * self.d_model * 2

    def grid_key(self) -> Tuple:
        return ("embedding", self.dtype)


@dataclasses.dataclass(frozen=True)
class MemOp:
    """Bulk HBM traffic with no compute (KV write-out, cache transpose)."""
    nbytes: float

    def flops(self) -> float:
        return 0.0

    def bytes(self) -> float:
        return self.nbytes

    def grid_key(self) -> Tuple:
        return ("mem",)


Operator = object  # GEMM | Attention | MoEOp | RecurrentOp | Comm | Embedding | MemOp


def op_family(op) -> str:
    """Calibration family of an operator: the granularity at which measured
    corrections are fitted and applied (repro.calibrate).  Attention splits
    by phase because prefill (compute-bound flash) and decode (memory-bound
    cache streaming) sit on different efficiency curves."""
    if isinstance(op, GEMM):
        return "gemm"
    if isinstance(op, Attention):
        return "attn_prefill" if op.phase == "prefill" else "attn_decode"
    if isinstance(op, MoEOp):
        return "moe"
    if isinstance(op, RecurrentOp):
        return "recurrent"
    if isinstance(op, Comm):
        return "comm"
    if isinstance(op, Embedding):
        return "embedding"
    if isinstance(op, MemOp):
        return "mem"
    return "other"
