"""Analytical executor — the silicon stand-in behind the PerfDatabase.

The paper fills its database by profiling kernels on real GPUs.  This
container has no accelerator, so the "offline collection" step queries this
executor instead: a calibrated-efficiency-curve model of TPU v5e-class
hardware (MXU alignment effects, memory-bound small-M GEMMs, flash-attention
utilization, ring-collective factors, per-launch overheads).  The
PerfDatabase machinery on top (grids + interpolation + speed-of-light
fallback) is exactly the paper's; only the data source differs — see
DESIGN.md §2.

``sol_latency`` is the *pure roofline* (no efficiency curves, no overhead):
it is both the paper's fallback for unprofiled operators and our ablation
baseline standing in for Vidur/APEX-style simulators.
"""
from __future__ import annotations

import numpy as np

from repro.core import operators as ops
from repro.core.hardware import Platform

# ---------------------------------------------------------------------------
# Efficiency curves ("calibration")
# ---------------------------------------------------------------------------

MXU_TILE_N = 128      # lane tiling
MXU_TILE_M = 8        # sublane tiling
BASE_GEMM_EFF = 0.88
FLASH_EFF = 0.52      # fused-attention MXU utilization (causal, fp32 softmax)
DECODE_ATTN_BW_EFF = 0.85
HBM_STREAM_EFF = 0.80
GATHER_EFF = 0.55     # embedding/gather HBM efficiency
VPU_FRACTION = 1 / 16  # elementwise throughput relative to MXU peak


def _align_eff(dim, tile):
    # np.ceil (not math.ceil) so the curve prices whole coordinate arrays
    # in one shot during vectorized grid collection
    padded = np.ceil(dim / tile) * tile
    return dim / padded


def gemm_eff(m, n, k, tile_m: int = MXU_TILE_M, tile_n: int = MXU_TILE_N):
    eff = BASE_GEMM_EFF
    eff = eff * _align_eff(np.maximum(m, 1), tile_m)
    eff = eff * _align_eff(np.maximum(n, 1), tile_n)
    eff = eff * _align_eff(np.maximum(k, 1), tile_n)
    # very skinny K or N can't keep the compute units busy (scaled to tile);
    # the raw (unclamped) k/n feed the skinny term, matching the scalar model
    skinny = 4.0 * tile_n
    eff = eff * np.minimum(1.0, np.minimum((k / skinny) ** 0.25,
                                           (n / skinny) ** 0.25))
    return np.maximum(eff, 0.02)


# ---------------------------------------------------------------------------
# Per-operator latency
# ---------------------------------------------------------------------------

def _gemm(p: Platform, g: ops.GEMM) -> float:
    peak = p.matmul_peak(g.dtype)
    t_c = g.flops() / (peak * gemm_eff(g.m, g.n, g.k, p.tile_m, p.tile_n))
    t_m = g.bytes() / (p.hbm_bw * HBM_STREAM_EFF)
    # float(): keep scalar callers (and JSON artifacts) on python floats
    return float(max(t_c, t_m) + p.launch_overhead)


def _attention(p: Platform, a: ops.Attention) -> float:
    if a.phase == "prefill":
        eff = FLASH_EFF * _align_eff(a.head_dim, MXU_TILE_N)
        t_c = a.flops() / (p.peak_flops_bf16 * eff)
        t_m = a.bytes() / (p.hbm_bw * HBM_STREAM_EFF)
        return float(max(t_c, t_m) + 2 * p.launch_overhead)
    # decode: stream the KV cache
    t_m = a.bytes() / (p.hbm_bw * DECODE_ATTN_BW_EFF)
    t_c = a.flops() / (p.peak_flops_bf16 * 0.35)   # skinny matmuls
    extra = 2 * p.launch_overhead
    if a.kind == "mla":
        # latent decompression matmuls
        t_c *= 1.6
        extra += p.launch_overhead
    return float(max(t_m, t_c) + extra)


def _moe(p: Platform, m: ops.MoEOp) -> float:
    toks = max(m.rank_tokens(), 1.0)
    g = ops.GEMM(m=int(toks), n=m.d_ff, k=m.d_model, dtype=m.dtype)
    peak = p.matmul_peak(m.dtype)
    t_c = 3 * g.flops() / (peak * gemm_eff(g.m, g.n, g.k, p.tile_m, p.tile_n))
    t_m = m.bytes() / (p.hbm_bw * HBM_STREAM_EFF)
    # dispatch/scatter bookkeeping
    return float(max(t_c, t_m) + 3 * p.launch_overhead)


def _recurrent(p: Platform, r: ops.RecurrentOp) -> float:
    t_c = r.flops() / (p.peak_flops_bf16 * VPU_FRACTION)
    t_m = r.bytes() / (p.hbm_bw * 0.7)
    return max(t_c, t_m) + p.launch_overhead


def _comm(p: Platform, c: ops.Comm) -> float:
    n = max(c.n_chips, 1)
    if n <= 1:
        return 0.0
    axis_bw = (p.inter_pod_bw if c.inter_pod
               else p.link_bw * p.links_per_axis)
    b = c.bytes_per_chip
    if c.kind == "all_reduce":
        vol = 2.0 * (n - 1) / n * b
        hops = 2 * (n - 1)
    elif c.kind in ("all_gather", "reduce_scatter"):
        vol = (n - 1) / n * b
        hops = n - 1
    elif c.kind == "all_to_all":
        # torus all-to-all: each chip exchanges b*(n-1)/n, average n/4 hops
        # of path sharing on a ring halves the effective bandwidth
        vol = (n - 1) / n * b * max(n / 8.0, 1.0)
        hops = n // 2
    elif c.kind == "p2p":
        vol = b
        hops = 1
    else:
        raise ValueError(c.kind)
    return vol / axis_bw + hops * p.hop_latency


def _embedding(p: Platform, e: ops.Embedding) -> float:
    return e.bytes() / (p.hbm_bw * GATHER_EFF) + p.launch_overhead


def _mem(p: Platform, m: ops.MemOp) -> float:
    return m.nbytes / (p.hbm_bw * HBM_STREAM_EFF) + p.launch_overhead


_DISPATCH = {
    ops.GEMM: _gemm,
    ops.Attention: _attention,
    ops.MoEOp: _moe,
    ops.RecurrentOp: _recurrent,
    ops.Comm: _comm,
    ops.Embedding: _embedding,
    ops.MemOp: _mem,
}


def latency(platform: Platform, op) -> float:
    """Calibrated latency estimate (the profiling stand-in)."""
    return _DISPATCH[type(op)](platform, op)


# ---------------------------------------------------------------------------
# Vectorized table builders — whole-grid collection without per-cell loops
# ---------------------------------------------------------------------------
# Each builder evaluates the matching per-operator latency model over a full
# coordinate mesh at once, mirroring the scalar expressions term for term
# (same operation order and the same raw-vs-clamped operands) so a grid built
# here is numerically identical to one filled by per-cell ``latency`` calls.

def gemm_table(p: Platform, M, N, K, dtype: str = "bf16") -> np.ndarray:
    m, n, k = np.meshgrid(np.asarray(M, dtype=np.float64),
                          np.asarray(N, dtype=np.float64),
                          np.asarray(K, dtype=np.float64), indexing="ij")
    b = ops.BYTES[dtype]
    flops = 2.0 * m * n * k
    nbytes = b * (m * k + k * n + m * n)
    t_c = flops / (p.matmul_peak(dtype)
                   * gemm_eff(m, n, k, p.tile_m, p.tile_n))
    t_m = nbytes / (p.hbm_bw * HBM_STREAM_EFF)
    return np.maximum(t_c, t_m) + p.launch_overhead


def attn_prefill_table(p: Platform, a: ops.Attention, Q, KV) -> np.ndarray:
    q, kv = np.meshgrid(np.asarray(Q, dtype=np.float64),
                        np.asarray(KV, dtype=np.float64), indexing="ij")
    avg_kv = np.minimum(a.q_offset + (q + 1) / 2.0, kv)
    flops = 4.0 * a.batch * a.heads * q * avg_kv * a.head_dim
    kv_row = 576 if a.kind == "mla" else 2 * a.kv_heads * a.head_dim
    io = a.batch * q * a.heads * a.head_dim * 2
    cache = a.batch * kv * kv_row
    nbytes = ops.BYTES[a.dtype] * (io + cache)
    eff = FLASH_EFF * _align_eff(a.head_dim, MXU_TILE_N)
    t_c = flops / (p.peak_flops_bf16 * eff)
    t_m = nbytes / (p.hbm_bw * HBM_STREAM_EFF)
    return np.maximum(t_c, t_m) + 2 * p.launch_overhead


def attn_decode_table(p: Platform, a: ops.Attention, B, KV) -> np.ndarray:
    bt, kv = np.meshgrid(np.asarray(B, dtype=np.float64),
                         np.asarray(KV, dtype=np.float64), indexing="ij")
    flops = 4.0 * bt * a.heads * kv * a.head_dim
    kv_row = 576 if a.kind == "mla" else 2 * a.kv_heads * a.head_dim
    io = bt * a.q_len * a.heads * a.head_dim * 2
    cache = bt * kv * kv_row
    nbytes = ops.BYTES[a.dtype] * (io + cache)
    t_m = nbytes / (p.hbm_bw * DECODE_ATTN_BW_EFF)
    t_c = flops / (p.peak_flops_bf16 * 0.35)
    extra = 2 * p.launch_overhead
    if a.kind == "mla":
        t_c = t_c * 1.6
        extra += p.launch_overhead
    return np.maximum(t_m, t_c) + extra


def moe_table(p: Platform, m: ops.MoEOp, TOK) -> np.ndarray:
    rt = np.asarray(TOK, dtype=np.float64)
    toks = np.maximum(rt, 1.0)
    t_c = (3 * (2.0 * toks * m.d_ff * m.d_model)
           / (p.matmul_peak(m.dtype)
              * gemm_eff(toks, m.d_ff, m.d_model, p.tile_m, p.tile_n)))
    w = 3 * (m.num_experts / m.ep) * m.d_model * m.d_ff
    acts = rt * (2 * m.d_model + 2 * m.d_ff)
    nbytes = ops.BYTES[m.dtype] * (w + acts)
    t_m = nbytes / (p.hbm_bw * HBM_STREAM_EFF)
    return np.maximum(t_c, t_m) + 3 * p.launch_overhead


def recurrent_table(p: Platform, r: ops.RecurrentOp, TOK) -> np.ndarray:
    seq = np.asarray(TOK, dtype=np.float64)
    per_tok = 8.0 * r.width
    dh = r.width // max(r.heads, 1)
    if r.kind == "mlstm":
        per_tok += 4.0 * r.heads * dh * dh
    if r.kind == "slstm":
        per_tok += 2.0 * r.heads * dh * 4 * dh
    flops = r.batch * seq * per_tok
    state = r.width + (r.heads * dh * dh if r.kind == "mlstm" else 0)
    nbytes = ops.BYTES[r.dtype] * r.batch * (seq * 4 * r.width
                                             + 2 * state * 4)
    t_c = flops / (p.peak_flops_bf16 * VPU_FRACTION)
    t_m = nbytes / (p.hbm_bw * 0.7)
    return np.maximum(t_c, t_m) + p.launch_overhead


def comm_table(p: Platform, kind: str, n_chips: int, inter_pod: bool,
               B) -> np.ndarray:
    # _comm's arithmetic is shape-polymorphic: an array bytes_per_chip
    # prices the whole axis in one call, guaranteeing scalar parity
    c = ops.Comm(kind=kind, bytes_per_chip=np.asarray(B, dtype=np.float64),
                 n_chips=n_chips, inter_pod=inter_pod)
    return _comm(p, c)


def sol_latency(platform: Platform, op) -> float:
    """Pure speed-of-light roofline: max(flops/peak, bytes/bw), no
    efficiency curves, no launch overhead.  Fallback + ablation baseline."""
    if isinstance(op, ops.Comm):
        n = max(op.n_chips, 1)
        if n <= 1:
            return 0.0
        bw = (platform.inter_pod_bw if op.inter_pod
              else platform.link_bw * platform.links_per_axis)
        return op.bytes_per_chip / bw
    peak = platform.peak_flops_bf16
    if hasattr(op, "dtype"):
        peak = platform.matmul_peak(getattr(op, "dtype"))
    t_c = op.flops() / peak
    t_m = op.bytes() / platform.hbm_bw
    return max(t_c, t_m)
