"""AIConfigurator command-line interface — the paper's user workflow
(Fig. 2) as one command:

    PYTHONPATH=src python -m repro.core.cli \\
        --model qwen3-32b --isl 4000 --osl 500 \\
        --ttft 1200 --min-speed 60 --chips 16 --dtype fp8 \\
        --backend repro-jax --save-launch launch.json

Prints the Pareto frontier and the top configurations, emits the launch
artifact for the chosen backend, and (optionally) the speculative-decoding
projection when a draft model is supplied.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.configs import list_archs
from repro.core import (ClusterSpec, PerfDatabase, SLA, TaskRunner,
                        WorkloadDescriptor, generate)


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="repro.core.cli",
        description="AIConfigurator: find the optimal serving configuration")
    ap.add_argument("--model", required=True,
                    help=f"one of {', '.join(list_archs(True))}")
    ap.add_argument("--isl", type=int, required=True)
    ap.add_argument("--osl", type=int, required=True)
    ap.add_argument("--ttft", type=float, default=1000.0,
                    help="TTFT SLA in ms")
    ap.add_argument("--min-speed", type=float, default=None,
                    help="min tokens/s/user SLA")
    ap.add_argument("--chips", type=int, default=8)
    ap.add_argument("--platform", default="tpu_v5e")
    ap.add_argument("--backend", default="repro-jax",
                    choices=["repro-jax", "trtllm", "vllm", "sglang"])
    ap.add_argument("--dtype", default="bf16",
                    choices=["bf16", "fp16", "fp8"])
    ap.add_argument("--modes", default="aggregated,disaggregated")
    ap.add_argument("--prefix-len", type=int, default=0)
    ap.add_argument("--moe-alpha", type=float, default=1.2)
    ap.add_argument("--top", type=int, default=5)
    ap.add_argument("--save-launch", default="")
    ap.add_argument("--draft-model", default="",
                    help="also project speculative decoding with this draft")
    ap.add_argument("--acceptance", type=float, default=0.8)
    args = ap.parse_args(argv)

    workload = WorkloadDescriptor(
        model=args.model, isl=args.isl, osl=args.osl,
        sla=SLA(ttft_ms=args.ttft, min_tokens_per_s_user=args.min_speed),
        cluster=ClusterSpec(n_chips=args.chips, platform=args.platform),
        backend=args.backend, dtype=args.dtype,
        prefix_len=args.prefix_len,
        modes=tuple(args.modes.split(",")),
        moe_alpha=args.moe_alpha)

    db = PerfDatabase(args.platform, args.backend)
    result = TaskRunner(workload, db).run()
    print(result.summary())

    from repro.core import pareto
    print(f"\ntop {args.top} SLA-valid configurations:")
    for p in pareto.top_k(result.projections, workload.sla, args.top):
        print(f"  [{p.mode:13s}] {p.tokens_per_s_per_chip:9.1f} tok/s/chip  "
              f"{p.tokens_per_s_user:7.1f} tok/s/user  "
              f"TTFT {p.ttft_ms:8.1f}ms  {p.config.get('describe', '')}")

    if result.best is None:
        print("\nno configuration satisfies the SLA on this cluster")
        return 1
    launch = generate(workload, result.best)
    print(f"\nlaunch command:\n  {launch.command}")
    if args.save_launch:
        with open(args.save_launch, "w") as f:
            f.write(launch.to_json())
        print(f"launch config -> {args.save_launch}")

    if args.draft_model:
        from repro.core.config import ParallelismConfig
        from repro.core.speculative import SpeculativeEstimator
        est = SpeculativeEstimator(workload, args.draft_model, db)
        par = ParallelismConfig(
            **{k: result.best.config.get("parallel", {}).get(k, 1)
               for k in ("tp", "pp", "ep", "dp")}) \
            if result.best.mode != "disaggregated" else ParallelismConfig(
                tp=min(args.chips, 8))
        best, _ = est.best_gamma(par, batch=result.best.batch_size,
                                 acceptance=args.acceptance)
        print(f"\nspeculative decoding ({args.draft_model}, "
              f"acceptance {args.acceptance}): best gamma={best.gamma} -> "
              f"{best.speedup_vs_autoregressive:.2f}x "
              f"({best.tokens_per_s_user:.0f} tok/s/user)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
