"""AIConfigurator command-line interface — a thin shell over ``repro.api``.

The paper's user workflow (Fig. 2) as subcommands:

    python -m repro.core.cli search   --model qwen3-32b --isl 4000 --osl 500 \\
        --ttft 1200 --min-speed 60 --chips 16 --dtype fp8 --backend repro-jax
    python -m repro.core.cli generate --from-report report.json --out launch.json
    python -m repro.core.cli compare  --model qwen3-32b --chips 16 \\
        --shapes 4000:200:60,512:1024:30
    python -m repro.core.cli list     backends
    python -m repro.core.cli calibrate run --timer deterministic \\
        --out cal.json
    python -m repro.core.cli calibrate report --artifact cal.json
    python -m repro.core.cli calibrate apply  --artifact cal.json \\
        --model qwen3-32b --isl 4000 --osl 500
    python -m repro.core.cli workload generate --arrivals bursty --rate 2 \\
        --n 200 --lengths sharegpt --seed 7 --out trace.jsonl
    python -m repro.core.cli workload describe --trace trace.jsonl
    python -m repro.core.cli workload replay --trace trace.jsonl \\
        --model qwen3-32b --tp 4 --batch 64 --slo-ttft-p99 2000 \\
        --slo-tpot-p99 80
    python -m repro.core.cli search --model qwen3-32b --isl 4000 --osl 500 \\
        --chips 16 --trace trace.jsonl --slo-ttft-p99 2000 \\
        --slo-tpot-p99 80 --replay-top-k 3
    python -m repro.core.cli capacity sweep --trace trace.jsonl \\
        --model qwen3-32b --tp 4 --batch 64 --ladder 1,2,4 \\
        --routing least_outstanding --json
    python -m repro.core.cli capacity plan --model qwen3-32b --isl 4000 \\
        --osl 500 --chips 16 --trace trace.jsonl --ladder 1,2,4 --top-k 3
    python -m repro.core.cli autoscale run --trace trace.jsonl \\
        --model qwen3-32b --tp 4 --batch 64 --policy target_queue_depth \\
        --max-replicas 4 --save-timeline timeline.jsonl
    python -m repro.core.cli autoscale compare --trace trace.jsonl \\
        --model qwen3-32b --tp 4 --batch 64 --ladder 1,2,4 --json

Every subcommand accepts ``--json`` to emit machine-readable output
(``search --json`` prints the schema-versioned SearchReport) on stdout,
with human chatter kept off it.  Exit codes are stable: 0 success, 1 no
configuration satisfies the SLA, 2 usage or validation error.

Streaming: ``search --stream`` rides ``Configurator.search_iter`` and
emits one JSON-lines record per priced projection plus a terminal
``{"type": "summary", ...}`` record; ``--first-n N`` stops the search as
soon as N SLA-valid configurations are found (works with or without
``--stream``, exit codes unchanged).  A consumer that closes the pipe
early (``head``, an interactive UI) shuts the search down cleanly.

The pre-subcommand flat-flag invocation (``python -m repro.core.cli
--model ... --isl ...``) still works through a deprecation shim and prints
byte-identical results to the ``search`` subcommand.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.api import (Comparison, Configurator, SearchReport,
                       stop_after_n_valid)
from repro.capacity.routing import ROUTING_POLICIES
from repro.configs import list_archs
from repro.core.backends.base import all_backends, backend_capabilities
from repro.core.generator import generate
from repro.core.hardware import PLATFORMS

EXIT_OK = 0
EXIT_NO_CONFIG = 1
EXIT_USAGE = 2

_SUBCOMMANDS = ("search", "generate", "compare", "list", "calibrate",
                "workload", "capacity", "autoscale", "explain", "obs")


# ---------------------------------------------------------------------------
# argument plumbing
# ---------------------------------------------------------------------------

def _add_workload_args(ap: argparse.ArgumentParser, traffic: bool = True,
                       required: bool = True):
    ap.add_argument("--model", required=required, default=None,
                    help=f"one of {', '.join(list_archs(True))}")
    if traffic:
        ap.add_argument("--isl", type=int, required=required, default=None)
        ap.add_argument("--osl", type=int, required=required, default=None)
        ap.add_argument("--prefix-len", type=int, default=0)
    ap.add_argument("--ttft", type=float, default=1000.0,
                    help="TTFT SLA in ms")
    ap.add_argument("--min-speed", type=float, default=None,
                    help="min tokens/s/user SLA")
    ap.add_argument("--chips", type=int, default=8)
    ap.add_argument("--platform", default="tpu_v5e")
    ap.add_argument("--backend", default="repro-jax",
                    help=f"one of {', '.join(all_backends())} "
                         "(or any registered plugin)")
    ap.add_argument("--dtype", default="bf16",
                    choices=["bf16", "fp16", "fp8"])
    ap.add_argument("--modes", default="aggregated,disaggregated")
    ap.add_argument("--moe-alpha", type=float, default=1.2)


def _configurator(args, isl=None, osl=None, prefix_len=0) -> Configurator:
    return (Configurator.for_model(args.model)
            .traffic(isl if isl is not None else args.isl,
                     osl if osl is not None else args.osl,
                     prefix_len or getattr(args, "prefix_len", 0))
            .sla(ttft_ms=args.ttft, min_tokens_per_s_user=args.min_speed)
            .cluster(chips=args.chips, platform=args.platform)
            .backend(args.backend)
            .dtype(args.dtype)
            .modes(*args.modes.split(","))
            .moe_alpha(args.moe_alpha))


# ---------------------------------------------------------------------------
# search
# ---------------------------------------------------------------------------

def _print_search_report(report: SearchReport, args) -> int:
    """The classic human-readable search output (legacy-compatible)."""
    print(report.summary())
    print(f"\ntop {args.top} SLA-valid configurations:")
    for p in report.top_k(args.top):
        print(f"  [{p.mode:13s}] {p.tokens_per_s_per_chip:9.1f} tok/s/chip  "
              f"{p.tokens_per_s_user:7.1f} tok/s/user  "
              f"TTFT {p.ttft_ms:8.1f}ms  {p.config.get('describe', '')}")

    if report.best is None:
        print("\nno configuration satisfies the SLA on this cluster")
        return EXIT_NO_CONFIG
    print(f"\nlaunch command:\n  {report.launch.command}")
    if args.save_launch:
        with open(args.save_launch, "w") as f:
            f.write(report.launch.to_json())
        print(f"launch config -> {args.save_launch}")

    if report.speculative:
        s = report.speculative
        print(f"\nspeculative decoding ({s['draft_model']}, "
              f"acceptance {s['acceptance']}): best gamma={s['gamma']} -> "
              f"{s['speedup_vs_autoregressive']:.2f}x "
              f"({s['tokens_per_s_user']:.0f} tok/s/user)")

    we = report.workload_eval
    if we:
        print(f"\nworkload replay (trace {we['trace']['digest']}, "
              f"{we['trace']['n_requests']} requests) — goodput ranking:")
        by_index = {c["index"]: c for c in we["candidates"]}
        for rank, idx in enumerate(we["ranking"]):
            c = by_index[idx]
            r = c["replay"]
            print(f"  #{rank + 1} [{c['mode']:11s}] {c['describe']:20s} "
                  f"goodput {r['goodput_tok_s']:9.1f} tok/s  "
                  f"attainment {100 * r['slo_attainment']:5.1f}%  "
                  f"p99 TTFT {r['ttft_ms']['p99']:8.1f}ms  "
                  f"(analytical #{c['analytical_rank'] + 1})")
        skipped = [c for c in we["candidates"] if c["skipped"]]
        for c in skipped:
            print(f"  -- [{c['mode']:11s}] {c['describe']:20s} "
                  f"skipped: {c['skipped']}")
        if we["reranked"]:
            print("  note: goodput ranking differs from the analytical "
                  "(static) ranking")
    return EXIT_OK


def _search_policies(args) -> list:
    first_n = getattr(args, "first_n", 0)
    return [stop_after_n_valid(first_n)] if first_n else []


def _attach_speculative(report: SearchReport, cfg: Configurator, args) -> None:
    draft = getattr(args, "draft_model", "")
    if draft and report.best is not None:
        best, _ = cfg.speculative(draft, acceptance=args.acceptance,
                                  report=report)
        report.speculative = {
            "draft_model": draft, "acceptance": args.acceptance,
            "gamma": best.gamma, "tpot_ms": best.tpot_ms,
            "tokens_per_s_user": best.tokens_per_s_user,
            "speedup_vs_autoregressive": best.speedup_vs_autoregressive,
        }


def _attach_workload_eval(report: SearchReport, cfg: Configurator,
                          args) -> None:
    """``--trace``: replay the frontier's top-K under the trace and record
    the goodput re-ranking in the report's ``workload_eval`` section."""
    trace = getattr(args, "trace", "")
    if trace:
        cfg.evaluate_frontier(trace, _slo_from_args(args),
                              top_k=args.replay_top_k, report=report)


def _run_search(args) -> "tuple[SearchReport, Configurator]":
    cfg = _configurator(args)
    # --first-n rides the same policy surface library users get: the
    # iterator stops early and the report records why under early_exit
    report = cfg.search(policies=_search_policies(args))
    _attach_speculative(report, cfg, args)
    _attach_workload_eval(report, cfg, args)
    return report, cfg


def _silence_broken_pipe() -> None:
    """The stream consumer closed the pipe (head, early-exiting UI): point
    stdout at devnull so the interpreter's exit flush cannot raise again.
    An early-exiting consumer is the intended use, so callers exit 0."""
    try:
        devnull = os.open(os.devnull, os.O_WRONLY)
        os.dup2(devnull, sys.stdout.fileno())
    except (OSError, ValueError):
        pass   # stdout has no real fd (captured stream): nothing to salvage


class _JsonLines:
    """JSON-lines emitter shared by every streaming subcommand.

    The first BrokenPipeError marks the emitter ``broken`` and silences
    stdout; ``emit`` then refuses further records so the caller can stop
    producing, still write its save files, and exit 0 — an early-exiting
    consumer (``head``, an interactive UI) is the intended use."""

    def __init__(self):
        self.broken = False

    def emit(self, obj, **dumps_kw) -> bool:
        """Print one record; False once the consumer is gone."""
        if self.broken:
            return False
        try:
            print(json.dumps(obj, **dumps_kw), flush=True)
            return True
        except BrokenPipeError:
            self.broken = True
            _silence_broken_pipe()
            return False

    def emit_text(self, text: str) -> bool:
        """Print pre-serialized lines (e.g. a JSONL artifact) verbatim."""
        if self.broken:
            return False
        try:
            sys.stdout.write(text)
            sys.stdout.flush()
            return True
        except BrokenPipeError:
            self.broken = True
            _silence_broken_pipe()
            return False


class _ObsCapture:
    """``--trace-out``/``--metrics-out``: install a ``repro.obs`` tracer
    and/or metrics registry for the duration of the command, then write
    the artifacts on the way out (``finish``).  The trace artifact keeps
    wall times out, so seeded runs write byte-identical files;
    ``--trace-out -`` streams the JSONL to stdout, a ``.chrome.json``
    suffix writes the Chrome ``trace_event`` export instead (load it in
    chrome://tracing or Perfetto).  The flight-recorder sampling knobs
    (``--span-sample-every``/``--max-request-spans``) bound per-request
    span volume on big traces."""

    def __init__(self, args):
        self.trace_out = getattr(args, "trace_out", "")
        self.metrics_out = getattr(args, "metrics_out", "")
        self.meta = {"command": getattr(args, "command", None) or "search",
                     "model": getattr(args, "model", None)}
        self.tracer = self.registry = None
        self._flight_restore = None
        sample = getattr(args, "span_sample_every", None)
        cap = getattr(args, "max_request_spans", None)
        if sample is not None or cap is not None:
            from repro.obs import configure_flight_recorder, flight_config
            prev = flight_config()
            self._flight_restore = (prev.sample_every,
                                    prev.max_request_spans)
            configure_flight_recorder(
                sample_every=sample if sample is not None else 1,
                max_request_spans=cap if cap is not None else 512)
        if self.trace_out:
            from repro.obs import enable_tracing
            self.tracer = enable_tracing()
        if self.metrics_out:
            from repro.obs import enable_metrics
            self.registry = enable_metrics()

    def finish(self) -> None:
        if self._flight_restore is not None:
            from repro.obs import configure_flight_recorder
            configure_flight_recorder(*self._flight_restore)
        if self.tracer is not None:
            from repro.obs import disable_tracing
            disable_tracing()
            art = self.tracer.artifact(meta=self.meta)
            if self.trace_out == "-":
                _JsonLines().emit_text(art.to_jsonl())
            elif self.trace_out.endswith(".chrome.json"):
                with open(self.trace_out, "w") as f:
                    f.write(json.dumps(art.to_chrome_trace(),
                                       sort_keys=True) + "\n")
            else:
                art.save(self.trace_out)
        if self.registry is not None:
            from repro.obs import disable_metrics
            disable_metrics()
            if self.metrics_out.endswith(".prom"):
                text = self.registry.to_prometheus()
            else:
                text = json.dumps(self.registry.to_dict(), indent=2,
                                  sort_keys=True) + "\n"
            with open(self.metrics_out, "w") as f:
                f.write(text)


def _stream_search(args) -> int:
    """``search --stream``: JSON-lines progress records + summary record.

    Honors the same post-search flags as the batch path (--draft-model,
    --save-launch, --save-report); a consumer that closes the pipe early
    still gets the report/launch files written before the clean exit.
    """
    cfg = _configurator(args)
    stream = cfg.search_iter(policies=_search_policies(args))
    em = _JsonLines()
    for ev in stream:
        p = ev.projection
        if not em.emit({
                "type": "candidate", "index": ev.index, "mode": p.mode,
                "describe": p.config.get("describe", ""),
                "tokens_per_s_per_chip": p.tokens_per_s_per_chip,
                "tokens_per_s_user": p.tokens_per_s_user,
                "ttft_ms": p.ttft_ms,
                "mem_bytes_per_chip": p.mem_bytes_per_chip,
                "meets_sla": ev.meets_sla, "n_priced": ev.n_priced,
                "n_valid": ev.n_valid, "frontier_size": ev.frontier_size,
        }):
            stream.close()
            break
    report = stream.report(generate_launch=bool(args.save_launch))
    _attach_speculative(report, cfg, args)
    _attach_workload_eval(report, cfg, args)
    if not em.broken:
        best = report.best
        em.emit({
            "type": "summary", "schema_version": report.schema_version,
            "n_candidates": report.n_candidates,
            "n_valid": stream.n_valid,
            "elapsed_s": report.elapsed_s,
            "early_exit": report.early_exit,
            "database": report.fingerprint,
            "speculative": report.speculative,
            "workload_eval": (None if report.workload_eval is None else {
                "trace": report.workload_eval["trace"]["digest"],
                "ranking": report.workload_eval["ranking"],
                "reranked": report.workload_eval["reranked"],
            }),
            "best": (None if best is None else {
                "mode": best.mode,
                "describe": best.config.get("describe", ""),
                "tokens_per_s_per_chip": best.tokens_per_s_per_chip,
                "tokens_per_s_user": best.tokens_per_s_user,
                "ttft_ms": best.ttft_ms,
            }),
        })
    if args.save_report:
        report.save(args.save_report)
    if args.save_launch and report.launch is not None:
        with open(args.save_launch, "w") as f:
            f.write(report.launch.to_json())
    if em.broken:
        return EXIT_OK
    return EXIT_OK if report.best is not None else EXIT_NO_CONFIG


def cmd_search(args) -> int:
    obs = _ObsCapture(args)
    try:
        if args.stream:
            return _stream_search(args)
        report, _ = _run_search(args)
    finally:
        obs.finish()
    if args.save_report:
        report.save(args.save_report)
    if args.json:
        if args.save_launch and report.launch is not None:
            with open(args.save_launch, "w") as f:
                f.write(report.launch.to_json())
        print(report.to_json())
        return EXIT_OK if report.best is not None else EXIT_NO_CONFIG
    return _print_search_report(report, args)


# ---------------------------------------------------------------------------
# generate
# ---------------------------------------------------------------------------

def cmd_generate(args) -> int:
    if args.from_report:
        report = SearchReport.load(args.from_report)
        launch = report.launch
        if launch is None and report.best is not None:
            launch = generate(report.workload, report.best)
    else:
        if args.model is None or args.isl is None or args.osl is None:
            print("error: generate needs --from-report or "
                  "--model/--isl/--osl", file=sys.stderr)
            return EXIT_USAGE
        report, _ = _run_search(args)
        launch = report.launch
    if launch is None:
        print("no configuration satisfies the SLA on this cluster",
              file=sys.stderr)
        return EXIT_NO_CONFIG
    if args.out:
        with open(args.out, "w") as f:
            f.write(launch.to_json())
    if args.json:
        print(launch.to_json())
    else:
        print(launch.command)
        if args.out:
            print(f"launch config -> {args.out}")
    return EXIT_OK


# ---------------------------------------------------------------------------
# compare
# ---------------------------------------------------------------------------

def _parse_shapes(text: str):
    """``isl:osl[:min_speed],...`` -> list of compare-variant dicts."""
    variants = []
    for part in text.split(","):
        bits = part.split(":")
        if len(bits) not in (2, 3):
            raise ValueError(
                f"bad shape {part!r}; expected isl:osl or isl:osl:min_speed")
        v = {"isl": int(bits[0]), "osl": int(bits[1])}
        if len(bits) == 3:
            v["min_tokens_per_s_user"] = float(bits[2])
        variants.append(v)
    return variants


def cmd_compare(args) -> int:
    variants = _parse_shapes(args.shapes)
    cfg = _configurator(args, isl=variants[0]["isl"], osl=variants[0]["osl"])
    comparison: Comparison = cfg.compare(variants)
    if args.json:
        print(comparison.to_json())
    else:
        print(comparison.summary())
    return EXIT_OK if any(r.best for r in comparison.reports) \
        else EXIT_NO_CONFIG


# ---------------------------------------------------------------------------
# calibrate
# ---------------------------------------------------------------------------

def cmd_calibrate_run(args) -> int:
    """Measure kernels, fit per-family corrections, write the artifact."""
    from repro.calibrate import (accuracy_report, format_accuracy,
                                 make_timer, run_calibration)
    created_at = args.timestamp
    if not created_at:
        import datetime
        created_at = datetime.datetime.now(datetime.timezone.utc) \
            .isoformat(timespec="seconds")
    families = args.families.split(",") if args.families else None
    art = run_calibration(
        platform=args.platform, backend=args.backend,
        timer=make_timer(args.timer, args.platform),
        created_at=created_at, points_per_axis=args.points,
        families=families, notes=args.notes)
    art.save(args.out)
    report = accuracy_report(art)
    if args.json:
        print(json.dumps({"artifact": args.out, "report": report}, indent=2))
    else:
        print(format_accuracy(report))
        print(f"calibration artifact -> {args.out}")
    return EXIT_OK


def cmd_calibrate_report(args) -> int:
    """Audit an artifact: per-family MAPE, calibrated vs uncalibrated."""
    from repro.calibrate import (CalibrationArtifact, accuracy_report,
                                 format_accuracy)
    report = accuracy_report(CalibrationArtifact.load(args.artifact))
    if args.json:
        print(json.dumps(report, indent=2))
    else:
        print(format_accuracy(report))
    return EXIT_OK


def cmd_calibrate_apply(args) -> int:
    """Load an artifact into a PerfDatabase; with a workload, run the
    calibrated search — without one, print the calibrated fingerprint."""
    from repro.calibrate import CalibrationArtifact
    art = CalibrationArtifact.load(args.artifact)
    workload_args = (args.model, args.isl, args.osl)
    if any(a is not None for a in workload_args) \
            and not all(a is not None for a in workload_args):
        print("error: calibrate apply needs all of --model/--isl/--osl "
              "for a calibrated search (or none, to print the calibrated "
              "fingerprint)", file=sys.stderr)
        return EXIT_USAGE
    if args.model is not None:
        # the apply parser defaults platform/backend to None (sentinel):
        # any explicitly passed value that mismatches the artifact earns
        # a note before the artifact's calibrated pair wins
        explicit = [(flag, got) for flag, got, want in
                    (("--platform", args.platform, art.platform),
                     ("--backend", args.backend, art.backend))
                    if got is not None and got != want]
        if explicit:
            print(f"note: using the artifact's calibrated pair "
                  f"({art.platform}, {art.backend}); ignoring "
                  + ", ".join(f"{f} {g}" for f, g in explicit),
                  file=sys.stderr)
        args.platform = art.platform
        args.backend = art.backend
        cfg = _configurator(args).with_calibration(art)
        report = cfg.search(policies=_search_policies(args))
        if args.save_report:
            report.save(args.save_report)
        if args.json:
            print(report.to_json())
        else:
            print(report.summary())
            fp = report.fingerprint or {}
            print(f"calibration: {json.dumps(fp.get('calibration'))}")
        return EXIT_OK if report.best is not None else EXIT_NO_CONFIG
    from repro.core.perf_database import PerfDatabase
    db = PerfDatabase(art.platform, art.backend, calibration=art)
    fp = db.fingerprint()
    if args.json:
        print(json.dumps(fp, indent=2))
    else:
        print(f"calibrated PerfDatabase ({art.platform}, {art.backend}):")
        print(json.dumps(fp, indent=2))
    return EXIT_OK


# ---------------------------------------------------------------------------
# workload
# ---------------------------------------------------------------------------

def _parse_tenants(text: str, lengths) -> tuple:
    """``name:weight[:priority],...`` -> TenantSpec tuple (shared lengths)."""
    from repro.workloads import TenantSpec
    tenants = []
    for part in text.split(","):
        bits = part.split(":")
        if len(bits) not in (2, 3):
            raise ValueError(f"bad tenant {part!r}; expected "
                             "name:weight or name:weight:priority")
        tenants.append(TenantSpec(
            name=bits[0], weight=float(bits[1]),
            priority=int(bits[2]) if len(bits) == 3 else 0,
            lengths=lengths))
    return tuple(tenants)


def _trace_spec_from_args(args):
    from repro.workloads import ArrivalSpec, LengthSpec, TenantSpec, TraceSpec
    if args.spec:
        with open(args.spec) as f:
            return TraceSpec.from_dict(json.load(f))
    isl_lo, isl_hi = (int(b) for b in args.isl_range.split(":"))
    osl_lo, osl_hi = (int(b) for b in args.osl_range.split(":"))
    lengths = LengthSpec(kind=args.lengths, isl=args.isl, osl=args.osl,
                         isl_lo=isl_lo, isl_hi=isl_hi,
                         osl_lo=osl_lo, osl_hi=osl_hi, sigma=args.sigma)
    tenants = (_parse_tenants(args.tenants, lengths) if args.tenants
               else (TenantSpec(lengths=lengths),))
    arrivals = ArrivalSpec(kind=args.arrivals, rate_rps=args.rate,
                           burst_factor=args.burst_factor,
                           period_s=args.period, amplitude=args.amplitude)
    return TraceSpec(n_requests=args.n, arrivals=arrivals, tenants=tenants)


def cmd_workload_generate(args) -> int:
    from repro.workloads import generate_trace
    spec = _trace_spec_from_args(args)
    trace = generate_trace(spec, seed=args.seed)
    trace.save(args.out)
    desc = trace.describe()
    if args.json:
        print(json.dumps({"out": args.out, "describe": desc}, indent=2))
    else:
        print(f"trace -> {args.out}  ({desc['n_requests']} requests, "
              f"{desc['duration_s']:.1f}s, {desc['arrival_rate_rps']:.2f} "
              f"req/s, digest {desc['digest']})")
    return EXIT_OK


def cmd_workload_describe(args) -> int:
    from repro.workloads import WorkloadTrace
    desc = WorkloadTrace.load(args.trace).describe()
    if args.json:
        print(json.dumps(desc, indent=2))
    else:
        print(f"trace {args.trace}: {desc['n_requests']} requests over "
              f"{desc['duration_s']:.1f}s ({desc['arrival_rate_rps']:.2f} "
              f"req/s), digest {desc['digest']}")
        for name, n in sorted(desc["tenants"].items()):
            print(f"  tenant {name}: {n} requests")
        for axis in ("isl", "osl"):
            if axis in desc:
                d = desc[axis]
                print(f"  {axis}: mean {d['mean']:.0f}  p50 {d['p50']:.0f}  "
                      f"p95 {d['p95']:.0f}  max {d['max']:.0f}")
    return EXIT_OK


def _slo_from_args(args):
    from repro.workloads import SLOSpec
    return SLOSpec(ttft_p99_ms=args.slo_ttft_p99,
                   tpot_p99_ms=args.slo_tpot_p99)


def _explicit_candidate(args, trace, n_chips=None):
    """One explicit serving candidate from ``--tp/--pp/--ep/--batch``
    flags plus a trace-shaped workload descriptor — shared by
    ``workload replay`` and ``capacity sweep``."""
    from repro.core.config import (CandidateConfig, ClusterSpec,
                                   ParallelismConfig, RuntimeFlags, SLA,
                                   WorkloadDescriptor)
    w = WorkloadDescriptor(
        model=args.model, isl=trace.mean_isl(), osl=trace.mean_osl(),
        sla=SLA(), cluster=ClusterSpec(
            n_chips=n_chips if n_chips is not None else args.tp * args.pp,
            platform=args.platform),
        backend=args.backend, modes=("aggregated",), dtype=args.dtype)
    cand = CandidateConfig(
        parallel=ParallelismConfig(tp=args.tp, pp=args.pp, ep=args.ep),
        batch_size=args.batch,
        flags=RuntimeFlags(max_num_tokens=args.max_num_tokens))
    return w, cand


def cmd_workload_replay(args) -> int:
    """Replay a trace against one explicit serving configuration."""
    from repro.core.task_runner import TaskRunner
    from repro.workloads import WorkloadTrace
    trace = WorkloadTrace.load(args.trace)
    w, cand = _explicit_candidate(args, trace)
    runner = TaskRunner(w)
    sim = runner.simulator(cand, priority_admission=True,
                           max_queue=args.max_queue)
    obs = _ObsCapture(args)
    try:
        metrics = sim.replay(trace, slo=_slo_from_args(args),
                             max_steps=args.max_steps)
    finally:
        obs.finish()
    payload = {"trace": {"path": args.trace, "digest": trace.digest()},
               "config": {"model": args.model, "describe": cand.describe(),
                          "platform": args.platform,
                          "backend": args.backend, "dtype": args.dtype},
               "metrics": metrics.to_dict()}
    if args.json:
        print(json.dumps(payload, indent=2))
    else:
        m = metrics
        print(f"replayed {m.n_requests} requests ({cand.describe()}): "
              f"{m.completed} completed, {m.rejected} rejected, "
              f"{m.unfinished} unfinished in {m.duration_s:.1f}s virtual")
        print(f"  TTFT ms  p50 {m.ttft_ms['p50']:.1f}  "
              f"p95 {m.ttft_ms['p95']:.1f}  p99 {m.ttft_ms['p99']:.1f}")
        print(f"  TPOT ms  p50 {m.tpot_ms['p50']:.1f}  "
              f"p95 {m.tpot_ms['p95']:.1f}  p99 {m.tpot_ms['p99']:.1f}")
        print(f"  queue depth mean {m.queue_depth_mean:.1f} "
              f"max {m.queue_depth_max}")
        print(f"  throughput {m.throughput_tok_s:.1f} tok/s; goodput "
              f"{m.goodput_tok_s:.1f} tok/s at "
              f"{100 * m.slo_attainment:.1f}% SLO attainment")
    return EXIT_OK if metrics.completed > 0 else EXIT_NO_CONFIG


# ---------------------------------------------------------------------------
# capacity
# ---------------------------------------------------------------------------

def _parse_ladder(text: str) -> tuple:
    """``1,2,4`` -> ascending replica-count ladder."""
    try:
        return tuple(int(b) for b in text.split(","))
    except ValueError:
        raise ValueError(f"bad ladder {text!r}; expected a comma list of "
                         "replica counts, e.g. 1,2,4") from None


def cmd_capacity_sweep(args) -> int:
    """Ladder sweep of one explicit candidate: stream-friendly per-rung
    records (JSON-lines with ``--json``) plus a min-chip summary."""
    from repro.capacity import iter_ladder
    from repro.core.task_runner import TaskRunner
    from repro.workloads import WorkloadTrace
    trace = WorkloadTrace.load(args.trace)
    ladder = _parse_ladder(args.ladder)
    w, cand = _explicit_candidate(args, trace,
                                  n_chips=args.tp * args.pp * max(ladder))
    runner = TaskRunner(w)
    best = None
    records = []
    em = _JsonLines()
    obs = _ObsCapture(args)
    try:
        for rec in iter_ladder(runner, [cand], trace, _slo_from_args(args),
                               ladder=ladder, routing=args.routing,
                               attain_target=args.attain_target,
                               max_steps=args.max_steps,
                               max_queue=args.max_queue):
            records.append(rec)
            if rec["attains"] and (best is None or rec["total_chips"]
                                   < best["total_chips"]):
                best = rec
            if args.json:
                m = rec["metrics"]
                # "describe" is always the string form; the summary
                # record's "deployment" is always the full dict — one
                # shape per key
                if not em.emit({
                        "type": "rung", "replicas": rec["replicas"],
                        "describe": rec["deployment"]["describe"],
                        "total_chips": rec["total_chips"],
                        "pruned": rec["pruned"], "attains": rec["attains"],
                        "goodput_tok_s": m["goodput_tok_s"] if m else None,
                        "slo_attainment": m["slo_attainment"] if m else None,
                        "p99_ttft_ms": m["ttft_ms"]["p99"] if m else None,
                        "imbalance": m["imbalance"] if m else None,
                }):
                    break           # consumer gone: stop sweeping rungs
            else:
                if rec["pruned"]:
                    print(f"  {rec['deployment']['describe']:>16s} "
                          f"{rec['total_chips']:4d} chips  pruned "
                          f"({rec['pruned']})")
                else:
                    m = rec["metrics"]
                    print(f"  {rec['deployment']['describe']:>16s} "
                          f"{rec['total_chips']:4d} chips  goodput "
                          f"{m['goodput_tok_s']:9.1f} tok/s  attainment "
                          f"{100 * m['slo_attainment']:5.1f}%  p99 TTFT "
                          f"{m['ttft_ms']['p99']:8.1f}ms  "
                          f"{'ATTAINS' if rec['attains'] else 'misses SLO'}")
    finally:
        obs.finish()
    if args.json:
        em.emit({
            "type": "summary", "trace": trace.digest(),
            "routing": args.routing, "ladder": list(ladder),
            "attain_target": args.attain_target,
            "n_rungs": len(records),
            "plan": (None if best is None else {
                "deployment": best["deployment"],
                "total_chips": best["total_chips"],
                "goodput_tok_s": best["metrics"]["goodput_tok_s"],
                "slo_attainment": best["metrics"]["slo_attainment"],
            }),
        })
        if em.broken:
            return EXIT_OK
    elif best is None:
        print(f"no rung on ladder {list(ladder)} attains "
              f"{100 * args.attain_target:.0f}% of the SLO")
    else:
        print(f"min-chip plan: {best['deployment']['describe']} = "
              f"{best['total_chips']} chips "
              f"({100 * best['metrics']['slo_attainment']:.1f}% attainment)")
    return EXIT_OK if best is not None else EXIT_NO_CONFIG


def cmd_capacity_plan(args) -> int:
    """Search, then size the deployment: analytical top-K × ladder →
    min-chip plan, recorded in the schema-v4 SearchReport."""
    cfg = _configurator(args)
    obs = _ObsCapture(args)
    try:
        report = cfg.plan_capacity(
            args.trace, _slo_from_args(args),
            ladder=_parse_ladder(args.ladder),
            top_k=args.top_k, routing=args.routing,
            attain_target=args.attain_target, max_steps=args.max_steps)
    finally:
        obs.finish()
    if args.save_report:
        report.save(args.save_report)
    if args.json:
        print(report.to_json())
        return (EXIT_OK if report.capacity["plan"]["attained"]
                else EXIT_NO_CONFIG)
    cap = report.capacity
    print(report.summary())
    print(f"\nladder {cap['ladder']} (routing {cap['routing']}, target "
          f"{100 * cap['attain_target']:.0f}% attainment, trace "
          f"{cap['trace']['digest']}):")
    for rec in cap["rungs"]:
        if rec["pruned"]:
            print(f"  {rec['deployment']['describe']:>16s} "
                  f"{rec['total_chips']:4d} chips  pruned ({rec['pruned']})")
            continue
        m = rec["metrics"]
        print(f"  {rec['deployment']['describe']:>16s} "
              f"{rec['total_chips']:4d} chips  goodput "
              f"{m['goodput_tok_s']:9.1f} tok/s  attainment "
              f"{100 * m['slo_attainment']:5.1f}%  "
              f"{'ATTAINS' if rec['attains'] else 'misses SLO'}")
    for s in cap.get("skipped", []):
        print(f"  -- [{s['mode']}] {s['describe']} skipped: {s['reason']}")
    return EXIT_OK if cap["plan"]["attained"] else EXIT_NO_CONFIG


# ---------------------------------------------------------------------------
# autoscale
# ---------------------------------------------------------------------------

def _policy_from_args(args):
    """Build the AutoscalerPolicy selected by ``--policy`` plus its
    tuning flags (policy-specific knobs only reach their own policy)."""
    from repro.autoscale import get_policy
    kw = dict(min_replicas=args.min_replicas,
              max_replicas=args.max_replicas,
              scale_up_step=args.up_step,
              scale_down_step=args.down_step,
              up_cooldown_s=args.up_cooldown,
              down_cooldown_s=args.down_cooldown,
              window_s=args.window)
    if args.policy == "target_queue_depth":
        kw["target_depth"] = args.target_depth
    elif args.policy == "slo_attainment":
        kw["attain_target"] = args.attain_target
        kw["scale_down_util"] = args.scale_down_util
    return get_policy(args.policy, **kw)


def _emit_timeline(timeline, args, em: _JsonLines) -> None:
    """Stream the timeline (JSON-lines sample records with ``--json``)
    and honor ``--save-timeline``.  A broken pipe stops the sample
    stream but never the save file."""
    if args.json:
        for s in timeline.samples:
            if not em.emit({"type": "sample", **s.to_dict()},
                           sort_keys=True):
                break
    if args.save_timeline:
        timeline.save(args.save_timeline)


def cmd_autoscale_run(args) -> int:
    """Autoscaled replay of one explicit candidate: JSON-lines timeline
    samples plus a summary record."""
    from repro.core.task_runner import TaskRunner
    from repro.workloads import WorkloadTrace
    trace = WorkloadTrace.load(args.trace)
    policy = _policy_from_args(args)
    w, cand = _explicit_candidate(
        args, trace, n_chips=args.tp * args.pp * policy.max_replicas)
    runner = TaskRunner(w)
    sim = runner.autoscale_simulator(
        cand, policy, routing=args.routing,
        initial_replicas=args.initial_replicas, tick_s=args.tick,
        cold_start_s=args.cold_start, max_queue=args.max_queue)
    obs = _ObsCapture(args)
    try:
        report = sim.run(trace, slo=_slo_from_args(args),
                         max_steps=args.max_steps)
    finally:
        obs.finish()
    em = _JsonLines()
    _emit_timeline(report.timeline, args, em)
    if args.json:
        em.emit({"type": "summary",
                 "trace": {"path": args.trace,
                           "digest": trace.digest()},
                 "config": {"model": args.model,
                            "describe": cand.describe()},
                 **report.to_dict()}, sort_keys=True)
        if em.broken:
            return EXIT_OK
    else:
        m = report.metrics
        print(report.summary())
        print(f"  {m.completed} completed, {m.rejected} rejected, "
              f"{m.unfinished} unfinished"
              + (" (budget truncated)" if m.truncated else ""))
        print(f"  goodput {m.goodput_tok_s:.1f} tok/s; events: "
              f"{len(report.events)} "
              f"({report.n_scale_ups} up, {report.n_scale_downs} down)")
        for ev in report.events:
            extra = (f" {ev['from']}->{ev['to']} ({ev['reason']})"
                     if "from" in ev else f" replica {ev['replica']}")
            print(f"    t={ev['t_s']:8.1f}s {ev['action']:<10s}{extra}")
    return EXIT_OK if report.metrics.completed > 0 else EXIT_NO_CONFIG


def cmd_autoscale_compare(args) -> int:
    """Autoscaled run vs the static min-chip plan on the same trace,
    candidate, and SLO — the chip-seconds savings view."""
    from repro.autoscale import build_autoscale_section
    from repro.core.task_runner import TaskRunner
    from repro.workloads import WorkloadTrace
    trace = WorkloadTrace.load(args.trace)
    ladder = _parse_ladder(args.ladder)
    policy = _policy_from_args(args)
    w, cand = _explicit_candidate(
        args, trace,
        n_chips=args.tp * args.pp * max(max(ladder), policy.max_replicas))
    runner = TaskRunner(w)
    obs = _ObsCapture(args)
    try:
        section, run = build_autoscale_section(
            runner, cand, trace, _slo_from_args(args), policy,
            ladder=ladder, routing=args.routing,
            attain_target=args.attain_target,
            tick_s=args.tick, cold_start_s=args.cold_start,
            initial_replicas=args.initial_replicas,
            max_steps=args.max_steps, max_queue=args.max_queue)
    finally:
        obs.finish()
    em = _JsonLines()
    _emit_timeline(run.timeline, args, em)
    ok = (section["static"] is not None
          and section["savings"]["holds_attainment"])
    if args.json:
        # the histogram block travels in the schema-v7 report (and
        # --metrics-out); the JSON-lines stream stays pre-v7 stable
        section["run"]["metrics"].pop("histograms", None)
        em.emit({"type": "summary", **section}, sort_keys=True)
        return EXIT_OK if (ok or em.broken) else EXIT_NO_CONFIG
    static = section["static"]
    if static is None:
        print(f"no rung on ladder {list(ladder)} attains "
              f"{100 * args.attain_target:.0f}% of the SLO; no static "
              f"baseline to compare against")
        print(run.summary())
        return EXIT_NO_CONFIG
    print(f"static plan: {static['deployment']['describe']} = "
          f"{static['total_chips']} chips x {static['duration_s']:.1f}s "
          f"= {static['chip_seconds']:.1f} chip-s "
          f"({100 * static['slo_attainment']:.1f}% attainment)")
    print(run.summary())
    sv = section["savings"]
    verdict = ("holds attainment" if sv["holds_attainment"]
               else "DROPS below target")
    print(f"savings: {sv['chip_seconds']:.1f} chip-s "
          f"({sv['chip_seconds_pct']:.1f}%), {verdict} "
          f"({100 * args.attain_target:.0f}% target)")
    return EXIT_OK if ok else EXIT_NO_CONFIG


# ---------------------------------------------------------------------------
# obs
# ---------------------------------------------------------------------------

def cmd_obs_export(args) -> int:
    """Re-encode a saved TraceArtifact: Chrome ``trace_event`` JSON for
    chrome://tracing / Perfetto, or the canonical JSONL."""
    from repro.obs import TraceArtifact
    art = TraceArtifact.load(args.trace)
    if args.format == "chrome":
        text = json.dumps(art.to_chrome_trace(), sort_keys=True) + "\n"
    else:
        text = art.to_jsonl()
    if args.out == "-":
        _JsonLines().emit_text(text)
    else:
        with open(args.out, "w") as f:
            f.write(text)
        print(f"{args.format} export ({art.n_spans} spans) -> {args.out}")
    return EXIT_OK


def cmd_obs_diff(args) -> int:
    """Diff two telemetry snapshots (registry dumps, SearchReports with
    telemetry, or bare replay histogram sections).  Exit 0 when
    identical, 1 when they differ — diff semantics."""
    from repro.obs import diff_metrics, format_diff
    d = diff_metrics(args.a, args.b)
    if args.json:
        _JsonLines().emit_text(json.dumps(d, indent=2, sort_keys=True)
                               + "\n")
    else:
        _JsonLines().emit_text(format_diff(d) + "\n")
    return EXIT_OK if d["identical"] else EXIT_NO_CONFIG


def cmd_obs_bench_run(args) -> int:
    """Run the benchmark suite through ``benchmarks/run.py`` and emit a
    versioned BenchArtifact (plus the history append).  The benchmarks
    package is not installed — it lives at the repo root — so this
    resolves it from the current directory when needed."""
    try:
        from benchmarks.run import main as bench_run_main
    except ImportError:
        if os.path.isdir(os.path.join(os.getcwd(), "benchmarks")):
            sys.path.insert(0, os.getcwd())
        try:
            from benchmarks.run import main as bench_run_main
        except ImportError:
            raise ValueError(
                "obs bench run needs the repo's benchmarks/ package on "
                "sys.path — run from the repo root")
    argv = []
    if args.quick:
        argv.append("--quick")
    if args.only:
        argv += ["--only", args.only]
    argv += ["--repeat", str(args.repeat)]
    if args.out:
        argv += ["--out", args.out]
    if args.history is not None:
        argv += ["--history", args.history]
    if args.timestamp:
        argv += ["--timestamp", args.timestamp]
    return EXIT_NO_CONFIG if bench_run_main(argv) else EXIT_OK


def cmd_obs_bench_compare(args) -> int:
    """Strict determinism check between two suite runs: identical work
    counters -> exit 0, any drift -> exit 1, mismatched environment
    fingerprints -> exit 2 (refusing to produce a misleading delta)."""
    from repro.obs.bench import (BenchArtifact, compare_artifacts,
                                 format_compare)
    a = BenchArtifact.load(args.a)
    b = BenchArtifact.load(args.b)
    # EnvironmentMismatch is a ValueError: main() maps it to exit 2.
    cmp = compare_artifacts(a, b)
    if args.json:
        _JsonLines().emit_text(json.dumps(cmp, indent=2, sort_keys=True)
                               + "\n")
    else:
        _JsonLines().emit_text(format_compare(cmp) + "\n")
    return EXIT_OK if cmp["identical"] else EXIT_NO_CONFIG


def cmd_obs_bench_gate(args) -> int:
    """Gate a current run against a baseline artifact: hard gates on
    work counters always run (a ``REPRO_*`` knob regression is exactly
    what they hunt); soft wallclock gates run only when the environment
    fingerprints match.  Exit 0 pass / 1 fail."""
    from repro.obs.bench import (DEFAULT_ABS_TOL_US, DEFAULT_REL_TOL,
                                 BenchArtifact, gate_artifacts)
    baseline = BenchArtifact.load(args.baseline)
    current = BenchArtifact.load(args.current)
    res = gate_artifacts(
        baseline, current,
        rel_tol=DEFAULT_REL_TOL if args.rel_tol is None else args.rel_tol,
        abs_tol_us=(DEFAULT_ABS_TOL_US if args.abs_tol_us is None
                    else args.abs_tol_us),
        hard_only=args.hard_only)
    if args.json:
        _JsonLines().emit_text(
            json.dumps(res.to_dict(), indent=2, sort_keys=True) + "\n")
    else:
        _JsonLines().emit_text(res.format() + "\n")
    return EXIT_OK if res.ok else EXIT_NO_CONFIG


def cmd_obs_bench_trend(args) -> int:
    """Summarize the append-only bench history: per-benchmark wallclock
    trajectory and how often the work-counter digest changed."""
    from repro.obs.bench import format_trend, load_history, trend_summary
    entries = load_history(args.history)
    summary = trend_summary(entries, suite=args.suite or None)
    if args.json:
        _JsonLines().emit_text(
            json.dumps(summary, indent=2, sort_keys=True) + "\n")
    else:
        _JsonLines().emit_text(format_trend(summary) + "\n")
    return EXIT_OK


# ---------------------------------------------------------------------------
# explain
# ---------------------------------------------------------------------------

def _configurator_from_workload(w) -> Configurator:
    """Rebuild a Configurator from a report's workload descriptor so
    ``explain --from-report`` prices through the exact same workload."""
    return (Configurator.for_model(w.model)
            .traffic(w.isl, w.osl, w.prefix_len)
            .sla(ttft_ms=w.sla.ttft_ms,
                 min_tokens_per_s_user=w.sla.min_tokens_per_s_user,
                 tpot_ms=w.sla.tpot_ms)
            .cluster(chips=w.cluster.n_chips, platform=w.cluster.platform,
                     chips_per_host=w.cluster.chips_per_host)
            .backend(w.backend).dtype(w.dtype)
            .modes(*w.modes).moe_alpha(w.moe_alpha))


def cmd_explain(args) -> int:
    """Per-candidate cost attribution: the operator-family latency
    waterfall for an analytical leader, optionally diffed against a
    second leader rank."""
    if args.from_report:
        report = SearchReport.load(args.from_report)
        cfg = _configurator_from_workload(report.workload)
    else:
        if args.model is None or args.isl is None or args.osl is None:
            print("error: explain needs --from-report or "
                  "--model/--isl/--osl", file=sys.stderr)
            return EXIT_USAGE
        cfg = _configurator(args)
        report = None
    try:
        ex = cfg.explain(rank=args.rank, baseline=args.baseline,
                         report=report, top_k=args.top_k)
    except ValueError as e:
        if "explainable candidate" not in str(e):
            raise
        print(f"error: {e}", file=sys.stderr)
        return EXIT_NO_CONFIG
    if args.json:
        print(json.dumps(ex.to_dict(), indent=2))
    else:
        print(ex.summary())
    return EXIT_OK


# ---------------------------------------------------------------------------
# list
# ---------------------------------------------------------------------------

def cmd_list(args) -> int:
    inventory = {
        "models": list_archs(True),
        "backends": {name: sorted(backend_capabilities(name))
                     for name in all_backends()},
        "platforms": sorted(PLATFORMS),
    }
    wanted = (inventory if args.what == "all"
              else {args.what: inventory[args.what]})
    if args.json:
        print(json.dumps(wanted, indent=2))
        return EXIT_OK
    for section, items in wanted.items():
        print(f"{section}:")
        if isinstance(items, dict):
            for name, caps in items.items():
                print(f"  {name}  ({', '.join(caps)})")
        else:
            for name in items:
                print(f"  {name}")
    return EXIT_OK


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def _add_slo_args(ap: argparse.ArgumentParser):
    ap.add_argument("--slo-ttft-p99", type=float, default=2000.0,
                    help="tail SLO: p99 TTFT target in ms")
    ap.add_argument("--slo-tpot-p99", type=float, default=100.0,
                    help="tail SLO: p99 TPOT target in ms")


def _add_candidate_args(ap: argparse.ArgumentParser):
    """The explicit-candidate flag block `workload replay` and
    `capacity sweep` share (consumed by ``_explicit_candidate``)."""
    ap.add_argument("--model", required=True,
                    help=f"one of {', '.join(list_archs(True))}")
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--pp", type=int, default=1)
    ap.add_argument("--ep", type=int, default=1)
    ap.add_argument("--batch", type=int, default=64,
                    help="decode slot count (max_batch)")
    ap.add_argument("--max-num-tokens", type=int, default=8192)
    ap.add_argument("--max-queue", type=int, default=100_000)
    ap.add_argument("--platform", default="tpu_v5e")
    ap.add_argument("--backend", default="repro-jax")
    ap.add_argument("--dtype", default="bf16",
                    choices=["bf16", "fp16", "fp8"])


def _add_obs_args(ap: argparse.ArgumentParser):
    """The ``repro.obs`` capture flags every instrumented command shares
    (search plus the replay family: workload replay, capacity
    sweep/plan, autoscale run/compare)."""
    ap.add_argument("--trace-out", default="", metavar="PATH",
                    help="record repro.obs spans and write the "
                         "TraceArtifact JSONL here ('-' streams it to "
                         "stdout; a .chrome.json suffix writes the Chrome "
                         "trace_event export for chrome://tracing / "
                         "Perfetto); deterministic across seeded runs")
    ap.add_argument("--metrics-out", default="", metavar="PATH",
                    help="collect repro.obs counters during the command "
                         "and write the registry snapshot here (JSON, or "
                         "Prometheus text format with a .prom suffix)")
    ap.add_argument("--span-sample-every", type=int, default=None,
                    metavar="N",
                    help="flight recorder: keep every N-th request's "
                         "lifecycle spans (default 1 = all sampled "
                         "requests; histograms always see every request)")
    ap.add_argument("--max-request-spans", type=int, default=None,
                    metavar="N",
                    help="flight recorder: cap request span trees per "
                         "replay (default 512)")


def _build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.core.cli",
        description="AIConfigurator: find the optimal serving configuration")
    sub = ap.add_subparsers(dest="command")

    sp = sub.add_parser("search", help="search the configuration space")
    _add_workload_args(sp)
    sp.add_argument("--top", type=int, default=5)
    sp.add_argument("--save-launch", default="")
    sp.add_argument("--save-report", default="",
                    help="write the SearchReport JSON here")
    sp.add_argument("--draft-model", default="",
                    help="also project speculative decoding with this draft")
    sp.add_argument("--acceptance", type=float, default=0.8)
    sp.add_argument("--json", action="store_true",
                    help="print the SearchReport JSON on stdout")
    sp.add_argument("--stream", action="store_true",
                    help="emit JSON-lines progress records as candidates "
                         "are priced, then a terminal summary record")
    sp.add_argument("--first-n", type=int, default=0, metavar="N",
                    help="stop as soon as N SLA-valid configurations are "
                         "found (early exit; prices fewer candidates)")
    sp.add_argument("--trace", default="",
                    help="workload trace JSONL (from `workload generate`): "
                         "replay the frontier's top-K under it open-loop "
                         "(queueing delay counts into TTFT) and re-rank "
                         "by goodput (SearchReport workload_eval section)")
    _add_slo_args(sp)
    sp.add_argument("--replay-top-k", type=int, default=3, metavar="K",
                    help="how many analytical leaders to replay "
                         "(disaggregated composites are skipped, not "
                         "replayed)")
    _add_obs_args(sp)
    sp.set_defaults(func=cmd_search)

    gp = sub.add_parser("generate", help="emit the launch artifact")
    gp.add_argument("--from-report", default="",
                    help="SearchReport JSON from `search --save-report`")
    gp.add_argument("--out", default="", help="write launch JSON here")
    gp.add_argument("--json", action="store_true")
    _add_workload_args(gp, required=False)
    gp.set_defaults(func=cmd_generate)

    cp = sub.add_parser("compare",
                        help="sweep traffic shapes (scenario diversity)")
    _add_workload_args(cp, traffic=False)
    cp.add_argument("--shapes", required=True,
                    help="comma list of isl:osl[:min_speed]")
    cp.add_argument("--json", action="store_true")
    cp.set_defaults(func=cmd_compare)

    cal = sub.add_parser(
        "calibrate",
        help="measured-kernel calibration: run | apply | report")
    calsub = cal.add_subparsers(dest="action")

    cr = calsub.add_parser("run", help="measure kernels and fit corrections")
    cr.add_argument("--platform", default="tpu_v5e",
                    help=f"one of {', '.join(sorted(PLATFORMS))}")
    cr.add_argument("--backend", default="repro-jax")
    cr.add_argument("--timer", default="deterministic",
                    choices=["deterministic", "wallclock"],
                    help="deterministic: CI-reproducible analytical-skew "
                         "timer; wallclock: execute the real kernels "
                         "(interpret mode on CPU, compiled on TPU)")
    cr.add_argument("--points", type=int, default=3,
                    help="measurement points per grid axis")
    cr.add_argument("--families", default="",
                    help="comma list (default: all measured families)")
    cr.add_argument("--out", required=True,
                    help="write the calibration artifact JSON here")
    cr.add_argument("--timestamp", default="",
                    help="ISO-8601 provenance timestamp (default: now UTC)")
    cr.add_argument("--notes", default="")
    cr.add_argument("--json", action="store_true")
    cr.set_defaults(func=cmd_calibrate_run)

    ca = calsub.add_parser(
        "apply", help="search through a calibrated PerfDatabase")
    ca.add_argument("--artifact", required=True)
    ca.add_argument("--save-report", default="")
    ca.add_argument("--json", action="store_true")
    _add_workload_args(ca, required=False)
    # sentinel defaults: the artifact supplies the calibrated pair, and
    # an EXPLICIT mismatching flag is detectable (and warned about)
    ca.set_defaults(func=cmd_calibrate_apply, platform=None, backend=None)

    crep = calsub.add_parser(
        "report", help="per-family accuracy audit of an artifact")
    crep.add_argument("--artifact", required=True)
    crep.add_argument("--json", action="store_true")
    crep.set_defaults(func=cmd_calibrate_report)

    wl = sub.add_parser(
        "workload",
        help="dynamic workload traces: generate | replay | describe")
    wlsub = wl.add_subparsers(dest="action")

    wg = wlsub.add_parser("generate",
                          help="expand a seeded (spec, seed) into a trace")
    wg.add_argument("--arrivals", default="poisson",
                    choices=["poisson", "bursty", "diurnal"])
    wg.add_argument("--rate", type=float, default=1.0,
                    help="mean arrival rate, requests/s")
    wg.add_argument("--burst-factor", type=float, default=4.0,
                    help="bursty: ON-phase rate multiplier")
    wg.add_argument("--period", type=float, default=120.0,
                    help="diurnal: modulation period, seconds")
    wg.add_argument("--amplitude", type=float, default=0.8,
                    help="diurnal: modulation amplitude in [0, 1)")
    wg.add_argument("--n", type=int, default=100, help="request count")
    wg.add_argument("--lengths", default="fixed",
                    choices=["fixed", "uniform", "lognormal", "sharegpt"])
    wg.add_argument("--isl", type=int, default=512,
                    help="fixed/lognormal input-length (median)")
    wg.add_argument("--osl", type=int, default=128,
                    help="fixed/lognormal output-length (median)")
    wg.add_argument("--isl-range", default="64:2048", metavar="LO:HI",
                    help="uniform input-length bounds")
    wg.add_argument("--osl-range", default="16:512", metavar="LO:HI",
                    help="uniform output-length bounds")
    wg.add_argument("--sigma", type=float, default=0.5,
                    help="lognormal spread")
    wg.add_argument("--tenants", default="",
                    help="comma list of name:weight[:priority] "
                         "(default: one 'default' tenant)")
    wg.add_argument("--spec", default="",
                    help="TraceSpec JSON file (overrides the flags above)")
    wg.add_argument("--seed", type=int, default=0)
    wg.add_argument("--out", required=True,
                    help="write the trace JSONL here")
    wg.add_argument("--json", action="store_true")
    wg.set_defaults(func=cmd_workload_generate)

    wd = wlsub.add_parser("describe", help="summarize a trace file")
    wd.add_argument("--trace", required=True)
    wd.add_argument("--json", action="store_true")
    wd.set_defaults(func=cmd_workload_describe)

    wr = wlsub.add_parser(
        "replay", help="open-loop replay against one serving config "
                       "(arrival-time-driven: queueing delay counts "
                       "into TTFT)")
    wr.add_argument("--trace", required=True)
    _add_candidate_args(wr)
    wr.add_argument("--max-steps", type=int, default=200_000)
    _add_slo_args(wr)
    wr.add_argument("--json", action="store_true")
    _add_obs_args(wr)
    wr.set_defaults(func=cmd_workload_replay)

    cap = sub.add_parser(
        "capacity",
        help="multi-replica capacity planning: plan | sweep")
    capsub = cap.add_subparsers(dest="action")

    def _add_capacity_args(p):
        p.add_argument("--trace", required=True,
                       help="workload trace JSONL (from `workload generate`)")
        p.add_argument("--ladder", default="1,2,4", metavar="N,N,...",
                       help="ascending replica-count ladder to sweep")
        p.add_argument("--routing", default="round_robin",
                       choices=list(ROUTING_POLICIES),
                       help="how requests are routed across replicas")
        p.add_argument("--attain-target", type=float, default=0.95,
                       help="fraction of requests that must meet the SLO "
                            "for a rung to attain")
        p.add_argument("--max-steps", type=int, default=200_000,
                       help="total iteration budget across all replicas")
        _add_slo_args(p)
        p.add_argument("--json", action="store_true")
        _add_obs_args(p)

    cs = capsub.add_parser(
        "sweep", help="replay one explicit candidate up the replica "
                      "ladder; per-rung records (JSON-lines with --json)")
    _add_capacity_args(cs)
    _add_candidate_args(cs)
    cs.set_defaults(func=cmd_capacity_sweep)

    cpl = capsub.add_parser(
        "plan", help="search, then find the minimum-chip deployment "
                     "whose goodput attains the SLO (schema-v4 report)")
    _add_workload_args(cpl)
    _add_capacity_args(cpl)
    cpl.add_argument("--top-k", type=int, default=1, metavar="K",
                     help="try the analytical top-K replayable candidates "
                          "at every rung (disaggregated composites are "
                          "skipped)")
    cpl.add_argument("--save-report", default="",
                     help="write the schema-v4 SearchReport JSON here")
    cpl.set_defaults(func=cmd_capacity_plan)

    asc = sub.add_parser(
        "autoscale",
        help="reactive autoscaling over the cluster simulator: "
             "run | compare")
    ascsub = asc.add_subparsers(dest="action")

    def _add_autoscale_args(p):
        from repro.autoscale import AUTOSCALER_POLICIES
        p.add_argument("--trace", required=True,
                       help="workload trace JSONL (from `workload "
                            "generate`)")
        p.add_argument("--routing", default="round_robin",
                       choices=list(ROUTING_POLICIES))
        p.add_argument("--policy", default="target_queue_depth",
                       choices=list(AUTOSCALER_POLICIES),
                       help="autoscaler policy evaluated each tick")
        p.add_argument("--target-depth", type=float, default=4.0,
                       help="target_queue_depth: outstanding requests "
                            "per replica to aim for")
        p.add_argument("--attain-target", type=float, default=0.95,
                       help="fraction of requests that must meet the SLO "
                            "(slo_attainment policy target; also the "
                            "static plan's bar under `compare`)")
        p.add_argument("--scale-down-util", type=float, default=0.5,
                       help="slo_attainment: scale down only below this "
                            "mean utilization")
        p.add_argument("--min-replicas", type=int, default=1)
        p.add_argument("--max-replicas", type=int, default=8)
        p.add_argument("--up-step", type=int, default=1,
                       help="max replicas added per scale-up")
        p.add_argument("--down-step", type=int, default=1,
                       help="max replicas drained per scale-down")
        p.add_argument("--up-cooldown", type=float, default=5.0,
                       help="seconds between scale-ups")
        p.add_argument("--down-cooldown", type=float, default=30.0,
                       help="seconds between scale-downs")
        p.add_argument("--window", type=float, default=10.0,
                       help="rolling metrics window the policy sees (s)")
        p.add_argument("--tick", type=float, default=1.0,
                       help="control-loop tick width (virtual s)")
        p.add_argument("--cold-start", type=float, default=5.0,
                       help="spawn-to-route-eligible delay (virtual s)")
        p.add_argument("--initial-replicas", type=int, default=None,
                       help="starting fleet size (default: policy "
                            "min-replicas; under `compare`, the static "
                            "plan's replica count)")
        p.add_argument("--max-steps", type=int, default=200_000,
                       help="total iteration budget across all replicas")
        p.add_argument("--save-timeline", default="",
                       help="write the ClusterTimeline JSONL here")
        _add_slo_args(p)
        p.add_argument("--json", action="store_true",
                       help="JSON-lines: one record per timeline sample, "
                            "then a terminal summary record")
        _add_obs_args(p)

    ar = ascsub.add_parser(
        "run", help="autoscaled replay of one explicit candidate; "
                    "timeline samples as JSON-lines with --json")
    _add_autoscale_args(ar)
    _add_candidate_args(ar)
    ar.set_defaults(func=cmd_autoscale_run)

    ac = ascsub.add_parser(
        "compare", help="autoscaled run vs the static min-chip plan on "
                        "the same trace (chip-seconds savings)")
    _add_autoscale_args(ac)
    _add_candidate_args(ac)
    ac.add_argument("--ladder", default="1,2,4", metavar="N,N,...",
                    help="replica ladder for the static baseline plan")
    ac.set_defaults(func=cmd_autoscale_compare)

    ep = sub.add_parser(
        "explain",
        help="attribute a candidate's projected latency to operator "
             "families (per-phase waterfall, optional two-rank diff)")
    _add_workload_args(ep, required=False)
    ep.add_argument("--from-report", default="",
                    help="SearchReport JSON from `search --save-report` "
                         "(skips the fresh search)")
    ep.add_argument("--rank", type=int, default=0,
                    help="analytical-leader rank to explain (0 = best "
                         "explainable candidate)")
    ep.add_argument("--baseline", type=int, default=None, metavar="RANK",
                    help="second leader rank to diff against (per-family "
                         "deltas + the parallelism changes behind them)")
    ep.add_argument("--top-k", type=int, default=5,
                    help="how many analytical leaders to consider")
    ep.add_argument("--json", action="store_true")
    ep.set_defaults(func=cmd_explain)

    lp = sub.add_parser("list", help="enumerate models/backends/platforms")
    lp.add_argument("what", nargs="?", default="all",
                    choices=["models", "backends", "platforms", "all"])
    lp.add_argument("--json", action="store_true")
    lp.set_defaults(func=cmd_list)

    ob = sub.add_parser(
        "obs", help="observability artifacts: export | diff | bench")
    obsub = ob.add_subparsers(dest="action")

    oe = obsub.add_parser(
        "export", help="re-encode a saved TraceArtifact (Chrome "
                       "trace_event JSON or canonical JSONL)")
    oe.add_argument("--trace", required=True,
                    help="TraceArtifact JSONL (from --trace-out)")
    oe.add_argument("--format", default="chrome",
                    choices=["chrome", "jsonl"],
                    help="chrome: trace_event JSON for chrome://tracing "
                         "and Perfetto; jsonl: the canonical artifact")
    oe.add_argument("--out", default="-",
                    help="output path ('-' = stdout)")
    oe.set_defaults(func=cmd_obs_export)

    od = obsub.add_parser(
        "diff", help="diff two telemetry snapshots: counter/gauge "
                     "deltas, per-histogram distribution shifts, the "
                     "SLO-attainment delta (exit 1 when they differ)")
    od.add_argument("a", help="baseline: metrics snapshot JSON, a "
                              "SearchReport with telemetry, or a replay "
                              "histogram section")
    od.add_argument("b", help="comparison snapshot (same shapes)")
    od.add_argument("--json", action="store_true")
    od.set_defaults(func=cmd_obs_diff)

    obb = obsub.add_parser(
        "bench", help="performance-regression sentinel: "
                      "run | compare | gate | trend")
    bsub = obb.add_subparsers(dest="bench_action")

    br = bsub.add_parser(
        "run", help="run the benchmark suite and emit a versioned "
                    "BenchArtifact (work counters, phase breakdown, "
                    "repeat timings, environment fingerprint)")
    br.add_argument("--quick", action="store_true",
                    help="CI-sized variants of every benchmark")
    br.add_argument("--only", default="",
                    help="comma-separated substrings of benchmark names")
    br.add_argument("--repeat", type=int, default=1,
                    help="timing repeats per benchmark")
    br.add_argument("--out", default="",
                    help="artifact path (default results/bench_<suite>"
                         ".json)")
    br.add_argument("--history", default=None, metavar="JSONL",
                    help="history file to append ('' disables; default "
                         "results/bench_history.jsonl)")
    br.add_argument("--timestamp", default="",
                    help="created_at override for deterministic artifacts")
    br.set_defaults(func=cmd_obs_bench_run)

    bc = bsub.add_parser(
        "compare", help="strict determinism check between two suite "
                        "runs (exit 0 identical work, 1 drift, 2 "
                        "mismatched environments)")
    bc.add_argument("a", help="first BenchArtifact JSON")
    bc.add_argument("b", help="second BenchArtifact JSON")
    bc.add_argument("--json", action="store_true")
    bc.set_defaults(func=cmd_obs_bench_compare)

    bg = bsub.add_parser(
        "gate", help="two-tier regression gate vs a baseline artifact: "
                     "hard (exact work counters) + soft (min-of-k "
                     "wallclock under tolerance); exit 1 on violation")
    bg.add_argument("--baseline", required=True,
                    help="baseline BenchArtifact (e.g. "
                         "results/baselines/bench_quick.json)")
    bg.add_argument("--current", required=True,
                    help="current-run BenchArtifact")
    bg.add_argument("--rel-tol", type=float, default=None,
                    help="soft-gate relative tolerance (default 0.5)")
    bg.add_argument("--abs-tol-us", type=float, default=None,
                    help="soft-gate absolute slack in us (default 5000)")
    bg.add_argument("--hard-only", action="store_true",
                    help="skip the wallclock tier (deterministic "
                         "cross-machine gating)")
    bg.add_argument("--json", action="store_true")
    bg.set_defaults(func=cmd_obs_bench_gate)

    bt = bsub.add_parser(
        "trend", help="summarize the append-only bench history "
                      "(wallclock trajectory + work-digest changes)")
    bt.add_argument("--history", default="results/bench_history.jsonl")
    bt.add_argument("--suite", default="",
                    help="filter to one suite (quick | full)")
    bt.add_argument("--json", action="store_true")
    bt.set_defaults(func=cmd_obs_bench_trend)
    return ap


def _legacy_argv_to_search(argv) -> list:
    """Deprecation shim: flat-flag invocation -> `search` subcommand argv."""
    print("deprecated: flat-flag invocation; use "
          "`python -m repro.core.cli search ...` (same flags)",
          file=sys.stderr)
    return ["search"] + list(argv)


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] not in _SUBCOMMANDS \
            and not argv[0] in ("-h", "--help"):
        argv = _legacy_argv_to_search(argv)
    ap = _build_parser()
    args = ap.parse_args(argv)
    if getattr(args, "func", None) is None:
        ap.print_help()
        return EXIT_USAGE
    try:
        return args.func(args)
    except (ValueError, OSError, KeyError) as e:
        # bad inputs (validation, unreadable/corrupt report files,
        # unregistered backends referenced by a loaded report) -> 2
        msg = e.args[0] if isinstance(e, KeyError) and e.args else e
        print(f"error: {msg}", file=sys.stderr)
        return EXIT_USAGE


if __name__ == "__main__":
    sys.exit(main())
