"""Power-law expert-load correction (§4.4.1, eq. 3–4).

Step 1: sample per-expert load weights from a bounded power law by inverse
transform sampling; normalize to integer token counts.
Step 2: build a synthetic router assignment matrix that deterministically
routes exactly N_i tokens to expert i (bypassing the learned router), so a
benchmark executes the precise workload shape — and the model captures the
tail latency of the hottest expert.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np

DEFAULT_ALPHA = 1.2       # matches Qwen3-235B observations in the paper
X_MIN, X_MAX = 1.0, 100.0


def sample_weights(num_experts: int, alpha: float,
                   rng: np.random.Generator,
                   x_min: float = X_MIN, x_max: float = X_MAX) -> np.ndarray:
    """Eq. 3: x_i = [ (x_max^{1-a} - x_min^{1-a}) U + x_min^{1-a} ]^{1/(1-a)}."""
    u = rng.uniform(0.0, 1.0, size=num_experts)
    if abs(alpha - 1.0) < 1e-9:
        # limit case: log-uniform
        return np.exp(np.log(x_min) + u * (np.log(x_max) - np.log(x_min)))
    e = 1.0 - alpha
    return (u * (x_max ** e - x_min ** e) + x_min ** e) ** (1.0 / e)


def token_counts(total_tokens: int, top_k: int, num_experts: int,
                 alpha: float, seed: int = 0,
                 rng: Optional[np.random.Generator] = None) -> np.ndarray:
    """Eq. 4: N_i = round(x_i / Σx_j * T_total * K), residuals rebalanced."""
    rng = rng or np.random.default_rng(seed)
    x = sample_weights(num_experts, alpha, rng)
    target = total_tokens * top_k
    n = np.round(x / x.sum() * target).astype(np.int64)
    # distribute rounding residue to keep Σ N_i == T_total * K exactly
    resid = int(target - n.sum())
    order = np.argsort(-x)
    i = 0
    while resid != 0:
        j = order[i % num_experts]
        step = 1 if resid > 0 else -1
        if n[j] + step >= 0:
            n[j] += step
            resid -= step
        i += 1
    return n


def assignment_matrix(total_tokens: int, counts: np.ndarray) -> np.ndarray:
    """Step 2: deterministic one-hot-ish routing matrix L (T_total x E) with
    exactly counts[e] tokens assigned to expert e (column sums == counts).
    Tokens are striped round-robin so every token gets sum(counts)/T slots."""
    E = len(counts)
    L = np.zeros((total_tokens, E), dtype=np.int32)
    tok = 0
    for e in np.argsort(-counts):
        for _ in range(int(counts[e])):
            L[tok % total_tokens, e] += 1
            tok += 1
    return L


@functools.lru_cache(maxsize=65536)
def hot_rank_tokens(total_tokens: int, top_k: int, num_experts: int,
                    ep: int, alpha: float, seed: int = 0) -> int:
    """Expected token count on the hottest EP rank under round-robin expert
    placement — the quantity the MoE operator's latency follows.

    Fully deterministic in its arguments (seeded rng), so the result is
    memoized: candidate sweeps and the batch encoder ask for the same
    (tokens, ep) points thousands of times."""
    counts = token_counts(total_tokens, top_k, num_experts, alpha, seed)
    if ep <= 1:
        return int(counts.sum())
    # contiguous expert->rank placement; expert identities are exchangeable
    # under iid sampling, so this is an unbiased placement draw
    pad = (-len(counts)) % ep
    padded = np.concatenate([counts, np.zeros(pad, counts.dtype)])
    per_rank = padded.reshape(ep, -1).sum(axis=1)
    return int(per_rank.max())
