"""Configurator input/output types: workload descriptor, SLA, cluster spec,
parallelism and serving-candidate configs (the search space elements)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class SLA:
    ttft_ms: float = 1000.0
    tpot_ms: Optional[float] = None          # cap on TPOT, or
    min_tokens_per_s_user: Optional[float] = None  # floor on 1000/TPOT

    def tpot_limit_ms(self) -> float:
        lims = []
        if self.tpot_ms is not None:
            lims.append(self.tpot_ms)
        if self.min_tokens_per_s_user:
            lims.append(1000.0 / self.min_tokens_per_s_user)
        return min(lims) if lims else float("inf")


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    n_chips: int = 8
    chips_per_host: int = 8                  # TP kept within a host/pod axis
    platform: str = "tpu_v5e"

    def valid_instance_sizes(self) -> List[int]:
        out = []
        g = 1
        while g <= self.n_chips:
            out.append(g)
            g *= 2
        return out


@dataclasses.dataclass(frozen=True)
class WorkloadDescriptor:
    """User-supplied description of the serving problem (§4.1 TaskRunner)."""
    model: str                               # arch id from repro.configs
    isl: int
    osl: int
    sla: SLA
    cluster: ClusterSpec
    backend: str = "repro-jax"               # repro-jax | trtllm | vllm | sglang
    prefix_len: int = 0                      # cached prefix (Alg. 1 "P")
    modes: Tuple[str, ...] = ("aggregated", "disaggregated")
    moe_alpha: float = 1.2                   # expert-load power-law skew
    dtype: str = "bf16"


@dataclasses.dataclass(frozen=True)
class ParallelismConfig:
    tp: int = 1
    pp: int = 1
    ep: int = 1                              # divides tp for MoE layers
    dp: int = 1                              # replicas of this instance

    @property
    def chips_per_instance(self) -> int:
        return self.tp * self.pp

    @property
    def chips(self) -> int:
        return self.tp * self.pp * self.dp

    def describe(self) -> str:
        parts = [f"TP{self.tp}"]
        if self.pp > 1:
            parts.append(f"PP{self.pp}")
        if self.ep > 1:
            parts.append(f"EP{self.ep}")
        if self.dp > 1:
            parts = [f"{self.dp}x"] + parts
        return "".join(parts) if len(parts) == 1 else parts[0] + "".join(parts[1:])


@dataclasses.dataclass(frozen=True)
class RuntimeFlags:
    """Framework runtime knobs the Generator resolves (§1, §4.1)."""
    max_num_tokens: int = 8192               # per-iteration context capacity
    kv_cache_mem_fraction: float = 0.90
    enable_chunked_context: bool = True
    enable_graph_capture: bool = True        # CUDA-graph / fixed-shape decode


@dataclasses.dataclass(frozen=True)
class CandidateConfig:
    """One point in the search space (aggregated/static) or one pool side
    of a disaggregated deployment."""
    parallel: ParallelismConfig
    batch_size: int
    flags: RuntimeFlags = dataclasses.field(default_factory=RuntimeFlags)

    def describe(self) -> str:
        return f"{self.parallel.describe()} b{self.batch_size}"


@dataclasses.dataclass
class Projection:
    """InferenceSession output for one candidate."""
    ttft_ms: float
    tpot_ms: float
    tokens_per_s_user: float
    tokens_per_s_per_chip: float
    chips: int
    batch_size: int
    mode: str
    config: Dict
    mem_bytes_per_chip: float = 0.0
    notes: str = ""

    def meets(self, sla: SLA) -> bool:
        if self.ttft_ms > sla.ttft_ms:
            return False
        return self.tpot_ms <= sla.tpot_limit_ms()


@dataclasses.dataclass(frozen=True)
class DisaggConfig:
    """(x)P(y)D composite server."""
    prefill: CandidateConfig
    decode: CandidateConfig
    x: int                                   # prefill worker count
    y: int                                   # decode worker count

    @property
    def chips(self) -> int:
        return (self.x * self.prefill.parallel.chips_per_instance
                + self.y * self.decode.parallel.chips_per_instance)

    def describe(self) -> str:
        return (f"{self.x}P({self.prefill.describe()})"
                f"{self.y}D({self.decode.describe()})")
