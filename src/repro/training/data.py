"""Synthetic token data pipeline.

Deterministic, seekable, host-side batch iterator with double-buffer
prefetch — the structure a real pipeline needs (sharding-aware global
batch assembly), with a synthetic source (hashed-position tokens with a
Zipfian marginal, so the loss curve is non-trivial).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    zipf_alpha: float = 1.1


class SyntheticDataset:
    """Deterministic synthetic LM dataset: batch(step) is pure in (seed, step)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # Zipf-ish unigram distribution over the vocab.
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = ranks ** -cfg.zipf_alpha
        self._probs = probs / probs.sum()

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.cfg.seed << 20) ^ step)
        shape = (self.cfg.global_batch, self.cfg.seq_len + 1)
        toks = rng.choice(self.cfg.vocab_size, size=shape, p=self._probs)
        toks = toks.astype(np.int32)
        # Inject local structure: every 8th token repeats its predecessor,
        # giving the model something learnable beyond unigram stats.
        toks[:, 8::8] = toks[:, 7::8][:, : toks[:, 8::8].shape[1]]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class Prefetcher:
    """One-thread-ahead host prefetch."""

    def __init__(self, dataset: SyntheticDataset, start_step: int = 0, depth: int = 2):
        self._ds = dataset
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put(self._ds.batch(step), timeout=0.1)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=1.0)


def make_dataset(cfg: ModelConfig, seq_len: int, global_batch: int,
                 seed: int = 0) -> SyntheticDataset:
    return SyntheticDataset(DataConfig(seq_len=seq_len, global_batch=global_batch,
                                       vocab_size=cfg.vocab_size, seed=seed))
