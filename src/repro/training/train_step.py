"""Training step: CE loss (+ MoE aux), grad, AdamW update.

``make_train_step`` returns the function the dry-run lowers for the
``train_4k`` shape and the trainer jits for real CPU smoke runs.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import models
from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.training import optimizer as opt

AUX_LOSS_COEF = 0.01


def loss_fn(params: Any, cfg: ModelConfig, tokens: jax.Array,
            labels: jax.Array, extras: Dict[str, Any]) -> Tuple[jax.Array, Dict]:
    hidden, aux = models.forward_train(params, cfg, tokens, **extras)
    chunk = cfg.sharding.loss_chunk
    if cfg.family == "audio":
        from repro.models import encdec
        logits = encdec._final_logits(params, cfg, hidden)
        ce = cm.cross_entropy(logits, labels)
    elif chunk:
        ce = cm.chunked_loss(params["embed"], hidden, labels, cfg, chunk)
    else:
        logits = cm.lm_logits(params["embed"], hidden, cfg)
        ce = cm.cross_entropy(logits, labels)
    total = ce + AUX_LOSS_COEF * aux
    return total, {"ce": ce, "aux": aux}


def make_train_step(cfg: ModelConfig, opt_cfg: Optional[opt.AdamWConfig] = None):
    opt_cfg = opt_cfg or opt.AdamWConfig()
    n_mb = max(cfg.sharding.microbatches, 1)

    def train_step(params, opt_state, tokens, labels, **extras):
        if n_mb == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, cfg, tokens, labels, extras)
        else:
            # gradient accumulation: scan microbatches, fp32 accumulators
            B = tokens.shape[0]
            assert B % n_mb == 0, (B, n_mb)
            tk = tokens.reshape(n_mb, B // n_mb, -1)
            lb = labels.reshape(n_mb, B // n_mb, -1)
            # modality extras split along their batch axis
            ex_axis = {"frames": 0, "image_embeds": 0, "mrope_positions": 1}
            ex_split = {}
            for k, v in extras.items():
                if v is None:
                    continue
                ax = ex_axis[k]
                shape = v.shape[:ax] + (n_mb, B // n_mb) + v.shape[ax + 1:]
                ex_split[k] = jnp.moveaxis(v.reshape(shape), ax, 0)

            def mb(acc, inp):
                g_acc, l_acc = acc
                t, l, ex = inp
                (loss_i, _), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, cfg, t, l, ex)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / n_mb, g_acc, g)
                return (g_acc, l_acc + loss_i / n_mb), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                mb, (zeros, jnp.float32(0.0)), (tk, lb, ex_split))
            metrics = {"ce": loss, "aux": jnp.float32(0.0)}
        params, opt_state, opt_metrics = opt.update(opt_cfg, grads, opt_state, params)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step
