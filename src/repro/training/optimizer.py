"""AdamW in pure JAX (pytree-structured, fp32 moments)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


class OptState(NamedTuple):
    mu: Any
    nu: Any
    step: jax.Array


def init(params: Any) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros),
                    step=jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def update(cfg: AdamWConfig, grads: Any, state: OptState,
           params: Any) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh, vh = m / bc1, v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        np_, nm, nv = upd(p, g, m, v)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return (jax.tree.unflatten(treedef, new_p),
            OptState(mu=jax.tree.unflatten(treedef, new_m),
                     nu=jax.tree.unflatten(treedef, new_v),
                     step=step),
            metrics)
