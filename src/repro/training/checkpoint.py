"""Flat-npz checkpointing for params + optimizer state.

Trees are flattened with '/'-joined key paths; restore rebuilds against a
reference tree (shape- and dtype-checked), so a checkpoint can never be
silently loaded into the wrong architecture.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_seg(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _seg(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(path: str, params: Any, opt_state: Any = None, step: int = 0) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    blob = {"__step": np.int64(step)}
    for k, v in _flatten(params).items():
        blob["p/" + k] = v
    if opt_state is not None:
        for k, v in _flatten(opt_state).items():
            blob["o/" + k] = v
    tmp = path + ".tmp"
    np.savez(tmp, **blob)
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)


def restore(path: str, params_like: Any,
            opt_like: Any = None) -> Tuple[Any, Optional[Any], int]:
    with np.load(path, allow_pickle=False) as blob:
        step = int(blob["__step"])

        def rebuild(like: Any, prefix: str) -> Any:
            flat, treedef = jax.tree_util.tree_flatten_with_path(like)
            leaves = []
            for path_, leaf in flat:
                key = prefix + "/".join(_seg(p) for p in path_)
                arr = blob[key]
                if arr.shape != leaf.shape:
                    raise ValueError(
                        f"checkpoint mismatch at {key}: {arr.shape} vs {leaf.shape}")
                leaves.append(arr.astype(leaf.dtype))
            return jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(like), leaves)

        p = rebuild(params_like, "p/")
        o = rebuild(opt_like, "o/") if opt_like is not None else None
    return p, o, step
