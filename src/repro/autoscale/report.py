"""Autoscale-vs-static comparison: the SearchReport v5 ``autoscale``
section.

:func:`build_autoscale_section` runs both sides on the *same* trace,
SLO, and memoized perf session: the static baseline is the cheapest
attaining deployment from :func:`~repro.capacity.planner.plan_min_chips`
(billed for its full chip count over the replay makespan), the dynamic
side is an :class:`~repro.autoscale.simulator.AutoscaleSimulator` run
starting from the static plan's replica count (so the comparison
isolates the *policy*, not the starting size).  The section records
both cost views plus the savings — the number the ROADMAP's reactive
autoscaling item asks for: chip-seconds saved while holding SLO
attainment.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

from repro.capacity.planner import (DEFAULT_ATTAIN_TARGET, plan_min_chips)
from repro.workloads.slo import SLOSpec
from repro.workloads.trace import WorkloadTrace

from repro.autoscale.policy import AutoscalerPolicy
from repro.autoscale.simulator import AutoscaleReport

#: Autoscale sections written by :func:`build_autoscale_section`.
AUTOSCALE_SCHEMA_VERSION = 1


def _static_cost(section: Dict) -> Optional[Dict]:
    """The baseline cost view from a capacity sweep section: the
    attaining rung's chips × its replay makespan."""
    plan = section["plan"]
    if not plan["attained"]:
        return None
    rung = next(r for r in section["rungs"]
                if r["pruned"] is None and r["attains"]
                and r["total_chips"] == plan["total_chips"])
    m = rung["metrics"]
    return {
        "deployment": plan["deployment"],
        "total_chips": plan["total_chips"],
        "duration_s": m["duration_s"],
        "chip_seconds": plan["total_chips"] * m["duration_s"],
        "slo_attainment": m["slo_attainment"],
        "goodput_tok_s": m["goodput_tok_s"],
        "truncated": m["truncated"],
    }


def build_autoscale_section(runner, candidate, trace: WorkloadTrace,
                            slo: SLOSpec, policy: AutoscalerPolicy,
                            ladder: Sequence[int] = (1, 2, 4),
                            routing: str = "round_robin",
                            attain_target: float = DEFAULT_ATTAIN_TARGET,
                            tick_s: float = 1.0,
                            cold_start_s: float = 5.0,
                            initial_replicas: Optional[int] = None,
                            max_steps: int = 200_000,
                            priority_admission: bool = True,
                            max_queue: int = 100_000
                            ) -> Tuple[Dict, AutoscaleReport]:
    """Run the static plan and the autoscaled replay side by side.

    ``runner`` is a :class:`~repro.core.task_runner.TaskRunner` (both
    simulators price through its memoized session).  Returns the
    report-ready section dict plus the full :class:`AutoscaleReport`
    (which carries the timeline the section only references by digest).

    ``initial_replicas`` defaults to the static plan's replica count
    when the plan attains (policy-bounds-clamped), else to the policy's
    ``min_replicas`` — the autoscaler starts where the static planner
    would deploy and earns its savings by riding the load curve down.
    """
    plan = plan_min_chips(
        runner, [candidate], trace, slo, ladder=ladder, routing=routing,
        attain_target=attain_target, max_steps=max_steps,
        priority_admission=priority_admission, max_queue=max_queue)
    static = _static_cost(plan.section)

    if initial_replicas is None:
        if plan.attained:
            initial_replicas = max(policy.min_replicas,
                                   min(policy.max_replicas,
                                       plan.deployment.replicas))
        else:
            initial_replicas = policy.min_replicas
    sim = runner.autoscale_simulator(
        candidate, policy, routing=routing,
        initial_replicas=initial_replicas, tick_s=tick_s,
        cold_start_s=cold_start_s, priority_admission=priority_admission,
        max_queue=max_queue)
    run = sim.run(trace, slo=slo, max_steps=max_steps)

    savings = None
    if static is not None:
        saved = static["chip_seconds"] - run.chip_seconds
        savings = {
            "chip_seconds": saved,
            "chip_seconds_pct": (100.0 * saved / static["chip_seconds"]
                                 if static["chip_seconds"] > 0 else 0.0),
            "holds_attainment": (run.metrics.slo_attainment or 0.0)
            >= attain_target,
        }
    section = {
        "schema_version": AUTOSCALE_SCHEMA_VERSION,
        "trace": {"digest": trace.digest(),
                  "n_requests": trace.n_requests,
                  "duration_s": trace.duration_s,
                  "tenants": trace.tenants,
                  "meta": trace.meta},
        "slo": slo.to_dict(),
        "routing": routing,
        "attain_target": attain_target,
        "ladder": list(ladder),
        "tick_s": tick_s,
        "cold_start_s": cold_start_s,
        "policy": policy.to_dict(),
        "database": runner.session.db.fingerprint(),
        "static": static,
        "run": run.to_dict(),
        "savings": savings,
    }
    section["run"]["metrics"]["histograms"] = run.metrics.histograms
    return section, run
