"""The autoscale control loop: a cluster replay that resizes itself.

:class:`AutoscaleSimulator` replays a workload trace exactly like
:class:`~repro.capacity.cluster.ClusterSimulator` — same per-replica
engines, same routing policies, same shared ``run_iteration`` step body
— but between arrivals it pauses at fixed tick boundaries to sample a
:class:`~repro.autoscale.timeline.TimelineRecorder` and evaluate an
:class:`~repro.autoscale.policy.AutoscalerPolicy` on the rolling
window:

- **Scale-up** spawns new replicas with a modeled cold start: a replica
  pays for its chips from the spawn tick but only becomes
  route-eligible once ``cold_start_s`` has elapsed.
- **Scale-down** drains before removal: the youngest non-draining
  replicas stop receiving traffic immediately but keep executing their
  outstanding work; a draining replica is retired (and stops billing)
  at the first tick where it sits empty.
- Cooldowns are asymmetric and enforced here (not in the policy):
  scale-ups and scale-downs each wait out their own cooldown clock.

Under the ``static`` policy the loop provably degenerates to
``ClusterSimulator.replay``: ticks advance engines *without* idle-clock
jumps, so they execute exactly the iterations the plain replay would,
and the aggregate metrics come out identical (the equivalence test in
``tests/test_autoscale.py`` asserts field-for-field equality).

The result object — :class:`AutoscaleReport` — carries the cost view
(chip-seconds, peak/mean replicas, the scaling-event log), the same
:class:`~repro.capacity.cluster.ClusterReplayMetrics` surface as a
static replay, and the full :class:`ClusterTimeline` artifact.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.capacity.cluster import ReplicaEngine, aggregate_cluster_metrics
from repro.capacity.routing import ROUTING_POLICIES, get_router
from repro.obs.flight import emit_engine_request_spans
from repro.obs.metrics import get_metrics
from repro.obs.trace import get_tracer
from repro.serving.scheduler import SchedulerConfig

from repro.autoscale.policy import AutoscalerPolicy
from repro.autoscale.timeline import ClusterTimeline, TimelineRecorder


class ScalableReplicaEngine(ReplicaEngine):
    """A replica engine with a lifecycle: spawn → (warm) → drain → retire."""

    def __init__(self, idx: int, sched_cfg, latency_fn,
                 spawned_at: float = 0.0, warm_at: float = 0.0):
        super().__init__(idx, sched_cfg, latency_fn)
        self.t = spawned_at
        self.spawned_at = spawned_at
        self.warm_at = warm_at            # route-eligible from here on
        self.draining = False
        self.retired_at: Optional[float] = None

    def state(self, t: float) -> str:
        if self.draining:
            return "draining"
        return "cold" if t < self.warm_at else "warm"


@dataclasses.dataclass
class AutoscaleReport:
    """One autoscaled run: cost, scaling history, metrics, timeline."""
    policy: Dict                           # policy.to_dict()
    routing: str
    tick_s: float
    cold_start_s: float
    chips_per_replica: int
    initial_replicas: int
    horizon_s: float                       # final tick (virtual seconds)
    chip_seconds: float                    # sum over replica lifetimes
    peak_replicas: int                     # max provisioned at any tick
    mean_replicas: float                   # time-weighted over the horizon
    n_scale_ups: int
    n_scale_downs: int
    #: scaling-event log: {"t_s", "action": scale_up | scale_down |
    #: retire, ...} — spawn/drain events carry "from"/"to"/"reason",
    #: retire events carry "replica"
    events: List[Dict]
    metrics: "ClusterReplayMetrics"        # noqa: F821 — same class as replay
    timeline: ClusterTimeline

    def to_dict(self, include_timeline: bool = False) -> Dict:
        d = {
            "policy": self.policy,
            "routing": self.routing,
            "tick_s": self.tick_s,
            "cold_start_s": self.cold_start_s,
            "chips_per_replica": self.chips_per_replica,
            "initial_replicas": self.initial_replicas,
            "horizon_s": self.horizon_s,
            "chip_seconds": self.chip_seconds,
            "peak_replicas": self.peak_replicas,
            "mean_replicas": self.mean_replicas,
            "n_scale_ups": self.n_scale_ups,
            "n_scale_downs": self.n_scale_downs,
            "events": self.events,
            "metrics": self.metrics.to_dict(),
            "timeline": {"digest": self.timeline.digest(),
                         "tick_s": self.timeline.tick_s,
                         "n_samples": self.timeline.n_samples},
        }
        if include_timeline:
            d["timeline"]["samples"] = [s.to_dict()
                                        for s in self.timeline.samples]
        return d

    def summary(self) -> str:
        m = self.metrics
        attain = ("" if m.slo_attainment is None
                  else f" at {100 * m.slo_attainment:.1f}% attainment")
        return (f"autoscale [{self.policy['name']}]: "
                f"{self.chip_seconds:.1f} chip-s over "
                f"{self.horizon_s:.1f}s (replicas mean "
                f"{self.mean_replicas:.2f}, peak {self.peak_replicas}; "
                f"{self.n_scale_ups} up / {self.n_scale_downs} down)"
                f"{attain}")


class AutoscaleSimulator:
    """Replay a trace while a policy resizes the replica fleet each tick."""

    def __init__(self, sched_cfg: SchedulerConfig,
                 latency_fn: Callable, policy: AutoscalerPolicy,
                 routing: str = "round_robin",
                 initial_replicas: Optional[int] = None,
                 chips_per_replica: int = 1,
                 tick_s: float = 1.0, cold_start_s: float = 5.0):
        if routing not in ROUTING_POLICIES:
            raise ValueError(f"unknown routing policy {routing!r}; valid "
                             f"choices: {', '.join(ROUTING_POLICIES)}")
        if tick_s <= 0:
            raise ValueError(f"tick_s must be positive, got {tick_s}")
        if cold_start_s < 0:
            raise ValueError(f"cold_start_s must be >= 0, got "
                             f"{cold_start_s}")
        if chips_per_replica < 1:
            raise ValueError(f"chips_per_replica must be >= 1, got "
                             f"{chips_per_replica}")
        if initial_replicas is None:
            initial_replicas = policy.min_replicas
        if not policy.min_replicas <= initial_replicas \
                <= policy.max_replicas:
            raise ValueError(
                f"initial_replicas {initial_replicas} outside the policy "
                f"bounds [{policy.min_replicas}, {policy.max_replicas}]")
        self.sched_cfg = sched_cfg
        self.latency_fn = latency_fn
        self.policy = policy
        self.routing = routing
        self.initial_replicas = initial_replicas
        self.chips_per_replica = chips_per_replica
        self.tick_s = tick_s
        self.cold_start_s = cold_start_s

    # ------------------------------------------------------------------
    def _spawn(self, idx: int, t: float, warm_at: float
               ) -> ScalableReplicaEngine:
        return ScalableReplicaEngine(idx, self.sched_cfg, self.latency_fn,
                                     spawned_at=t, warm_at=warm_at)

    def run(self, trace, slo=None, max_steps: int = 200_000
            ) -> AutoscaleReport:
        """Drive the control loop over ``trace``.

        Arrivals are routed exactly as in ``ClusterSimulator.replay``
        (all engines advanced to the arrival instant, idle clocks
        jumping), restricted to *eligible* replicas — warm and not
        draining.  Between arrivals the loop pauses at every tick
        boundary: engines advance to the boundary without idle jumps,
        the timeline is sampled, drained replicas are retired, and the
        policy's desired count is actuated under step/bound/cooldown
        constraints.  After the last arrival the loop keeps ticking
        until the fleet drains (one trailing sample covers the final
        partial window).
        """
        tracer = get_tracer()
        with tracer.span("autoscale.run", policy=self.policy.name,
                         routing=self.routing, tick_s=self.tick_s) as sp:
            report, engines = self._run(trace, slo, max_steps)
            emit_engine_request_spans(tracer, engines, base=sp.v_start)
            tracer.virtual_time = sp.v_start + report.horizon_s
            sp.set(horizon_s=report.horizon_s,
                   peak_replicas=report.peak_replicas,
                   scale_ups=report.n_scale_ups,
                   scale_downs=report.n_scale_downs)
        m = get_metrics()
        if m is not None:
            met = report.metrics
            m.inc("repro_replay_iterations_total", met.steps)
            m.inc("repro_replay_admissions_total",
                  met.n_requests - met.rejected)
            m.inc("repro_replay_rejections_total", met.rejected)
            m.inc("repro_replay_completions_total", met.completed)
            m.inc("repro_autoscale_ticks_total",
                  report.timeline.n_samples)
            m.inc("repro_autoscale_scale_ups_total", report.n_scale_ups)
            m.inc("repro_autoscale_scale_downs_total",
                  report.n_scale_downs)
            m.inc("repro_autoscale_retires_total",
                  sum(1 for e in report.events
                      if e.get("action") == "retire"))
            if met.slo_attainment is not None:
                m.set_gauge("repro_replay_slo_attainment",
                            met.slo_attainment, sim="autoscale")
        return report

    def _run(self, trace, slo, max_steps: int):
        policy = self.policy
        records = list(getattr(trace, "requests", trace))
        router = get_router(self.routing)
        fleet: List[ScalableReplicaEngine] = [
            self._spawn(i, 0.0, warm_at=0.0)
            for i in range(self.initial_replicas)]
        retired: List[ScalableReplicaEngine] = []
        recorder = TimelineRecorder(self.tick_s, slo=slo)
        events: List[Dict] = []
        n_ups = n_downs = 0
        last_up = last_down = float("-inf")
        next_idx = self.initial_replicas
        peak = self.initial_replicas
        budget = max_steps
        k = 0                              # completed ticks
        i = 0                              # next trace record

        def eligible_at(t: float) -> List[ScalableReplicaEngine]:
            ready = [e for e in fleet
                     if not e.draining and e.warm_at <= t]
            if ready:
                return ready
            # every non-draining replica is still cold (or the fleet is
            # all-draining, which the min-replicas floor prevents):
            # fall back rather than dropping the request
            return [e for e in fleet if not e.draining] or fleet

        while budget > 0:
            boundary = (k + 1) * self.tick_s
            if i < len(records) and records[i].arrival_s <= boundary:
                rec = records[i]
                for eng in fleet:
                    budget -= eng.advance_to(rec.arrival_s, budget)
                pool = eligible_at(rec.arrival_s)
                target = router.select(pool, rec, i)
                pool[target].admit(rec, rid=i)
                i += 1
                continue

            # -- tick boundary: advance (no idle jumps), sample, actuate
            budget_before = budget
            for eng in fleet:
                budget -= eng.advance_to(boundary, budget, jump_idle=False)
            busy = [e for e in fleet if e.outstanding > 0]
            if budget == budget_before and i >= len(records) and busy \
                    and all(e.t < boundary for e in busy):
                # no arrivals left, and every engine holding work sat
                # below the boundary yet executed nothing (a scheduler
                # that refuses to plan): it will never step again, so
                # don't tick forever — leftover work counts as
                # unfinished, exactly as in the plain replay
                break
            k += 1
            t = k * self.tick_s
            recorder.on_tick(t, fleet,
                             states=[e.state(t) for e in fleet])
            for eng in [e for e in fleet
                        if e.draining and e.outstanding == 0]:
                eng.retired_at = t
                retired.append(eng)
                fleet.remove(eng)
                events.append({"t_s": t, "action": "retire",
                               "replica": eng.idx})
            if i >= len(records) \
                    and not any(e.outstanding > 0 for e in fleet) \
                    and not any(e.draining for e in fleet):
                break

            provisioned = sum(1 for e in fleet if not e.draining)
            window = recorder.window(policy.window_s)
            desired, reason = policy.desired_replicas(window, provisioned)
            desired = max(policy.min_replicas,
                          min(policy.max_replicas, desired))
            delta = desired - provisioned
            if delta > 0 and t - last_up >= policy.up_cooldown_s:
                delta = min(delta, policy.scale_up_step)
                for _ in range(delta):
                    fleet.append(self._spawn(
                        next_idx, t, warm_at=t + self.cold_start_s))
                    next_idx += 1
                last_up = t
                n_ups += 1
                events.append({"t_s": t, "action": "scale_up",
                               "from": provisioned,
                               "to": provisioned + delta,
                               "reason": reason})
                peak = max(peak, len(fleet))
            elif delta < 0 and t - last_down >= policy.down_cooldown_s:
                delta = max(delta, -policy.scale_down_step)
                victims = sorted((e for e in fleet if not e.draining),
                                 key=lambda e: e.idx,
                                 reverse=True)[:-delta]
                for eng in victims:
                    eng.draining = True
                last_down = t
                n_downs += 1
                events.append({"t_s": t, "action": "scale_down",
                               "from": provisioned,
                               "to": provisioned + delta,
                               "reason": reason,
                               "draining": [e.idx for e in victims]})

        horizon = k * self.tick_s
        all_engines = sorted(fleet + retired, key=lambda e: e.idx)
        routed = sum(e.routed for e in all_engines)
        truncated = budget <= 0 and (
            routed < len(records)
            or any(e.outstanding > 0 for e in all_engines))
        metrics = aggregate_cluster_metrics(
            all_engines, n_requests=len(records), routing=self.routing,
            replicas=len(all_engines), truncated=truncated, slo=slo,
            sim="autoscale")
        chip_seconds = self.chips_per_replica * sum(
            (e.retired_at if e.retired_at is not None else horizon)
            - e.spawned_at
            for e in all_engines)
        mean_replicas = (chip_seconds / self.chips_per_replica / horizon
                         if horizon > 0 else float(self.initial_replicas))
        report = AutoscaleReport(
            policy=policy.to_dict(),
            routing=self.routing,
            tick_s=self.tick_s,
            cold_start_s=self.cold_start_s,
            chips_per_replica=self.chips_per_replica,
            initial_replicas=self.initial_replicas,
            horizon_s=horizon,
            chip_seconds=chip_seconds,
            peak_replicas=peak,
            mean_replicas=mean_replicas,
            n_scale_ups=n_ups,
            n_scale_downs=n_downs,
            events=events,
            metrics=metrics,
            timeline=recorder.timeline(meta={
                "policy": policy.to_dict(),
                "routing": self.routing,
                "cold_start_s": self.cold_start_s,
                "initial_replicas": self.initial_replicas,
            }),
        )
        return report, all_engines
