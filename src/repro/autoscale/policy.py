"""Autoscaler policies: how many replicas the fleet *should* have.

A policy looks at the rolling window of :class:`TimelineSample
<repro.autoscale.timeline.TimelineSample>` records and returns a
*desired* replica count plus a human-readable reason.  The control loop
(:class:`~repro.autoscale.simulator.AutoscaleSimulator`) owns actuation:
it clamps the desired count to ``[min_replicas, max_replicas]``, limits
each move to ``scale_up_step``/``scale_down_step``, and enforces the
asymmetric ``up_cooldown_s``/``down_cooldown_s`` — scaling up is
typically allowed to react fast while scaling down waits out the noise
(the Ray Serve autoscaler shape).

Concrete policies:

``target_queue_depth``
    Proportional control on load: size the fleet so the window-mean
    outstanding work per replica sits at ``target_depth`` (desired =
    ceil(mean outstanding / target_depth)).  Reacts to queue growth
    before the SLO is breached.
``slo_attainment``
    Feedback control on the objective itself: scale up while the
    window's completion-weighted SLO attainment is below
    ``attain_target``, scale down only when attainment holds *and* mean
    utilization is below ``scale_down_util`` (attainment alone cannot
    distinguish "healthy" from "overprovisioned").
``static``
    Never scales — the control-loop identity: an autoscaled run under
    ``static`` reproduces ``ClusterSimulator.replay`` exactly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import ClassVar, Dict, Sequence, Tuple

#: Every policy name :func:`get_policy` accepts.
AUTOSCALER_POLICIES = ("target_queue_depth", "slo_attainment", "static")


@dataclasses.dataclass(frozen=True)
class AutoscalerPolicy:
    """Shared knobs + the ``desired_replicas`` protocol.

    Subclasses implement :meth:`desired_replicas`; the bounds, step
    sizes, and cooldowns declared here are enforced by the control
    loop, not by the policy itself.
    """
    name: ClassVar[str] = "base"
    min_replicas: int = 1
    max_replicas: int = 8
    scale_up_step: int = 1                 # max replicas added per move
    scale_down_step: int = 1               # max replicas drained per move
    up_cooldown_s: float = 5.0             # min gap between scale-ups
    down_cooldown_s: float = 30.0          # min gap between scale-downs
    window_s: float = 10.0                 # rolling evaluation window

    def __post_init__(self):
        if self.min_replicas < 1:
            raise ValueError(f"min_replicas must be >= 1, got "
                             f"{self.min_replicas}")
        if self.max_replicas < self.min_replicas:
            raise ValueError(
                f"max_replicas ({self.max_replicas}) must be >= "
                f"min_replicas ({self.min_replicas})")
        if self.scale_up_step < 1 or self.scale_down_step < 1:
            raise ValueError("scale steps must be >= 1")
        if self.up_cooldown_s < 0 or self.down_cooldown_s < 0:
            raise ValueError("cooldowns must be >= 0")
        if self.window_s <= 0:
            raise ValueError(f"window_s must be positive, got "
                             f"{self.window_s}")

    def desired_replicas(self, window: Sequence,
                         provisioned: int) -> Tuple[int, str]:
        """(desired replica count, reason) for the current window.

        ``window`` is the rolling list of ``TimelineSample`` records
        ending at the current tick; ``provisioned`` counts replicas
        that are neither retired nor draining.
        """
        raise NotImplementedError

    def to_dict(self) -> Dict:
        return {"name": self.name, **dataclasses.asdict(self)}


@dataclasses.dataclass(frozen=True)
class StaticPolicy(AutoscalerPolicy):
    """Never scale: the fleet stays at its initial size."""
    name: ClassVar[str] = "static"

    def desired_replicas(self, window, provisioned):
        return provisioned, "static fleet"


@dataclasses.dataclass(frozen=True)
class TargetQueueDepth(AutoscalerPolicy):
    """Hold window-mean outstanding work per replica at ``target_depth``."""
    name: ClassVar[str] = "target_queue_depth"
    target_depth: float = 4.0

    def __post_init__(self):
        super().__post_init__()
        if self.target_depth <= 0:
            raise ValueError(f"target_depth must be positive, got "
                             f"{self.target_depth}")

    def desired_replicas(self, window, provisioned):
        if not window:
            return provisioned, "no samples yet"
        mean_out = sum(s.outstanding for s in window) / len(window)
        desired = max(1, math.ceil(mean_out / self.target_depth))
        return desired, (f"mean outstanding {mean_out:.1f} over "
                         f"{len(window)} ticks / target "
                         f"{self.target_depth:g} -> {desired}")


@dataclasses.dataclass(frozen=True)
class SLOAttainmentWindow(AutoscalerPolicy):
    """Scale on the objective: up while windowed attainment misses
    ``attain_target``, down only when it holds and the fleet idles."""
    name: ClassVar[str] = "slo_attainment"
    attain_target: float = 0.95
    scale_down_util: float = 0.5           # mean utilization floor

    def __post_init__(self):
        super().__post_init__()
        if not 0.0 < self.attain_target <= 1.0:
            raise ValueError(f"attain_target must be in (0, 1], got "
                             f"{self.attain_target}")
        if not 0.0 <= self.scale_down_util <= 1.0:
            raise ValueError(f"scale_down_util must be in [0, 1], got "
                             f"{self.scale_down_util}")

    def desired_replicas(self, window, provisioned):
        if not window:
            return provisioned, "no samples yet"
        done = sum(s.completed for s in window
                   if s.slo_window_attainment is not None)
        met = sum(s.completed * s.slo_window_attainment for s in window
                  if s.slo_window_attainment is not None)
        util = sum(s.utilization for s in window) / len(window)
        if done > 0:
            attain = met / done
            if attain < self.attain_target:
                return provisioned + self.scale_up_step, (
                    f"window attainment {attain:.2f} < target "
                    f"{self.attain_target:g}")
            if util < self.scale_down_util:
                return provisioned - self.scale_down_step, (
                    f"attainment {attain:.2f} holds, utilization "
                    f"{util:.2f} < {self.scale_down_util:g}")
            return provisioned, (f"attainment {attain:.2f} holds, "
                                 f"utilization {util:.2f}")
        if util < self.scale_down_util:
            return provisioned - self.scale_down_step, (
                f"no completions, utilization {util:.2f} < "
                f"{self.scale_down_util:g}")
        return provisioned, "no completions in window"


_POLICIES: dict = {
    "target_queue_depth": TargetQueueDepth,
    "slo_attainment": SLOAttainmentWindow,
    "static": StaticPolicy,
}


def get_policy(name: str, **overrides) -> AutoscalerPolicy:
    """Instantiate a policy by name (:data:`AUTOSCALER_POLICIES`) with
    field overrides — the CLI's policy factory."""
    try:
        cls = _POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown autoscaler policy {name!r}; valid "
                         f"choices: {', '.join(AUTOSCALER_POLICIES)}") \
            from None
    try:
        return cls(**overrides)
    except TypeError as e:
        raise ValueError(f"bad {name} policy parameters: {e}") from None
