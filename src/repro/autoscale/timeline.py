"""ClusterTimeline: the versioned time-series artifact of a cluster run.

A :class:`ClusterTimeline` is an ordered sequence of
:class:`TimelineSample` records — one per fixed-width tick of virtual
time — each carrying the aggregate cluster view (QPS, queue depth,
outstanding work, active/provisioned replica counts, utilization,
windowed SLO attainment) plus one :class:`ReplicaSample` row per
provisioned replica.  It is Date-free by construction: every timestamp
is virtual seconds since trace start, so two runs of the same seeded
trace serialize byte-identically.

Serialization follows the workload-trace JSONL idiom (one header record
carrying ``schema_version``/``tick_s``/metadata, then one record per
sample; ``ClusterTimeline.from_jsonl(t.to_jsonl()) == t`` is exact and
``digest()`` is a stable content identity) — the timeline file, not the
simulator invocation, is the interchange artifact between ``autoscale
run``, dashboards, and downstream analysis.

:class:`TimelineRecorder` builds the samples live: it subscribes to the
``on_tick`` emission hook of :meth:`ClusterSimulator.replay
<repro.capacity.cluster.ClusterSimulator.replay>` (or is driven
directly by the :class:`~repro.autoscale.simulator.AutoscaleSimulator`
control loop) and differences each engine's cumulative counters into
per-window rates.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, List, Optional, Sequence, Tuple

#: Bump on any backwards-incompatible change to the JSONL layout.
TIMELINE_SCHEMA_VERSION = 1
SUPPORTED_TIMELINE_SCHEMA_VERSIONS = (1,)

#: Lifecycle states a replica can be sampled in.
REPLICA_STATES = ("warm", "cold", "draining")


@dataclasses.dataclass(frozen=True)
class ReplicaSample:
    """One replica's view at one tick (counts are per-window deltas)."""
    replica: int                  # engine index (stable across the run)
    state: str                    # warm | cold | draining
    queue_depth: int              # waiting at the sample instant
    outstanding: int              # waiting + in flight at the instant
    routed: int                   # requests routed to it this window
    completed: int                # requests it finished this window
    gen_tokens: int               # tokens it generated this window
    busy_s: float                 # execution time accrued this window
    utilization: float            # busy_s / tick_s (can exceed 1.0 when
                                  # an iteration overshoots the boundary)

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "ReplicaSample":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class TimelineSample:
    """The aggregate cluster view at one tick boundary."""
    t_s: float                    # virtual seconds since trace start
    qps: float                    # requests routed this window / tick_s
    queue_depth: int              # total waiting across replicas
    outstanding: int              # total waiting + in flight
    active_replicas: int          # route-eligible (warm, not draining)
    provisioned_replicas: int     # all chip-occupying replicas
    utilization: float            # mean per-replica utilization
    completed: int                # requests finished this window
    gen_tokens: int               # tokens generated this window
    throughput_tok_s: float       # gen_tokens / tick_s
    #: fraction of this window's completions meeting the SLO; None when
    #: no SLO was supplied or nothing completed in the window
    slo_window_attainment: Optional[float]
    replicas: Tuple[ReplicaSample, ...]

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["replicas"] = [r.to_dict() for r in self.replicas]
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "TimelineSample":
        kw = dict(d)
        kw["replicas"] = tuple(ReplicaSample.from_dict(r)
                               for r in d["replicas"])
        return cls(**kw)


@dataclasses.dataclass(frozen=True)
class ClusterTimeline:
    """An immutable, serializable cluster-metrics time series."""
    tick_s: float
    samples: Tuple[TimelineSample, ...]
    meta: Dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        object.__setattr__(self, "samples", tuple(self.samples))
        if self.tick_s <= 0:
            raise ValueError(f"tick_s must be positive, got {self.tick_s}")
        prev = 0.0
        for i, s in enumerate(self.samples):
            if s.t_s <= prev and i > 0:
                raise ValueError(
                    f"sample {i}: tick times must be increasing "
                    f"({s.t_s} after {prev})")
            prev = s.t_s

    # -- views ---------------------------------------------------------------
    @property
    def n_samples(self) -> int:
        return len(self.samples)

    @property
    def duration_s(self) -> float:
        return self.samples[-1].t_s if self.samples else 0.0

    def peak_provisioned(self) -> int:
        return max((s.provisioned_replicas for s in self.samples),
                   default=0)

    def window(self, t_s: float, window_s: float) -> List[TimelineSample]:
        """Samples with ``t`` in the half-open window ``(t_s - window_s,
        t_s]`` — the rolling view autoscaler policies evaluate."""
        return [s for s in self.samples
                if t_s - window_s < s.t_s <= t_s]

    # -- serialization -------------------------------------------------------
    def to_jsonl(self) -> str:
        header = {"type": "header",
                  "schema_version": TIMELINE_SCHEMA_VERSION,
                  "tick_s": self.tick_s,
                  "n_samples": self.n_samples,
                  "meta": self.meta}
        lines = [json.dumps(header, sort_keys=True)]
        lines += [json.dumps(s.to_dict(), sort_keys=True)
                  for s in self.samples]
        return "\n".join(lines) + "\n"

    @classmethod
    def from_jsonl(cls, text: str) -> "ClusterTimeline":
        lines = [ln for ln in text.splitlines() if ln.strip()]
        if not lines:
            raise ValueError("empty timeline file (missing header record)")
        header = json.loads(lines[0])
        if header.get("type") != "header":
            raise ValueError("timeline file must start with a header "
                             "record ({'type': 'header', ...})")
        version = header.get("schema_version")
        if version not in SUPPORTED_TIMELINE_SCHEMA_VERSIONS:
            raise ValueError(
                f"unsupported timeline schema_version {version!r}; this "
                f"build reads versions "
                f"{', '.join(map(str, SUPPORTED_TIMELINE_SCHEMA_VERSIONS))}")
        try:
            samples = tuple(TimelineSample.from_dict(json.loads(ln))
                            for ln in lines[1:])
        except (KeyError, TypeError) as e:
            raise ValueError(f"malformed timeline record: {e}") from e
        declared = header.get("n_samples")
        if declared is not None and declared != len(samples):
            raise ValueError(f"timeline header declares {declared} samples "
                             f"but file carries {len(samples)}")
        return cls(tick_s=header["tick_s"], samples=samples,
                   meta=header.get("meta", {}))

    def digest(self) -> str:
        """Stable content identity over the canonical JSONL form."""
        return hashlib.sha256(self.to_jsonl().encode()).hexdigest()[:16]

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_jsonl())
        return path

    @classmethod
    def load(cls, path: str) -> "ClusterTimeline":
        with open(path) as f:
            return cls.from_jsonl(f.read())


class TimelineRecorder:
    """Differences cumulative replica-engine counters into timeline
    samples, one per tick.

    ``on_tick(t, engines[, states])`` matches the emission-hook
    signature of :meth:`ClusterSimulator.replay
    <repro.capacity.cluster.ClusterSimulator.replay>`; ``states`` (one
    of :data:`REPLICA_STATES` per engine, in order) is supplied by the
    autoscale control loop — a static replay's replicas are always
    ``warm``.  Engines retired between ticks simply stop appearing;
    their last partial window is captured because the autoscale loop
    samples *before* retiring drained replicas.
    """

    def __init__(self, tick_s: float, slo=None):
        if tick_s <= 0:
            raise ValueError(f"tick_s must be positive, got {tick_s}")
        self.tick_s = tick_s
        self.slo = slo
        self.samples: List[TimelineSample] = []
        # cumulative (routed, done_idx, gen_tokens, busy_s) per engine idx
        self._seen: Dict[int, Tuple[int, int, int, float]] = {}

    def on_tick(self, t: float, engines: Sequence,
                states: Optional[Sequence[str]] = None) -> None:
        if states is None:
            states = ["warm"] * len(engines)
        rows: List[ReplicaSample] = []
        met_win = 0
        done_win = 0
        for eng, state in zip(engines, states):
            routed0, done0, gen0, busy0 = self._seen.get(
                eng.idx, (0, 0, 0, 0.0))
            finished = eng.done[done0:]
            completed = sum(1 for r in finished if r.ttft is not None)
            if self.slo is not None:
                done_win += completed
                met_win += sum(1 for r in finished
                               if r.ttft is not None
                               and self.slo.request_meets(r.ttft, r.tpot))
            busy_delta = eng.busy_s - busy0
            rows.append(ReplicaSample(
                replica=eng.idx,
                state=state,
                queue_depth=len(eng.sched.waiting),
                outstanding=eng.outstanding,
                routed=eng.routed - routed0,
                completed=completed,
                gen_tokens=eng.gen_tokens - gen0,
                busy_s=busy_delta,
                utilization=busy_delta / self.tick_s,
            ))
            self._seen[eng.idx] = (eng.routed, len(eng.done),
                                   eng.gen_tokens, eng.busy_s)
        gen_win = sum(r.gen_tokens for r in rows)
        n = len(rows)
        self.samples.append(TimelineSample(
            t_s=t,
            qps=sum(r.routed for r in rows) / self.tick_s,
            queue_depth=sum(r.queue_depth for r in rows),
            outstanding=sum(r.outstanding for r in rows),
            active_replicas=sum(1 for r in rows if r.state == "warm"),
            provisioned_replicas=n,
            utilization=(sum(r.utilization for r in rows) / n) if n else 0.0,
            completed=sum(r.completed for r in rows),
            gen_tokens=gen_win,
            throughput_tok_s=gen_win / self.tick_s,
            slo_window_attainment=(met_win / done_win
                                   if self.slo is not None and done_win
                                   else None),
            replicas=tuple(rows),
        ))

    def window(self, window_s: float) -> List[TimelineSample]:
        """The rolling window ending at the latest sample."""
        if not self.samples:
            return []
        t = self.samples[-1].t_s
        return [s for s in self.samples if t - window_s < s.t_s <= t]

    def timeline(self, meta: Optional[Dict] = None) -> ClusterTimeline:
        return ClusterTimeline(tick_s=self.tick_s,
                               samples=tuple(self.samples),
                               meta=meta or {})
