"""repro.autoscale — reactive autoscaling over the cluster simulator.

``repro.capacity`` (PR 5) sizes a *static* fleet for a whole trace;
production fleets ride the load curve.  This package closes that gap
with a tick-driven control loop on top of the same per-replica engines:

- :mod:`~repro.autoscale.timeline` — :class:`ClusterTimeline`: a
  versioned, Date-free, JSONL-serializable time series of per-replica
  and aggregate cluster metrics (QPS, queue depth, outstanding work,
  utilization, active replicas, windowed SLO attainment), sampled on a
  fixed tick by :class:`TimelineRecorder` through the ``on_tick``
  emission hook of ``ClusterSimulator.replay`` or the autoscale loop.
- :mod:`~repro.autoscale.policy` — the :class:`AutoscalerPolicy`
  protocol plus concrete policies (``target_queue_depth``,
  ``slo_attainment``, ``static``) with scale-step sizes, min/max
  replica bounds, and asymmetric up/down cooldowns.
- :mod:`~repro.autoscale.simulator` — :class:`AutoscaleSimulator`:
  evaluates the policy each tick against the rolling window, spawns
  replicas with modeled cold start (route-eligible only after
  ``cold_start_s``), drains before removal, and reports
  :class:`AutoscaleReport` — chip-seconds, peak/mean replicas, the
  scaling-event log, and the familiar cluster replay metrics.
- :mod:`~repro.autoscale.report` — :func:`build_autoscale_section`:
  the static ``plan_min_chips`` baseline and the autoscaled run on the
  same trace, folded into the SearchReport schema-v5 ``autoscale``
  section.

Canonical flow::

    from repro.autoscale import TargetQueueDepth
    from repro.workloads import SLOSpec

    report = cfg.autoscale("trace.jsonl",
                           SLOSpec(ttft_p99_ms=2000, tpot_p99_ms=100),
                           policy=TargetQueueDepth(max_replicas=4))
    report.autoscale["savings"]      # chip-seconds vs the static plan

CLI: ``python -m repro.core.cli autoscale run|compare``
(docs/autoscale.md).
"""
from repro.autoscale.policy import (AUTOSCALER_POLICIES, AutoscalerPolicy,
                                    SLOAttainmentWindow, StaticPolicy,
                                    TargetQueueDepth, get_policy)
from repro.autoscale.report import (AUTOSCALE_SCHEMA_VERSION,
                                    build_autoscale_section)
from repro.autoscale.simulator import (AutoscaleReport, AutoscaleSimulator,
                                       ScalableReplicaEngine)
from repro.autoscale.timeline import (ClusterTimeline, ReplicaSample,
                                      TIMELINE_SCHEMA_VERSION,
                                      TimelineRecorder, TimelineSample)

__all__ = [
    "AUTOSCALER_POLICIES", "AUTOSCALE_SCHEMA_VERSION", "AutoscaleReport",
    "AutoscaleSimulator", "AutoscalerPolicy", "ClusterTimeline",
    "ReplicaSample", "SLOAttainmentWindow", "ScalableReplicaEngine",
    "StaticPolicy", "TIMELINE_SCHEMA_VERSION", "TargetQueueDepth",
    "TimelineRecorder", "TimelineSample", "build_autoscale_section",
    "get_policy",
]
