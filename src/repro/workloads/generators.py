"""Seeded workload-trace generators: arrival processes × length mixes.

Every generator is a pure function of ``(spec, seed)`` — arrivals and
lengths come from one ``random.Random(seed)`` stream, never ambient time
or global RNG state — so a :class:`TraceSpec` plus a seed IS the trace
(and both are embedded in the trace's metadata for provenance).

Arrival processes (:class:`ArrivalSpec`):
  ``poisson``   homogeneous Poisson at ``rate_rps``
  ``bursty``    on/off-modulated Poisson: Gamma-distributed ON bursts at
                ``rate_rps * burst_factor`` alternating with quiet OFF
                periods at ``rate_rps / burst_factor``
  ``diurnal``   non-homogeneous Poisson via thinning, rate modulated by
                ``1 + amplitude * sin(2*pi*t / period_s)``

Length distributions (:class:`LengthSpec`):
  ``fixed``     every request is (isl, osl)
  ``uniform``   isl ~ U[isl_lo, isl_hi], osl ~ U[osl_lo, osl_hi]
  ``lognormal`` lognormal lengths around (isl, osl) medians with
                ``sigma`` spread, clamped to [1, 4*median]
  ``sharegpt``  a ShareGPT-like mixture: mostly short chat turns, a
                long-context tail, and a code-generation slice

Multi-tenant mixes: each :class:`TenantSpec` carries a weight, a
priority, and its own length distribution; the arrival process is
global and each arrival is assigned a tenant by weighted draw.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Dict, List, Sequence, Tuple

from repro.workloads.trace import TraceRequest, WorkloadTrace

ARRIVAL_KINDS = ("poisson", "bursty", "diurnal")
LENGTH_KINDS = ("fixed", "uniform", "lognormal", "sharegpt")


@dataclasses.dataclass(frozen=True)
class ArrivalSpec:
    kind: str = "poisson"
    rate_rps: float = 1.0             # mean request rate
    # bursty knobs
    burst_factor: float = 4.0         # ON rate multiplier (OFF divides)
    mean_on_s: float = 10.0           # mean Gamma burst duration
    mean_off_s: float = 20.0          # mean quiet-period duration
    # diurnal knobs
    period_s: float = 120.0
    amplitude: float = 0.8            # in [0, 1)

    def __post_init__(self):
        if self.kind not in ARRIVAL_KINDS:
            raise ValueError(f"unknown arrival kind {self.kind!r}; "
                             f"valid: {', '.join(ARRIVAL_KINDS)}")
        if self.rate_rps <= 0:
            raise ValueError(f"rate_rps must be positive, got {self.rate_rps}")
        if self.kind == "bursty" and (self.burst_factor <= 1
                                      or self.mean_on_s <= 0
                                      or self.mean_off_s <= 0):
            raise ValueError("bursty arrivals need burst_factor > 1 and "
                             "positive mean_on_s/mean_off_s")
        if self.kind == "diurnal" and not (0 <= self.amplitude < 1):
            raise ValueError(f"amplitude must be in [0, 1), "
                             f"got {self.amplitude}")


@dataclasses.dataclass(frozen=True)
class LengthSpec:
    kind: str = "fixed"
    isl: int = 512
    osl: int = 128
    isl_lo: int = 64
    isl_hi: int = 2048
    osl_lo: int = 16
    osl_hi: int = 512
    sigma: float = 0.5                # lognormal spread

    def __post_init__(self):
        if self.kind not in LENGTH_KINDS:
            raise ValueError(f"unknown length kind {self.kind!r}; "
                             f"valid: {', '.join(LENGTH_KINDS)}")
        if min(self.isl, self.osl, self.isl_lo, self.osl_lo) < 1:
            raise ValueError("lengths must be >= 1")
        if self.isl_hi < self.isl_lo or self.osl_hi < self.osl_lo:
            raise ValueError("length ranges must satisfy lo <= hi")
        if self.sigma <= 0:
            raise ValueError(f"sigma must be positive, got {self.sigma}")


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    name: str = "default"
    weight: float = 1.0
    priority: int = 0
    lengths: LengthSpec = dataclasses.field(default_factory=LengthSpec)

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(f"tenant weight must be positive, "
                             f"got {self.weight}")


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Everything a deterministic trace generation needs except the seed."""
    n_requests: int = 100
    arrivals: ArrivalSpec = dataclasses.field(default_factory=ArrivalSpec)
    tenants: Tuple[TenantSpec, ...] = (TenantSpec(),)

    def __post_init__(self):
        if self.n_requests < 1:
            raise ValueError(f"n_requests must be >= 1, "
                             f"got {self.n_requests}")
        if not self.tenants:
            raise ValueError("at least one tenant required")
        names = [t.name for t in self.tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names: {names}")

    # -- serialization (embedded in trace meta; drives the CLI) --------------
    def to_dict(self) -> Dict:
        return {
            "n_requests": self.n_requests,
            "arrivals": dataclasses.asdict(self.arrivals),
            "tenants": [dataclasses.asdict(t) for t in self.tenants],
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "TraceSpec":
        tenants = tuple(
            TenantSpec(name=t["name"], weight=t["weight"],
                       priority=t["priority"],
                       lengths=LengthSpec(**t["lengths"]))
            for t in d["tenants"])
        return cls(n_requests=d["n_requests"],
                   arrivals=ArrivalSpec(**d["arrivals"]),
                   tenants=tenants)


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

def _poisson_arrivals(rng: random.Random, n: int, rate: float) -> List[float]:
    t, out = 0.0, []
    for _ in range(n):
        t += rng.expovariate(rate)
        out.append(t)
    return out


def _bursty_arrivals(rng: random.Random, n: int, a: ArrivalSpec) -> List[float]:
    """On/off-modulated Poisson (Gamma-distributed burst durations).

    The ON/OFF rates keep a burst_factor**2 contrast but are normalized
    by the expected phase-time split so the *time-weighted mean* rate
    stays ``rate_rps`` — raising burst_factor changes burstiness, not
    offered load.
    """
    f_on = a.mean_on_s / (a.mean_on_s + a.mean_off_s)
    norm = f_on * a.burst_factor + (1.0 - f_on) / a.burst_factor
    on_rate = a.rate_rps * a.burst_factor / norm
    off_rate = a.rate_rps / (a.burst_factor * norm)
    out: List[float] = []
    t = 0.0
    on = True                         # start inside a burst
    # Gamma(shape=2) keeps durations away from 0 while staying skewed
    phase_end = t + rng.gammavariate(2.0, a.mean_on_s / 2.0)
    while len(out) < n:
        rate = on_rate if on else off_rate
        gap = rng.expovariate(rate)
        if t + gap > phase_end:
            # no arrival before the phase flips: advance to the boundary
            t = phase_end
            on = not on
            mean = a.mean_on_s if on else a.mean_off_s
            phase_end = t + rng.gammavariate(2.0, mean / 2.0)
            continue
        t += gap
        out.append(t)
    return out


def _diurnal_arrivals(rng: random.Random, n: int, a: ArrivalSpec) -> List[float]:
    """Thinned non-homogeneous Poisson with sinusoidal rate modulation."""
    peak = a.rate_rps * (1.0 + a.amplitude)
    t, out = 0.0, []
    while len(out) < n:
        t += rng.expovariate(peak)
        rate_t = a.rate_rps * (
            1.0 + a.amplitude * math.sin(2.0 * math.pi * t / a.period_s))
        if rng.random() * peak <= rate_t:
            out.append(t)
    return out


def _arrivals(rng: random.Random, n: int, a: ArrivalSpec) -> List[float]:
    if a.kind == "poisson":
        return _poisson_arrivals(rng, n, a.rate_rps)
    if a.kind == "bursty":
        return _bursty_arrivals(rng, n, a)
    return _diurnal_arrivals(rng, n, a)


# ---------------------------------------------------------------------------
# length distributions
# ---------------------------------------------------------------------------

def _lognormal_len(rng: random.Random, median: int, sigma: float) -> int:
    val = median * math.exp(rng.gauss(0.0, sigma))
    return max(1, min(int(round(val)), 4 * median))


#: ShareGPT-like mixture: (weight, isl_median, osl_median, sigma)
_SHAREGPT_MIX = (
    (0.60, 330, 180, 0.6),            # short chat turns
    (0.30, 1800, 320, 0.5),           # long-context / document turns
    (0.10, 900, 650, 0.4),            # code generation (long outputs)
)


def _draw_lengths(rng: random.Random, spec: LengthSpec) -> Tuple[int, int]:
    if spec.kind == "fixed":
        return spec.isl, spec.osl
    if spec.kind == "uniform":
        return (rng.randint(spec.isl_lo, spec.isl_hi),
                rng.randint(spec.osl_lo, spec.osl_hi))
    if spec.kind == "lognormal":
        return (_lognormal_len(rng, spec.isl, spec.sigma),
                _lognormal_len(rng, spec.osl, spec.sigma))
    # sharegpt mixture
    u = rng.random()
    acc = 0.0
    for w, isl_m, osl_m, sigma in _SHAREGPT_MIX:
        acc += w
        if u <= acc:
            return (_lognormal_len(rng, isl_m, sigma),
                    _lognormal_len(rng, osl_m, sigma))
    w, isl_m, osl_m, sigma = _SHAREGPT_MIX[-1]
    return (_lognormal_len(rng, isl_m, sigma),
            _lognormal_len(rng, osl_m, sigma))


# ---------------------------------------------------------------------------
# generation
# ---------------------------------------------------------------------------

def _pick_tenant(rng: random.Random,
                 tenants: Sequence[TenantSpec]) -> TenantSpec:
    total = sum(t.weight for t in tenants)
    u = rng.random() * total
    acc = 0.0
    for t in tenants:
        acc += t.weight
        if u <= acc:
            return t
    return tenants[-1]


def generate_trace(spec: TraceSpec, seed: int = 0) -> WorkloadTrace:
    """Deterministically expand ``(spec, seed)`` into a WorkloadTrace."""
    rng = random.Random(seed)
    arrivals = _arrivals(rng, spec.n_requests, spec.arrivals)
    reqs = []
    for arrival in arrivals:
        tenant = _pick_tenant(rng, spec.tenants)
        isl, osl = _draw_lengths(rng, tenant.lengths)
        reqs.append(TraceRequest(arrival_s=arrival, isl=isl, osl=osl,
                                 tenant=tenant.name,
                                 priority=tenant.priority))
    meta = {"generator": {"spec": spec.to_dict(), "seed": seed}}
    return WorkloadTrace(requests=tuple(reqs), meta=meta)


def constant_trace(isl: int, osl: int, n_requests: int,
                   rate_rps: float) -> WorkloadTrace:
    """Evenly-spaced fixed-length trace (the closed-loop-equivalence
    reference used by the property tests)."""
    gap = 1.0 / rate_rps
    reqs = tuple(TraceRequest(arrival_s=i * gap, isl=isl, osl=osl)
                 for i in range(n_requests))
    return WorkloadTrace(requests=reqs,
                         meta={"generator": {"constant": {
                             "isl": isl, "osl": osl,
                             "n_requests": n_requests,
                             "rate_rps": rate_rps}}})
