"""Tail-latency SLOs and goodput: the ranking objective for replayed
candidates.

A deployment meets its SLO when its *tail* latencies stay under the
targets; :class:`SLOSpec` carries the p99 TTFT/TPOT thresholds and
scores each replayed request against them.  **Goodput** is then the
token throughput contributed only by requests that individually met
both thresholds — the production metric the analytical static view
cannot see (a config can win on steady-state tok/s/chip while queueing
bursts push its p99 TTFT far past the SLO).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """Per-request tail-latency targets."""
    ttft_p99_ms: float = 2000.0
    tpot_p99_ms: float = 100.0

    def __post_init__(self):
        if self.ttft_p99_ms <= 0 or self.tpot_p99_ms <= 0:
            raise ValueError(
                f"SLO thresholds must be positive, got "
                f"ttft_p99_ms={self.ttft_p99_ms}, "
                f"tpot_p99_ms={self.tpot_p99_ms}")

    def request_meets(self, ttft_s: float,
                      tpot_s: Optional[float]) -> bool:
        """Does one completed request meet both targets?  ``tpot_s`` is
        ``None`` for single-token outputs (no decode interval exists),
        which vacuously satisfies the TPOT target."""
        if 1e3 * ttft_s > self.ttft_p99_ms:
            return False
        return tpot_s is None or 1e3 * tpot_s <= self.tpot_p99_ms

    def to_dict(self) -> Dict:
        return {"ttft_p99_ms": self.ttft_p99_ms,
                "tpot_p99_ms": self.tpot_p99_ms}

    @classmethod
    def from_dict(cls, d: Dict) -> "SLOSpec":
        return cls(ttft_p99_ms=d["ttft_p99_ms"],
                   tpot_p99_ms=d["tpot_p99_ms"])
