"""repro.workloads — trace-driven dynamic workloads.

The static search evaluates every candidate at one fixed
``(isl, osl, concurrency)`` point; this package supplies the dynamic
axis the paper's production claim rests on:

- :mod:`~repro.workloads.trace` — the versioned JSONL trace format
  (:class:`TraceRequest` / :class:`WorkloadTrace`, lossless round-trip).
- :mod:`~repro.workloads.generators` — seeded, deterministic trace
  generators: Poisson / bursty / diurnal arrivals × fixed / uniform /
  lognormal / ShareGPT-like length mixes × multi-tenant splits, all
  reproducible from ``(spec, seed)``.
- :mod:`~repro.workloads.slo` — tail-latency :class:`SLOSpec` and the
  goodput objective.
- :mod:`~repro.workloads.frontier` — replay the analytical top-K
  through the open-loop simulator (``ServingSimulator.replay``) and
  re-rank by goodput under the SLO; the result lands in the
  ``workload`` section of a schema-v3 SearchReport.

Canonical flow::

    from repro.workloads import (ArrivalSpec, SLOSpec, TenantSpec,
                                 TraceSpec, generate_trace)

    trace = generate_trace(TraceSpec(
        n_requests=200,
        arrivals=ArrivalSpec(kind="bursty", rate_rps=2.0),
        tenants=(TenantSpec(name="chat", weight=0.7, priority=1),
                 TenantSpec(name="batch", weight=0.3))), seed=7)
    trace.save("trace.jsonl")

    report = cfg.evaluate_frontier("trace.jsonl",
                                   SLOSpec(ttft_p99_ms=2000,
                                           tpot_p99_ms=80))
    report.workload_eval["ranking"]  # goodput order, not analytical order
"""
from repro.workloads.generators import (ARRIVAL_KINDS, LENGTH_KINDS,
                                        ArrivalSpec, LengthSpec, TenantSpec,
                                        TraceSpec, constant_trace,
                                        generate_trace)
from repro.workloads.frontier import (DISAGG_SKIP_REASON,
                                      analytical_leaders,
                                      candidate_from_projection,
                                      replay_frontier)
from repro.workloads.slo import SLOSpec
from repro.workloads.trace import (SUPPORTED_TRACE_SCHEMA_VERSIONS,
                                   TRACE_SCHEMA_VERSION, TraceRequest,
                                   WorkloadTrace)

__all__ = [
    "ARRIVAL_KINDS", "ArrivalSpec", "DISAGG_SKIP_REASON", "LENGTH_KINDS",
    "LengthSpec", "SLOSpec", "SUPPORTED_TRACE_SCHEMA_VERSIONS",
    "TRACE_SCHEMA_VERSION", "TenantSpec", "TraceRequest", "TraceSpec",
    "WorkloadTrace", "analytical_leaders", "candidate_from_projection",
    "constant_trace", "generate_trace", "replay_frontier",
]
