"""SLO-aware frontier re-ranking: replay the analytical leaders under a
dynamic trace and rank them by goodput.

The analytical search ranks candidates by steady-state tok/s/chip at one
fixed ``(isl, osl, concurrency)`` point.  Under a bursty multi-tenant
trace, the ordering can flip: a throughput-optimal config with small
headroom queues during bursts and blows its p99 TTFT, while a slightly
"slower" config absorbs them.  :func:`replay_frontier` replays the
top-K analytical candidates through the discrete-event simulator
(open-loop, queueing counted) and re-ranks by goodput under the SLO —
the result is the ``workload_eval`` section of a schema-v3 SearchReport.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.core import pareto
from repro.core.config import (CandidateConfig, ParallelismConfig,
                               Projection, RuntimeFlags, SLA)
from repro.serving.sim import ReplayMetrics
from repro.workloads.slo import SLOSpec
from repro.workloads.trace import WorkloadTrace


#: Why a disaggregated composite cannot be replayed: both the frontier
#: re-ranker and the capacity planner drive single-engine simulators
#: (one scheduler per engine/replica), and a composite runs two pools.
#: One string, shared, so report consumers can match on it.
DISAGG_SKIP_REASON = ("disaggregated composite: not replayable on the "
                      "single-engine simulator")


def analytical_leaders(projections: Sequence[Projection], sla: SLA,
                       top_k: int) -> List[Projection]:
    """The top-K candidates the dynamic views replay: SLA-valid Pareto
    leaders, falling back to raw throughput order when nothing is
    SLA-valid (so the dynamic view still says something about the
    space).  Shared by :func:`replay_frontier` and
    ``Configurator.plan_capacity`` — one selection policy."""
    if top_k < 1:
        raise ValueError(f"top_k must be >= 1, got {top_k}")
    leaders = pareto.top_k(list(projections), sla, top_k)
    if not leaders:
        leaders = sorted(projections,
                         key=lambda p: -p.tokens_per_s_per_chip)[:top_k]
    return leaders


def candidate_from_projection(p: Projection) -> Optional[CandidateConfig]:
    """Rebuild the CandidateConfig a projection priced, or None when the
    projection is not a single-engine deployment (disaggregated
    composites run two pools; the one-engine simulator cannot replay
    them)."""
    cfg = p.config or {}
    if p.mode == "disaggregated" or "parallel" not in cfg:
        return None
    par = ParallelismConfig(**cfg["parallel"])
    flags = (RuntimeFlags(**cfg["flags"]) if "flags" in cfg
             else RuntimeFlags())
    return CandidateConfig(parallel=par, batch_size=p.batch_size,
                           flags=flags)


def replay_frontier(runner, projections: Sequence[Projection],
                    trace: WorkloadTrace, slo: SLOSpec,
                    top_k: int = 5,
                    sla: Optional[SLA] = None,
                    max_steps: int = 200_000) -> Dict:
    """Replay the top-K analytical candidates; return the ``workload``
    report section.

    ``runner`` is a :class:`repro.core.task_runner.TaskRunner` (its
    session prices the simulator's iterations, so replay and search
    share one PerfDatabase).  ``projections`` is the full priced list
    (report order); indices in the returned section refer into it.
    Candidates the simulator cannot replay (disaggregated composites)
    are recorded as skipped, not silently dropped.
    """
    sla = sla if sla is not None else runner.w.sla
    leaders = analytical_leaders(projections, sla, top_k)
    index_of = {id(p): i for i, p in enumerate(projections)}

    candidates: List[Dict] = []
    ranked: List[tuple] = []
    for rank, p in enumerate(leaders):
        entry: Dict = {
            "index": index_of[id(p)],
            "analytical_rank": rank,
            "mode": p.mode,
            "describe": p.config.get("describe", ""),
            "tokens_per_s_per_chip": p.tokens_per_s_per_chip,
            "replay": None,
            "skipped": None,
        }
        cand = candidate_from_projection(p)
        if cand is None:
            entry["skipped"] = DISAGG_SKIP_REASON
            candidates.append(entry)
            continue
        sim = runner.simulator(cand, priority_admission=True)
        metrics: ReplayMetrics = sim.replay(trace, slo=slo,
                                            max_steps=max_steps)
        entry["replay"] = metrics.to_dict()
        entry["replay"]["histograms"] = metrics.histograms
        candidates.append(entry)
        ranked.append((metrics.goodput_tok_s or 0.0,
                       metrics.slo_attainment or 0.0, rank, entry["index"]))

    # goodput-first ordering; ties (including a zero-signal replay where
    # nothing attains the SLO) fall back to the analytical order, so
    # ``reranked`` is only True when replay actually discriminated
    ranked.sort(key=lambda t: (-t[0], -t[1], t[2]))
    goodput_ranking = [idx for _, _, _, idx in ranked]
    analytical_ranking = [c["index"] for c in candidates
                          if c["replay"] is not None]
    return {
        "trace": {"digest": trace.digest(),
                  "n_requests": trace.n_requests,
                  "duration_s": trace.duration_s,
                  "tenants": trace.tenants,
                  "meta": trace.meta},
        "slo": slo.to_dict(),
        # the PerfDatabase that priced the replay iterations — auditable
        # against the report's own `database` section (they differ when a
        # loaded report is replayed on a fresh, e.g. uncalibrated, db)
        "database": runner.session.db.fingerprint(),
        "top_k": top_k,
        "candidates": candidates,
        "ranking": goodput_ranking,
        "analytical_ranking": analytical_ranking,
        "best_index": goodput_ranking[0] if goodput_ranking else None,
        "reranked": goodput_ranking != analytical_ranking,
    }
