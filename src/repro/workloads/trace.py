"""Workload traces: the dynamic-traffic record the replay layer consumes.

A :class:`WorkloadTrace` is an ordered sequence of :class:`TraceRequest`
records — arrival time, prompt/output lengths, tenant, priority — plus
provenance metadata (the generator spec and seed that produced it, when
one did).  Traces serialize to a versioned JSONL format: one header
record carrying ``schema_version`` and metadata, then one record per
request.  ``WorkloadTrace.from_jsonl(t.to_jsonl()) == t`` is exact
(floats survive via JSON's shortest-round-trip repr), so the trace file
— not the generator invocation — is the interchange artifact between
``workload generate``, ``workload replay``, and ``search --trace``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import statistics
from typing import Dict, List, Sequence, Tuple

from repro.serving.sim import percentile

#: Bump on any backwards-incompatible change to the JSONL layout.
TRACE_SCHEMA_VERSION = 1
SUPPORTED_TRACE_SCHEMA_VERSIONS = (1,)


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    """One request in a dynamic workload trace."""
    arrival_s: float              # seconds since trace start (>= 0)
    isl: int                      # input (prompt) length, tokens
    osl: int                      # output length, tokens
    tenant: str = "default"
    priority: int = 0             # higher value = scheduled first

    def to_dict(self) -> Dict:
        return {"arrival_s": self.arrival_s, "isl": self.isl,
                "osl": self.osl, "tenant": self.tenant,
                "priority": self.priority}

    @classmethod
    def from_dict(cls, d: Dict) -> "TraceRequest":
        return cls(arrival_s=d["arrival_s"], isl=d["isl"], osl=d["osl"],
                   tenant=d.get("tenant", "default"),
                   priority=d.get("priority", 0))


def _validate(requests: Sequence[TraceRequest]) -> None:
    prev = 0.0
    for i, r in enumerate(requests):
        if r.arrival_s < 0:
            raise ValueError(
                f"request {i}: negative arrival {r.arrival_s}")
        if r.arrival_s < prev:
            raise ValueError(
                f"request {i}: arrivals must be non-decreasing "
                f"({r.arrival_s} after {prev})")
        if r.isl < 1 or r.osl < 1:
            raise ValueError(
                f"request {i}: isl/osl must be >= 1, got "
                f"{r.isl}/{r.osl}")
        prev = r.arrival_s


@dataclasses.dataclass(frozen=True)
class WorkloadTrace:
    """An immutable, validated, serializable dynamic workload."""
    requests: Tuple[TraceRequest, ...]
    meta: Dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        object.__setattr__(self, "requests", tuple(self.requests))
        _validate(self.requests)

    # -- views ---------------------------------------------------------------
    @property
    def n_requests(self) -> int:
        return len(self.requests)

    @property
    def duration_s(self) -> float:
        return self.requests[-1].arrival_s if self.requests else 0.0

    @property
    def tenants(self) -> List[str]:
        return sorted({r.tenant for r in self.requests})

    def mean_isl(self) -> int:
        if not self.requests:
            return 1
        return max(1, round(statistics.mean(r.isl for r in self.requests)))

    def mean_osl(self) -> int:
        if not self.requests:
            return 1
        return max(1, round(statistics.mean(r.osl for r in self.requests)))

    def arrival_rate_rps(self) -> float:
        """Mean arrival rate over the trace span (0 for <2 requests)."""
        if self.n_requests < 2 or self.duration_s <= 0:
            return 0.0
        return self.n_requests / self.duration_s

    def describe(self) -> Dict:
        """Summary statistics (the ``workload describe`` payload)."""
        def dist(vals: List[float]) -> Dict:
            return {"mean": statistics.mean(vals),
                    "p50": percentile(vals, 0.50),
                    "p95": percentile(vals, 0.95), "max": max(vals)}

        per_tenant: Dict[str, int] = {}
        for r in self.requests:
            per_tenant[r.tenant] = per_tenant.get(r.tenant, 0) + 1
        out = {
            "schema_version": TRACE_SCHEMA_VERSION,
            "n_requests": self.n_requests,
            "duration_s": self.duration_s,
            "arrival_rate_rps": self.arrival_rate_rps(),
            "tenants": per_tenant,
            "digest": self.digest(),
            "meta": self.meta,
        }
        if self.requests:
            out["isl"] = dist([float(r.isl) for r in self.requests])
            out["osl"] = dist([float(r.osl) for r in self.requests])
        return out

    # -- serialization -------------------------------------------------------
    def to_jsonl(self) -> str:
        header = {"type": "header",
                  "schema_version": TRACE_SCHEMA_VERSION,
                  "n_requests": self.n_requests,
                  "meta": self.meta}
        lines = [json.dumps(header, sort_keys=True)]
        lines += [json.dumps(r.to_dict(), sort_keys=True)
                  for r in self.requests]
        return "\n".join(lines) + "\n"

    @classmethod
    def from_jsonl(cls, text: str) -> "WorkloadTrace":
        lines = [ln for ln in text.splitlines() if ln.strip()]
        if not lines:
            raise ValueError("empty trace file (missing header record)")
        header = json.loads(lines[0])
        if header.get("type") != "header":
            raise ValueError("trace file must start with a header record "
                             "({'type': 'header', ...})")
        version = header.get("schema_version")
        if version not in SUPPORTED_TRACE_SCHEMA_VERSIONS:
            raise ValueError(
                f"unsupported trace schema_version {version!r}; this "
                f"build reads versions "
                f"{', '.join(map(str, SUPPORTED_TRACE_SCHEMA_VERSIONS))}")
        try:
            reqs = [TraceRequest.from_dict(json.loads(ln))
                    for ln in lines[1:]]
        except (KeyError, TypeError) as e:
            raise ValueError(f"malformed trace record: {e}") from e
        declared = header.get("n_requests")
        if declared is not None and declared != len(reqs):
            raise ValueError(f"trace header declares {declared} requests "
                             f"but file carries {len(reqs)}")
        return cls(requests=tuple(reqs), meta=header.get("meta", {}))

    def digest(self) -> str:
        """Stable content identity over the canonical JSONL serialization."""
        return hashlib.sha256(self.to_jsonl().encode()).hexdigest()[:16]

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_jsonl())
        return path

    @classmethod
    def load(cls, path: str) -> "WorkloadTrace":
        with open(path) as f:
            return cls.from_jsonl(f.read())
