"""Versioned calibration artifact — the measured-kernel correction layer as
a file.

A :class:`CalibrationArtifact` is what ``calibrate run`` produces and what
:meth:`PerfDatabase.apply_calibration` consumes: per-operator-family
log-space correction models (``measured ≈ scale · predicted^exponent``)
fitted against the analytical executor, together with the raw measurement
samples they were fitted from and full provenance (platform, backend, timer,
grid digest, caller-supplied timestamp — never ambient wall-clock, so
artifacts are reproducible byte-for-byte).

The JSON schema (see docs/calibration.md) round-trips losslessly:
``CalibrationArtifact.from_json(a.to_json()) == a``.  Python's ``json``
emits shortest-round-trip float reprs, so every scale/exponent/sample
survives save → load bit-exactly — the property the golden fixture under
``tests/fixtures/`` locks in.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, List, Sequence, Tuple

#: Bump on any backwards-incompatible change to the artifact JSON layout.
SCHEMA_VERSION = 1
SUPPORTED_SCHEMA_VERSIONS = (1,)

#: Sanity marker so a SearchReport or PerfDatabase blob is never
#: accidentally loaded as a calibration artifact.
KIND = "repro-calibration"


@dataclasses.dataclass(frozen=True)
class Sample:
    """One measured grid point: an operator family at ``coords`` on the
    measurement grid, the analytical prediction, and what the timer saw."""
    family: str
    coords: Tuple[float, ...]
    predicted_s: float
    measured_s: float

    def to_dict(self) -> Dict:
        return {"family": self.family, "coords": list(self.coords),
                "predicted_s": self.predicted_s,
                "measured_s": self.measured_s}

    @classmethod
    def from_dict(cls, d: Dict) -> "Sample":
        return cls(family=d["family"], coords=tuple(d["coords"]),
                   predicted_s=d["predicted_s"], measured_s=d["measured_s"])


@dataclasses.dataclass(frozen=True)
class FamilyFit:
    """Log-space correction model for one operator family.

    ``corrected = scale * predicted ** exponent`` — a straight line in
    (log predicted, log measured) space.  Goodness-of-fit stats ride along
    so ``calibrate report`` can audit the fit without re-measuring.
    """
    family: str
    scale: float
    exponent: float
    n_samples: int
    r2: float                  # of the log-log regression
    residual_std: float        # std of log residuals after correction
    mape_uncalibrated: float   # % on the fit's own samples
    mape_calibrated: float

    def correct(self, predicted_s: float) -> float:
        return self.scale * max(predicted_s, 1e-12) ** self.exponent

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "FamilyFit":
        return cls(**d)


def grid_digest(samples: Sequence[Sample]) -> str:
    """Stable digest over the measurement grid (families × coords), i.e.
    WHERE the silicon was sampled — independent of the latencies found
    there, so two runs of the same sweep on different hardware share it."""
    h = hashlib.sha256()
    for s in sorted(samples, key=lambda s: (s.family, s.coords)):
        h.update(s.family.encode())
        h.update(repr(tuple(float(c) for c in s.coords)).encode())
    return h.hexdigest()[:16]


@dataclasses.dataclass
class CalibrationArtifact:
    """The calibrated artifact: fits + samples + provenance, versioned."""
    platform: str
    backend: str
    timer: str                 # timer implementation that produced samples
    created_at: str            # ISO-8601, supplied by the caller
    grid_digest: str
    fits: Dict[str, FamilyFit]
    samples: List[Sample]
    notes: str = ""
    schema_version: int = SCHEMA_VERSION

    # -- what PerfDatabase consumes -----------------------------------------
    def corrections(self) -> Dict[str, Tuple[float, float]]:
        """family -> (scale, exponent), the per-family correction layer."""
        return {name: (fit.scale, fit.exponent)
                for name, fit in self.fits.items()}

    def digest(self) -> str:
        """Content digest over the full artifact (fits AND samples)."""
        blob = json.dumps(self.to_dict(), sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    def identity(self) -> Dict:
        """Compact provenance record ``PerfDatabase.fingerprint()`` embeds
        (and SearchReport v2's ``database`` section therefore carries)."""
        return {"schema_version": self.schema_version,
                "digest": self.digest(),
                "timer": self.timer,
                "created_at": self.created_at,
                "grid_digest": self.grid_digest,
                "families": sorted(self.fits)}

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> Dict:
        return {
            "kind": KIND,
            "schema_version": self.schema_version,
            "platform": self.platform,
            "backend": self.backend,
            "timer": self.timer,
            "created_at": self.created_at,
            "grid_digest": self.grid_digest,
            "notes": self.notes,
            "fits": {name: fit.to_dict()
                     for name, fit in sorted(self.fits.items())},
            "samples": [s.to_dict() for s in self.samples],
        }

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: Dict) -> "CalibrationArtifact":
        if d.get("kind") != KIND:
            raise ValueError(
                f"not a calibration artifact (kind={d.get('kind')!r}; "
                f"expected {KIND!r})")
        version = d.get("schema_version")
        if version not in SUPPORTED_SCHEMA_VERSIONS:
            raise ValueError(
                f"unsupported calibration schema_version {version!r}; this "
                f"build reads versions "
                f"{', '.join(map(str, SUPPORTED_SCHEMA_VERSIONS))}")
        return cls(
            platform=d["platform"], backend=d["backend"], timer=d["timer"],
            created_at=d["created_at"], grid_digest=d["grid_digest"],
            notes=d.get("notes", ""),
            fits={name: FamilyFit.from_dict(f)
                  for name, f in d["fits"].items()},
            samples=[Sample.from_dict(s) for s in d["samples"]],
            schema_version=version)

    @classmethod
    def from_json(cls, text: str) -> "CalibrationArtifact":
        return cls.from_dict(json.loads(text))

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return path

    @classmethod
    def load(cls, path: str) -> "CalibrationArtifact":
        with open(path) as f:
            return cls.from_json(f.read())
