"""Measurement harness: times the real Pallas kernels over the same grid
axes the PerfDatabase interpolates on.

For each operator family the harness walks a (subsampled) measurement grid,
builds the operator descriptor the analytical executor prices AND a kernel
thunk that runs the matching real kernel (`repro.kernels.ops` wrappers:
flash_attention / decode_attention / moe_gemm / rglru_scan, plain jnp for
dense GEMM), then asks the pluggable timer for a latency.  The timer
decides whether the thunk actually executes: :class:`WallClockTimer` runs
it (interpret mode on CPU, compiled on TPU), :class:`DeterministicTimer`
prices the descriptor analytically with a fixed skew — same harness, same
samples schema, CI-deterministic.

Thunk construction is fully lazy: the jit wrapper and its input arrays
are built on the thunk's first call and cached, so input materialization
never lands inside a timed rep — and a deterministic run, which never
calls the thunk, neither imports jax through the harness nor allocates
anything.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.calibrate.artifact import Sample
from repro.calibrate.timers import DeterministicTimer, Thunk
from repro.core import analytical
from repro.core import operators as ops
from repro.core.hardware import Platform, get_platform

_POW2 = lambda lo, hi: tuple(
    2 ** i for i in range(int(math.log2(lo)), int(math.log2(hi)) + 1))

#: Measurement axes: the PerfDatabase's grid axes, capped to shapes a
#: wall-clock interpret-mode run can execute in reasonable time.  The fit
#: is a per-family global correction, so a subgrid suffices.
DEFAULT_AXES: Dict[str, Tuple[Tuple[float, ...], ...]] = {
    "gemm": (_POW2(1, 1024), _POW2(128, 2048), _POW2(128, 2048)),
    "attn_prefill": (_POW2(64, 1024), _POW2(64, 1024)),   # q_len, kv_len
    "attn_decode": (_POW2(1, 16), _POW2(128, 2048)),      # batch, kv_len
    "moe": (_POW2(8, 512),),                              # hot-rank tokens
    "recurrent": (_POW2(64, 1024),),                      # tokens
}

MEASURED_FAMILIES = tuple(DEFAULT_AXES)

# fixed kernel geometry for the shape-rich families (one representative
# head/expert config; the database's per-config grids share the family fit)
ATTN_HEADS = 4
ATTN_KV_HEADS = 2
ATTN_HEAD_DIM = 64
MOE_EXPERTS = 4
MOE_D_MODEL = 256
MOE_D_FF = 512
REC_WIDTH = 256


def subsample(axis: Sequence[float], n: int) -> Tuple[float, ...]:
    """n log-evenly spaced points of ``axis`` including both endpoints."""
    if n <= 0:
        raise ValueError(f"points_per_axis must be >= 1, got {n}")
    if n >= len(axis):
        return tuple(axis)
    if n == 1:
        return (axis[len(axis) // 2],)
    idx = sorted({round(i * (len(axis) - 1) / (n - 1)) for i in range(n)})
    return tuple(axis[i] for i in idx)


@dataclasses.dataclass(frozen=True)
class FamilySpec:
    """One operator family's measurement recipe."""
    family: str
    axes: Tuple[Tuple[float, ...], ...]
    build_op: Callable[..., object]       # coords -> operator descriptor
    make_thunk: Callable[..., Thunk]      # coords -> kernel runner


# -- per-family op builders + kernel thunks ---------------------------------

def _gemm_op(m, n, k):
    return ops.GEMM(int(m), int(n), int(k), "bf16")


def _gemm_thunk(m, n, k):
    import jax
    import jax.numpy as jnp
    mm = jax.jit(lambda a, b: a @ b)
    a = jnp.ones((int(m), int(k)), jnp.bfloat16)
    b = jnp.ones((int(k), int(n)), jnp.bfloat16)
    return lambda: mm(a, b)


def _attn_prefill_op(q_len, kv_len):
    return ops.Attention(
        phase="prefill", batch=1, q_len=int(q_len), kv_len=int(kv_len),
        heads=ATTN_HEADS, kv_heads=ATTN_KV_HEADS, head_dim=ATTN_HEAD_DIM,
        kind="gqa", dtype="bf16")


def _attn_prefill_thunk(q_len, kv_len):
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops as kops

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(
        ks[0], (1, int(q_len), ATTN_HEADS, ATTN_HEAD_DIM), jnp.bfloat16)
    k = jax.random.normal(
        ks[1], (1, int(kv_len), ATTN_KV_HEADS, ATTN_HEAD_DIM), jnp.bfloat16)
    v = jax.random.normal(
        ks[2], (1, int(kv_len), ATTN_KV_HEADS, ATTN_HEAD_DIM), jnp.bfloat16)
    return lambda: kops.flash_attention(q, k, v, causal=True,
                                        block_q=128, block_k=128)


def _attn_decode_op(batch, kv_len):
    return ops.Attention(
        phase="decode", batch=int(batch), q_len=1, kv_len=int(kv_len),
        heads=ATTN_HEADS, kv_heads=ATTN_KV_HEADS, head_dim=ATTN_HEAD_DIM,
        kind="gqa", dtype="bf16")


def _attn_decode_thunk(batch, kv_len):
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops as kops
    b, w = int(batch), int(kv_len)

    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, ATTN_HEADS, ATTN_HEAD_DIM),
                          jnp.bfloat16)
    kc = jax.random.normal(
        ks[1], (b, w, ATTN_KV_HEADS, ATTN_HEAD_DIM), jnp.bfloat16)
    vc = jax.random.normal(
        ks[2], (b, w, ATTN_KV_HEADS, ATTN_HEAD_DIM), jnp.bfloat16)
    vl = jnp.full((b,), w, jnp.int32)
    return lambda: kops.decode_attention(q, kc, vc, vl, block_k=128)


def _moe_op(rank_tokens):
    return ops.MoEOp(
        tokens=int(rank_tokens), d_model=MOE_D_MODEL, d_ff=MOE_D_FF,
        num_experts=MOE_EXPERTS, top_k=1, ep=1,
        hot_rank_tokens=int(rank_tokens), dtype="bf16")


def _moe_thunk(rank_tokens):
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops as kops
    c = max(int(rank_tokens) // MOE_EXPERTS, 1)

    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    xe = jax.random.normal(ks[0], (MOE_EXPERTS, c, MOE_D_MODEL),
                           jnp.bfloat16)
    w_gate = jax.random.normal(
        ks[1], (MOE_EXPERTS, MOE_D_MODEL, MOE_D_FF), jnp.bfloat16)
    w_up = jax.random.normal(
        ks[2], (MOE_EXPERTS, MOE_D_MODEL, MOE_D_FF), jnp.bfloat16)
    w_down = jax.random.normal(
        ks[3], (MOE_EXPERTS, MOE_D_FF, MOE_D_MODEL), jnp.bfloat16)

    def run():
        # the operator's 3 expert GEMMs (gate/up/down), end to end
        g = kops.moe_gemm(xe, w_gate)
        u = kops.moe_gemm(xe, w_up)
        return kops.moe_gemm(g * u, w_down)

    return run


def _recurrent_op(tokens):
    return ops.RecurrentOp(kind="rglru", batch=1, seq=int(tokens),
                           width=REC_WIDTH, heads=1, dtype="bf16")


def _recurrent_thunk(tokens):
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops as kops
    s = int(tokens)

    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    a = jax.nn.sigmoid(
        jax.random.normal(ks[0], (1, s, REC_WIDTH), jnp.float32))
    b = jax.random.normal(ks[1], (1, s, REC_WIDTH), jnp.float32)
    h0 = jnp.zeros((1, REC_WIDTH), jnp.float32)
    return lambda: kops.rglru_scan(a, b, h0)


_SPEC_BUILDERS = {
    "gemm": (_gemm_op, _gemm_thunk),
    "attn_prefill": (_attn_prefill_op, _attn_prefill_thunk),
    "attn_decode": (_attn_decode_op, _attn_decode_thunk),
    "moe": (_moe_op, _moe_thunk),
    "recurrent": (_recurrent_op, _recurrent_thunk),
}


class MeasurementHarness:
    """Sweep the measurement grids for one (platform, backend)."""

    def __init__(self, platform: "str | Platform" = "tpu_v5e",
                 backend: str = "repro-jax",
                 timer=None, points_per_axis: int = 3,
                 families: Optional[Sequence[str]] = None,
                 axes_override: Optional[Dict[str, Sequence[Sequence[float]]]]
                 = None):
        self.platform = (platform if isinstance(platform, Platform)
                         else get_platform(platform))
        self.backend = backend
        self.timer = timer or DeterministicTimer(self.platform)
        self.points_per_axis = points_per_axis
        families = tuple(families) if families else MEASURED_FAMILIES
        unknown = set(families) - set(MEASURED_FAMILIES)
        if unknown:
            raise ValueError(
                f"unknown measurement families {sorted(unknown)}; "
                f"measurable: {', '.join(MEASURED_FAMILIES)}")
        self.families = families
        self._axes_override = dict(axes_override or {})

    def spec(self, family: str) -> FamilySpec:
        build_op, make_thunk = _SPEC_BUILDERS[family]
        full_axes = self._axes_override.get(family, DEFAULT_AXES[family])
        axes = tuple(subsample(a, self.points_per_axis) for a in full_axes)
        return FamilySpec(family=family, axes=axes,
                          build_op=build_op, make_thunk=make_thunk)

    def measure_family(self, family: str) -> List[Sample]:
        spec = self.spec(family)
        samples = []
        for coords in itertools.product(*spec.axes):
            op = spec.build_op(*coords)
            predicted = analytical.latency(self.platform, op)
            measured = self.timer.time(op, _deferred(spec.make_thunk,
                                                     coords))
            samples.append(Sample(
                family=family, coords=tuple(float(c) for c in coords),
                predicted_s=predicted, measured_s=measured))
        return samples

    def measure_all(self) -> List[Sample]:
        out: List[Sample] = []
        for family in self.families:
            out.extend(self.measure_family(family))
        return out


def _deferred(make_thunk, coords) -> Thunk:
    """Defer even thunk CONSTRUCTION (jax import, jit wrapper) to the
    first call: a timer that never executes the kernel never pays it."""
    state: dict = {}

    def thunk():
        if "t" not in state:
            state["t"] = make_thunk(*coords)
        return state["t"]()

    return thunk
