"""Fitting layer: per-operator-family correction models.

Each family's samples are regressed in log-log space —
``log(measured) = exponent · log(predicted) + log(scale)`` — which captures
both a constant efficiency gap (scale) and a size-dependent drift
(exponent ≠ 1: e.g. launch overhead dominating small shapes, or bandwidth
saturation kicking in late).  Degenerate sample sets (fewer than 3 points,
or no spread in the predictor) fall back to a pure log-space scale with
exponent pinned to 1, the exponent is clamped to a sane band so a handful
of noisy points can never produce a runaway power law, and the final model
is selected by sample MAPE against scale-only and identity fallbacks so a
fitted correction is never worse than no correction.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Sequence

from repro.calibrate.artifact import FamilyFit, Sample

#: Exponent clamp: outside this band a "fit" is extrapolating noise, not
#: modeling silicon — pin to the boundary and let scale absorb the rest.
EXPONENT_MIN = 0.5
EXPONENT_MAX = 2.0

#: Below this variance in log(predicted) the slope is unidentifiable.
_MIN_LOG_VAR = 1e-9


def mape(pred: Sequence[float], true: Sequence[float]) -> float:
    """Mean absolute percentage error (%), the paper's fidelity metric."""
    pairs = [(p, t) for p, t in zip(pred, true) if t > 0]
    if not pairs:
        return float("nan")
    return 100.0 * sum(abs(p - t) / t for p, t in pairs) / len(pairs)


def fit_family(family: str, samples: Sequence[Sample]) -> FamilyFit:
    """Fit measured against predicted latency for one family.

    Model selection by sample MAPE among three nested candidates —
    log-log power law (clamped exponent), log-space scale only, and the
    identity — so the correction can never be worse than no correction
    on its own samples: noisy measurements whose regression slope
    collapses (e.g. interpret-mode CPU wall clock against TPU analytics)
    degrade gracefully to scale-only or identity instead of installing a
    distorting power law.  This is what makes the
    ``mape_calibrated <= mape_uncalibrated`` invariant a guarantee.
    """
    xs = [math.log(max(s.predicted_s, 1e-12)) for s in samples]
    ys = [math.log(max(s.measured_s, 1e-12)) for s in samples]
    n = len(samples)
    if n == 0:
        raise ValueError(f"family {family!r} has no samples to fit")

    mean_x = sum(xs) / n
    mean_y = sum(ys) / n
    var_x = sum((x - mean_x) ** 2 for x in xs) / n
    if n < 3 or var_x < _MIN_LOG_VAR:
        slope = 1.0
    else:
        cov = sum((x - mean_x) * (y - mean_y)
                  for x, y in zip(xs, ys)) / n
        slope = min(max(cov / var_x, EXPONENT_MIN), EXPONENT_MAX)

    measured = [s.measured_s for s in samples]
    predicted = [s.predicted_s for s in samples]

    def _model_mape(scale: float, exponent: float) -> float:
        corrected = [scale * max(p, 1e-12) ** exponent for p in predicted]
        return mape(corrected, measured)

    # intercepts refit per candidate exponent: unbiased in log space
    candidates = [
        (math.exp(mean_y - slope * mean_x), slope),      # power law
        (math.exp(mean_y - mean_x), 1.0),                # scale only
        (1.0, 1.0),                                      # identity
    ]
    scale, exponent = min(candidates, key=lambda c: _model_mape(*c))

    intercept = math.log(scale)
    residuals = [y - (exponent * x + intercept) for x, y in zip(xs, ys)]
    ss_res = sum(r * r for r in residuals)
    ss_tot = sum((y - mean_y) ** 2 for y in ys)
    r2 = 1.0 - ss_res / ss_tot if ss_tot > 0 else 1.0
    residual_std = math.sqrt(ss_res / n)

    return FamilyFit(
        family=family, scale=scale, exponent=exponent, n_samples=n,
        r2=r2, residual_std=residual_std,
        mape_uncalibrated=mape(predicted, measured),
        mape_calibrated=_model_mape(scale, exponent))


def group_by_family(samples: Iterable[Sample]) -> Dict[str, List[Sample]]:
    grouped: Dict[str, List[Sample]] = {}
    for s in samples:
        grouped.setdefault(s.family, []).append(s)
    return grouped


def fit_families(samples: Iterable[Sample]) -> Dict[str, FamilyFit]:
    """One :class:`FamilyFit` per operator family present in ``samples``."""
    return {family: fit_family(family, group)
            for family, group in sorted(group_by_family(samples).items())}
