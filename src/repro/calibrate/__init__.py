"""repro.calibrate — measured-kernel calibration pipeline.

The paper's "calibrated kernel-level performance database" as a real
measure → fit → persist → load loop:

* :mod:`~repro.calibrate.harness` times the actual Pallas kernels
  (flash/decode attention, MoE GEMM, RG-LRU scan, plain-jnp GEMM) over the
  PerfDatabase's grid axes, through a pluggable timer
  (:class:`WallClockTimer` for real execution, :class:`DeterministicTimer`
  for bit-reproducible CI runs);
* :mod:`~repro.calibrate.fit` turns (predicted, measured) pairs into
  per-operator-family log-space correction models with goodness-of-fit
  stats;
* :class:`CalibrationArtifact` is the versioned JSON artifact with full
  provenance that :meth:`PerfDatabase.apply_calibration` loads as a
  correction layer, surfaced by ``fingerprint()`` and therefore by
  SearchReport v2's ``database`` section;
* :func:`accuracy_report` audits calibrated vs uncalibrated MAPE from the
  artifact's embedded samples.

Quickstart::

    from repro.calibrate import DeterministicTimer, run_calibration

    art = run_calibration("tpu_v5e", "repro-jax",
                          timer=DeterministicTimer("tpu_v5e"),
                          created_at="2026-07-28T00:00:00Z")
    art.save("cal.json")

    from repro.api import Configurator
    report = (Configurator.for_model("qwen3-32b")
              .traffic(isl=4000, osl=500)
              .with_calibration("cal.json")
              .search())
    # report.fingerprint["calibration"] carries the artifact's identity

CLI: ``python -m repro.core.cli calibrate run | apply | report``.

``MeasurementHarness`` (which imports jax and the kernels) is exported
lazily so artifact consumers never pay the kernel-import cost.
"""
from repro.calibrate.artifact import (KIND, SCHEMA_VERSION,
                                      SUPPORTED_SCHEMA_VERSIONS,
                                      CalibrationArtifact, FamilyFit, Sample,
                                      grid_digest)
from repro.calibrate.fit import fit_families, fit_family, mape
from repro.calibrate.pipeline import (accuracy_report, format_accuracy,
                                      run_calibration)
from repro.calibrate.timers import (DeterministicTimer, WallClockTimer,
                                    make_timer, median_time)

__all__ = [
    "CalibrationArtifact", "DeterministicTimer", "FamilyFit", "KIND",
    "MeasurementHarness", "Sample", "SCHEMA_VERSION",
    "SUPPORTED_SCHEMA_VERSIONS", "WallClockTimer", "accuracy_report",
    "fit_families", "fit_family", "format_accuracy", "grid_digest",
    "make_timer", "mape", "median_time", "run_calibration",
]


def __getattr__(name: str):
    if name == "MeasurementHarness":
        from repro.calibrate.harness import MeasurementHarness
        return MeasurementHarness
    raise AttributeError(f"module 'repro.calibrate' has no attribute {name!r}")
