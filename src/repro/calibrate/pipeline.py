"""measure → fit → persist, and the accuracy report that audits it.

``run_calibration`` is the whole offline pipeline in one call: sweep the
measurement grids with the chosen timer, fit per-family corrections, and
package a versioned :class:`CalibrationArtifact`.  ``accuracy_report``
recomputes predicted-vs-measured MAPE from the artifact's embedded samples
(it does NOT trust the stats stored in the fits), so a tampered or stale
artifact audits honestly.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

from repro.calibrate.artifact import CalibrationArtifact, grid_digest
from repro.calibrate.fit import fit_families, group_by_family, mape


def run_calibration(platform: str = "tpu_v5e", backend: str = "repro-jax",
                    timer=None, created_at: str = "",
                    points_per_axis: int = 3,
                    families: Optional[Sequence[str]] = None,
                    notes: str = "",
                    axes_override: Optional[Dict] = None
                    ) -> CalibrationArtifact:
    """Run the full calibration pipeline and return the artifact.

    ``created_at`` is required provenance supplied by the caller (an
    ISO-8601 timestamp) — the pipeline never reads ambient wall-clock
    time, so the same sweep with the deterministic timer reproduces the
    artifact byte-for-byte.
    """
    if not created_at:
        raise ValueError(
            "created_at is required provenance: pass an ISO-8601 timestamp "
            "(the pipeline never stamps ambient time)")
    # keep the harness (and, on wallclock runs, jax + the Pallas kernels
    # its thunks pull in) out of module import so artifact consumers
    # (PerfDatabase) stay light
    from repro.calibrate.harness import MeasurementHarness
    harness = MeasurementHarness(
        platform=platform, backend=backend, timer=timer,
        points_per_axis=points_per_axis, families=families,
        axes_override=axes_override)
    samples = harness.measure_all()
    return CalibrationArtifact(
        platform=harness.platform.name, backend=backend,
        timer=harness.timer.name, created_at=created_at,
        grid_digest=grid_digest(samples),
        fits=fit_families(samples), samples=samples, notes=notes)


def accuracy_report(artifact: CalibrationArtifact) -> Dict:
    """Per-family + overall MAPE, calibrated vs uncalibrated, recomputed
    from the artifact's raw samples."""
    families: Dict[str, Dict] = {}
    all_pred, all_corr, all_meas = [], [], []
    for family, group in sorted(group_by_family(artifact.samples).items()):
        fit = artifact.fits.get(family)
        pred = [s.predicted_s for s in group]
        meas = [s.measured_s for s in group]
        corr = [fit.correct(p) if fit is not None else p for p in pred]
        families[family] = {
            "n_samples": len(group),
            "scale": fit.scale if fit else 1.0,
            "exponent": fit.exponent if fit else 1.0,
            "r2": fit.r2 if fit else float("nan"),
            "mape_uncalibrated": mape(pred, meas),
            "mape_calibrated": mape(corr, meas),
        }
        all_pred.extend(pred)
        all_corr.extend(corr)
        all_meas.extend(meas)
    return {
        "platform": artifact.platform,
        "backend": artifact.backend,
        "timer": artifact.timer,
        "created_at": artifact.created_at,
        "grid_digest": artifact.grid_digest,
        "digest": artifact.digest(),
        "families": families,
        "overall": {
            "n_samples": len(all_meas),
            "mape_uncalibrated": mape(all_pred, all_meas),
            "mape_calibrated": mape(all_corr, all_meas),
        },
    }


def format_accuracy(report: Dict) -> str:
    """Human-readable table for ``calibrate report``."""
    lines = [
        f"calibration {report['digest']} — {report['platform']} / "
        f"{report['backend']} (timer: {report['timer']}, "
        f"created {report['created_at']})",
        f"{'family':<14} {'n':>4} {'scale':>8} {'exp':>6} {'r2':>6} "
        f"{'MAPE uncal':>11} {'MAPE cal':>9}",
    ]
    for family, row in report["families"].items():
        lines.append(
            f"{family:<14} {row['n_samples']:>4} {row['scale']:>8.3f} "
            f"{row['exponent']:>6.3f} {row['r2']:>6.3f} "
            f"{row['mape_uncalibrated']:>10.1f}% "
            f"{row['mape_calibrated']:>8.1f}%")
    o = report["overall"]
    lines.append(
        f"{'overall':<14} {o['n_samples']:>4} {'':>8} {'':>6} {'':>6} "
        f"{o['mape_uncalibrated']:>10.1f}% {o['mape_calibrated']:>8.1f}%")
    return "\n".join(lines)
