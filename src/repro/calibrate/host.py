"""Host-silicon calibration: the measured-platform / measured-backend half
of the pipeline.

Where :mod:`~repro.calibrate.harness` measures individual kernels,
this module measures the *machine* and the *engine* — the two
calibrations benchmarks/cpu_silicon_fidelity.py and
benchmarks/engine_calibration.py perform against the only real silicon in
this container (the host CPU):

* :func:`calibrate_cpu_platform` micro-benchmarks jit'd matmul throughput
  and memory-stream bandwidth into a ``cpu_host`` :class:`Platform` — the
  per-SKU hardware-spec calibration the paper runs once per GPU;
* :func:`measure_engine_overheads` times the real continuous-batching
  engine's per-prefill-call and per-decode-iteration wall clock, subtracts
  the operator-modeled compute, and returns a :class:`BackendProfile`
  with measured ``step_overhead``/``chunk_overhead`` — the
  framework-dynamics calibration (§1, §3) operator math cannot see;
* :func:`measure_engine_iteration` isolates the per-iteration host
  overhead of a draining engine (the quantity
  ``BackendProfile.step_overhead`` models).

All timing goes through :func:`repro.calibrate.timers.median_time`, the
subsystem's one timing discipline.

Engine/model imports stay function-local: artifact-only consumers of
``repro.calibrate`` never pay for them.
"""
from __future__ import annotations

import statistics
import time
from typing import Dict

from repro.calibrate.timers import median_time
from repro.core.hardware import Platform


def calibrate_cpu_platform() -> Platform:
    """Measure this host's matmul throughput and stream bandwidth."""
    import jax
    import jax.numpy as jnp
    mm = jax.jit(lambda a, b: a @ b)
    a = jnp.ones((1024, 1024), jnp.float32)
    b = jnp.ones((1024, 1024), jnp.float32)
    t_mm = median_time(lambda: mm(a, b), reps=5, trials=3)
    flops = 2 * 1024 ** 3 / t_mm
    cp = jax.jit(lambda x: x * 1.0001)
    big = jnp.ones((64, 1024, 1024), jnp.float32)
    t_cp = median_time(lambda: cp(big), reps=5, trials=3)
    bw = 2 * big.size * 4 / t_cp
    return Platform(
        name="cpu_host",
        peak_flops_bf16=flops, peak_flops_fp8=flops,
        hbm_bw=bw, hbm_capacity=8 * 2 ** 30,
        link_bw=bw, links_per_axis=1, inter_pod_bw=bw,
        launch_overhead=30e-6, hop_latency=1e-6,
        tile_m=8, tile_n=8)          # SIMD CPU, not a 128-lane MXU


def measure_engine_iteration(eng, cfg, osl: int = 48,
                             n_requests: int = 4) -> Dict[str, float]:
    """Per-iteration host overhead of a live engine: wall-clock decode
    iterations of a draining engine minus the back-to-back jit compute.

    Returns ``{"iteration_p50", "jit_compute", "host_overhead"}`` in
    seconds.  The engine should be freshly constructed; its jits are
    warmed here.
    """
    import jax.numpy as jnp
    import numpy as np
    from repro.serving.request import Request
    rng = np.random.default_rng(0)
    for i in range(n_requests):
        prompt = rng.integers(0, cfg.vocab_size, 8).tolist()
        eng.add_request(Request(rid=i, isl=8, osl=osl,
                                arrival=time.perf_counter(), prompt=prompt))
    eng.step()                                   # warm the decode jit
    times = []
    while eng.sched.active:
        t0 = time.perf_counter()
        eng.step()
        times.append(time.perf_counter() - t0)
    tok = jnp.zeros((n_requests, 1), jnp.int32)
    cache = eng.cache
    state = {"cache": cache}

    def decode_once():
        lg, state["cache"] = eng._decode_fn(params=eng.params, token=tok,
                                            cache=state["cache"])
        return lg

    compute = median_time(decode_once, reps=10, trials=1)
    p50 = statistics.median(times)
    return {"iteration_p50": p50, "jit_compute": compute,
            "host_overhead": max(p50 - compute, 0.0)}


def measure_engine_overheads(cfg, params, db, name: str = "repro-jax-cpu"):
    """Measure the engine's per-prefill-call and per-decode-iteration
    overheads and return a calibrated :class:`BackendProfile` (caller
    registers it via ``backends.base.register`` if wanted).

    This is the framework-specific-dynamics calibration the paper insists
    must be profiled per backend: jit dispatch, host argmax sync, and the
    engine's cache-insertion copy are all invisible to operator-level
    math, so they are measured as residuals against the operator model.
    """
    import numpy as np
    from repro.core import decompose
    from repro.core.backends.base import BackendProfile
    from repro.core.config import ParallelismConfig
    from repro.serving.engine import Engine, EngineConfig
    from repro.serving.request import Request
    from repro.serving.sim import StepSpec
    eng = Engine(cfg, params, EngineConfig(max_batch=2, max_seq=64))
    rng = np.random.default_rng(0)
    for i in range(2):
        eng.add_request(Request(rid=i, isl=16, osl=4, arrival=0.0,
                                prompt=rng.integers(0, cfg.vocab_size,
                                                    16).tolist()))
    eng.run_until_drained()                       # warm every jit
    t_prefills, t_decodes = [], []
    for trial in range(5):
        t0 = time.perf_counter()
        eng.add_request(Request(rid=50 + trial, isl=16, osl=3, arrival=t0,
                                prompt=rng.integers(0, cfg.vocab_size,
                                                    16).tolist()))
        eng.step()
        t_prefills.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        eng.step()
        t_decodes.append(time.perf_counter() - t0)
        eng.run_until_drained()
    t_prefill_call = statistics.median(t_prefills)
    t_decode_iter = statistics.median(t_decodes)
    # subtract the operator-modeled compute to isolate overheads
    par = ParallelismConfig(tp=1)
    comp_prefill = db.sequence_latency(decompose.iteration_ops(
        cfg, par, StepSpec(prefill=((16, 0),), decode=()), dtype="fp32"))
    comp_decode = db.sequence_latency(decompose.iteration_ops(
        cfg, par, StepSpec(prefill=(), decode=(17, 17)), dtype="fp32"))
    return BackendProfile(
        name=name,
        step_overhead=max(t_decode_iter - comp_decode, 1e-4),
        chunk_overhead=max(t_prefill_call - comp_prefill, 1e-3),
        runtime_mem_overhead=0.04,
        default_max_num_tokens=8192,
        graph_capture_saving=0.0,
        f_corr_base=1.0,
        sequential_prefill=True,
        launcher="python -m repro.launch.serve")
