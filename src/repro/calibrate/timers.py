"""Pluggable kernel timers for the measurement harness.

A timer maps ``(operator descriptor, kernel thunk) -> seconds``.  Two
implementations ship:

* :class:`WallClockTimer` — actually executes the kernel thunk (Pallas in
  interpret mode on CPU, compiled on a real TPU backend) and returns a
  median-of-trials wall-clock measurement.  This is the timer a real-TPU
  calibration run swaps in; on this CPU container it exercises the same
  code path through the interpreter.

* :class:`DeterministicTimer` — the CI timer.  It never executes the
  kernel: it derives a pseudo-measurement from the analytical model with a
  fixed per-family efficiency skew plus a small content-hashed jitter, so
  a CI run is bit-for-bit reproducible while still presenting the fitting
  layer with exactly the estimation problem real silicon poses (the
  analytical prediction is off by family-specific factors the fit must
  recover).

Both stamp a ``name`` recorded in the artifact's provenance, so a loaded
artifact always says how its numbers were obtained.
"""
from __future__ import annotations

import hashlib
import statistics
import time
from typing import Callable, Dict, Optional

from repro.core import analytical
from repro.core import operators as ops
from repro.core.hardware import Platform, get_platform

#: A kernel thunk: zero-arg callable running the kernel once and returning
#: something blockable (a jax array) or None.
Thunk = Callable[[], object]


def median_time(thunk: Thunk, reps: int = 3, trials: int = 3) -> float:
    """Median-of-trials wall-clock timing of ``thunk`` (seconds per call).

    The first call warms the jit (compile/trace time excluded); each trial
    then runs ``reps`` back-to-back calls and blocks on the last result.
    Single-shot CPU measurements swing ~35%, hence median-of-trials — the
    same discipline benchmarks/cpu_silicon_fidelity.py always used, now
    shared through the calibration subsystem.
    """
    out = thunk()
    _block(out)
    results = []
    for _ in range(trials):
        t0 = time.perf_counter()
        for _ in range(reps):
            out = thunk()
        _block(out)
        results.append((time.perf_counter() - t0) / reps)
    return statistics.median(results)


def _block(out) -> None:
    block = getattr(out, "block_until_ready", None)
    if block is not None:
        block()


class WallClockTimer:
    """Times the real kernel via :func:`median_time`."""

    name = "wallclock"

    def __init__(self, reps: int = 3, trials: int = 3):
        self.reps = reps
        self.trials = trials

    def time(self, op, thunk: Thunk) -> float:
        return median_time(thunk, reps=self.reps, trials=self.trials)


class DeterministicTimer:
    """Deterministic CI stand-in for silicon.

    ``measured = analytical.latency(platform, op) · skew[family] ·
    exp(jitter · u)`` with ``u ∈ [-1, 1]`` derived from a content hash of
    (family, op) — stable across runs, machines, and Python hash seeds.
    The default skews model a silicon whose flash attention runs hotter
    than the efficiency curves assume and whose decode path runs cooler;
    any profile can be injected to build test scenarios.
    """

    name = "deterministic"

    #: Family-specific "silicon disagrees with analytics by this factor".
    DEFAULT_SKEW: Dict[str, float] = {
        "gemm": 1.18,
        "attn_prefill": 1.34,
        "attn_decode": 0.91,
        "moe": 1.27,
        "recurrent": 1.12,
        "comm": 1.05,
    }

    def __init__(self, platform: "str | Platform",
                 skew: Optional[Dict[str, float]] = None,
                 jitter: float = 0.03):
        self.platform = (platform if isinstance(platform, Platform)
                         else get_platform(platform))
        self.skew = dict(self.DEFAULT_SKEW if skew is None else skew)
        self.jitter = jitter

    def time(self, op, thunk: Thunk) -> float:
        family = ops.op_family(op)
        base = analytical.latency(self.platform, op)
        factor = self.skew.get(family, 1.0)
        if self.jitter:
            digest = hashlib.sha256(
                f"{family}|{op!r}".encode()).digest()
            u = int.from_bytes(digest[:8], "big") / float(1 << 64)  # [0, 1)
            factor *= pow(2.718281828459045, self.jitter * (2.0 * u - 1.0))
        return base * factor


def make_timer(name: str, platform: "str | Platform",
               **kwargs) -> "WallClockTimer | DeterministicTimer":
    """Timer factory the CLI uses: ``deterministic`` or ``wallclock``."""
    if name == "deterministic":
        return DeterministicTimer(platform, **kwargs)
    if name == "wallclock":
        return WallClockTimer(**kwargs)
    raise ValueError(
        f"unknown timer {name!r}; valid choices: deterministic, wallclock")
