"""Qwen3-235B-A22B — paper §5.1 MoE fidelity model (128 experts top-8)
[hf:Qwen/Qwen3-235B-A22B]. Perf-model-only."""
from repro.configs.base import ModelConfig, ShardingRules

CONFIG = ModelConfig(
    name="qwen3-235b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    d_ff=1536,
    moe_d_ff=1536,
    vocab_size=151_936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    num_experts=128,
    top_k=8,
    perf_model_only=True,
    source="hf:Qwen/Qwen3-235B-A22B",
    sharding=ShardingRules(moe_mode="expert"),
)
