"""RecurrentGemma-2B — Griffin hybrid: RG-LRU + local attention, pattern
(rec, rec, attn) [arXiv:2402.19427]."""
from repro.configs.base import ModelConfig, ShardingRules

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,           # MQA on the local-attention layers
    d_ff=7680,
    vocab_size=256_000,
    lru_width=2560,
    conv_width=4,
    local_window=2048,
    rope_theta=10_000.0,
    tie_embeddings=True,
    source="arXiv:2402.19427",
    # 256k vocab: compute CE over sequence chunks to bound the fp32 logits;
    # associative-scan states at B=256 x S=4096 need 2-way grad accumulation
    sharding=ShardingRules(loss_chunk=512, microbatches=2),
)
