"""Mixtral-8x22B — MoE 8 experts top-2, SWA [arXiv:2401.04088]."""
from repro.configs.base import ModelConfig, ShardingRules

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16_384,
    moe_d_ff=16_384,
    vocab_size=32_768,
    sliding_window=4096,      # per assignment -> long_500k eligible
    rope_theta=1_000_000.0,
    num_experts=8,
    top_k=2,
    source="arXiv:2401.04088",
    # 8 experts cannot split over a 16-wide model axis -> TP the expert FFN
    # dim as the baseline (hillclimb explores expert x ffn hybrid).
    # 141B params + AdamW on 256 v5e chips is memory-tight: accumulate
    # gradients over 4 microbatches to bound the dispatch transients.
    sharding=ShardingRules(moe_mode="ffn", microbatches=4),
)
