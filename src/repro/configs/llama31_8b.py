"""Llama-3.1-8B — paper Table 1 search-efficiency model [arXiv:2407.21783].
Perf-model-only: used by the configurator benchmarks, not the dry-run matrix."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.1-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=128_256,
    rope_theta=500_000.0,
    perf_model_only=True,
    source="arXiv:2407.21783",
)
