"""xLSTM-350M — sLSTM + mLSTM blocks, d_ff=0 (projections inside blocks)
[arXiv:2405.04517]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    mlstm_proj_factor=2.0,
    slstm_proj_factor=4.0 / 3.0,
    source="arXiv:2405.04517",
)
