"""Qwen3-32B — paper §5.1/§5.4 fidelity + case-study model [hf:Qwen/Qwen3-32B].
Perf-model-only."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    d_ff=25_600,
    vocab_size=151_936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    perf_model_only=True,
    source="hf:Qwen/Qwen3-32B",
)
