"""Architecture config registry.

``get_config(arch_id)`` returns the exact assigned full-size config;
``list_archs()`` enumerates the dry-run matrix archs (perf-model-only
configs like the paper's eval models are excluded from the matrix).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig, InputShape, INPUT_SHAPES, ShardingRules

_ARCH_MODULES = {
    # assigned pool (dry-run matrix)
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "h2o-danube-3-4b": "repro.configs.h2o_danube3_4b",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "whisper-small": "repro.configs.whisper_small",
    "qwen2-7b": "repro.configs.qwen2_7b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "internlm2-1.8b": "repro.configs.internlm2_1_8b",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "xlstm-350m": "repro.configs.xlstm_350m",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    # the paper's own evaluation models (perf-model benchmarks only)
    "llama3.1-8b": "repro.configs.llama31_8b",
    "qwen3-32b": "repro.configs.qwen3_32b",
    "qwen3-235b": "repro.configs.qwen3_235b",
    "deepseek-v3": "repro.configs.deepseek_v3",
}


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(_ARCH_MODULES[arch])
    return mod.CONFIG


def list_archs(include_perf_only: bool = False) -> List[str]:
    out = []
    for name in _ARCH_MODULES:
        cfg = get_config(name)
        if cfg.perf_model_only and not include_perf_only:
            continue
        out.append(name)
    return out


def dryrun_pairs() -> List[tuple]:
    """The (arch, shape) dry-run matrix with the documented long_500k skips."""
    pairs = []
    for arch in list_archs():
        cfg = get_config(arch)
        for shape_name, shape in INPUT_SHAPES.items():
            if shape_name == "long_500k" and not cfg.sub_quadratic:
                continue  # full-attention arch: skip per DESIGN.md §5
            pairs.append((arch, shape_name))
    return pairs


__all__ = [
    "ModelConfig", "InputShape", "INPUT_SHAPES", "ShardingRules",
    "get_config", "list_archs", "dryrun_pairs",
]
