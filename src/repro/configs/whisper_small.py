"""Whisper-small — enc-dec audio; conv frontend is a STUB (input_specs
provides precomputed frame embeddings) [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig, ShardingRules

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,            # decoder layers
    d_model=768,
    num_heads=12,
    num_kv_heads=12,          # MHA (kv=12)
    d_ff=3072,
    vocab_size=51_865,
    rope_theta=10_000.0,      # (whisper uses learned abs pos; we use sinusoidal-equiv)
    is_encoder_decoder=True,
    encoder_layers=12,
    num_source_positions=1500,
    attention_kind="mha",
    source="arXiv:2212.04356",
    # enc(1500 frames) + dec(4k) at global batch 256 needs microbatching
    # to fit v5e HBM at train_4k
    sharding=ShardingRules(microbatches=4),
)
