"""Qwen2-VL-2B — VLM decoder with M-RoPE; ViT frontend is a STUB
(input_specs provides precomputed patch embeddings) [arXiv:2409.12191]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151_936,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(16, 24, 24),   # t/h/w frequency sections (head_dim/2 = 64)
    num_image_tokens=256,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="arXiv:2409.12191",
)
