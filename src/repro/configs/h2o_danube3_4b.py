"""H2O-Danube-3-4B — dense, llama+mistral mix with SWA [arXiv:2401.16818]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    d_ff=10_240,
    vocab_size=32_000,
    sliding_window=4096,      # mistral-style SWA -> long_500k eligible
    rope_theta=10_000.0,
    source="arXiv:2401.16818",
)
