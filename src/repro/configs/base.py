"""Shared architecture-config dataclasses.

Every assigned architecture gets one ``<arch>.py`` in this package exporting
``CONFIG`` (the exact full-size config from the assignment) built on
:class:`ModelConfig`.  ``ModelConfig.reduced()`` derives the smoke-test
variant (2 layers, d_model<=512, <=4 experts) used by CPU tests; the full
configs are exercised only through the dry-run (ShapeDtypeStruct, no
allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical-axis -> mesh-axis mapping knobs (the hillclimb surface).

    The dry-run/launch layer turns these into NamedShardings.  ``model``
    here always refers to the mesh axis named 'model'; batch is sharded on
    ('pod', 'data') when present.
    """
    # How to shard the MoE expert weights: 'expert' = expert-parallel on the
    # model axis (requires num_experts % model_axis == 0), 'ffn' = tensor-
    # parallel on the per-expert FFN dim, 'expert_ffn' = split model axis
    # between both (requires both divisibility).
    moe_mode: str = "expert"
    # Shard attention heads on the model axis (megatron TP).
    shard_heads: bool = True
    # Shard vocab/embedding on the model axis.
    shard_vocab: bool = True
    # Shard the dense-FFN hidden dim on the model axis.
    shard_ffn: bool = True
    # Shard long-context decode KV cache sequence dim on the data axis
    # (context-parallel decode for batch==1 shapes).
    shard_kv_seq: bool = False
    # Activation remat policy for training: 'none' | 'full' | 'dots'
    remat: str = "full"
    # Compute cross-entropy loss in vocab chunks of this size (0 = one shot).
    loss_chunk: int = 0
    # Gradient-accumulation microbatches per train step (1 = none).
    microbatches: int = 1
    # Pin decode-attention q/logits shardings to the KV-cache layout,
    # eliminating GSPMD's involuntary per-step cache rematerialization
    # (perf-iteration knob; see EXPERIMENTS.md §Perf).
    decode_attn_pin: bool = False
    # Blockwise (prefill/train) attention: shard the q-block row dim on the
    # model axis with K/V model-replicated — removes the per-block partial-
    # logit all-reduces GSPMD emits when head counts don't divide the axis.
    blockwise_q_shard: bool = False
    # ffn-TP MoE: keep the down-proj output D-sharded so the partial-sum
    # combine lowers to reduce-scatter (half the all-reduce wire bytes).
    moe_down_rs: bool = False
    # On the 3-axis expert mesh: TP the per-expert FFN over the residual
    # 'model' axis (True) or keep experts whole per device (False — trades
    # MoE flops for zero partial-sum all-reduces).
    moe_ffn_tp: bool = True
    # Store the decode KV cache in int8 with per-(token, head) scales —
    # halves the decode memory term (dense/vlm families).
    kv_quant: bool = False


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    source: str = ""                 # citation from the assignment

    # ---- attention variants ----
    qk_norm: bool = False            # qwen3-style per-head RMSNorm on q,k
    qkv_bias: bool = False           # qwen2-style bias on qkv projections
    sliding_window: int = 0          # 0 = full attention; else SWA width
    swa_every: int = 1               # apply SWA on layers where i % swa_every != swa_full_idx
    rope_theta: float = 1_000_000.0

    # ---- MoE ----
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim (0 -> d_ff)
    capacity_factor: float = 1.25    # GShard expert-capacity factor
    n_shared_experts: int = 0        # DeepSeek-style always-on experts

    # ---- hybrid (RG-LRU / Griffin) ----
    block_pattern: Tuple[str, ...] = ()   # per-layer kinds, len == num_layers
    lru_width: int = 0                    # RG-LRU recurrence width (0 -> d_model)
    conv_width: int = 4
    local_window: int = 2048              # local-attention window for hybrid

    # ---- ssm (xLSTM) ----
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0

    # ---- encoder-decoder (whisper) ----
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    num_source_positions: int = 1500      # whisper: 30s audio -> 1500 frames

    # ---- vlm ----
    mrope: bool = False
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    num_image_tokens: int = 256           # stub ViT patch-embedding count

    # ---- misc ----
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    sharding: ShardingRules = dataclasses.field(default_factory=ShardingRules)

    # Architectures that only exist for the perf-model benchmarks (the
    # paper's own eval models); they are not part of the dry-run matrix.
    perf_model_only: bool = False
    attention_kind: str = "gqa"           # mha | gqa | mla (perf DB operator kind)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_experts and self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        if self.family == "hybrid" and not self.block_pattern:
            # Griffin/RecurrentGemma pattern: (rec, rec, attn) repeating.
            pat = []
            for i in range(self.num_layers):
                pat.append("attn" if i % 3 == 2 else "rec")
            object.__setattr__(self, "block_pattern", tuple(pat))
        if self.family == "ssm" and not self.block_pattern:
            # xLSTM: alternate mLSTM / sLSTM blocks.
            pat = tuple("m" if i % 2 == 0 else "s" for i in range(self.num_layers))
            object.__setattr__(self, "block_pattern", pat)
        if self.family == "hybrid" and self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)

    # ------------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff if self.num_experts else self.d_ff

    def kv_cache_len(self, seq_len: int, layer_kind: str = "attn") -> int:
        """Per-layer KV length a decode cache actually stores."""
        if layer_kind == "rec" or self.family == "ssm":
            return 0
        win = self.local_window if self.family == "hybrid" else self.sliding_window
        if win:
            return min(seq_len, win)
        return seq_len

    @property
    def sub_quadratic(self) -> bool:
        """True if long-context decode state is bounded (SWA/recurrent)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0

    def param_count(self) -> int:
        """Approximate total parameter count (for roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.head_dim
        attn = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) \
            + (self.num_heads * hd) * d
        if self.num_experts:
            ffn = ((self.num_experts + self.n_shared_experts) * 3 * d
                   * self.moe_d_ff + d * self.num_experts)
        else:
            ffn = 3 * d * self.d_ff
        per_layer = attn + ffn
        if self.family == "hybrid":
            # recurrent layers replace attention with LRU block (~4*d*lru).
            n_attn = sum(1 for k in self.block_pattern if k == "attn")
            n_rec = self.num_layers - n_attn
            per_layer = 0
            total = n_attn * (attn + ffn) + n_rec * (4 * d * self.lru_width + ffn)
        elif self.family == "ssm":
            up_m = int(self.d_model * self.mlstm_proj_factor)
            m_blk = 2 * d * up_m + 3 * up_m * up_m // 4 + up_m * d
            s_blk = 4 * d * d + int(2 * d * d * self.slstm_proj_factor)
            n_m = sum(1 for k in self.block_pattern if k == "m")
            total = n_m * m_blk + (self.num_layers - n_m) * s_blk
        else:
            total = self.num_layers * per_layer
        if self.is_encoder_decoder:
            total += self.encoder_layers * (2 * attn + ffn)  # self+cross enc approx
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return int(total + emb)

    def active_param_count(self) -> int:
        """Activated params per token (MoE uses top_k of num_experts)."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        dense_part = self.param_count() - self.num_layers * 3 * d * self.moe_d_ff * self.num_experts
        return int(dense_part + self.num_layers * 3 * d * self.moe_d_ff * self.top_k)

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: tiny but same family/topology knobs."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.num_heads, 4)
        head_dim = min(self.head_dim, 64)
        n_kv = max(1, min(self.num_kv_heads, n_heads))
        n_layers = 4 if self.family in ("hybrid", "ssm") else 2
        kw = dict(
            name=self.name + "-reduced",
            family=self.family,
            num_layers=n_layers,
            d_model=d_model,
            num_heads=n_heads,
            num_kv_heads=n_kv,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 1024),
            head_dim=head_dim,
            qk_norm=self.qk_norm,
            qkv_bias=self.qkv_bias,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            rope_theta=self.rope_theta,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_d_ff=min(self.moe_d_ff, 128) if self.num_experts else 0,
            # tiny random routers are heavily imbalanced; avoid drops so the
            # smoke tests can assert decode == forward exactly
            capacity_factor=8.0,
            block_pattern=(),
            lru_width=0,
            local_window=16,
            conv_width=self.conv_width,
            is_encoder_decoder=self.is_encoder_decoder,
            encoder_layers=2 if self.is_encoder_decoder else 0,
            num_source_positions=8 if self.is_encoder_decoder else self.num_source_positions,
            mrope=self.mrope,
            mrope_sections=(8, 12, 12) if self.mrope else self.mrope_sections,
            num_image_tokens=4 if self.family == "vlm" else self.num_image_tokens,
            tie_embeddings=self.tie_embeddings,
            norm_eps=self.norm_eps,
            dtype="float32",
            attention_kind=self.attention_kind,
        )
        return ModelConfig(**kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned (seq_len, global_batch) workload shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
