"""Qwen3-30B-A3B — MoE, 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ModelConfig, ShardingRules

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=768,                 # per-expert moe_intermediate_size
    moe_d_ff=768,
    vocab_size=151_936,
    head_dim=128,             # Qwen3 MoE uses head_dim 128 (q_dim 4096 > d_model)
    qk_norm=True,
    rope_theta=1_000_000.0,
    num_experts=128,
    top_k=8,
    source="hf:Qwen/Qwen3-30B-A3B",
    # 128 experts % 16 model-axis == 0 -> expert-parallel baseline.
    sharding=ShardingRules(moe_mode="expert"),
)
