"""DeepSeek-V3 — paper §5.2 disaggregated-fidelity model (671B MoE, MLA)
[arXiv:2412.19437]. Perf-model-only: MLA enters the perf DB as its own
attention-operator kind."""
from repro.configs.base import ModelConfig, ShardingRules

CONFIG = ModelConfig(
    name="deepseek-v3",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,          # MLA: latent cache, kv head count nominal
    d_ff=2048,
    moe_d_ff=2048,
    vocab_size=129_280,
    head_dim=128,
    rope_theta=10_000.0,
    num_experts=256,
    top_k=8,
    n_shared_experts=1,
    attention_kind="mla",
    perf_model_only=True,
    source="arXiv:2412.19437",
    sharding=ShardingRules(moe_mode="expert"),
)
