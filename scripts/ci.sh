#!/usr/bin/env bash
# Tier-1 CI: full test suite + CLI JSON smoke test.
# Run from the repo root: bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

echo "=== tier-1: pytest ==="
python -m pytest -x -q

echo "=== smoke: search --json emits valid SearchReport JSON on stdout ==="
PYTHONPATH=src python -m repro.core.cli search \
    --model qwen3-32b --isl 512 --osl 64 --chips 8 --json \
  | python -c '
import json
import sys

report = json.load(sys.stdin)
version = report["schema_version"]
n_projections = len(report["projections"])
best_index = report["best"]
assert version == 1, version
assert n_projections > 0, "search produced no projections"
print(f"ok: schema v{version}, {n_projections} projections, "
      f"best index {best_index}")
'

echo "=== ci passed ==="
