#!/usr/bin/env bash
# Tier-1 CI: fast suite, slow suite, CLI JSON smoke test, streaming smoke,
# calibration smoke, workload-trace smoke, capacity smoke, autoscale smoke,
# observability smoke (trace/metrics determinism + explain attribution),
# bench sentinel (deterministic work counters + regression gate).
# Run from the repo root: bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

echo "=== tier-1 (fast): pytest -m 'not slow' ==="
python -m pytest -x -q -m "not slow"

echo "=== tier-2 (slow): pytest -m slow ==="
python -m pytest -x -q -m slow

echo "=== smoke: search --json emits valid SearchReport JSON on stdout ==="
PYTHONPATH=src python -m repro.core.cli search \
    --model qwen3-32b --isl 512 --osl 64 --chips 8 --json \
  | python -c '
import json
import sys

report = json.load(sys.stdin)
version = report["schema_version"]
n_projections = len(report["projections"])
best_index = report["best"]
assert version == 7, version
assert n_projections > 0, "search produced no projections"
assert report["database"]["platform"] == "tpu_v5e", report["database"]
assert len(report["memory"]["per_candidate_bytes_per_chip"]) \
    == n_projections, "memory section must cover every projection"
print(f"ok: schema v{version}, {n_projections} projections, "
      f"best index {best_index}")
'

echo "=== smoke: search --stream survives an early-exiting consumer ==="
# The consumer reads 5 records and exits; the producer must shut down
# cleanly (exit 0, no BrokenPipeError traceback) under pipefail.
stream_err=$(mktemp)
PYTHONPATH=src python -m repro.core.cli search \
    --model llama3.1-8b --isl 256 --osl 64 --chips 8 --dtype fp8 \
    --modes aggregated --stream 2>"$stream_err" \
  | python -c '
import json
import sys

for i, line in zip(range(5), sys.stdin):
    record = json.loads(line)
    assert record["type"] in ("candidate", "summary"), record
sys.exit(0)   # close the pipe with the producer mid-sweep
'
if grep -q "BrokenPipeError" "$stream_err"; then
    echo "streaming producer leaked a BrokenPipeError:" >&2
    cat "$stream_err" >&2
    rm -f "$stream_err"
    exit 1
fi
rm -f "$stream_err"
echo "ok: early-exiting consumer, clean shutdown"

echo "=== smoke: search --stream --first-n emits an early_exit summary ==="
PYTHONPATH=src python -m repro.core.cli search \
    --model llama3.1-8b --isl 256 --osl 64 --chips 8 --dtype fp8 \
    --ttft 2000 --min-speed 10 --modes aggregated --stream --first-n 3 \
  | python -c '
import json
import sys

records = [json.loads(line) for line in sys.stdin if line.strip()]
summary = records[-1]
assert summary["type"] == "summary", summary
assert summary["early_exit"] is not None, "expected an early-exit record"
assert summary["n_valid"] == 3, summary["n_valid"]
n_candidates = summary["n_candidates"]
reason = summary["early_exit"]["reason"]
print(f"ok: early exit after {n_candidates} candidates ({reason})")
'

echo "=== smoke: calibrate run -> artifact round-trips, MAPE finite ==="
# Tiny grid on the deterministic (CI-reproducible) timer: the artifact
# must reload losslessly and the accuracy report must show finite MAPE
# with calibrated <= uncalibrated on every measured family.
cal_dir=$(mktemp -d)
PYTHONPATH=src python -m repro.core.cli calibrate run \
    --timer deterministic --points 2 \
    --timestamp 2026-01-01T00:00:00Z --out "$cal_dir/cal.json" \
  > /dev/null
PYTHONPATH=src python - "$cal_dir/cal.json" <<'PY'
import math
import sys

from repro.calibrate import CalibrationArtifact, accuracy_report

path = sys.argv[1]
art = CalibrationArtifact.load(path)
again = CalibrationArtifact.from_json(art.to_json())
assert again == art, "artifact did not round-trip losslessly"
report = accuracy_report(art)
for family, row in report["families"].items():
    assert math.isfinite(row["mape_calibrated"]), family
    assert row["mape_calibrated"] <= row["mape_uncalibrated"], family
overall = report["overall"]
print(f"ok: {overall['n_samples']} samples, MAPE "
      f"{overall['mape_uncalibrated']:.1f}% -> "
      f"{overall['mape_calibrated']:.1f}% calibrated "
      f"(digest {art.digest()})")
PY
rm -rf "$cal_dir"

echo "=== smoke: workload generate -> replay emits finite goodput ==="
# Tiny seeded trace: generation must be digest-stable across runs, and
# an open-loop replay must produce finite goodput/attainment.
wl_dir=$(mktemp -d)
PYTHONPATH=src python -m repro.core.cli workload generate \
    --arrivals bursty --rate 4 --n 24 --lengths lognormal \
    --isl 128 --osl 32 --tenants "chat:0.7:1,batch:0.3" --seed 7 \
    --out "$wl_dir/trace.jsonl" --json > "$wl_dir/gen1.json"
PYTHONPATH=src python -m repro.core.cli workload generate \
    --arrivals bursty --rate 4 --n 24 --lengths lognormal \
    --isl 128 --osl 32 --tenants "chat:0.7:1,batch:0.3" --seed 7 \
    --out "$wl_dir/trace2.jsonl" --json > "$wl_dir/gen2.json"
PYTHONPATH=src python -m repro.core.cli workload replay \
    --trace "$wl_dir/trace.jsonl" --model llama3.1-8b --tp 2 --batch 32 \
    --dtype fp8 --slo-ttft-p99 2000 --slo-tpot-p99 100 --json \
  > "$wl_dir/replay.json"
PYTHONPATH=src python - "$wl_dir" <<'PY'
import json
import math
import sys

wl_dir = sys.argv[1]
gen1 = json.load(open(f"{wl_dir}/gen1.json"))
gen2 = json.load(open(f"{wl_dir}/gen2.json"))
digest = gen1["describe"]["digest"]
assert digest == gen2["describe"]["digest"], "trace digest is not stable"
replay = json.load(open(f"{wl_dir}/replay.json"))
assert replay["trace"]["digest"] == digest, "replay saw a different trace"
m = replay["metrics"]
assert m["completed"] + m["rejected"] + m["unfinished"] == 24, m
assert math.isfinite(m["goodput_tok_s"]), m["goodput_tok_s"]
assert math.isfinite(m["throughput_tok_s"]), m["throughput_tok_s"]
assert 0.0 <= m["slo_attainment"] <= 1.0, m["slo_attainment"]
print(f"ok: trace {digest}, {m['completed']} completed, goodput "
      f"{m['goodput_tok_s']:.1f} tok/s at "
      f"{100 * m['slo_attainment']:.0f}% attainment")
PY
rm -rf "$wl_dir"

echo "=== smoke: capacity sweep --json finds a deterministic min-chip plan ==="
# Seeded bursty trace over a 3-rung ladder: the sweep must report a
# finite min-chip plan and emit byte-identical records across two runs.
cap_dir=$(mktemp -d)
PYTHONPATH=src python -m repro.core.cli workload generate \
    --arrivals bursty --rate 60 --burst-factor 4 --n 60 \
    --lengths lognormal --isl 256 --osl 64 \
    --tenants "chat:0.7:1,batch:0.3" --seed 7 \
    --out "$cap_dir/trace.jsonl" > /dev/null
for i in 1 2; do
    PYTHONPATH=src python -m repro.core.cli capacity sweep \
        --trace "$cap_dir/trace.jsonl" --model llama3.1-8b \
        --tp 1 --batch 64 --dtype fp8 --ladder 1,2,4 \
        --routing least_outstanding \
        --slo-ttft-p99 400 --slo-tpot-p99 50 --json \
      > "$cap_dir/sweep$i.jsonl"
done
cmp "$cap_dir/sweep1.jsonl" "$cap_dir/sweep2.jsonl" \
    || { echo "capacity sweep output is not deterministic" >&2; exit 1; }
PYTHONPATH=src python - "$cap_dir/sweep1.jsonl" <<'PY'
import json
import math
import sys

records = [json.loads(line) for line in open(sys.argv[1])]
summary = records[-1]
assert summary["type"] == "summary", summary
plan = summary["plan"]
assert plan is not None, "expected a min-chip plan on the ladder"
assert math.isfinite(plan["goodput_tok_s"]), plan
assert plan["total_chips"] >= 1, plan
rungs = [r for r in records[:-1] if r["pruned"] is None]
cheaper = [r for r in rungs if r["total_chips"] < plan["total_chips"]]
assert cheaper and all(not r["attains"] for r in cheaper), \
    "expected every cheaper rung to miss the SLO"
print(f"ok: min-chip {plan['deployment']['describe']} = "
      f"{plan['total_chips']} chips "
      f"({100 * plan['slo_attainment']:.0f}% attainment), "
      f"deterministic across runs")
PY
rm -rf "$cap_dir"

echo "=== smoke: autoscale compare --json saves chips while holding the SLO ==="
# Seeded diurnal trace: the autoscaled run must spend fewer chip-seconds
# than the static min-chip plan, hold the attainment target, and emit
# byte-identical output across two runs.
asc_dir=$(mktemp -d)
PYTHONPATH=src python -m repro.core.cli workload generate \
    --arrivals diurnal --rate 1.2 --period 60 --amplitude 0.9 --n 250 \
    --lengths fixed --isl 512 --osl 128 --seed 11 \
    --out "$asc_dir/trace.jsonl" > /dev/null
for i in 1 2; do
    PYTHONPATH=src python -m repro.core.cli autoscale compare \
        --trace "$asc_dir/trace.jsonl" --model qwen3-32b \
        --tp 1 --batch 16 --ladder 1,2,4 \
        --policy target_queue_depth --target-depth 6 --max-replicas 2 \
        --up-cooldown 2 --down-cooldown 8 --window 5 \
        --tick 1 --cold-start 2 \
        --slo-ttft-p99 2500 --slo-tpot-p99 100 --json \
      > "$asc_dir/compare$i.jsonl"
done
cmp "$asc_dir/compare1.jsonl" "$asc_dir/compare2.jsonl" \
    || { echo "autoscale compare output is not deterministic" >&2; exit 1; }
PYTHONPATH=src python - "$asc_dir/compare1.jsonl" <<'PY'
import json
import math
import sys

records = [json.loads(line) for line in open(sys.argv[1])]
summary = records[-1]
assert summary["type"] == "summary", summary["type"]
static = summary["static"]
assert static is not None, "expected an attaining static plan"
run = summary["run"]
assert math.isfinite(run["chip_seconds"]), run["chip_seconds"]
assert run["chip_seconds"] < static["chip_seconds"], \
    (run["chip_seconds"], static["chip_seconds"])
savings = summary["savings"]
assert savings["holds_attainment"], savings
samples = [r for r in records[:-1] if r["type"] == "sample"]
assert samples, "expected timeline sample records"
assert len(samples) == run["timeline"]["n_samples"], \
    (len(samples), run["timeline"]["n_samples"])
print(f"ok: {run['chip_seconds']:.0f} chip-s autoscaled vs "
      f"{static['chip_seconds']:.0f} static "
      f"({savings['chip_seconds_pct']:.1f}% saved), attainment held, "
      f"deterministic across runs")
PY
rm -rf "$asc_dir"

echo "=== smoke: batched pricing matches the scalar frontier, >=10x kernel ==="
# Quick arm of the Table-1 batched benchmark: runs the scalar and batched
# search paths on one model, asserts frontier identity + float parity and
# a >=10x pricing-kernel speedup (the full >=50x gate runs with the
# benchmark suite, not in CI).
PYTHONPATH=src:. python benchmarks/table1_search_efficiency.py \
    --batched --quick

echo "=== smoke: REPRO_BATCHED_PRICING=0/1 agree on the CLI ranking ==="
bp_dir=$(mktemp -d)
for b in 0 1; do
    REPRO_BATCHED_PRICING=$b PYTHONPATH=src python -m repro.core.cli search \
        --model qwen3-32b --isl 512 --osl 64 --chips 8 --json \
      > "$bp_dir/search$b.json"
done
PYTHONPATH=src python - "$bp_dir" <<'PY'
import json
import sys

d = sys.argv[1]
scalar = json.load(open(f"{d}/search0.json"))
batched = json.load(open(f"{d}/search1.json"))
key = lambda r: [(p["mode"], p["config"].get("describe"))
                 for p in r["projections"]]
assert key(scalar) == key(batched), \
    "scalar and batched searches rank candidates differently"
assert scalar["best"] == batched["best"], (scalar["best"], batched["best"])
print(f"ok: {len(scalar['projections'])} projections identical, "
      f"best index {scalar['best']}")
PY
rm -rf "$bp_dir"

echo "=== smoke: obs — deterministic trace + metrics, zero-cost when off ==="
# Two seeded instrumented searches must write byte-identical trace and
# metrics artifacts; counters must be finite and nonzero; and enabling
# tracing must not perturb a single candidate record.
obs_dir=$(mktemp -d)
for i in 1 2; do
    PYTHONPATH=src python -m repro.core.cli search \
        --model llama3.1-8b --isl 256 --osl 64 --chips 8 --dtype fp8 \
        --modes aggregated --json \
        --trace-out "$obs_dir/trace$i.jsonl" \
        --metrics-out "$obs_dir/metrics$i.json" > /dev/null
done
cmp "$obs_dir/trace1.jsonl" "$obs_dir/trace2.jsonl" \
    || { echo "trace artifact is not deterministic" >&2; exit 1; }
cmp "$obs_dir/metrics1.json" "$obs_dir/metrics2.json" \
    || { echo "metrics snapshot is not deterministic" >&2; exit 1; }
PYTHONPATH=src python - "$obs_dir" <<'PY'
import json
import math
import sys

from repro.obs.trace import TraceArtifact

d = sys.argv[1]
art = TraceArtifact.load(f"{d}/trace1.jsonl")
assert art.n_spans > 0, "trace captured no spans"
names = {s.name for s in art.spans}
assert {"search.chunk", "price.kernel"} <= names, names
counters = json.load(open(f"{d}/metrics1.json"))["counters"]
assert counters, "no counters recorded"
assert all(math.isfinite(v) for v in counters.values()), counters
chunks = sum(v for k, v in counters.items()
             if k.startswith("repro_search_chunks_total"))
priced = sum(v for k, v in counters.items()
             if k.startswith("repro_search_candidates_priced_total"))
assert chunks >= 1 and priced >= 1, (chunks, priced)
print(f"ok: {art.n_spans} spans (digest {art.digest()}), "
      f"{len(counters)} counters, {priced:.0f} candidates priced")
PY
PYTHONPATH=src python -m repro.core.cli search \
    --model llama3.1-8b --isl 256 --osl 64 --chips 8 --dtype fp8 \
    --modes aggregated --stream \
  | grep '"type": "candidate"' > "$obs_dir/plain.jsonl"
PYTHONPATH=src python -m repro.core.cli search \
    --model llama3.1-8b --isl 256 --osl 64 --chips 8 --dtype fp8 \
    --modes aggregated --stream --trace-out "$obs_dir/t.jsonl" \
  | grep '"type": "candidate"' > "$obs_dir/traced.jsonl"
cmp "$obs_dir/plain.jsonl" "$obs_dir/traced.jsonl" \
    || { echo "enabling tracing perturbed the search output" >&2; exit 1; }
echo "ok: candidate stream byte-identical with tracing on and off"

echo "=== smoke: flight recorder — Chrome trace valid + replay byte-identity ==="
# A seeded replay with the flight recorder on must (a) write a valid,
# byte-deterministic Chrome trace_event export with per-request lanes,
# (b) leave the replay JSON byte-identical to an uninstrumented run,
# and (c) record sampled spans within a generous wallclock factor of
# the tracing-off replay.
fr_dir=$(mktemp -d)
PYTHONPATH=src python -m repro.core.cli workload generate \
    --arrivals poisson --rate 6 --n 80 --lengths fixed \
    --isl 128 --osl 32 --seed 5 --out "$fr_dir/trace.jsonl" > /dev/null
for i in 1 2; do
    PYTHONPATH=src python -m repro.core.cli workload replay \
        --trace "$fr_dir/trace.jsonl" --model llama3.1-8b \
        --tp 1 --batch 16 --dtype fp8 --json \
        --trace-out "$fr_dir/t$i.chrome.json" \
        --metrics-out "$fr_dir/m$i.json" \
      > "$fr_dir/replay$i.json"
done
cmp "$fr_dir/t1.chrome.json" "$fr_dir/t2.chrome.json" \
    || { echo "chrome trace export is not deterministic" >&2; exit 1; }
cmp "$fr_dir/m1.json" "$fr_dir/m2.json" \
    || { echo "replay metrics snapshot is not deterministic" >&2; exit 1; }
PYTHONPATH=src python -m repro.core.cli workload replay \
    --trace "$fr_dir/trace.jsonl" --model llama3.1-8b \
    --tp 1 --batch 16 --dtype fp8 --json > "$fr_dir/replay_plain.json"
cmp "$fr_dir/replay1.json" "$fr_dir/replay_plain.json" \
    || { echo "flight recorder perturbed the replay output" >&2; exit 1; }
PYTHONPATH=src python - "$fr_dir" <<'PY'
import json
import sys
import time

d = sys.argv[1]
ct = json.load(open(f"{d}/t1.chrome.json"))
events = [e for e in ct["traceEvents"] if e["ph"] == "X"]
assert events, "chrome export carries no complete events"
for e in events:
    missing = {"name", "ph", "ts", "dur", "pid", "tid"} - set(e)
    assert not missing, (e["name"], missing)
    assert e["dur"] >= 0, e
reqs = [e for e in events if e["name"] == "request"]
assert len(reqs) == 80, len(reqs)
lanes = {(e["pid"], e["tid"]) for e in reqs}
assert len(lanes) == 80, "expected one lane per request"
hists = json.load(open(f"{d}/m1.json"))["histograms"]
h = hists["repro_request_ttft_ms{sim=serving}"]
assert sum(h["counts"]) == h["count"] == 80, h["count"]

# overhead: sampled span recording must stay within a generous factor
# of the tracing-off replay (it runs after the simulation loop, so the
# bound is loose by design — this guards against quadratic blowups)
sys.path.insert(0, "src")
from repro.obs import disable_tracing, enable_tracing
from repro.serving.scheduler import SchedulerConfig
from repro.serving.sim import ServingSimulator
from repro.workloads import WorkloadTrace

trace = WorkloadTrace.load(f"{d}/trace.jsonl")
cfg = SchedulerConfig(max_batch=16)
lat = lambda s: 1e-3 + 1e-5 * len(s.decode)
def bench(instrumented):
    best = float("inf")
    for _ in range(3):
        if instrumented:
            enable_tracing()
        t0 = time.perf_counter()
        ServingSimulator(cfg, lat).replay(trace)
        best = min(best, time.perf_counter() - t0)
        disable_tracing()
    return best
off, on = bench(False), bench(True)
assert on <= 25 * off + 0.05, f"span recording overhead: {on:.4f}s vs {off:.4f}s"
print(f"ok: 80 request lanes, deterministic chrome export, replay "
      f"byte-identical; span overhead {on / max(off, 1e-9):.1f}x "
      f"(bound 25x)")
PY
rm -rf "$fr_dir"

echo "=== smoke: obs diff — regression detection on replay snapshots ==="
od_dir=$(mktemp -d)
PYTHONPATH=src python -m repro.core.cli workload generate \
    --arrivals poisson --rate 6 --n 40 --lengths fixed \
    --isl 128 --osl 32 --seed 5 --out "$od_dir/trace.jsonl" > /dev/null
PYTHONPATH=src python -m repro.core.cli workload replay \
    --trace "$od_dir/trace.jsonl" --model llama3.1-8b --tp 1 --batch 16 \
    --dtype fp8 --json --metrics-out "$od_dir/a.json" > /dev/null
PYTHONPATH=src python -m repro.core.cli workload replay \
    --trace "$od_dir/trace.jsonl" --model llama3.1-8b --tp 1 --batch 1 \
    --dtype fp8 --json --metrics-out "$od_dir/b.json" > /dev/null
PYTHONPATH=src python -m repro.core.cli obs diff \
    "$od_dir/a.json" "$od_dir/a.json" > /dev/null \
    || { echo "obs diff flagged identical snapshots" >&2; exit 1; }
if PYTHONPATH=src python -m repro.core.cli obs diff \
    "$od_dir/a.json" "$od_dir/b.json" > "$od_dir/diff.txt"; then
    echo "obs diff missed a real regression" >&2; exit 1
fi
grep -q "repro_request_ttft_ms" "$od_dir/diff.txt" \
    || { echo "obs diff did not report the TTFT shift" >&2; exit 1; }
echo "ok: obs diff exits 0 on identical, 1 with the TTFT shift reported"
rm -rf "$od_dir"

echo "=== smoke: explain — the waterfall adds back up to the iteration ==="
PYTHONPATH=src python -m repro.core.cli explain \
    --model llama3.1-8b --isl 256 --osl 64 --chips 8 --dtype fp8 \
    --modes aggregated --rank 0 --baseline 1 --json \
  > "$obs_dir/explain.json"
PYTHONPATH=src python - "$obs_dir/explain.json" <<'PY'
import json
import math
import sys

ex = json.load(open(sys.argv[1]))
cand = ex["candidate"]
total = sum(p["total_ms"] for p in cand["phases"])
assert math.isfinite(total) and total > 0, total
assert abs(total - cand["total_ms"]) <= 1e-9 * cand["total_ms"], \
    (total, cand["total_ms"])
assert ex["baseline"] is not None and ex["diff"] is not None
print(f"ok: {cand['describe']} = {total:.3f} ms/iteration attributed, "
      f"diff vs {ex['baseline']['describe']}")
PY
rm -rf "$obs_dir"

echo "=== smoke: bench sentinel — deterministic counters + regression gate ==="
# Two identical quick-suite runs must produce byte-identical work
# counters (compare exit 0); the current run must hold the committed
# counter baseline (gate exit 0); and an injected pricing regression
# (REPRO_PRICING_CHUNK=1 inflates repro_search_chunks_total) must fail
# the gate (exit 1). See docs/benchmarking.md.
bsn_dir=$(mktemp -d)
for i in 1 2; do
    PYTHONPATH=src python -m benchmarks.run --quick \
        --timestamp 2026-01-01T00:00:00Z \
        --out "$bsn_dir/run$i.json" --history "$bsn_dir/history.jsonl" \
      > /dev/null
done
PYTHONPATH=src python -m repro.core.cli obs bench compare \
    "$bsn_dir/run1.json" "$bsn_dir/run2.json" > /dev/null \
  || { echo "quick-suite work counters drifted between identical runs" >&2
       exit 1; }
PYTHONPATH=src python -m repro.core.cli obs bench gate \
    --baseline results/baselines/bench_quick.json \
    --current "$bsn_dir/run1.json" --hard-only > /dev/null \
  || { echo "work counters regressed vs results/baselines/bench_quick.json" \
       >&2
       echo "(if intentional, refresh the baseline per docs/benchmarking.md)" \
       >&2
       exit 1; }
REPRO_PRICING_CHUNK=1 PYTHONPATH=src python -m benchmarks.run --quick \
    --only workload_goodput --timestamp 2026-01-01T00:00:00Z \
    --out "$bsn_dir/regressed.json" --history "" > /dev/null
if PYTHONPATH=src python -m repro.core.cli obs bench gate \
    --baseline results/baselines/bench_quick.json \
    --current "$bsn_dir/regressed.json" --hard-only > "$bsn_dir/gate.txt"
then
    echo "bench gate missed the injected chunk regression" >&2; exit 1
fi
grep -q "repro_search_chunks_total" "$bsn_dir/gate.txt" \
  || { echo "bench gate did not name the inflated counter" >&2; exit 1; }
PYTHONPATH=src python -m repro.core.cli obs bench trend \
    --history "$bsn_dir/history.jsonl" > /dev/null
echo "ok: counters byte-stable across runs, baseline held," \
     "injected regression caught"
rm -rf "$bsn_dir"

echo "=== ci passed ==="
