"""Third-party backend plugin path: the contrib ``disagg-router`` profile
registers lazily through ``@register_backend`` and its restricted
capability set gates workloads end to end."""
import importlib
import sys

import pytest

from repro.api import Configurator
from repro.core.backends.base import (all_backends, backend_capabilities,
                                      get_backend, unregister_backend)


@pytest.fixture()
def contrib():
    """Import (= register) the contrib plugin; fully unwind afterwards so
    the shared registry never leaks into other tests."""
    mod = importlib.import_module("repro.core.backends.contrib")
    yield mod
    unregister_backend("disagg-router")
    sys.modules.pop("repro.core.backends.contrib", None)


def _configurator():
    return (Configurator.for_model("llama3.1-8b")
            .traffic(isl=256, osl=64)
            .sla(ttft_ms=2000, min_tokens_per_s_user=10)
            .cluster(chips=8))


def test_import_registers_lazily(contrib):
    assert "disagg-router" in all_backends()
    prof = get_backend("disagg-router")          # factory resolved here
    assert prof.name == "disagg-router"
    assert prof.capabilities == frozenset({"disaggregated"})
    assert get_backend("disagg-router") is prof  # resolved once, cached


def test_not_registered_without_import():
    # builtin loading must NOT drag the contrib module in
    if "repro.core.backends.contrib" not in sys.modules:
        assert "disagg-router" not in all_backends()


def test_capability_gating_rejects_unsupported_modes(contrib):
    c = _configurator().backend("disagg-router")
    for mode in ("aggregated", "static"):
        with pytest.raises(ValueError, match="does not support"):
            c.modes(mode).workload()
    with pytest.raises(ValueError, match="does not support"):
        c.modes("aggregated", "disaggregated").workload()


def test_capability_gating_rejects_speculative(contrib):
    c = (_configurator().backend("disagg-router").modes("disaggregated"))
    with pytest.raises(ValueError, match="speculative"):
        c.speculative("internlm2-1.8b")


def test_supported_mode_searches_end_to_end(contrib):
    assert backend_capabilities("disagg-router") == \
        frozenset({"disaggregated"})
    c = _configurator().backend("disagg-router").modes("disaggregated")
    w = c.workload()
    assert w.modes == ("disaggregated",)
    report = c.search(generate_launch=False)
    assert report.n_candidates > 0
    assert all(p.mode == "disaggregated" for p in report.projections)
