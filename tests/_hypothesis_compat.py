"""Deterministic fallback for ``hypothesis`` on bare environments.

The tier-1 suite must collect and pass without optional dependencies
(ISSUE 1 satellite).  When hypothesis is installed the real library is
used; otherwise this shim supplies ``given``/``settings``/``st`` with just
the strategy surface our property tests need.  Each ``@given`` test runs a
fixed number of seeded-random examples plus the all-minimal and
all-maximal corner draws — far weaker than hypothesis's shrinking search,
but deterministic and dependency-free.

Usage (in test modules)::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

import random
import types

N_EXAMPLES = 25  # random draws per test, after the two corner draws


class _Strategy:
    def __init__(self, draw, minimal, maximal):
        self.draw = draw
        self.minimal = minimal
        self.maximal = maximal


def _floats(lo, hi):
    return _Strategy(lambda rng: rng.uniform(lo, hi),
                     lambda: float(lo), lambda: float(hi))


def _integers(lo, hi):
    return _Strategy(lambda rng: rng.randint(lo, hi),
                     lambda: lo, lambda: hi)


def _booleans():
    return _Strategy(lambda rng: rng.random() < 0.5,
                     lambda: False, lambda: True)


def _tuples(*strategies):
    return _Strategy(
        lambda rng: tuple(s.draw(rng) for s in strategies),
        lambda: tuple(s.minimal() for s in strategies),
        lambda: tuple(s.maximal() for s in strategies))


def _lists(elem, min_size=0, max_size=10):
    return _Strategy(
        lambda rng: [elem.draw(rng)
                     for _ in range(rng.randint(min_size, max_size))],
        lambda: [elem.minimal() for _ in range(min_size)],
        lambda: [elem.maximal() for _ in range(max_size)])


def _sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda rng: seq[rng.randrange(len(seq))],
                     lambda: seq[0], lambda: seq[-1])


st = types.SimpleNamespace(
    floats=_floats, integers=_integers, booleans=_booleans,
    tuples=_tuples, lists=_lists, sampled_from=_sampled_from)


def given(*strategies):
    """Run the test over corner draws + N_EXAMPLES seeded-random draws.

    The wrapper takes no arguments so pytest does not mistake the
    strategy-bound parameters for fixtures (hypothesis's ``@given`` hides
    them the same way).
    """
    def deco(fn):
        def run():
            fn(*(s.minimal() for s in strategies))
            fn(*(s.maximal() for s in strategies))
            rng = random.Random(fn.__name__)  # deterministic per test
            for _ in range(N_EXAMPLES):
                fn(*(s.draw(rng) for s in strategies))
        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        run.__module__ = fn.__module__
        return run
    return deco


def settings(**_kwargs):
    """No-op stand-in for ``hypothesis.settings``."""
    def deco(fn):
        return fn
    return deco
