"""Comm.bytes_per_chip convention — unit-tested per call site.

The convention (documented on ops.Comm): ring collectives (all_reduce /
all_gather / reduce_scatter) take the FULL logical tensor — the cost
model applies the (n-1)/n sharding factor itself — while all_to_all and
p2p take the per-chip payload one rank actually sends.  Each decompose
call site is pinned here so a payload regression (pre-sharded tensor
passed to a gather, full tensor passed to an a2a) fails loudly.
"""
import pytest

from repro.configs import get_config
from repro.core import decompose
from repro.core import operators as ops
from repro.core.config import ParallelismConfig
from repro.serving.sim import StepSpec

SPEC = StepSpec(prefill=((256, 0),), decode=(64, 64))


def _comms(model, par, *, backend="repro-jax", dtype="bf16", spec=SPEC):
    cfg = get_config(model)
    out = decompose.iteration_ops(cfg, par, spec, backend=backend,
                                  dtype=dtype)
    return cfg, [(op, n) for op, n in out if isinstance(op, ops.Comm)]


def _tokens(spec, pp):
    t = sum(c for c, _ in spec.prefill) + len(spec.decode)
    return -(-t // pp) if pp > 1 else t


def test_tp_all_reduce_takes_full_tensor():
    par = ParallelismConfig(tp=4, pp=1, ep=1)
    cfg, comms = _comms("llama3.1-8b", par)
    T = _tokens(SPEC, 1)
    full = T * cfg.d_model * ops.BYTES["bf16"]
    ars = [c for c, _ in comms if c.kind == "all_reduce"]
    assert ars, "tp>1 must emit all_reduce"
    for c in ars:
        assert c.bytes_per_chip == full     # never pre-divided by tp
        assert c.n_chips == par.tp


def test_lm_head_all_gather_full_fp32_logits():
    par = ParallelismConfig(tp=4, pp=1, ep=1)
    cfg, comms = _comms("llama3.1-8b", par)
    n_emit = len(SPEC.decode) + len(SPEC.prefill)
    v_loc = -(-cfg.vocab_size // par.tp)
    ags = [c for c, _ in comms if c.kind == "all_gather"]
    assert len(ags) == 1
    # the full padded-vocab fp32 logits tensor, not the local shard
    assert ags[0].bytes_per_chip == n_emit * v_loc * par.tp * 4
    assert ags[0].n_chips == par.tp


def _moe_comms(backend, par):
    cfg = get_config("qwen3-moe-30b-a3b")
    T = _tokens(SPEC, par.pp)
    layer = decompose._moe_ops(cfg, par, T, "bf16", 1.2, backend, 0)
    return cfg, T, [op for op in layer if isinstance(op, ops.Comm)
                    and op.kind != "all_reduce"]     # EP dispatch/combine


@pytest.mark.parametrize("backend", sorted(decompose.EP_A2A_BACKENDS))
def test_moe_a2a_backends_send_per_chip_payload(backend):
    par = ParallelismConfig(tp=4, pp=1, ep=4)
    cfg, T, comms = _moe_comms(backend, par)
    per_chip = T * cfg.top_k * cfg.d_model * ops.BYTES["bf16"] / par.ep
    assert [c.kind for c in comms] == ["all_to_all", "all_to_all"]
    for c in comms:                         # dispatch + combine
        assert c.bytes_per_chip == pytest.approx(per_chip)
        assert c.n_chips == par.ep


@pytest.mark.parametrize("backend", ["repro-jax", "vllm"])
def test_moe_gather_scatter_backends_send_full_tensor(backend):
    par = ParallelismConfig(tp=4, pp=1, ep=4)
    cfg, T, comms = _moe_comms(backend, par)
    full = T * cfg.top_k * cfg.d_model * ops.BYTES["bf16"]
    assert [c.kind for c in comms] == ["all_gather", "reduce_scatter"]
    for c in comms:                         # dispatch gather, combine scatter
        assert c.bytes_per_chip == full
        assert c.n_chips == par.ep


def test_pp_p2p_sends_one_stage_activation():
    par = ParallelismConfig(tp=1, pp=2, ep=1)
    cfg, comms = _comms("llama3.1-8b", par)
    T = _tokens(SPEC, par.pp)
    p2ps = [(c, n) for c, n in comms if c.kind == "p2p"]
    assert len(p2ps) == 1
    c, n = p2ps[0]
    assert c.bytes_per_chip == T * cfg.d_model * ops.BYTES["bf16"]
    assert c.n_chips == 2 and n == par.pp - 1


def test_batch_encoder_uses_same_payloads():
    """The struct-of-arrays encoder prices exactly the comm payloads the
    scalar op list carries (per kind, per n_chips)."""
    for backend in ("repro-jax", "trtllm"):
        cfg = get_config("qwen3-moe-30b-a3b")
        par = ParallelismConfig(tp=4, pp=2, ep=4)
        scalar = {}
        for op, n in decompose.iteration_ops(cfg, par, SPEC,
                                             backend=backend):
            if isinstance(op, ops.Comm):
                key = (op.kind, op.n_chips)
                scalar[key] = scalar.get(key, 0.0) + n * op.bytes_per_chip
        batch = decompose.encode_iteration_batch([(cfg, par, SPEC)],
                                                 backend=backend)
        encoded = {}
        for rows in batch.grid_rows:
            if isinstance(rows.rep_op, ops.Comm):
                key = (rows.rep_op.kind, rows.rep_op.n_chips)
                encoded[key] = encoded.get(key, 0.0) + float(
                    (rows.mult * rows.coords[rows.ridx, 0]).sum())
        assert set(encoded) == set(scalar)
        for key in scalar:
            assert encoded[key] == pytest.approx(scalar[key], rel=1e-12), \
                (backend, key)
