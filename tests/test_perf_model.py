"""Unit tests for the AIConfigurator core: PerfDatabase grids +
interpolation, Algorithms 1–3 against the paper's pseudocode semantics,
throughput equations, and end-to-end search."""
import math

import numpy as np
import pytest

from repro.core import analytical, modes
from repro.core import operators as ops
from repro.core.config import (CandidateConfig, ClusterSpec,
                               ParallelismConfig, RuntimeFlags, SLA,
                               WorkloadDescriptor)
from repro.core.hardware import get_platform
from repro.core.perf_database import OpGrid, PerfDatabase
from repro.core.session import InferenceSession
from repro.core.task_runner import TaskRunner


@pytest.fixture(scope="module")
def db():
    return PerfDatabase("tpu_v5e", "repro-jax")


# ---------------------------------------------------------------------------
# PerfDatabase
# ---------------------------------------------------------------------------

def test_grid_exact_on_grid_points(db):
    g = ops.GEMM(1024, 4096, 4096, "bf16")
    measured = analytical.latency(db.platform, g)
    assert db.op_latency(g) == pytest.approx(measured, rel=1e-6)


def test_interpolation_between_neighbors(db):
    lo = db.op_latency(ops.GEMM(1024, 4096, 4096, "bf16"))
    hi = db.op_latency(ops.GEMM(2048, 4096, 4096, "bf16"))
    mid = db.op_latency(ops.GEMM(1536, 4096, 4096, "bf16"))
    assert min(lo, hi) <= mid <= max(lo, hi)


def test_interpolation_clamps_at_edges(db):
    tiny = db.op_latency(ops.GEMM(1, 128, 128, "bf16"))
    assert tiny > 0
    huge = db.op_latency(ops.GEMM(1 << 22, 32768, 32768, "bf16"))
    assert math.isfinite(huge)


def test_sol_fallback_smaller_than_calibrated(db):
    """SoL (no efficiency curves/overhead) must lower-bound calibrated."""
    g = ops.GEMM(4096, 4096, 4096, "bf16")
    assert analytical.sol_latency(db.platform, g) \
        <= analytical.latency(db.platform, g)


def test_fp8_faster_than_bf16(db):
    b = db.op_latency(ops.GEMM(8192, 8192, 8192, "bf16"))
    f = db.op_latency(ops.GEMM(8192, 8192, 8192, "fp8"))
    assert f < b


def test_decode_attention_memory_bound(db):
    """Decode attention latency tracks KV bytes / HBM bandwidth."""
    a = ops.Attention("decode", 32, 1, 32768, 32, 8, 128)
    t = db.op_latency(a)
    floor = a.bytes() / db.platform.hbm_bw
    assert t >= floor
    assert t < 20 * floor


def test_comm_scaling(db):
    small = db.op_latency(ops.Comm("all_reduce", 2**20, 16))
    big = db.op_latency(ops.Comm("all_reduce", 2**30, 16))
    assert big > small
    assert db.op_latency(ops.Comm("all_reduce", 2**20, 1)) == 0.0


def test_db_save_load(tmp_path, db):
    path = str(tmp_path / "db.json")
    # touch a lazy grid first so it round-trips
    a = ops.Attention("decode", 8, 1, 4096, 16, 4, 128)
    before = db.op_latency(a)
    db.save(path)
    db2 = PerfDatabase.load(path)
    assert db2.op_latency(a) == pytest.approx(before, rel=1e-9)
    g = ops.GEMM(777, 2048, 2048, "bf16")
    assert db2.op_latency(g) == pytest.approx(db.op_latency(g), rel=1e-9)


def test_weighted_sequence_latency(db):
    g = ops.GEMM(128, 1024, 1024)
    assert db.sequence_latency([(g, 3)]) == pytest.approx(
        3 * db.op_latency(g))


# ---------------------------------------------------------------------------
# Algorithm 1 — static
# ---------------------------------------------------------------------------

def test_static_mode_ttft_is_prefill():
    lat = lambda b, s, ph: 100.0 if ph == "prefill" else 2.0
    ttft, tpot = modes.static_mode(lat, isl=512, osl=64, batch=4)
    assert ttft == 100.0
    assert tpot == pytest.approx(2.0)


def test_static_mode_stride_weighting():
    """Latency growing with seq must be averaged with stride interpolation."""
    lat = lambda b, s, ph: 0.0 if ph == "prefill" else float(s)
    isl, osl = 100, 65
    _, tpot = modes.static_mode(lat, isl, osl, 1)
    # strided sum: steps at k=0,32,64 covering 32,32,... of OSL-1=64
    expected = (float(isl + 1) * 32 + float(isl + 33) * 32) / 64
    assert tpot == pytest.approx(expected)


def test_static_mode_osl1():
    lat = lambda b, s, ph: 5.0
    ttft, tpot = modes.static_mode(lat, 128, 1, 1)
    assert (ttft, tpot) == (5.0, 0.0)


def test_static_prefix_reduces_prefill():
    seen = {}
    def lat(b, s, ph):
        if ph == "prefill":
            seen["s"] = s
        return 1.0
    modes.static_mode(lat, isl=512, osl=2, batch=1, prefix=128)
    assert seen["s"] == 384


# ---------------------------------------------------------------------------
# Algorithm 2 — aggregated
# ---------------------------------------------------------------------------

def test_aggregated_rate_match_throttle():
    """Context-dominant regime throttles decode streams (lines 6-10)."""
    captured = {}
    def mix(nc, ng, i, o):
        captured["ng"] = ng
        return 10.0
    gen = lambda b, i, o: 1.0
    isl, osl, B, c = 4096, 16, 64, 4096
    # T_total_ctx = 64 >= OSL=16 -> N_gen = B/(T/OSL) = 64/4 = 16
    modes.aggregated_mode(mix, gen, isl, osl, B, c)
    assert captured["ng"] == 16


def test_aggregated_f_corr_formula():
    mix = lambda nc, ng, i, o: 10.0
    gen = lambda b, i, o: 1.0
    isl, osl, B, c = 1024, 256, 8, 4096
    t_total = math.ceil(isl * B / c)           # 2
    ttft, _ = modes.aggregated_mode(mix, gen, isl, osl, B, c)
    f_corr = min(2 + (t_total - 3) / 20, 4.0)
    assert ttft == pytest.approx(10.0 * math.ceil(isl / c) * f_corr)


def test_aggregated_jitter_offset():
    """TPOT weighting uses max(1, T_mix - 3)."""
    mix = lambda nc, ng, i, o: 100.0
    gen = lambda b, i, o: 1.0
    isl, osl, B, c = 4096, 100, 8, 4096
    t_mix = math.ceil(isl * B / c)             # 8
    t_gen = osl - t_mix                        # 92
    t_mix_p = max(1, t_mix - 3)                # 5
    _, tpot = modes.aggregated_mode(mix, gen, isl, osl, B, c)
    assert tpot == pytest.approx((100.0 * t_mix_p + 1.0 * t_gen)
                                 / (t_mix_p + t_gen))


def test_aggregated_batch1_pure_decode():
    mix = lambda nc, ng, i, o: 50.0
    gen = lambda b, i, o: 3.0
    _, tpot = modes.aggregated_mode(mix, gen, 1024, 64, 1, 8192)
    assert tpot == 3.0


# ---------------------------------------------------------------------------
# Algorithm 3 — disaggregated
# ---------------------------------------------------------------------------

def _pool(lat, thru, chips=1, cfg=None):
    return modes.PoolCandidate(config=cfg, chips=chips, latency_ms=lat,
                               req_throughput=thru)


def test_disagg_rate_matching_picks_min():
    pre = [_pool(100.0, 10.0)]
    dec = [_pool(5.0, 4.0)]
    best, _ = modes.disaggregated_mode(
        pre, dec, ttft_limit_ms=1000, tpot_limit_ms=50,
        valid_totals=range(1, 9), osl=100)
    assert best is not None
    r_pre = 10.0 * best.x * modes.ALPHA_PRE
    r_dec = 4.0 * best.y * modes.ALPHA_DEC
    assert best.req_per_s == pytest.approx(min(r_pre, r_dec))


def test_disagg_beta_ttft_filter():
    # latency 600 * 1.8 = 1080 > 1000 -> filtered out
    pre = [_pool(600.0, 10.0)]
    dec = [_pool(5.0, 4.0)]
    best, _ = modes.disaggregated_mode(pre, dec, 1000, 50,
                                       range(1, 9), osl=100)
    assert best is None


def test_disagg_tpot_filter():
    pre = [_pool(100.0, 10.0)]
    dec = [_pool(60.0, 4.0)]
    best, _ = modes.disaggregated_mode(pre, dec, 1000, 50,
                                       range(1, 9), osl=100)
    assert best is None


def test_disagg_respects_valid_totals():
    pre = [_pool(100.0, 10.0, chips=4)]
    dec = [_pool(5.0, 4.0, chips=4)]
    best, _ = modes.disaggregated_mode(pre, dec, 1000, 50,
                                       valid_totals=[8], osl=10)
    assert best is not None
    assert best.total_chips == 8 and best.x == 1 and best.y == 1


# ---------------------------------------------------------------------------
# Session / TaskRunner end-to-end
# ---------------------------------------------------------------------------

def _workload(**kw):
    base = dict(model="llama3.1-8b", isl=1024, osl=256,
                sla=SLA(ttft_ms=2000, min_tokens_per_s_user=10),
                cluster=ClusterSpec(n_chips=16), backend="repro-jax",
                dtype="fp8")
    base.update(kw)
    return WorkloadDescriptor(**base)


def test_throughput_equation(db):
    """System throughput follows eq. (2) exactly."""
    s = InferenceSession(_workload(), db)
    cand = CandidateConfig(parallel=ParallelismConfig(tp=8), batch_size=8)
    p = s.evaluate_static(cand)
    assert p is not None
    expect = 1000.0 / (p.ttft_ms + (256 - 1) * p.tpot_ms) * 8 * 256 / 8
    assert p.tokens_per_s_per_chip == pytest.approx(expect, rel=1e-6)


def test_memory_pruning(db):
    """A config that cannot fit HBM returns None."""
    s = InferenceSession(_workload(dtype="bf16"), db)
    too_big = CandidateConfig(parallel=ParallelismConfig(tp=1),
                              batch_size=256)
    assert s.evaluate_static(too_big) is None


def test_search_end_to_end(db):
    r = TaskRunner(_workload(), db).run()
    assert r.n_candidates > 50
    assert r.best is not None
    assert r.best.meets(_workload().sla)
    assert r.per_candidate_ms < 50          # paper: ~1.5ms; CI headroom
    # frontier is non-dominated and sorted by speed desc
    f = r.frontier
    for a, b in zip(f, f[1:]):
        assert a.tokens_per_s_user >= b.tokens_per_s_user
        assert a.tokens_per_s_per_chip <= b.tokens_per_s_per_chip


def test_backends_differ(db):
    """Framework-specific dynamics: identical workload, different backend,
    different projections (the paper's core motivation)."""
    results = {}
    for be in ("repro-jax", "trtllm", "vllm", "sglang"):
        w = _workload(backend=be)
        s = InferenceSession(w, PerfDatabase("tpu_v5e", be))
        cand = CandidateConfig(parallel=ParallelismConfig(tp=8), batch_size=8)
        results[be] = s.evaluate_aggregated(cand).tpot_ms
    assert len(set(round(v, 6) for v in results.values())) > 1
    assert results["trtllm"] < results["vllm"]   # static engine < py sched
