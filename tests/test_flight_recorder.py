"""The request-level flight recorder: lifecycle spans from every replay
simulator, latency histograms and the quantile estimator, Chrome
trace_event export, telemetry diffing, and the byte-identity guarantee
under the null tracer."""
import json
import math

import pytest

try:
    from hypothesis import given, strategies as st
except ImportError:
    from _hypothesis_compat import given, st

from repro.autoscale.policy import StaticPolicy, TargetQueueDepth
from repro.autoscale.simulator import AutoscaleSimulator
from repro.capacity.cluster import ClusterSimulator
from repro.obs import (disable_metrics, disable_tracing, enable_metrics,
                       enable_tracing)
from repro.obs.diff import diff_metrics, format_diff, load_metrics_snapshot
from repro.obs.flight import (HISTOGRAM_METRICS, FlightRecorderConfig,
                              configure_flight_recorder, emit_request_spans,
                              flight_config, latency_histograms,
                              request_latencies_ms)
from repro.obs.metrics import (LATENCY_MS_BUCKETS, MetricsRegistry,
                               histogram_quantile)
from repro.obs.trace import NULL_TRACER, Tracer
from repro.serving.request import Request
from repro.serving.scheduler import SchedulerConfig
from repro.serving.sim import ServingSimulator, percentile
from repro.workloads import (ArrivalSpec, LengthSpec, SLOSpec, TenantSpec,
                             TraceSpec, generate_trace)


@pytest.fixture(autouse=True)
def _clean_globals():
    disable_tracing()
    disable_metrics()
    configure_flight_recorder()            # back to defaults
    yield
    disable_tracing()
    disable_metrics()
    configure_flight_recorder()


def _lat(spec):
    return 1e-3 + 1e-6 * sum(c for c, _ in spec.prefill) \
        + 1e-5 * len(spec.decode)


def _trace(kind="poisson", n=40, seed=7, rate=2.0):
    arrivals = {"poisson": ArrivalSpec(kind="poisson", rate_rps=rate),
                "bursty": ArrivalSpec(kind="bursty", rate_rps=rate,
                                      burst_factor=4.0),
                "diurnal": ArrivalSpec(kind="diurnal", rate_rps=rate,
                                       period_s=12.0, amplitude=0.8)}[kind]
    return generate_trace(TraceSpec(
        n_requests=n, arrivals=arrivals,
        tenants=(TenantSpec(lengths=LengthSpec(kind="fixed",
                                               isl=64, osl=8)),)),
        seed=seed)


_SLO = SLOSpec(ttft_p99_ms=2000.0, tpot_p99_ms=100.0)
_SCHED = SchedulerConfig(max_batch=4, max_queue=64)


def _fake_request(rid, arrival=0.0, sched=0.1, first=0.2, finish=0.5,
                  osl=8):
    r = Request(rid=rid, isl=64, osl=osl, arrival=arrival)
    r.t_first_sched = sched
    r.t_first_token = first
    r.t_finish = finish
    return r


# ---------------------------------------------------------------------------
# histogram_quantile — the estimator the v7 report relies on
# ---------------------------------------------------------------------------

def _fold(values, buckets=LATENCY_MS_BUCKETS):
    counts = [0] * (len(buckets) + 1)
    for v in values:
        for i, le in enumerate(buckets):
            if v <= le:
                counts[i] += 1
                break
        else:
            counts[-1] += 1
    return counts


def _bucket_width_at(value, buckets=LATENCY_MS_BUCKETS):
    idx = next((i for i, le in enumerate(buckets) if value <= le),
               len(buckets) - 1)
    lo = buckets[idx - 1] if idx > 0 else 0.0
    return buckets[min(idx, len(buckets) - 1)] - lo


def test_quantile_empty_histogram_is_none_not_nan():
    counts = [0] * (len(LATENCY_MS_BUCKETS) + 1)
    est = histogram_quantile(LATENCY_MS_BUCKETS, counts, 0.5)
    assert est is None
    assert est is not float("nan")


def test_quantile_validates_inputs():
    counts = [0] * (len(LATENCY_MS_BUCKETS) + 1)
    with pytest.raises(ValueError):
        histogram_quantile(LATENCY_MS_BUCKETS, counts, 1.5)
    with pytest.raises(ValueError):
        histogram_quantile(LATENCY_MS_BUCKETS, counts[:-1], 0.5)


def test_quantile_single_sample_lands_in_its_bucket():
    counts = _fold([3.0])
    for p in (0.0, 0.5, 0.99, 1.0):
        est = histogram_quantile(LATENCY_MS_BUCKETS, counts, p)
        assert 2.0 < est <= 4.0               # the (2, 4] bucket


def test_quantile_constant_sample():
    counts = _fold([10.0] * 500)
    for p in (0.01, 0.5, 0.99):
        est = histogram_quantile(LATENCY_MS_BUCKETS, counts, p)
        assert 8.0 < est <= 16.0              # all mass in (8, 16]


def test_quantile_overflow_clamps_to_last_finite_edge():
    top = LATENCY_MS_BUCKETS[-1]
    counts = _fold([top * 10] * 5)
    assert histogram_quantile(LATENCY_MS_BUCKETS, counts, 0.99) == top


@given(st.lists(st.floats(0.1, 1e6), min_size=1, max_size=200),
       st.floats(0.0, 1.0))
def test_quantile_within_one_bucket_of_exact(values, p):
    counts = _fold(values)
    est = histogram_quantile(LATENCY_MS_BUCKETS, counts, p)
    exact = percentile(values, p)
    assert est is not None
    # the estimate interpolates inside the bucket holding the rank-th
    # sample, so it can be off by at most that bucket's width
    assert abs(est - exact) <= _bucket_width_at(exact) + 1e-9


@given(st.lists(st.floats(0.1, 1e5), min_size=1, max_size=100))
def test_quantile_monotone_in_p(values):
    counts = _fold(values)
    grid = [i / 20 for i in range(21)]
    ests = [histogram_quantile(LATENCY_MS_BUCKETS, counts, p)
            for p in grid]
    assert all(a <= b + 1e-12 for a, b in zip(ests, ests[1:]))


def test_quantile_lognormal_sample():
    import random
    rng = random.Random(42)
    values = [math.exp(rng.gauss(3.0, 1.0)) for _ in range(1000)]
    counts = _fold(values)
    for p in (0.5, 0.95, 0.99):
        est = histogram_quantile(LATENCY_MS_BUCKETS, counts, p)
        exact = percentile(values, p)
        assert abs(est - exact) <= _bucket_width_at(exact)


def test_registry_quantile_method():
    reg = MetricsRegistry()
    for v in (1.0, 2.0, 3.0, 100.0):
        reg.observe("lat_ms", v, buckets=LATENCY_MS_BUCKETS, sim="t")
    assert reg.quantile("lat_ms", 0.0, sim="t") <= 1.0
    assert reg.quantile("lat_ms", 1.0, sim="t") > 64.0
    assert reg.quantile("missing", 0.5) is None


def test_registry_pins_bucket_schema():
    reg = MetricsRegistry()
    reg.observe("lat_ms", 1.0, buckets=(1.0, 2.0))
    with pytest.raises(ValueError, match="pinned"):
        reg.observe("lat_ms", 1.0, buckets=(1.0, 4.0))
    with pytest.raises(ValueError, match="increasing"):
        reg.observe("other", 1.0, buckets=(2.0, 1.0))


# ---------------------------------------------------------------------------
# Prometheus label escaping (satellite bugfix)
# ---------------------------------------------------------------------------

def test_prometheus_escapes_hostile_label_values():
    reg = MetricsRegistry()
    hostile = 'he said "hi"\nback\\slash'
    reg.inc("requests_total", model=hostile)
    text = reg.to_prometheus()
    line = next(l for l in text.splitlines()
                if l.startswith("requests_total"))
    assert '\n' not in line                  # newline must be escaped
    assert '\\n' in line
    assert '\\"' in line
    assert '\\\\slash' in line
    # escaping must be unambiguous: backslash first, then quote/newline
    assert 'model="he said \\"hi\\"\\nback\\\\slash"' in line


def test_prometheus_plain_labels_unchanged():
    reg = MetricsRegistry()
    reg.inc("requests_total", model="llama")
    assert 'requests_total{model="llama"} 1' in reg.to_prometheus()


# ---------------------------------------------------------------------------
# per-request latencies + histogram folding
# ---------------------------------------------------------------------------

def test_request_latencies_ms():
    r = _fake_request(0, arrival=0.0, sched=0.1, first=0.2, finish=0.5)
    lat = request_latencies_ms(r)
    assert lat["queue_wait_ms"] == pytest.approx(100.0)
    assert lat["ttft_ms"] == pytest.approx(200.0)
    assert lat["e2e_ms"] == pytest.approx(500.0)
    assert lat["tpot_ms"] == pytest.approx(1e3 * 0.3 / 7)


def test_request_latencies_partial_lifecycle():
    r = Request(rid=1, isl=64, osl=1, arrival=0.0)
    assert all(v is None for v in request_latencies_ms(r).values())
    r.t_first_sched = 0.1
    r.t_first_token = 0.2
    r.t_finish = 0.2
    lat = request_latencies_ms(r)
    assert lat["tpot_ms"] is None            # osl == 1: no decode steps
    assert lat["ttft_ms"] == pytest.approx(200.0)


def test_latency_histograms_section_shape():
    reqs = [_fake_request(i, finish=0.5 + 0.1 * i) for i in range(10)]
    section = latency_histograms(reqs, sim="test")
    assert set(section) == set(HISTOGRAM_METRICS)
    for hist in section.values():
        assert hist["buckets"] == list(LATENCY_MS_BUCKETS)
        assert sum(hist["counts"]) == hist["count"] == 10


def test_latency_histograms_feed_installed_registry():
    reg = enable_metrics()
    latency_histograms([_fake_request(0)], sim="test")
    snap = reg.to_dict()["histograms"]
    assert "repro_request_ttft_ms{sim=test}" in snap
    assert snap["repro_request_e2e_ms{sim=test}"]["count"] == 1


# ---------------------------------------------------------------------------
# span emission
# ---------------------------------------------------------------------------

def test_emit_request_spans_structure():
    tracer = Tracer()
    n = emit_request_spans(
        tracer, [_fake_request(0)], [Request(rid=1, isl=8, osl=4,
                                             arrival=1.0)], base=100.0)
    assert n == 2
    spans = {s.name: s for s in tracer.spans}
    req = [s for s in tracer.spans if s.name == "request"]
    assert [s.attrs["rid"] for s in req] == [0, 1]
    assert req[0].attrs["outcome"] == "completed"
    assert req[1].attrs["outcome"] == "rejected"
    assert req[0].v_start == pytest.approx(100.0)
    assert req[0].v_end == pytest.approx(100.5)
    assert req[1].v_start == req[1].v_end == pytest.approx(101.0)
    assert spans["request.queued"].v_end == pytest.approx(100.1)
    assert spans["request.prefill"].v_end == pytest.approx(100.2)
    assert spans["request.decode"].v_end == pytest.approx(100.5)


def test_emit_request_spans_replica_attrs():
    tracer = Tracer()
    r = _fake_request(0)
    emit_request_spans(tracer, [r], [], base=0.0,
                       replica_of={id(r): 3})
    req = next(s for s in tracer.spans if s.name == "request")
    assert req.attrs["replica"] == 3


def test_emit_request_spans_null_tracer_is_byte_free():
    assert emit_request_spans(NULL_TRACER, [_fake_request(0)], [],
                              base=0.0) == 0


def test_sampling_knobs():
    reqs = [_fake_request(i) for i in range(20)]
    configure_flight_recorder(sample_every=3)
    tracer = Tracer()
    emit_request_spans(tracer, reqs, [], base=0.0)
    rids = [s.attrs["rid"] for s in tracer.spans if s.name == "request"]
    assert rids == [0, 3, 6, 9, 12, 15, 18]

    configure_flight_recorder(max_request_spans=5)
    tracer = Tracer()
    emit_request_spans(tracer, reqs, [], base=0.0)
    rids = [s.attrs["rid"] for s in tracer.spans if s.name == "request"]
    assert rids == [0, 1, 2, 3, 4]


def test_flight_config_validation():
    with pytest.raises(ValueError):
        FlightRecorderConfig(sample_every=0)
    with pytest.raises(ValueError):
        FlightRecorderConfig(max_request_spans=-1)
    cfg = configure_flight_recorder(sample_every=2, max_request_spans=9)
    assert flight_config() is cfg


# ---------------------------------------------------------------------------
# the three simulators emit the same span taxonomy
# ---------------------------------------------------------------------------

def _span_names(tracer):
    names = {}
    for s in tracer.spans:
        names[s.name] = names.get(s.name, 0) + 1
    return names


def test_serving_replay_emits_request_spans():
    tracer = enable_tracing()
    metrics = ServingSimulator(_SCHED, _lat).replay(_trace(), slo=_SLO)
    names = _span_names(tracer)
    assert names["request"] == metrics.completed + metrics.rejected == 40
    assert names["request.queued"] == names["request.prefill"] \
        == names["request.decode"] == metrics.completed
    # request timelines nest inside the replay span
    replay = next(s for s in tracer.spans if s.name == "serving.replay")
    for s in tracer.spans:
        if s.name == "request":
            assert replay.v_start <= s.v_start
            assert s.v_end <= replay.v_end + 1e-9


def test_cluster_replay_emits_replica_attributed_spans():
    tracer = enable_tracing()
    ClusterSimulator(_SCHED, _lat, replicas=2).replay(_trace(), slo=_SLO)
    req = [s for s in tracer.spans if s.name == "request"]
    assert len(req) == 40
    assert {s.attrs["replica"] for s in req} == {0, 1}
    assert [s.attrs["rid"] for s in req] == sorted(
        s.attrs["rid"] for s in req)          # global rid order


def test_autoscale_run_emits_request_spans():
    tracer = enable_tracing()
    sim = AutoscaleSimulator(_SCHED, _lat,
                             TargetQueueDepth(min_replicas=1,
                                              max_replicas=3))
    rep = sim.run(_trace(rate=8.0, n=80), slo=_SLO)
    req = [s for s in tracer.spans if s.name == "request"]
    assert len(req) == rep.metrics.completed + rep.metrics.rejected
    assert all("replica" in s.attrs for s in req)


def test_rejected_requests_get_zero_length_spans():
    tracer = enable_tracing()
    tight = SchedulerConfig(max_batch=1, max_queue=1)
    metrics = ServingSimulator(tight, _lat).replay(
        _trace(rate=50.0), slo=_SLO)
    assert metrics.rejected > 0
    rejected = [s for s in tracer.spans if s.name == "request"
                and s.attrs["outcome"] == "rejected"]
    assert len(rejected) == metrics.rejected
    for s in rejected:
        assert s.v_start == s.v_end


def test_tracing_off_replay_is_unchanged():
    """The flight recorder must not perturb the simulation: metrics are
    identical with and without span recording."""
    with_spans_tracer = enable_tracing()
    m_on = ServingSimulator(_SCHED, _lat).replay(_trace(), slo=_SLO)
    disable_tracing()
    m_off = ServingSimulator(_SCHED, _lat).replay(_trace(), slo=_SLO)
    assert m_on.to_dict() == m_off.to_dict()
    assert m_on.histograms == m_off.histograms
    assert any(s.name == "request" for s in with_spans_tracer.spans)


def test_histograms_absent_from_to_dict():
    m = ServingSimulator(_SCHED, _lat).replay(_trace(), slo=_SLO)
    assert m.histograms is not None
    assert "histograms" not in m.to_dict()
    cm = ClusterSimulator(_SCHED, _lat, replicas=2).replay(_trace(),
                                                           slo=_SLO)
    assert cm.histograms is not None
    assert "histograms" not in cm.to_dict()


# ---------------------------------------------------------------------------
# histogram percentiles vs exact — every trace shape × every simulator
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["poisson", "bursty", "diurnal"])
@pytest.mark.parametrize("sim_name", ["serving", "cluster", "autoscale"])
def test_histogram_quantiles_track_exact_percentiles(kind, sim_name):
    trace = _trace(kind=kind, n=60, rate=6.0)
    if sim_name == "serving":
        metrics = ServingSimulator(_SCHED, _lat).replay(trace, slo=_SLO)
    elif sim_name == "cluster":
        metrics = ClusterSimulator(_SCHED, _lat, replicas=2).replay(
            trace, slo=_SLO)
    else:
        metrics = AutoscaleSimulator(
            _SCHED, _lat, StaticPolicy(min_replicas=2, max_replicas=2)
        ).run(trace, slo=_SLO).metrics
    assert metrics.completed > 0
    for name in ("ttft_ms", "tpot_ms"):
        h = metrics.histograms[name]
        exact = getattr(metrics, name)
        for label, p in (("p50", 0.50), ("p95", 0.95), ("p99", 0.99)):
            est = histogram_quantile(h["buckets"], h["counts"], p)
            if h["count"] == 0:
                assert est is None
                continue
            width = _bucket_width_at(exact[label])
            assert abs(est - exact[label]) <= width + 1e-9, \
                (sim_name, kind, name, label)


# ---------------------------------------------------------------------------
# Chrome trace export
# ---------------------------------------------------------------------------

def _chrome_trace():
    tracer = enable_tracing()
    ClusterSimulator(_SCHED, _lat, replicas=2).replay(_trace(), slo=_SLO)
    disable_tracing()
    return tracer.artifact(meta={"command": "test"})


def test_chrome_trace_event_structure():
    ct = _chrome_trace().to_chrome_trace()
    assert set(ct) == {"traceEvents", "displayTimeUnit", "otherData"}
    events = [e for e in ct["traceEvents"] if e["ph"] == "X"]
    assert events
    for e in events:
        assert {"name", "cat", "ph", "ts", "dur", "pid", "tid",
                "args"} <= set(e)
        assert e["dur"] >= 0
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)


def test_chrome_trace_request_lanes():
    ct = _chrome_trace().to_chrome_trace()
    meta = [e for e in ct["traceEvents"] if e["ph"] == "M"]
    thread_names = {(e["pid"], e["tid"]): e["args"]["name"]
                    for e in meta if e["name"] == "thread_name"}
    reqs = [e for e in ct["traceEvents"] if e.get("name") == "request"]
    assert len(reqs) == 40
    lanes = {thread_names[(e["pid"], e["tid"])] for e in reqs}
    assert all(l.startswith("request ") for l in lanes)
    assert len(lanes) == 40                  # one lane per request
    # child spans land in their parent request's lane
    children = [e for e in ct["traceEvents"]
                if e.get("name") == "request.prefill"]
    assert {(e["pid"], e["tid"]) for e in children} \
        <= {(e["pid"], e["tid"]) for e in reqs}


def test_chrome_trace_timestamps_are_virtual_micros():
    art = _chrome_trace()
    ct = art.to_chrome_trace()
    req_span = next(s for s in art.spans if s.name == "request")
    req_event = next(e for e in ct["traceEvents"]
                     if e.get("name") == "request")
    assert req_event["ts"] == pytest.approx(req_span.v_start * 1e6)
    assert req_event["dur"] == pytest.approx(
        (req_span.v_end - req_span.v_start) * 1e6)


def test_chrome_trace_carries_digest_and_meta():
    art = _chrome_trace()
    ct = art.to_chrome_trace()
    assert ct["otherData"]["digest"] == art.digest()
    assert ct["otherData"]["meta"]["command"] == "test"


def test_chrome_trace_deterministic():
    a = json.dumps(_chrome_trace().to_chrome_trace(), sort_keys=True)
    b = json.dumps(_chrome_trace().to_chrome_trace(), sort_keys=True)
    assert a == b


# ---------------------------------------------------------------------------
# telemetry diffing
# ---------------------------------------------------------------------------

def _snapshot(batch=4, queue=64, n=40):
    reg = enable_metrics()
    ServingSimulator(SchedulerConfig(max_batch=batch, max_queue=queue),
                     _lat).replay(_trace(n=n, rate=8.0), slo=_SLO)
    disable_metrics()
    return reg.to_dict()


def test_diff_identical_snapshots():
    a = _snapshot()
    d = diff_metrics(a, a)
    assert d["identical"]
    assert format_diff(d) == "snapshots are identical"


def test_diff_detects_counter_and_histogram_shifts():
    a, b = _snapshot(batch=4), _snapshot(batch=1)
    d = diff_metrics(a, b)
    assert not d["identical"]
    key = "repro_request_ttft_ms{sim=serving}"
    assert key in d["histograms"]["changed"]
    entry = d["histograms"]["changed"][key]
    # batch 1 queues harder: the p99 TTFT shift is positive
    assert entry["p99"]["shift"] > 0
    assert entry["schema_changed"] is False
    text = format_diff(d)
    assert key in text


def test_diff_slo_attainment_delta():
    a = _snapshot(batch=4)
    b = _snapshot(batch=1, queue=2)
    d = diff_metrics(a, b)
    att = d["slo_attainment"]
    assert att is not None
    assert att["a"] == pytest.approx(1.0)
    assert att["delta"] <= 0.0


def test_diff_added_removed_keys():
    a = {"counters": {"x": 1.0}, "gauges": {}, "histograms": {}}
    b = {"counters": {"y": 2.0}, "gauges": {}, "histograms": {}}
    d = diff_metrics(a, b)
    assert d["counters"]["added"] == {"y": 2.0}
    assert d["counters"]["removed"] == {"x": 1.0}


def test_load_snapshot_accepts_bare_histogram_section():
    m = ServingSimulator(_SCHED, _lat).replay(_trace(), slo=_SLO)
    snap = load_metrics_snapshot(m.histograms)
    assert snap["counters"] == {}
    assert set(snap["histograms"]) == set(HISTOGRAM_METRICS)
    d = diff_metrics(m.histograms, m.histograms)
    assert d["identical"]


def test_load_snapshot_accepts_report_with_telemetry(tmp_path):
    from repro.api import Configurator
    enable_metrics()
    report = (Configurator.for_model("llama3.1-8b")
              .traffic(isl=64, osl=16).sla(ttft_ms=2000)
              .cluster(chips=4).backend("repro-jax").dtype("fp8")
              .modes("aggregated").search(generate_launch=False))
    disable_metrics()
    path = tmp_path / "report.json"
    report.save(str(path))
    snap = load_metrics_snapshot(str(path))
    assert snap["counters"]


def test_load_snapshot_rejects_garbage():
    with pytest.raises(ValueError):
        load_metrics_snapshot({"whatever": 1})
    with pytest.raises(ValueError):
        load_metrics_snapshot([1, 2, 3])
    with pytest.raises(ValueError):
        # report without telemetry
        load_metrics_snapshot({"schema_version": 7, "telemetry": None})
