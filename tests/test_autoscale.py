"""repro.autoscale: timeline artifact, autoscaler policies, the
control-loop simulator (never-scale equivalence, cold starts,
drain-before-removal, cooldowns), the autoscale-vs-static section, and
the end-to-end ``Configurator.autoscale`` acceptance property."""
import json

import pytest

from repro.autoscale import (AutoscaleSimulator, ClusterTimeline,
                             SLOAttainmentWindow, StaticPolicy,
                             TargetQueueDepth, TimelineRecorder,
                             build_autoscale_section, get_policy)
from repro.capacity import ClusterSimulator, plan_min_chips
from repro.core.config import CandidateConfig, ParallelismConfig
from repro.serving.scheduler import SchedulerConfig
from repro.serving.sim import StepSpec
from repro.workloads import (ArrivalSpec, LengthSpec, SLOSpec, TenantSpec,
                             TraceSpec, constant_trace, generate_trace)


def _lat(spec: StepSpec) -> float:
    return 1e-3 + 1e-6 * sum(c for c, _ in spec.prefill) \
        + 1e-5 * len(spec.decode)


def _slow_lat(spec: StepSpec) -> float:
    """A heavier step model: one replica saturates around 10 req/s."""
    return 2e-2 + 1e-6 * sum(c for c, _ in spec.prefill) \
        + 1e-3 * len(spec.decode)


def _diurnal_trace(rate=10.0, period=12.0, amplitude=0.9, n=240, seed=13):
    return generate_trace(TraceSpec(
        n_requests=n,
        arrivals=ArrivalSpec(kind="diurnal", rate_rps=rate,
                             period_s=period, amplitude=amplitude),
        tenants=(TenantSpec(name="chat", weight=1.0,
                            lengths=LengthSpec(kind="fixed",
                                               isl=64, osl=8)),)),
        seed=seed)


_CFG = dict(max_batch=4, max_num_tokens=256)


def _autoscaler(policy, latency=_lat, **kw):
    return AutoscaleSimulator(SchedulerConfig(**_CFG), latency, policy, **kw)


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------

def test_policy_registry_and_overrides():
    p = get_policy("target_queue_depth", target_depth=2.0, max_replicas=3)
    assert isinstance(p, TargetQueueDepth)
    assert p.target_depth == 2.0 and p.max_replicas == 3
    assert isinstance(get_policy("slo_attainment"), SLOAttainmentWindow)
    assert isinstance(get_policy("static"), StaticPolicy)
    with pytest.raises(ValueError, match="unknown autoscaler policy"):
        get_policy("psychic")
    with pytest.raises(ValueError, match="bad static policy"):
        get_policy("static", target_depth=2.0)   # base policy knob-free
    assert p.to_dict()["name"] == "target_queue_depth"
    assert p.to_dict()["target_depth"] == 2.0


def test_policy_validation():
    with pytest.raises(ValueError, match="min_replicas"):
        TargetQueueDepth(min_replicas=0)
    with pytest.raises(ValueError, match="max_replicas"):
        TargetQueueDepth(min_replicas=4, max_replicas=2)
    with pytest.raises(ValueError, match="target_depth"):
        TargetQueueDepth(target_depth=0.0)
    with pytest.raises(ValueError, match="window_s"):
        TargetQueueDepth(window_s=-1.0)
    with pytest.raises(ValueError, match="attain_target"):
        SLOAttainmentWindow(attain_target=1.5)


def test_target_queue_depth_desired_math():
    p = TargetQueueDepth(target_depth=4.0, max_replicas=8)
    # empty window: hold steady
    assert p.desired_replicas([], 3) == (3, "no samples yet")

    class _S:
        def __init__(self, outstanding):
            self.outstanding = outstanding

    desired, reason = p.desired_replicas([_S(8), _S(16)], 1)
    assert desired == 3                    # ceil(mean 12 / target 4)
    assert "12.0" in reason
    desired, _ = p.desired_replicas([_S(0), _S(0)], 5)
    assert desired == 1                    # floor at one replica


def test_static_policy_never_moves():
    p = StaticPolicy(max_replicas=4)
    assert p.desired_replicas([], 2) == (2, "static fleet")


# ---------------------------------------------------------------------------
# never-scale equivalence: the control loop degenerates to the replay
# ---------------------------------------------------------------------------

def test_static_policy_reproduces_cluster_replay_exactly():
    """The ISSUE acceptance property: with a never-scaling policy and
    zero cold start, every metrics field equals a plain
    ``ClusterSimulator.replay`` of the same trace — the tick machinery
    adds observation, not perturbation."""
    trace = _diurnal_trace()
    slo = SLOSpec(ttft_p99_ms=600.0, tpot_p99_ms=100.0)
    sim = _autoscaler(StaticPolicy(min_replicas=2, max_replicas=2),
                      initial_replicas=2, tick_s=1.0, cold_start_s=0.0)
    auto = sim.run(trace, slo=slo)
    plain = ClusterSimulator(SchedulerConfig(**_CFG), _lat,
                             replicas=2).replay(trace, slo=slo)
    assert auto.metrics.to_dict() == plain.to_dict()
    assert auto.metrics.per_request == plain.per_request
    assert auto.n_scale_ups == auto.n_scale_downs == 0
    assert auto.peak_replicas == 2
    # static fleet: chip-seconds is exactly replicas x horizon
    assert auto.chip_seconds == pytest.approx(2 * auto.horizon_s)
    assert auto.mean_replicas == pytest.approx(2.0)


def test_instrumented_cluster_replay_matches_uninstrumented():
    """The on_tick emission hook observes without perturbing: metrics
    are identical with and without a recorder attached."""
    trace = _diurnal_trace(n=120)
    slo = SLOSpec(ttft_p99_ms=600.0, tpot_p99_ms=100.0)
    rec = TimelineRecorder(tick_s=0.5, slo=slo)
    sim = ClusterSimulator(SchedulerConfig(**_CFG), _lat, replicas=2)
    instrumented = sim.replay(trace, slo=slo, tick_s=0.5,
                              on_tick=rec.on_tick)
    plain = ClusterSimulator(SchedulerConfig(**_CFG), _lat,
                             replicas=2).replay(trace, slo=slo)
    assert instrumented.to_dict() == plain.to_dict()
    tl = rec.timeline()
    assert tl.n_samples > 0
    # the timeline tells the same completion story as the metrics
    assert sum(s.completed for s in tl.samples) == instrumented.completed
    assert sum(s.gen_tokens for s in tl.samples) \
        == sum(r["gen_tokens"] for r in instrumented.per_replica)
    assert all(s.provisioned_replicas == 2 for s in tl.samples)
    assert all(r.state == "warm"
               for s in tl.samples for r in s.replicas)


def test_cluster_replay_tick_validation():
    sim = ClusterSimulator(SchedulerConfig(**_CFG), _lat, replicas=1)
    with pytest.raises(ValueError, match="tick_s"):
        sim.replay(constant_trace(isl=8, osl=2, n_requests=2,
                                  rate_rps=1.0), tick_s=0.0,
                   on_tick=lambda t, engines: None)


# ---------------------------------------------------------------------------
# timeline artifact
# ---------------------------------------------------------------------------

def _timeline():
    trace = _diurnal_trace(n=100)
    slo = SLOSpec(ttft_p99_ms=600.0, tpot_p99_ms=100.0)
    sim = _autoscaler(TargetQueueDepth(target_depth=3.0, max_replicas=3,
                                       up_cooldown_s=1.0,
                                       down_cooldown_s=4.0, window_s=3.0),
                      latency=_slow_lat,
                      initial_replicas=1, tick_s=0.5, cold_start_s=0.5)
    return sim.run(trace, slo=slo).timeline


def test_timeline_jsonl_roundtrip_exact_and_digest_stable():
    tl = _timeline()
    blob = tl.to_jsonl()
    back = ClusterTimeline.from_jsonl(blob)
    assert back == tl
    assert back.to_jsonl() == blob
    assert back.digest() == tl.digest()
    header = json.loads(blob.splitlines()[0])
    assert header["type"] == "header"
    assert header["schema_version"] == 1
    assert header["n_samples"] == tl.n_samples
    assert header["meta"]["policy"]["name"] == "target_queue_depth"


def test_timeline_save_load(tmp_path):
    tl = _timeline()
    path = str(tmp_path / "timeline.jsonl")
    tl.save(path)
    assert ClusterTimeline.load(path) == tl


def test_timeline_rejects_malformed_input():
    tl = _timeline()
    with pytest.raises(ValueError, match="empty timeline"):
        ClusterTimeline.from_jsonl("")
    with pytest.raises(ValueError, match="header"):
        ClusterTimeline.from_jsonl('{"type": "sample"}\n')
    bad_version = tl.to_jsonl().replace('"schema_version": 1',
                                        '"schema_version": 99')
    with pytest.raises(ValueError, match="unsupported timeline"):
        ClusterTimeline.from_jsonl(bad_version)
    truncated = "\n".join(tl.to_jsonl().splitlines()[:-1]) + "\n"
    with pytest.raises(ValueError, match="declares"):
        ClusterTimeline.from_jsonl(truncated)
    with pytest.raises(ValueError, match="increasing"):
        ClusterTimeline(tick_s=1.0,
                        samples=(tl.samples[1], tl.samples[0]))
    with pytest.raises(ValueError, match="tick_s"):
        ClusterTimeline(tick_s=0.0, samples=())


def test_timeline_window_is_half_open():
    tl = _timeline()
    assert tl.n_samples >= 6
    t = tl.samples[5].t_s
    win = tl.window(t, 2 * tl.tick_s)
    assert [s.t_s for s in win] == [tl.samples[4].t_s, tl.samples[5].t_s]
    assert tl.duration_s == tl.samples[-1].t_s
    assert tl.peak_provisioned() >= 1


# ---------------------------------------------------------------------------
# control loop mechanics
# ---------------------------------------------------------------------------

def test_simulator_validation():
    pol = TargetQueueDepth(min_replicas=2, max_replicas=4)
    with pytest.raises(ValueError, match="routing"):
        _autoscaler(pol, routing="lunar")
    with pytest.raises(ValueError, match="tick_s"):
        _autoscaler(pol, tick_s=0.0)
    with pytest.raises(ValueError, match="cold_start_s"):
        _autoscaler(pol, cold_start_s=-1.0)
    with pytest.raises(ValueError, match="chips_per_replica"):
        _autoscaler(pol, chips_per_replica=0)
    with pytest.raises(ValueError, match="bounds"):
        _autoscaler(pol, initial_replicas=1)
    # default initial size is the policy floor
    assert _autoscaler(pol).initial_replicas == 2


def test_cold_replicas_receive_no_traffic_until_warm():
    """A spawned replica is billed immediately but only routed to after
    cold_start_s: its timeline rows show routed == 0 while cold."""
    trace = _diurnal_trace()
    slo = SLOSpec(ttft_p99_ms=600.0, tpot_p99_ms=100.0)
    run = _autoscaler(TargetQueueDepth(target_depth=3.0, max_replicas=2,
                                       up_cooldown_s=1.0,
                                       down_cooldown_s=1e9, window_s=3.0),
                      latency=_slow_lat, initial_replicas=1, tick_s=0.5,
                      cold_start_s=3.0).run(trace, slo=slo)
    assert run.n_scale_ups >= 1
    cold_rows = [r for s in run.timeline.samples for r in s.replicas
                 if r.state == "cold"]
    assert cold_rows, "expected the spawned replica to be sampled cold"
    assert all(r.routed == 0 and r.completed == 0 for r in cold_rows)
    warm_later = [r for s in run.timeline.samples for r in s.replicas
                  if r.replica == cold_rows[0].replica
                  and r.state == "warm"]
    assert warm_later, "the cold replica must eventually warm up"
    # billing starts at spawn, not at warm-up: chip-seconds exceed the
    # sum of warm time alone
    up = next(e for e in run.events if e["action"] == "scale_up")
    assert run.chip_seconds > (run.horizon_s - up["t_s"] - 3.0)


def test_scale_down_drains_before_removal():
    """Draining replicas finish their outstanding work — no request is
    lost to a scale-down — and retire only once empty."""
    trace = _diurnal_trace()
    slo = SLOSpec(ttft_p99_ms=600.0, tpot_p99_ms=100.0)
    run = _autoscaler(TargetQueueDepth(target_depth=3.0, max_replicas=2,
                                       up_cooldown_s=1.0,
                                       down_cooldown_s=4.0, window_s=3.0),
                      latency=_slow_lat, initial_replicas=2, tick_s=0.5,
                      cold_start_s=0.5).run(trace, slo=slo)
    assert run.n_scale_downs >= 1
    m = run.metrics
    assert m.completed + m.rejected + m.unfinished == m.n_requests
    assert m.unfinished == 0
    retire = [e for e in run.events if e["action"] == "retire"]
    downs = [e for e in run.events if e["action"] == "scale_down"]
    assert retire, "a drained replica must eventually retire"
    drained = {i for e in downs for i in e["draining"]}
    assert {e["replica"] for e in retire} <= drained
    # every retire happens at-or-after its scale_down mark
    first_down = {i: min(e["t_s"] for e in downs if i in e["draining"])
                  for i in drained}
    for e in retire:
        assert e["t_s"] >= first_down[e["replica"]]
    # draining rows appear in the timeline
    states = {r.state for s in run.timeline.samples for r in s.replicas}
    assert "draining" in states


def test_cooldowns_rate_limit_scaling():
    """The cooldown clocks gate *repeat* events: the first move in each
    direction is free, then an effectively-infinite cooldown blocks all
    further ones, while a short cooldown lets them through."""
    trace = _diurnal_trace()
    pol = dict(target_depth=3.0, max_replicas=4, window_s=3.0)
    fast = _autoscaler(TargetQueueDepth(up_cooldown_s=1.0,
                                        down_cooldown_s=2.0, **pol),
                       latency=_slow_lat, initial_replicas=1, tick_s=0.5,
                       cold_start_s=0.5).run(trace)
    slow = _autoscaler(TargetQueueDepth(up_cooldown_s=1e9,
                                        down_cooldown_s=1e9, **pol),
                       latency=_slow_lat, initial_replicas=1, tick_s=0.5,
                       cold_start_s=0.5).run(trace)
    assert slow.n_scale_ups <= 1 and slow.n_scale_downs <= 1
    assert fast.n_scale_ups > slow.n_scale_ups
    # consecutive same-direction events respect the cooldown spacing
    for run, up_cd, down_cd in ((fast, 1.0, 2.0),):
        ups = [e["t_s"] for e in run.events if e["action"] == "scale_up"]
        downs = [e["t_s"] for e in run.events
                 if e["action"] == "scale_down"]
        assert all(b - a >= up_cd for a, b in zip(ups, ups[1:]))
        assert all(b - a >= down_cd for a, b in zip(downs, downs[1:]))


def test_scale_steps_and_bounds_are_enforced():
    trace = _diurnal_trace()
    run = _autoscaler(TargetQueueDepth(target_depth=1.0, max_replicas=3,
                                       scale_up_step=2, up_cooldown_s=0.0,
                                       down_cooldown_s=1e9, window_s=2.0),
                      latency=_slow_lat, initial_replicas=1, tick_s=0.5,
                      cold_start_s=0.5).run(trace)
    assert run.peak_replicas <= 3          # hard ceiling
    ups = [e for e in run.events if e["action"] == "scale_up"]
    assert any(e["to"] - e["from"] == 2 for e in ups)  # step respected
    assert all(e["to"] - e["from"] <= 2 for e in ups)


def test_truncated_run_is_flagged():
    trace = _diurnal_trace(n=60)
    run = _autoscaler(StaticPolicy(), initial_replicas=1,
                      tick_s=0.5).run(trace, max_steps=5)
    assert run.metrics.truncated is True
    full = _autoscaler(StaticPolicy(), initial_replicas=1,
                       tick_s=0.5).run(trace)
    assert full.metrics.truncated is False


def test_run_is_deterministic():
    trace = _diurnal_trace()
    slo = SLOSpec(ttft_p99_ms=600.0, tpot_p99_ms=100.0)

    def go():
        return _autoscaler(
            TargetQueueDepth(target_depth=3.0, max_replicas=2,
                             up_cooldown_s=1.0, down_cooldown_s=4.0,
                             window_s=3.0),
            latency=_slow_lat, initial_replicas=2, tick_s=0.5,
            cold_start_s=0.5).run(trace, slo=slo)

    a, b = go(), go()
    assert json.dumps(a.to_dict(include_timeline=True), sort_keys=True) \
        == json.dumps(b.to_dict(include_timeline=True), sort_keys=True)
    assert a.timeline.digest() == b.timeline.digest()


def test_report_to_dict_shapes():
    run = _autoscaler(StaticPolicy(), initial_replicas=1,
                      tick_s=1.0).run(_diurnal_trace(n=60))
    d = run.to_dict()
    assert set(d["timeline"]) == {"digest", "tick_s", "n_samples"}
    json.dumps(d)                          # JSON-safe without the samples
    full = run.to_dict(include_timeline=True)
    assert len(full["timeline"]["samples"]) == run.timeline.n_samples
    assert "chip-s" in run.summary()


# ---------------------------------------------------------------------------
# autoscale vs the static plan (stub runner: synthetic latency)
# ---------------------------------------------------------------------------

class _StubRunner:
    """Just enough TaskRunner surface for build_autoscale_section: the
    two simulator factories plus a fingerprintable session.db."""

    class _DB:
        def fingerprint(self):
            return {"platform": "stub", "backend": "stub",
                    "grid_hash": "0" * 16}

    class _Session:
        db = None

    def __init__(self):
        self.session = self._Session()
        self.session.db = self._DB()

    def cluster_simulator(self, dep, routing="round_robin",
                          priority_admission=True, max_queue=100_000):
        return ClusterSimulator(SchedulerConfig(**_CFG), _slow_lat,
                                replicas=dep.replicas, routing=routing)

    def autoscale_simulator(self, cand, policy, routing="round_robin",
                            initial_replicas=None, tick_s=1.0,
                            cold_start_s=5.0, priority_admission=True,
                            max_queue=100_000):
        return AutoscaleSimulator(
            SchedulerConfig(**_CFG), _slow_lat, policy, routing=routing,
            initial_replicas=initial_replicas,
            chips_per_replica=cand.parallel.chips_per_instance,
            tick_s=tick_s, cold_start_s=cold_start_s)


_CAND = CandidateConfig(parallel=ParallelismConfig(tp=1), batch_size=4)
_SAVE_SLO = SLOSpec(ttft_p99_ms=600.0, tpot_p99_ms=100.0)
_SAVE_POLICY = TargetQueueDepth(target_depth=3.0, min_replicas=1,
                                max_replicas=2, up_cooldown_s=1.0,
                                down_cooldown_s=4.0, window_s=3.0)


def test_autoscaler_beats_static_plan_on_diurnal_trace():
    """The ISSUE acceptance property: on a seeded diurnal trace the
    autoscaler spends strictly fewer chip-seconds than the static
    min-chip plan while holding the attainment target."""
    trace = _diurnal_trace()
    runner = _StubRunner()
    plan = plan_min_chips(runner, [_CAND], trace, _SAVE_SLO,
                          ladder=(1, 2, 4))
    assert plan.attained and plan.deployment.replicas == 2
    section, run = build_autoscale_section(
        runner, _CAND, trace, _SAVE_SLO, _SAVE_POLICY, ladder=(1, 2, 4),
        tick_s=0.5, cold_start_s=0.5)
    static = section["static"]
    assert static["total_chips"] == 2
    assert run.chip_seconds < static["chip_seconds"]
    assert run.metrics.slo_attainment >= section["attain_target"]
    sv = section["savings"]
    assert sv["chip_seconds"] > 0 and sv["holds_attainment"]
    assert sv["chip_seconds_pct"] == pytest.approx(
        100.0 * sv["chip_seconds"] / static["chip_seconds"])
    # the autoscaler started from the static plan's size
    assert run.initial_replicas == 2
    assert section["run"]["timeline"]["digest"] == run.timeline.digest()


def test_build_section_without_attaining_static_plan():
    """An unattainable ladder yields static=None and savings=None; the
    autoscaled run still happens (from the policy floor)."""
    trace = _diurnal_trace()
    tight = SLOSpec(ttft_p99_ms=1.0, tpot_p99_ms=1.0)
    section, run = build_autoscale_section(
        _StubRunner(), _CAND, trace, tight, _SAVE_POLICY, ladder=(1,),
        tick_s=0.5, cold_start_s=0.5)
    assert section["static"] is None
    assert section["savings"] is None
    assert run.initial_replicas == _SAVE_POLICY.min_replicas
    assert section["run"]["chip_seconds"] > 0


# ---------------------------------------------------------------------------
# end-to-end: Configurator.autoscale (the acceptance path)
# ---------------------------------------------------------------------------

def test_configurator_autoscale_records_v5_section():
    from repro.api import Configurator
    cfg = (Configurator.for_model("llama3.1-8b")
           .traffic(isl=256, osl=64)
           .sla(ttft_ms=2000, min_tokens_per_s_user=10)
           .cluster(chips=8).backend("repro-jax").dtype("fp8")
           .modes("aggregated"))
    trace = generate_trace(TraceSpec(
        n_requests=150,
        arrivals=ArrivalSpec(kind="diurnal", rate_rps=30.0,
                             period_s=20.0, amplitude=0.9),
        tenants=(TenantSpec(name="chat", weight=1.0,
                            lengths=LengthSpec(kind="lognormal",
                                               isl=256, osl=64)),)),
        seed=5)
    slo = SLOSpec(ttft_p99_ms=1000, tpot_p99_ms=50)
    report = cfg.autoscale(
        trace, slo,
        policy=TargetQueueDepth(target_depth=6.0, max_replicas=4,
                                up_cooldown_s=1.0, down_cooldown_s=4.0,
                                window_s=3.0),
        ladder=(1, 2, 4), tick_s=0.5, cold_start_s=1.0)
    a = report.autoscale
    from repro.api import SCHEMA_VERSION
    assert report.schema_version == SCHEMA_VERSION
    assert a["trace"]["digest"] == trace.digest()
    assert a["candidate"]["describe"]
    assert a["candidate"]["index"] >= 0
    assert a["policy"]["name"] == "target_queue_depth"
    assert a["run"]["chip_seconds"] > 0
    # determinism across fresh sessions
    again = (Configurator.for_model("llama3.1-8b")
             .traffic(isl=256, osl=64)
             .sla(ttft_ms=2000, min_tokens_per_s_user=10)
             .cluster(chips=8).backend("repro-jax").dtype("fp8")
             .modes("aggregated")).autoscale(
        trace, slo,
        policy=TargetQueueDepth(target_depth=6.0, max_replicas=4,
                                up_cooldown_s=1.0, down_cooldown_s=4.0,
                                window_s=3.0),
        ladder=(1, 2, 4), tick_s=0.5, cold_start_s=1.0)
    assert again.autoscale == a


def test_configurator_autoscale_validates_top_k():
    from repro.api import Configurator
    cfg = (Configurator.for_model("llama3.1-8b")
           .traffic(isl=256, osl=64)
           .sla(ttft_ms=2000, min_tokens_per_s_user=10)
           .cluster(chips=8).backend("repro-jax").dtype("fp8"))
    with pytest.raises(ValueError, match="top_k"):
        cfg.autoscale(_diurnal_trace(n=10), _SAVE_SLO, top_k=0)
