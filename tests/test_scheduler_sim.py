"""Scheduler invariants (hypothesis) + discrete-event simulator behaviour."""
import dataclasses
from collections import deque

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare environment: deterministic fallback shim
    from _hypothesis_compat import given, settings, st

from repro.serving.request import Phase, Request
from repro.serving.scheduler import ContinuousBatchingScheduler, SchedulerConfig
from repro.serving.sim import ServingSimulator, StepSpec


@given(st.lists(st.tuples(st.integers(1, 500), st.integers(1, 40)),
                min_size=1, max_size=40),
       st.integers(1, 16), st.integers(64, 2048), st.booleans())
@settings(max_examples=60, deadline=None)
def test_scheduler_invariants(reqs, max_batch, c_ctx, chunked):
    sched = ContinuousBatchingScheduler(SchedulerConfig(
        max_batch=max_batch, max_num_tokens=c_ctx, chunked_prefill=chunked))
    for i, (isl, osl) in enumerate(reqs):
        sched.add(Request(rid=i, isl=isl, osl=osl))
    t, finished, steps = 0.0, [], 0
    while sched.active and steps < 20_000:
        plan = sched.plan(t)
        if plan.empty:
            break
        # invariant: decode slots never exceed max_batch
        assert len(plan.decode) + len(sched.prefilling) <= max_batch
        # invariant: chunked mode respects the token budget
        if chunked:
            assert plan.ctx_tokens <= c_ctx
        # invariant: chunks only cover un-processed prompt
        for c in plan.prefill:
            assert c.start == c.req.prefill_done
            assert c.start + c.length <= c.req.isl
        t += 1.0
        finished += sched.commit(plan, t)
        steps += 1
    # all requests complete, each generated exactly osl tokens
    assert len(finished) == len(reqs)
    for r in finished:
        assert r.generated == r.osl
        assert r.phase == Phase.DONE
        assert r.prefill_done == r.isl
    # all slots returned
    assert len(sched._free_slots) == max_batch


def test_prefill_priority_order():
    sched = ContinuousBatchingScheduler(SchedulerConfig(
        max_batch=4, max_num_tokens=100, chunked_prefill=True))
    sched.add(Request(rid=0, isl=250, osl=4))
    p1 = sched.plan(0.0)
    assert p1.ctx_tokens == 100 and not p1.decode
    sched.commit(p1, 1.0)
    p2 = sched.plan(1.0)
    assert p2.prefill[0].start == 100


def _lat(spec: StepSpec) -> float:
    return 1e-3 + 1e-6 * sum(c for c, _ in spec.prefill) \
        + 1e-5 * len(spec.decode)


def test_sim_completes_and_reports():
    sim = ServingSimulator(SchedulerConfig(max_batch=8, max_num_tokens=2048),
                           _lat)
    m = sim.run(isl=256, osl=32, concurrency=8, max_requests=24)
    assert m.completed == 24
    assert m.ttft_ms > 0 and m.tpot_ms > 0
    assert m.tokens_per_s_per_user == pytest.approx(1000.0 / m.tpot_ms)


def test_sim_concurrency_tradeoff():
    """More concurrency -> more throughput, worse (or equal) TPOT."""
    sim = ServingSimulator(SchedulerConfig(max_batch=64, max_num_tokens=4096),
                           _lat)
    lo = sim.run(isl=128, osl=32, concurrency=2, max_requests=16)
    hi = sim.run(isl=128, osl=32, concurrency=32, max_requests=32)
    assert hi.throughput_tok_s > lo.throughput_tok_s
    assert hi.tpot_ms >= lo.tpot_ms - 1e-6


# ---------------------------------------------------------------------------
# per-request metrics regression: no None -> 0.0 coercion
# ---------------------------------------------------------------------------

def test_per_request_carries_none_tpot_for_single_token_outputs():
    """osl=1 requests have no decode interval, so tpot is undefined; it
    must surface as None, not 0.0 (a 0.0 silently drags down any
    percentile computed over per_request)."""
    sim = ServingSimulator(SchedulerConfig(max_batch=4, max_num_tokens=512),
                           _lat)
    m = sim.run(isl=64, osl=1, concurrency=4, max_requests=8, warmup=0)
    assert m.completed == 8
    assert len(m.per_request) == 8
    for ttft, tpot in m.per_request:
        assert ttft is not None and ttft > 0
        assert tpot is None                       # carried, not coerced
    # a percentile over the defined samples only sees real values
    tpots = [t for _, t in m.per_request if t is not None]
    assert tpots == []


def test_per_request_has_no_zero_placeholders():
    sim = ServingSimulator(SchedulerConfig(max_batch=8, max_num_tokens=2048),
                           _lat)
    m = sim.run(isl=256, osl=32, concurrency=8, max_requests=16)
    assert len(m.per_request) == m.completed
    for ttft, tpot in m.per_request:
        assert ttft > 0.0
        assert tpot is not None and tpot > 0.0


# ---------------------------------------------------------------------------
# scheduler edge cases (ISSUE 4 satellites)
# ---------------------------------------------------------------------------

def test_non_chunked_oversized_prompt_admitted_not_livelocked():
    """chunked_prefill=False with isl > max_num_tokens: the scheduler
    admits the whole prompt over budget on a fresh iteration rather than
    waiting forever for a budget that can never be big enough."""
    sched = ContinuousBatchingScheduler(SchedulerConfig(
        max_batch=2, max_num_tokens=100, chunked_prefill=False))
    sched.add(Request(rid=0, isl=250, osl=2))
    plan = sched.plan(0.0)
    assert len(plan.prefill) == 1
    assert plan.prefill[0].length == 250          # over-budget admission
    finished = sched.commit(plan, 1.0)
    assert sched.waiting == deque() and len(sched.decoding) == 1
    assert not finished


def test_non_chunked_oversized_prompt_waits_for_fresh_iteration():
    """With part of the budget already consumed, a non-chunked oversized
    prompt defers instead of stacking over-budget work."""
    sched = ContinuousBatchingScheduler(SchedulerConfig(
        max_batch=2, max_num_tokens=100, chunked_prefill=False))
    sched.add(Request(rid=0, isl=60, osl=2))
    sched.add(Request(rid=1, isl=250, osl=2))
    plan = sched.plan(0.0)
    # the small prompt consumed budget; the big one must wait
    assert [c.req.rid for c in plan.prefill] == [0]
    sched.commit(plan, 1.0)
    plan2 = sched.plan(1.0)
    assert [c.req.rid for c in plan2.prefill] == [1]
    assert plan2.prefill[0].length == 250


def test_max_queue_rejection_path():
    sched = ContinuousBatchingScheduler(SchedulerConfig(
        max_batch=1, max_queue=2))
    assert sched.add(Request(rid=0, isl=8, osl=2))
    assert sched.add(Request(rid=1, isl=8, osl=2))
    rejected = Request(rid=2, isl=8, osl=2)
    assert not sched.add(rejected)
    assert rejected not in sched.waiting
    assert sched.active == 2                      # rejected never counted
    # draining the queue reopens admission
    plan = sched.plan(0.0)
    sched.commit(plan, 1.0)
    assert sched.add(Request(rid=3, isl=8, osl=2))


def test_osl_1_finishes_on_prefill_commit():
    """A request with osl=1 produces its only token when prefill
    completes: the same commit must finish it and free its slot."""
    sched = ContinuousBatchingScheduler(SchedulerConfig(
        max_batch=1, max_num_tokens=512))
    req = Request(rid=0, isl=64, osl=1)
    sched.add(req)
    plan = sched.plan(0.0)
    finished = sched.commit(plan, 1.0)
    assert finished == [req]
    assert req.phase == Phase.DONE
    assert req.generated == 1
    assert req.t_first_token == 1.0 and req.t_finish == 1.0
    assert req.tpot is None                       # no decode interval
    assert len(sched._free_slots) == 1            # slot returned
    assert sched.active == 0


def test_osl_1_chunked_prefill_finishes_after_last_chunk():
    sched = ContinuousBatchingScheduler(SchedulerConfig(
        max_batch=1, max_num_tokens=100, chunked_prefill=True))
    req = Request(rid=0, isl=250, osl=1)
    sched.add(req)
    t, finished = 0.0, []
    while sched.active and t < 10:
        plan = sched.plan(t)
        t += 1.0
        finished += sched.commit(plan, t)
    assert finished == [req]
    assert req.prefill_done == 250 and req.generated == 1
    assert req.t_finish == req.t_first_token      # done the same commit
