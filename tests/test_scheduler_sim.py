"""Scheduler invariants (hypothesis) + discrete-event simulator behaviour."""
import dataclasses

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare environment: deterministic fallback shim
    from _hypothesis_compat import given, settings, st

from repro.serving.request import Phase, Request
from repro.serving.scheduler import ContinuousBatchingScheduler, SchedulerConfig
from repro.serving.sim import ServingSimulator, StepSpec


@given(st.lists(st.tuples(st.integers(1, 500), st.integers(1, 40)),
                min_size=1, max_size=40),
       st.integers(1, 16), st.integers(64, 2048), st.booleans())
@settings(max_examples=60, deadline=None)
def test_scheduler_invariants(reqs, max_batch, c_ctx, chunked):
    sched = ContinuousBatchingScheduler(SchedulerConfig(
        max_batch=max_batch, max_num_tokens=c_ctx, chunked_prefill=chunked))
    for i, (isl, osl) in enumerate(reqs):
        sched.add(Request(rid=i, isl=isl, osl=osl))
    t, finished, steps = 0.0, [], 0
    while sched.active and steps < 20_000:
        plan = sched.plan(t)
        if plan.empty:
            break
        # invariant: decode slots never exceed max_batch
        assert len(plan.decode) + len(sched.prefilling) <= max_batch
        # invariant: chunked mode respects the token budget
        if chunked:
            assert plan.ctx_tokens <= c_ctx
        # invariant: chunks only cover un-processed prompt
        for c in plan.prefill:
            assert c.start == c.req.prefill_done
            assert c.start + c.length <= c.req.isl
        t += 1.0
        finished += sched.commit(plan, t)
        steps += 1
    # all requests complete, each generated exactly osl tokens
    assert len(finished) == len(reqs)
    for r in finished:
        assert r.generated == r.osl
        assert r.phase == Phase.DONE
        assert r.prefill_done == r.isl
    # all slots returned
    assert len(sched._free_slots) == max_batch


def test_prefill_priority_order():
    sched = ContinuousBatchingScheduler(SchedulerConfig(
        max_batch=4, max_num_tokens=100, chunked_prefill=True))
    sched.add(Request(rid=0, isl=250, osl=4))
    p1 = sched.plan(0.0)
    assert p1.ctx_tokens == 100 and not p1.decode
    sched.commit(p1, 1.0)
    p2 = sched.plan(1.0)
    assert p2.prefill[0].start == 100


def _lat(spec: StepSpec) -> float:
    return 1e-3 + 1e-6 * sum(c for c, _ in spec.prefill) \
        + 1e-5 * len(spec.decode)


def test_sim_completes_and_reports():
    sim = ServingSimulator(SchedulerConfig(max_batch=8, max_num_tokens=2048),
                           _lat)
    m = sim.run(isl=256, osl=32, concurrency=8, max_requests=24)
    assert m.completed == 24
    assert m.ttft_ms > 0 and m.tpot_ms > 0
    assert m.tokens_per_s_per_user == pytest.approx(1000.0 / m.tpot_ms)


def test_sim_concurrency_tradeoff():
    """More concurrency -> more throughput, worse (or equal) TPOT."""
    sim = ServingSimulator(SchedulerConfig(max_batch=64, max_num_tokens=4096),
                           _lat)
    lo = sim.run(isl=128, osl=32, concurrency=2, max_requests=16)
    hi = sim.run(isl=128, osl=32, concurrency=32, max_requests=32)
    assert hi.throughput_tok_s > lo.throughput_tok_s
    assert hi.tpot_ms >= lo.tpot_ms - 1e-6
