"""SearchReport schema v4: the ``capacity`` section round-trips, the new
v3 golden fixture migrates losslessly — its ``workload_eval`` section
byte-for-byte — and every older golden still loads."""
import json
import os

import pytest

from repro.api import Configurator, SCHEMA_VERSION, SearchReport
from repro.capacity import CAPACITY_SCHEMA_VERSION
from repro.workloads import (ArrivalSpec, LengthSpec, SLOSpec, TenantSpec,
                             TraceSpec, generate_trace)

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")
V3_FIXTURE = os.path.join(FIXTURES, "search_report_v3.json")

_SLO = SLOSpec(ttft_p99_ms=400, tpot_p99_ms=50)


def _configurator():
    return (Configurator.for_model("llama3.1-8b")
            .traffic(isl=256, osl=64)
            .sla(ttft_ms=2000, min_tokens_per_s_user=10)
            .cluster(chips=8).backend("repro-jax").dtype("fp8")
            .modes("aggregated"))


def _trace(seed=7):
    return generate_trace(TraceSpec(
        n_requests=60,
        arrivals=ArrivalSpec(kind="bursty", rate_rps=60.0, burst_factor=4.0),
        tenants=(TenantSpec(name="chat", weight=0.7, priority=1,
                            lengths=LengthSpec(kind="lognormal",
                                               isl=256, osl=64)),
                 TenantSpec(name="batch", weight=0.3,
                            lengths=LengthSpec(kind="lognormal",
                                               isl=512, osl=96)))),
        seed=seed)


@pytest.fixture(scope="module")
def planned():
    return _configurator().plan_capacity(_trace(), _SLO,
                                         ladder=(1, 2, 4), top_k=2)


# ---------------------------------------------------------------------------
# the v4 capacity section
# ---------------------------------------------------------------------------

def test_capacity_section_structure(planned):
    cap = planned.capacity
    assert cap is not None
    assert cap["schema_version"] == CAPACITY_SCHEMA_VERSION
    assert set(cap) >= {"trace", "slo", "routing", "attain_target",
                        "ladder", "database", "rungs", "plan",
                        "candidates", "skipped"}
    for rec in cap["rungs"]:
        assert set(rec) == {"replicas", "candidate_rank", "deployment",
                            "total_chips", "pruned", "attains", "truncated",
                            "metrics"}
        if rec["pruned"] is None:
            m = rec["metrics"]
            assert m["replicas"] == rec["replicas"]
            assert len(m["per_replica"]) == rec["replicas"]
            assert set(m["imbalance"]) == {"routed_max_over_mean",
                                           "routed_cv",
                                           "tokens_max_over_mean",
                                           "tokens_cv"}
    # candidate_rank indexes into the candidates metadata
    for rec in cap["rungs"]:
        assert 0 <= rec["candidate_rank"] < len(cap["candidates"])


def test_v4_roundtrip_preserves_capacity(planned):
    blob = planned.to_json()
    assert json.loads(blob)["schema_version"] == SCHEMA_VERSION
    back = SearchReport.from_json(blob)
    assert back == planned
    assert back.capacity == planned.capacity
    assert back.to_json() == blob            # byte-stable second hop


def test_summary_mentions_capacity_plan(planned):
    text = planned.summary()
    assert "capacity plan" in text
    assert planned.capacity["trace"]["digest"] in text


def test_plan_capacity_composes_with_workload_eval(planned):
    """capacity (v4) and workload_eval (v3) coexist in one report."""
    cfg = _configurator()
    report = cfg.evaluate_frontier(_trace(), _SLO, top_k=2)
    report = cfg.plan_capacity(_trace(), _SLO,
                               ladder=(1, 2), report=report)
    assert report.workload_eval is not None
    assert report.capacity is not None
    back = SearchReport.from_json(report.to_json())
    assert back.workload_eval == report.workload_eval
    assert back.capacity == report.capacity


# ---------------------------------------------------------------------------
# golden fixture: v3 migrates losslessly, workload_eval byte-for-byte
# ---------------------------------------------------------------------------

def test_v3_golden_fixture_migrates_losslessly():
    with open(V3_FIXTURE) as f:
        payload = json.load(f)
    assert payload["schema_version"] == 3
    rep = SearchReport.load(V3_FIXTURE)
    assert rep.schema_version == SCHEMA_VERSION
    assert rep.n_candidates == payload["search"]["n_candidates"]
    assert rep.elapsed_s == payload["search"]["elapsed_s"]
    assert rep.frontier_indices == payload["frontier"]
    assert rep.best_index == payload["best"]
    assert rep.fingerprint == payload["database"]
    assert len(rep.projections) == len(payload["projections"])
    for proj, raw in zip(rep.projections, payload["projections"]):
        assert proj.tokens_per_s_per_chip == raw["tokens_per_s_per_chip"]
        assert proj.config == raw["config"]
    # v3 never carried a capacity section: it defaults to None
    assert rep.capacity is None


def test_v3_golden_migration_preserves_workload_eval_bytes():
    """The v3 fixture's workload_eval must survive the v3→v4 migration
    byte-for-byte: identical JSON serialization, not merely equal-ish."""
    with open(V3_FIXTURE) as f:
        payload = json.load(f)
    assert payload["workload_eval"] is not None
    rep = SearchReport.load(V3_FIXTURE)
    assert rep.workload_eval == payload["workload_eval"]
    reserialized = rep.to_dict()
    assert json.dumps(reserialized["workload_eval"], sort_keys=True) \
        == json.dumps(payload["workload_eval"], sort_keys=True)
    # and the whole report keeps round-tripping after migration
    again = SearchReport.from_json(rep.to_json())
    assert again == rep


def test_all_golden_fixtures_still_load():
    for name, version in (("search_report_v1.json", 1),
                          ("search_report_v2.json", 2),
                          ("search_report_v3.json", 3)):
        path = os.path.join(FIXTURES, name)
        with open(path) as f:
            assert json.load(f)["schema_version"] == version
        rep = SearchReport.load(path)
        assert rep.schema_version == SCHEMA_VERSION
        assert rep.capacity is None
