"""Golden-file round-trip tests for SearchReport schema v2: v1 fixtures
migrate losslessly, v2 serialization is exact, and the PerfDatabase
fingerprint behaves like an identity (stable across repeat runs, changed
by platform/backend)."""
import copy
import json
import os

import pytest

from repro.api import (Configurator, SCHEMA_VERSION,
                       SUPPORTED_SCHEMA_VERSIONS, SearchReport,
                       stop_after_n_valid)
from repro.core.perf_database import PerfDatabase

V1_FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                          "search_report_v1.json")


def _small_configurator(**kw):
    return (Configurator.for_model("llama3.1-8b")
            .traffic(isl=256, osl=64)
            .sla(ttft_ms=2000, min_tokens_per_s_user=10)
            .cluster(chips=8, platform=kw.get("platform", "tpu_v5e"))
            .backend(kw.get("backend", "repro-jax")).dtype("fp8")
            .modes("aggregated"))


@pytest.fixture(scope="module")
def v1_payload():
    with open(V1_FIXTURE) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def report():
    return _small_configurator().search()


# ---------------------------------------------------------------------------
# v1 -> v2 migration
# ---------------------------------------------------------------------------

def test_v1_fixture_migrates_losslessly(v1_payload):
    rep = SearchReport.load(V1_FIXTURE)
    assert rep.schema_version == SCHEMA_VERSION   # migrated to current
    # every v1 field survives byte-exact
    w = v1_payload["workload"]
    assert rep.workload.model == w["model"]
    assert rep.workload.isl == w["isl"] and rep.workload.osl == w["osl"]
    assert rep.workload.sla.min_tokens_per_s_user \
        == w["sla"]["min_tokens_per_s_user"]
    assert rep.n_candidates == v1_payload["search"]["n_candidates"]
    assert rep.elapsed_s == v1_payload["search"]["elapsed_s"]
    assert rep.frontier_indices == v1_payload["frontier"]
    assert rep.best_index == v1_payload["best"]
    assert len(rep.projections) == len(v1_payload["projections"])
    for proj, raw in zip(rep.projections, v1_payload["projections"]):
        assert proj.tokens_per_s_per_chip == raw["tokens_per_s_per_chip"]
        assert proj.mem_bytes_per_chip == raw["mem_bytes_per_chip"]
        assert proj.config == raw["config"]
    assert rep.launch.command == v1_payload["launch"]["command"]
    # the sections v1 never carried default to empty
    assert rep.fingerprint is None and rep.early_exit is None


def test_migrated_v1_reserializes_as_current(v1_payload):
    rep = SearchReport.load(V1_FIXTURE)
    d = rep.to_dict()
    assert d["schema_version"] == SCHEMA_VERSION
    assert d["database"] is None
    assert d["memory"]["per_candidate_bytes_per_chip"] \
        == [p["mem_bytes_per_chip"] for p in v1_payload["projections"]]
    assert d["memory"]["peak_bytes_per_chip"] \
        == max(p["mem_bytes_per_chip"] for p in v1_payload["projections"])
    # and the current-schema re-serialization round-trips exactly
    assert SearchReport.from_json(rep.to_json()) == rep


# ---------------------------------------------------------------------------
# v2 round-trip
# ---------------------------------------------------------------------------

def test_current_roundtrip_is_exact(report):
    blob = report.to_json()
    d = json.loads(blob)
    assert d["schema_version"] == SCHEMA_VERSION
    assert 1 in SUPPORTED_SCHEMA_VERSIONS and 2 in SUPPORTED_SCHEMA_VERSIONS
    back = SearchReport.from_json(blob)
    assert back == report
    assert back.to_json() == blob                 # byte-stable second hop


def test_v2_carries_memory_and_fingerprint(report):
    d = report.to_dict()
    assert len(d["memory"]["per_candidate_bytes_per_chip"]) \
        == len(report.projections)
    assert all(m > 0 for m in d["memory"]["per_candidate_bytes_per_chip"])
    assert d["memory"]["peak_bytes_per_chip"] \
        == max(p.mem_bytes_per_chip for p in report.projections)
    fp = d["database"]
    assert fp["platform"] == "tpu_v5e" and fp["backend"] == "repro-jax"
    assert fp["n_grids"] > 0 and len(fp["grid_hash"]) == 16


def test_v2_early_exit_record_roundtrips():
    c = _small_configurator()
    stream = c.search_iter(policies=[stop_after_n_valid(2)])
    for _ in stream:
        pass
    rep = stream.report(generate_launch=False)
    assert rep.early_exit["reason"] == "stop_after_n_valid(2)"
    back = SearchReport.from_json(rep.to_json())
    assert back == rep
    assert back.early_exit == rep.early_exit


def test_unknown_schema_version_rejected(report):
    d = report.to_dict()
    d["schema_version"] = SCHEMA_VERSION + 1
    with pytest.raises(ValueError, match="schema_version"):
        SearchReport.from_dict(d)
    d["schema_version"] = None
    with pytest.raises(ValueError, match="schema_version"):
        SearchReport.from_dict(d)


def test_malformed_v1_payload_rejected(v1_payload):
    broken = copy.deepcopy(v1_payload)
    del broken["projections"]
    with pytest.raises(ValueError, match="malformed"):
        SearchReport.from_dict(broken)


# ---------------------------------------------------------------------------
# fingerprint identity
# ---------------------------------------------------------------------------

def test_fingerprint_stable_across_repeat_runs(report):
    again = _small_configurator().search()
    assert report.fingerprint == again.fingerprint
    # and within one Configurator across repeated searches
    c = _small_configurator()
    assert c.search().fingerprint == c.search().fingerprint


def test_fingerprint_changes_with_platform_and_backend(report):
    other_platform = _small_configurator(platform="tpu_v5p").search()
    assert other_platform.fingerprint["platform"] == "tpu_v5p"
    assert other_platform.fingerprint["grid_hash"] \
        != report.fingerprint["grid_hash"]
    other_backend = _small_configurator(backend="vllm").search()
    assert other_backend.fingerprint != report.fingerprint
    assert other_backend.fingerprint["backend"] == "vllm"


def test_fingerprint_tracks_database_contents():
    db = PerfDatabase("tpu_v5e", "repro-jax")
    fp1 = db.fingerprint()
    assert fp1 == db.fingerprint()                 # idempotent
    db._comm_grid("all_reduce", 4, False)          # lazily grow the db
    fp2 = db.fingerprint()
    assert fp2["n_grids"] == fp1["n_grids"] + 1
    assert fp2["grid_hash"] != fp1["grid_hash"]
