"""CLI surface of the bench sentinel: ``obs bench compare|gate|trend``
exit codes (0 pass / 1 violation / 2 environment mismatch), ``obs
diff`` on bench artifacts, and the ``run_suite`` harness machinery
driven by fake benchmarks (fresh registry + tracer per repeat, error
capture, --only selection)."""
import dataclasses
import json
import os
import sys

import pytest

from repro.core import cli
from repro.obs.bench import (BenchArtifact, BenchRecord, BenchTiming,
                             append_history)

ENV = {"platform": "test-host", "repro": {"REPRO_PRICING_CHUNK": 64}}


def _record(name, counters, min_us=1000.0, status="ok"):
    return BenchRecord(name=name, status=status,
                       timing=BenchTiming.from_samples([min_us]),
                       counters=counters, phases={})


def _save(tmp_path, filename, records, env=None):
    art = BenchArtifact(suite="quick", created_at="2026-01-01T00:00:00Z",
                        environment=env or ENV, records=records)
    path = str(tmp_path / filename)
    art.save(path)
    return path


# ---------------------------------------------------------------------------
# obs bench compare
# ---------------------------------------------------------------------------

def test_compare_identical_exit_0(tmp_path, capsys):
    a = _save(tmp_path, "a.json", [_record("b", {"w": 1.0})])
    b = _save(tmp_path, "b.json", [_record("b", {"w": 1.0}, min_us=999.0)])
    assert cli.main(["obs", "bench", "compare", a, b]) == 0
    assert "identical work" in capsys.readouterr().out


def test_compare_drift_exit_1(tmp_path, capsys):
    a = _save(tmp_path, "a.json", [_record("b", {"w": 1.0})])
    b = _save(tmp_path, "b.json", [_record("b", {"w": 2.0})])
    assert cli.main(["obs", "bench", "compare", a, b]) == 1
    assert "NOT identical" in capsys.readouterr().out


def test_compare_env_mismatch_exit_2(tmp_path, capsys):
    a = _save(tmp_path, "a.json", [_record("b", {"w": 1.0})])
    b = _save(tmp_path, "b.json", [_record("b", {"w": 1.0})],
              env={"platform": "test-host",
                   "repro": {"REPRO_PRICING_CHUNK": 1}})
    assert cli.main(["obs", "bench", "compare", a, b]) == 2
    err = capsys.readouterr().err
    assert "environment fingerprints differ" in err
    assert "REPRO_PRICING_CHUNK" in err


def test_compare_json_output(tmp_path, capsys):
    a = _save(tmp_path, "a.json", [_record("b", {"w": 1.0})])
    assert cli.main(["obs", "bench", "compare", a, a, "--json"]) == 0
    blob = json.loads(capsys.readouterr().out)
    assert blob["identical"] and blob["digest_a"] == blob["digest_b"]


def test_compare_rejects_non_bench_json(tmp_path, capsys):
    bogus = tmp_path / "report.json"
    bogus.write_text(json.dumps({"schema_version": 7, "telemetry": None}))
    a = _save(tmp_path, "a.json", [_record("b", {"w": 1.0})])
    assert cli.main(["obs", "bench", "compare", a, str(bogus)]) == 2
    assert "not a bench artifact" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# obs bench gate
# ---------------------------------------------------------------------------

def test_gate_pass_exit_0(tmp_path, capsys):
    base = _save(tmp_path, "base.json", [_record("b", {"w": 5.0})])
    cur = _save(tmp_path, "cur.json", [_record("b", {"w": 5.0})])
    assert cli.main(["obs", "bench", "gate", "--baseline", base,
                     "--current", cur]) == 0
    assert "PASS" in capsys.readouterr().out


def test_gate_counter_growth_exit_1(tmp_path, capsys):
    base = _save(tmp_path, "base.json", [_record("b", {"w": 5.0})])
    cur = _save(tmp_path, "cur.json", [_record("b", {"w": 6.0})])
    assert cli.main(["obs", "bench", "gate", "--baseline", base,
                     "--current", cur, "--hard-only"]) == 1
    out = capsys.readouterr().out
    assert "FAIL" in out and "grew" in out


def test_gate_soft_violation_and_hard_only_escape(tmp_path, capsys):
    base = _save(tmp_path, "base.json",
                 [_record("b", {"w": 1.0}, min_us=100.0)])
    cur = _save(tmp_path, "cur.json",
                [_record("b", {"w": 1.0}, min_us=10_000_000.0)])
    assert cli.main(["obs", "bench", "gate", "--baseline", base,
                     "--current", cur]) == 1
    assert "SOFT" in capsys.readouterr().out
    assert cli.main(["obs", "bench", "gate", "--baseline", base,
                     "--current", cur, "--hard-only"]) == 0
    capsys.readouterr()


def test_gate_rel_tol_flag(tmp_path, capsys):
    base = _save(tmp_path, "base.json",
                 [_record("b", {}, min_us=1000.0)])
    cur = _save(tmp_path, "cur.json",
                [_record("b", {}, min_us=1400.0)])
    common = ["obs", "bench", "gate", "--baseline", base, "--current", cur,
              "--abs-tol-us", "0"]
    assert cli.main(common + ["--rel-tol", "0.5"]) == 0
    assert cli.main(common + ["--rel-tol", "0.2"]) == 1
    capsys.readouterr()


def test_gate_json_output(tmp_path, capsys):
    base = _save(tmp_path, "base.json", [_record("b", {"w": 2.0})])
    cur = _save(tmp_path, "cur.json", [_record("b", {"w": 1.0})])
    assert cli.main(["obs", "bench", "gate", "--baseline", base,
                     "--current", cur, "--json"]) == 0
    blob = json.loads(capsys.readouterr().out)
    assert blob["ok"] and blob["improvements"][0]["counter"] == "w"


# ---------------------------------------------------------------------------
# obs bench trend
# ---------------------------------------------------------------------------

def test_trend_cli(tmp_path, capsys):
    history = str(tmp_path / "h.jsonl")
    for w, us in ((1.0, 100.0), (1.0, 90.0), (3.0, 80.0)):
        append_history(history, BenchArtifact(
            suite="quick", created_at="2026-01-01T00:00:00Z",
            environment=ENV, records=[_record("b", {"w": w}, min_us=us)]))
    assert cli.main(["obs", "bench", "trend", "--history", history]) == 0
    out = capsys.readouterr().out
    assert "3 runs" in out and "work-changes 1" in out
    assert cli.main(["obs", "bench", "trend", "--history", history,
                     "--json"]) == 0
    blob = json.loads(capsys.readouterr().out)
    assert blob["benches"]["b"]["best_min_us"] == 80.0


def test_trend_missing_history_exit_2(tmp_path, capsys):
    assert cli.main(["obs", "bench", "trend", "--history",
                     str(tmp_path / "nope.jsonl")]) == 2
    capsys.readouterr()


# ---------------------------------------------------------------------------
# obs diff understands bench artifacts
# ---------------------------------------------------------------------------

def test_obs_diff_flattens_bench_counters(tmp_path, capsys):
    a = _save(tmp_path, "a.json", [_record("bench_x", {"w": 1.0})])
    b = _save(tmp_path, "b.json", [_record("bench_x", {"w": 4.0})])
    assert cli.main(["obs", "diff", a, b, "--json"]) == 1
    blob = json.loads(capsys.readouterr().out)
    assert blob["counters"]["changed"]["bench_x/w"]["delta"] == 3.0
    assert cli.main(["obs", "diff", a, a]) == 0
    capsys.readouterr()


# ---------------------------------------------------------------------------
# run_suite harness (fake benches — no real benchmarks run)
# ---------------------------------------------------------------------------

@pytest.fixture()
def run_suite():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    try:
        from benchmarks.run import run_suite as rs
    finally:
        sys.path.pop(0)
    return rs


def _fake_benches():
    from repro.obs import get_metrics, get_tracer

    def counting(quick=False):
        get_metrics().inc("fake_work_total", 7)
        with get_tracer().span("fake.phase"):
            pass
        return {"x": 2 if quick else 9}

    def failing(quick=False):
        raise RuntimeError("nope")

    return [("counting", counting, lambda r: f"x={r['x']}"),
            ("failing", failing, lambda r: "")]


def test_run_suite_captures_counters_phases_and_errors(run_suite):
    lines = []
    art, failures = run_suite(quick=True, repeat=3,
                              created_at="2026-01-01T00:00:00Z",
                              benches=_fake_benches(), emit=lines.append)
    assert failures == 1
    ok = art.record("counting")
    assert ok.status == "ok"
    assert ok.counters == {"fake_work_total": 7.0}  # fresh registry per rep
    assert "fake.phase" in ok.phases
    assert ok.timing.n == 3 and ok.derived == "x=2"
    bad = art.record("failing")
    assert bad.status == "error" and "RuntimeError" in bad.error
    assert art.suite == "quick"
    assert "repro" in art.environment
    assert lines[0] == "name,us_per_call,derived"
    assert any(line.startswith("counting,") for line in lines)
    assert any("ERROR:RuntimeError" in line for line in lines)
    # registry/tracer are uninstalled after the suite
    from repro.obs.metrics import get_metrics
    from repro.obs.trace import NULL_TRACER, get_tracer
    assert get_tracer() is NULL_TRACER
    assert get_metrics() is None


def test_run_suite_only_selection(run_suite):
    art, failures = run_suite(quick=True, only="count",
                              created_at="t", benches=_fake_benches(),
                              emit=lambda s: None)
    assert failures == 0 and art.names == ["counting"]


def test_run_suite_round_trips(run_suite):
    art, _ = run_suite(quick=True, created_at="t",
                       benches=_fake_benches(), emit=lambda s: None)
    assert BenchArtifact.from_json(art.to_json()) == art


def test_result_dicts_carry_environment(run_suite):
    """Satellite: every benchmark result dict is stamped with the
    environment fingerprint via common.finalize_result."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    try:
        from benchmarks.common import bench_environment, finalize_result
    finally:
        sys.path.pop(0)
    out = finalize_result({"csv": "x.csv"})
    assert out["csv"] == "x.csv"
    assert out["environment"] is bench_environment()
    assert out["environment"]["repro"]["REPRO_PRICING_CHUNK"] == 64
