"""CLI ``autoscale run|compare``: timeline JSON-lines records, summary
records, ``--save-timeline``, byte-stable output across runs, and stable
exit codes."""
import json

import pytest

from repro.autoscale import ClusterTimeline
from repro.core import cli

_TRACE_ARGS = ["workload", "generate", "--arrivals", "diurnal", "--rate",
               "1.2", "--period", "60", "--amplitude", "0.9", "--n", "250",
               "--lengths", "fixed", "--isl", "512", "--osl", "128",
               "--seed", "11"]

_RUN_ARGS = ["--model", "qwen3-32b", "--tp", "1", "--batch", "16",
             "--policy", "target_queue_depth", "--target-depth", "6",
             "--max-replicas", "2", "--up-cooldown", "2",
             "--down-cooldown", "8", "--window", "5", "--tick", "1",
             "--cold-start", "2", "--slo-ttft-p99", "2500",
             "--slo-tpot-p99", "100"]


@pytest.fixture(scope="module")
def trace_path(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("asc") / "trace.jsonl")
    assert cli.main(_TRACE_ARGS + ["--out", path]) == 0
    return path


def _records(capsys):
    lines = capsys.readouterr().out.strip().splitlines()
    return [json.loads(line) for line in lines if line.strip()]


def test_autoscale_run_json_emits_samples_and_summary(trace_path, capsys):
    rc = cli.main(["autoscale", "run", "--trace", trace_path]
                  + _RUN_ARGS + ["--json"])
    records = _records(capsys)
    assert rc == 0
    samples, summary = records[:-1], records[-1]
    assert samples and all(r["type"] == "sample" for r in samples)
    assert summary["type"] == "summary"
    assert summary["policy"]["name"] == "target_queue_depth"
    assert summary["metrics"]["completed"] == 250
    assert summary["chip_seconds"] > 0
    assert summary["timeline"]["n_samples"] == len(samples)
    # sample ticks are the fixed grid the loop ran on
    assert [s["t_s"] for s in samples] == \
        [summary["tick_s"] * (i + 1) for i in range(len(samples))]


def test_autoscale_run_saves_loadable_timeline(trace_path, capsys,
                                               tmp_path):
    path = str(tmp_path / "timeline.jsonl")
    rc = cli.main(["autoscale", "run", "--trace", trace_path] + _RUN_ARGS
                  + ["--save-timeline", path])
    capsys.readouterr()
    assert rc == 0
    tl = ClusterTimeline.load(path)
    assert tl.n_samples > 0
    assert tl.meta["policy"]["name"] == "target_queue_depth"


def test_autoscale_compare_saves_chips_and_holds_slo(trace_path, capsys):
    rc = cli.main(["autoscale", "compare", "--trace", trace_path]
                  + _RUN_ARGS + ["--ladder", "1,2,4", "--json"])
    records = _records(capsys)
    assert rc == 0
    summary = records[-1]
    assert summary["type"] == "summary"
    static = summary["static"]
    assert static is not None and static["total_chips"] == 2
    run = summary["run"]
    # the acceptance property, through the CLI surface
    assert run["chip_seconds"] < static["chip_seconds"]
    assert summary["savings"]["holds_attainment"] is True
    assert summary["savings"]["chip_seconds"] > 0
    assert run["initial_replicas"] == 2    # starts at the static size


def test_autoscale_compare_json_byte_stable_across_runs(trace_path,
                                                        capsys):
    args = (["autoscale", "compare", "--trace", trace_path] + _RUN_ARGS
            + ["--ladder", "1,2,4", "--json"])
    rc1 = cli.main(args)
    out1 = capsys.readouterr().out
    rc2 = cli.main(args)
    out2 = capsys.readouterr().out
    assert rc1 == rc2 == 0
    assert out1 == out2                    # byte-identical, not merely close


def test_autoscale_compare_exit_1_when_nothing_attains(trace_path,
                                                       capsys):
    rc = cli.main(["autoscale", "compare", "--trace", trace_path]
                  + _RUN_ARGS[:-4]
                  + ["--slo-ttft-p99", "1", "--slo-tpot-p99", "1",
                     "--ladder", "1", "--json"])
    records = _records(capsys)
    assert rc == 1
    assert records[-1]["static"] is None
    assert records[-1]["savings"] is None


def test_autoscale_usage_errors_exit_2(trace_path):
    # unreadable trace
    assert cli.main(["autoscale", "run", "--trace", "/nope.jsonl"]
                    + _RUN_ARGS) == 2
    # initial size outside the policy bounds
    assert cli.main(["autoscale", "run", "--trace", trace_path]
                    + _RUN_ARGS + ["--initial-replicas", "9"]) == 2
    # bad ladder spelling
    assert cli.main(["autoscale", "compare", "--trace", trace_path]
                    + _RUN_ARGS + ["--ladder", "one,two"]) == 2


def test_autoscale_human_output_mentions_savings(trace_path, capsys):
    rc = cli.main(["autoscale", "compare", "--trace", trace_path]
                  + _RUN_ARGS + ["--ladder", "1,2,4"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "static plan:" in out
    assert "savings:" in out and "holds attainment" in out
