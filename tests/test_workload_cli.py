"""CLI surface of the workloads subsystem: `workload generate | describe |
replay` and `search --trace` (SLO-aware frontier re-ranking)."""
import json

import pytest

from repro.api import SCHEMA_VERSION
from repro.core import cli
from repro.workloads import WorkloadTrace

_GEN_ARGS = ["workload", "generate", "--arrivals", "bursty", "--rate", "4",
             "--n", "40", "--lengths", "lognormal", "--isl", "256",
             "--osl", "64", "--tenants", "chat:0.7:1,batch:0.3",
             "--seed", "7"]


@pytest.fixture()
def trace_path(tmp_path, capsys):
    path = str(tmp_path / "trace.jsonl")
    rc = cli.main(_GEN_ARGS + ["--out", path])
    capsys.readouterr()
    assert rc == 0
    return path


def test_workload_generate_writes_versioned_jsonl(trace_path):
    trace = WorkloadTrace.load(trace_path)
    assert trace.n_requests == 40
    assert set(trace.tenants) == {"batch", "chat"}
    assert trace.meta["generator"]["seed"] == 7
    with open(trace_path) as f:
        header = json.loads(f.readline())
    assert header["type"] == "header" and header["schema_version"] == 1


def test_workload_generate_deterministic(tmp_path, capsys):
    a, b = str(tmp_path / "a.jsonl"), str(tmp_path / "b.jsonl")
    assert cli.main(_GEN_ARGS + ["--out", a, "--json"]) == 0
    rec_a = json.loads(capsys.readouterr().out)
    assert cli.main(_GEN_ARGS + ["--out", b, "--json"]) == 0
    rec_b = json.loads(capsys.readouterr().out)
    assert rec_a["describe"]["digest"] == rec_b["describe"]["digest"]
    assert open(a).read() == open(b).read()


def test_workload_generate_from_spec_file(tmp_path, capsys):
    spec = {"n_requests": 12,
            "arrivals": {"kind": "poisson", "rate_rps": 2.0,
                         "burst_factor": 4.0, "mean_on_s": 10.0,
                         "mean_off_s": 20.0, "period_s": 120.0,
                         "amplitude": 0.8},
            "tenants": [{"name": "only", "weight": 1.0, "priority": 0,
                         "lengths": {"kind": "fixed", "isl": 128, "osl": 32,
                                     "isl_lo": 64, "isl_hi": 2048,
                                     "osl_lo": 16, "osl_hi": 512,
                                     "sigma": 0.5}}]}
    spec_path = tmp_path / "spec.json"
    spec_path.write_text(json.dumps(spec))
    out = str(tmp_path / "t.jsonl")
    rc = cli.main(["workload", "generate", "--spec", str(spec_path),
                   "--out", out, "--json"])
    assert rc == 0
    rec = json.loads(capsys.readouterr().out)
    assert rec["describe"]["n_requests"] == 12
    trace = WorkloadTrace.load(out)
    assert all(r.isl == 128 and r.osl == 32 for r in trace.requests)


def test_workload_describe(trace_path, capsys):
    rc = cli.main(["workload", "describe", "--trace", trace_path, "--json"])
    assert rc == 0
    desc = json.loads(capsys.readouterr().out)
    assert desc["n_requests"] == 40
    assert set(desc["tenants"]) == {"batch", "chat"}
    assert desc["isl"]["p50"] <= desc["isl"]["p95"]
    # human-readable variant mentions the tenants
    rc = cli.main(["workload", "describe", "--trace", trace_path])
    text = capsys.readouterr().out
    assert rc == 0 and "tenant chat" in text


def test_workload_replay_json(trace_path, capsys):
    rc = cli.main(["workload", "replay", "--trace", trace_path,
                   "--model", "llama3.1-8b", "--tp", "2", "--batch", "64",
                   "--dtype", "fp8", "--slo-ttft-p99", "1500",
                   "--slo-tpot-p99", "60", "--json"])
    assert rc == 0
    payload = json.loads(capsys.readouterr().out)
    m = payload["metrics"]
    assert m["n_requests"] == 40
    assert m["completed"] + m["rejected"] + m["unfinished"] == 40
    assert m["goodput_tok_s"] >= 0.0
    assert m["goodput_tok_s"] <= m["throughput_tok_s"] + 1e-9
    assert set(m["ttft_ms"]) == {"p50", "p95", "p99"}
    assert payload["trace"]["digest"] == WorkloadTrace.load(trace_path).digest()
    assert payload["config"]["describe"] == "TP2 b64"


def test_workload_replay_human_output(trace_path, capsys):
    rc = cli.main(["workload", "replay", "--trace", trace_path,
                   "--model", "llama3.1-8b", "--tp", "1", "--batch", "32",
                   "--dtype", "fp8"])
    assert rc == 0
    text = capsys.readouterr().out
    assert "SLO attainment" in text and "goodput" in text


def test_search_with_trace_rerank(trace_path, capsys):
    rc = cli.main(["search", "--model", "llama3.1-8b", "--isl", "256",
                   "--osl", "64", "--ttft", "2000", "--min-speed", "10",
                   "--chips", "8", "--dtype", "fp8", "--modes", "aggregated",
                   "--trace", trace_path, "--slo-ttft-p99", "1500",
                   "--slo-tpot-p99", "60", "--replay-top-k", "2", "--json"])
    assert rc == 0
    report = json.loads(capsys.readouterr().out)
    assert report["schema_version"] == SCHEMA_VERSION
    we = report["workload_eval"]
    assert we is not None
    assert we["top_k"] == 2
    assert len(we["ranking"]) <= 2
    replayed = [c for c in we["candidates"] if c["replay"] is not None]
    assert replayed
    for c in replayed:
        assert c["replay"]["slo"] == {"ttft_p99_ms": 1500.0,
                                      "tpot_p99_ms": 60.0}


def test_search_without_trace_has_no_workload_eval(capsys):
    rc = cli.main(["search", "--model", "llama3.1-8b", "--isl", "256",
                   "--osl", "64", "--ttft", "2000", "--min-speed", "10",
                   "--chips", "8", "--dtype", "fp8", "--modes", "aggregated",
                   "--json"])
    assert rc == 0
    report = json.loads(capsys.readouterr().out)
    assert report["schema_version"] == SCHEMA_VERSION
    assert report["workload_eval"] is None


def test_workload_bad_inputs_exit_2(tmp_path, capsys):
    missing = str(tmp_path / "nope.jsonl")
    assert cli.main(["workload", "describe", "--trace", missing]) == 2
    capsys.readouterr()
    # malformed tenant spec
    assert cli.main(["workload", "generate", "--tenants", "justname",
                     "--out", str(tmp_path / "x.jsonl")]) == 2
    capsys.readouterr()
    # corrupt trace file
    bad = tmp_path / "bad.jsonl"
    bad.write_text("not json\n")
    assert cli.main(["workload", "replay", "--trace", str(bad),
                     "--model", "llama3.1-8b"]) == 2
