"""SearchReport schema v5: the ``autoscale`` section round-trips, the
new v4 golden fixture migrates losslessly — its ``capacity`` and
``workload_eval`` sections byte-for-byte — and every older golden still
loads."""
import json
import os

import pytest

from repro.api import Configurator, SCHEMA_VERSION, SearchReport
from repro.autoscale import (AUTOSCALE_SCHEMA_VERSION, TargetQueueDepth)
from repro.workloads import (ArrivalSpec, LengthSpec, SLOSpec, TenantSpec,
                             TraceSpec, generate_trace)

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")
V4_FIXTURE = os.path.join(FIXTURES, "search_report_v4.json")

_SLO = SLOSpec(ttft_p99_ms=1000, tpot_p99_ms=50)


def _configurator():
    return (Configurator.for_model("llama3.1-8b")
            .traffic(isl=256, osl=64)
            .sla(ttft_ms=2000, min_tokens_per_s_user=10)
            .cluster(chips=8).backend("repro-jax").dtype("fp8")
            .modes("aggregated"))


def _diurnal_trace(seed=5):
    return generate_trace(TraceSpec(
        n_requests=120,
        arrivals=ArrivalSpec(kind="diurnal", rate_rps=30.0, period_s=20.0,
                             amplitude=0.9),
        tenants=(TenantSpec(name="chat", weight=1.0,
                            lengths=LengthSpec(kind="lognormal",
                                               isl=256, osl=64)),)),
        seed=seed)


@pytest.fixture(scope="module")
def autoscaled():
    return _configurator().autoscale(
        _diurnal_trace(), _SLO,
        policy=TargetQueueDepth(target_depth=6.0, max_replicas=4,
                                up_cooldown_s=1.0, down_cooldown_s=4.0,
                                window_s=3.0),
        ladder=(1, 2, 4), tick_s=0.5, cold_start_s=1.0)


# ---------------------------------------------------------------------------
# the v5 autoscale section
# ---------------------------------------------------------------------------

def test_autoscale_section_structure(autoscaled):
    a = autoscaled.autoscale
    assert a is not None
    assert a["schema_version"] == AUTOSCALE_SCHEMA_VERSION
    assert set(a) >= {"trace", "slo", "routing", "attain_target", "ladder",
                      "tick_s", "cold_start_s", "policy", "database",
                      "static", "run", "savings", "candidate", "skipped"}
    run = a["run"]
    assert run["policy"]["name"] == "target_queue_depth"
    assert run["chip_seconds"] > 0
    assert run["peak_replicas"] >= run["metrics"]["replicas"] >= 1 \
        or run["peak_replicas"] >= 1
    # the section references the timeline by identity, not by value
    assert set(run["timeline"]) == {"digest", "tick_s", "n_samples"}
    assert a["candidate"]["describe"]


def test_v5_roundtrip_preserves_autoscale(autoscaled):
    blob = autoscaled.to_json()
    assert json.loads(blob)["schema_version"] == SCHEMA_VERSION
    back = SearchReport.from_json(blob)
    assert back == autoscaled
    assert back.autoscale == autoscaled.autoscale
    assert back.to_json() == blob            # byte-stable second hop


def test_summary_mentions_autoscale(autoscaled):
    text = autoscaled.summary()
    assert "autoscale" in text
    assert autoscaled.autoscale["trace"]["digest"] in text


def test_autoscale_composes_with_capacity(autoscaled):
    """autoscale (v5) coexists with capacity (v4) in one report."""
    cfg = _configurator()
    report = cfg.plan_capacity(_diurnal_trace(), _SLO, ladder=(1, 2))
    report = cfg.autoscale(_diurnal_trace(), _SLO, ladder=(1, 2),
                           report=report)
    assert report.capacity is not None
    assert report.autoscale is not None
    back = SearchReport.from_json(report.to_json())
    assert back.capacity == report.capacity
    assert back.autoscale == report.autoscale


# ---------------------------------------------------------------------------
# golden fixture: v4 migrates losslessly, capacity byte-for-byte
# ---------------------------------------------------------------------------

def test_v4_golden_fixture_migrates_losslessly():
    with open(V4_FIXTURE) as f:
        payload = json.load(f)
    assert payload["schema_version"] == 4
    rep = SearchReport.load(V4_FIXTURE)
    assert rep.schema_version == SCHEMA_VERSION
    assert rep.n_candidates == payload["search"]["n_candidates"]
    assert rep.elapsed_s == payload["search"]["elapsed_s"]
    assert rep.frontier_indices == payload["frontier"]
    assert rep.best_index == payload["best"]
    assert rep.fingerprint == payload["database"]
    assert len(rep.projections) == len(payload["projections"])
    for proj, raw in zip(rep.projections, payload["projections"]):
        assert proj.tokens_per_s_per_chip == raw["tokens_per_s_per_chip"]
        assert proj.config == raw["config"]
    # v4 never carried an autoscale section: it defaults to None
    assert rep.autoscale is None


def test_v4_golden_migration_preserves_sections_bytes():
    """The v4 fixture's capacity and workload_eval must survive the
    v4→v5 migration byte-for-byte: identical JSON serialization, not
    merely equal-ish."""
    with open(V4_FIXTURE) as f:
        payload = json.load(f)
    assert payload["capacity"] is not None
    assert payload["workload_eval"] is not None
    rep = SearchReport.load(V4_FIXTURE)
    reserialized = rep.to_dict()
    for section in ("capacity", "workload_eval"):
        assert json.dumps(reserialized[section], sort_keys=True) \
            == json.dumps(payload[section], sort_keys=True), section
    # and the whole report keeps round-tripping after migration
    again = SearchReport.from_json(rep.to_json())
    assert again == rep


def test_all_golden_fixtures_still_load():
    for name, version in (("search_report_v1.json", 1),
                          ("search_report_v2.json", 2),
                          ("search_report_v3.json", 3),
                          ("search_report_v4.json", 4)):
        path = os.path.join(FIXTURES, name)
        with open(path) as f:
            assert json.load(f)["schema_version"] == version
        rep = SearchReport.load(path)
        assert rep.schema_version == SCHEMA_VERSION
        assert rep.autoscale is None
