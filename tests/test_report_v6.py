"""SearchReport schema v6: the ``telemetry`` section round-trips, stays
``None`` on uninstrumented runs, the new v5 golden fixture migrates
losslessly — its ``capacity`` and ``autoscale`` sections byte-for-byte —
and every older golden still loads."""
import json
import os

import pytest

from repro.api import Configurator, SCHEMA_VERSION, SearchReport
from repro.obs import (disable_metrics, disable_tracing, enable_metrics,
                       enable_tracing)

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")
V5_FIXTURE = os.path.join(FIXTURES, "search_report_v5.json")


def _configurator():
    return (Configurator.for_model("llama3.1-8b")
            .traffic(isl=256, osl=64)
            .sla(ttft_ms=2000, min_tokens_per_s_user=10)
            .cluster(chips=8).backend("repro-jax").dtype("fp8")
            .modes("aggregated"))


@pytest.fixture(autouse=True)
def _clean_instrumentation():
    disable_tracing()
    disable_metrics()
    yield
    disable_tracing()
    disable_metrics()


@pytest.fixture(scope="module")
def instrumented():
    tracer, registry = enable_tracing(), enable_metrics()
    try:
        report = _configurator().search(generate_launch=False)
    finally:
        disable_tracing()
        disable_metrics()
    return report, tracer, registry


# ---------------------------------------------------------------------------
# the v6 telemetry section
# ---------------------------------------------------------------------------

def test_telemetry_section_structure(instrumented):
    report, tracer, registry = instrumented
    t = report.telemetry
    assert t is not None
    assert set(t) == {"trace", "metrics"}
    assert t["trace"]["schema_version"] == 1
    assert t["trace"]["n_spans"] == len(tracer.spans) > 0
    assert t["trace"]["digest"] == tracer.artifact().digest()
    counters = t["metrics"]["counters"]
    assert counters == registry.to_dict()["counters"]
    assert any(k.startswith("repro_db_ops_total") for k in counters)
    assert any(k.startswith("repro_search_candidates_priced_total")
               for k in counters)


def test_v6_roundtrip_preserves_telemetry(instrumented):
    report, _, _ = instrumented
    blob = report.to_json()
    assert json.loads(blob)["schema_version"] == SCHEMA_VERSION
    back = SearchReport.from_json(blob)
    assert back == report
    assert back.telemetry == report.telemetry
    assert back.to_json() == blob            # byte-stable second hop


def test_summary_mentions_telemetry(instrumented):
    report, _, _ = instrumented
    text = report.summary()
    assert "telemetry" in text
    assert report.telemetry["trace"]["digest"] in text


def test_uninstrumented_search_has_no_telemetry():
    report = _configurator().search(generate_launch=False)
    assert report.telemetry is None
    assert '"telemetry": null' in report.to_json()
    assert "telemetry" not in report.summary()


def test_metrics_only_telemetry():
    """A registry without a tracer still lands in the report; the trace
    half stays None."""
    enable_metrics()
    try:
        report = _configurator().search(generate_launch=False)
    finally:
        disable_metrics()
    assert report.telemetry is not None
    assert report.telemetry["trace"] is None
    assert report.telemetry["metrics"]["counters"]


# ---------------------------------------------------------------------------
# golden fixture: v5 migrates losslessly, sections byte-for-byte
# ---------------------------------------------------------------------------

def test_v5_golden_fixture_migrates_losslessly():
    with open(V5_FIXTURE) as f:
        payload = json.load(f)
    assert payload["schema_version"] == 5
    rep = SearchReport.load(V5_FIXTURE)
    assert rep.schema_version == SCHEMA_VERSION
    assert rep.n_candidates == payload["search"]["n_candidates"]
    assert rep.elapsed_s == payload["search"]["elapsed_s"]
    assert rep.frontier_indices == payload["frontier"]
    assert rep.best_index == payload["best"]
    assert rep.fingerprint == payload["database"]
    assert len(rep.projections) == len(payload["projections"])
    for proj, raw in zip(rep.projections, payload["projections"]):
        assert proj.tokens_per_s_per_chip == raw["tokens_per_s_per_chip"]
        assert proj.config == raw["config"]
    # v5 never carried a telemetry section: it defaults to None
    assert rep.telemetry is None


def test_v5_golden_migration_preserves_sections_bytes():
    """The v5 fixture's capacity and autoscale sections must survive the
    v5→v6 migration byte-for-byte: identical JSON serialization, not
    merely equal-ish."""
    with open(V5_FIXTURE) as f:
        payload = json.load(f)
    assert payload["capacity"] is not None
    assert payload["autoscale"] is not None
    rep = SearchReport.load(V5_FIXTURE)
    reserialized = rep.to_dict()
    for section in ("capacity", "autoscale"):
        assert json.dumps(reserialized[section], sort_keys=True) \
            == json.dumps(payload[section], sort_keys=True), section
    again = SearchReport.from_json(rep.to_json())
    assert again == rep


def test_all_golden_fixtures_still_load():
    for name, version in (("search_report_v1.json", 1),
                          ("search_report_v2.json", 2),
                          ("search_report_v3.json", 3),
                          ("search_report_v4.json", 4),
                          ("search_report_v5.json", 5)):
        path = os.path.join(FIXTURES, name)
        with open(path) as f:
            assert json.load(f)["schema_version"] == version
        rep = SearchReport.load(path)
        assert rep.schema_version == SCHEMA_VERSION
        assert rep.telemetry is None
