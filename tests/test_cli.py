"""Configurator CLI (the paper's Fig. 2 workflow as one command), plus
the streaming surface: `search --stream` JSON-lines, `--first-n` early
exit, and exit-code stability."""
import json
import re

import pytest

from repro.api import SCHEMA_VERSION
from repro.core import cli

_STREAM_ARGS = ["--model", "llama3.1-8b", "--isl", "256", "--osl", "64",
                "--ttft", "2000", "--min-speed", "10", "--chips", "8",
                "--dtype", "fp8", "--modes", "aggregated"]


def _records(capsys):
    lines = capsys.readouterr().out.strip().splitlines()
    return [json.loads(line) for line in lines if line.strip()]


def test_cli_end_to_end(tmp_path, capsys):
    out = str(tmp_path / "launch.json")
    rc = cli.main(["--model", "llama3.1-8b", "--isl", "1024", "--osl", "256",
                   "--ttft", "2000", "--min-speed", "10", "--chips", "16",
                   "--dtype", "fp8", "--save-launch", out])
    assert rc == 0
    text = capsys.readouterr().out
    assert "launch command:" in text
    assert "tok/s/chip" in text
    raw = json.load(open(out))
    assert raw["model"] == "llama3.1-8b"
    assert raw["mode"] in ("static", "aggregated", "disaggregated")


def test_cli_unsatisfiable_sla(capsys):
    rc = cli.main(["--model", "qwen3-235b", "--isl", "8192", "--osl", "512",
                   "--ttft", "1", "--min-speed", "10000", "--chips", "8",
                   "--dtype", "fp8"])
    assert rc == 1
    assert "no configuration satisfies" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# search --stream: JSON-lines progress + terminal summary record
# ---------------------------------------------------------------------------

def test_cli_stream_emits_parseable_jsonl_with_summary(capsys):
    rc = cli.main(["search"] + _STREAM_ARGS + ["--stream"])
    records = _records(capsys)
    assert rc == 0
    assert len(records) > 1
    candidates, summary = records[:-1], records[-1]
    assert summary["type"] == "summary"
    assert all(r["type"] == "candidate" for r in candidates)
    # candidate records carry the streaming progress counters
    for r in candidates:
        assert {"index", "mode", "tokens_per_s_per_chip", "meets_sla",
                "n_priced", "frontier_size",
                "mem_bytes_per_chip"} <= set(r)
    assert [r["index"] for r in candidates] == list(range(len(candidates)))
    priced = [r["n_priced"] for r in candidates]
    assert priced == sorted(priced)
    # terminal record summarizes the whole (non-early-exited) sweep
    assert summary["early_exit"] is None
    assert summary["n_candidates"] == priced[-1]
    assert summary["best"] is not None
    assert summary["schema_version"] == SCHEMA_VERSION
    assert summary["database"]["platform"] == "tpu_v5e"


def test_cli_stream_first_n_early_exit(capsys):
    rc = cli.main(["search"] + _STREAM_ARGS + ["--stream"])
    full = _records(capsys)[-1]
    assert rc == 0

    rc = cli.main(["search"] + _STREAM_ARGS + ["--stream", "--first-n", "3"])
    records = _records(capsys)
    assert rc == 0                               # exit code preserved
    summary = records[-1]
    assert summary["type"] == "summary"
    assert summary["n_valid"] == 3
    assert summary["early_exit"]["reason"] == "stop_after_n_valid(3)"
    assert sum(r["meets_sla"] for r in records[:-1]) == 3
    # strictly fewer candidates priced than the full sweep
    assert summary["n_candidates"] < full["n_candidates"]


def test_cli_first_n_without_stream_prints_report_and_early_exit(capsys):
    rc = cli.main(["search"] + _STREAM_ARGS + ["--first-n", "2", "--json"])
    out = capsys.readouterr().out
    assert rc == 0
    report = json.loads(out)
    assert report["search"]["early_exit"]["reason"] == "stop_after_n_valid(2)"
    assert report["best"] is not None


# ---------------------------------------------------------------------------
# exit codes 0/1/2 are preserved under --first-n / --stream
# ---------------------------------------------------------------------------

_IMPOSSIBLE = ["--model", "llama3.1-8b", "--isl", "2048", "--osl", "128",
               "--ttft", "1", "--min-speed", "100000", "--chips", "8",
               "--dtype", "fp8", "--modes", "aggregated"]


def test_cli_first_n_unsatisfiable_exits_1(capsys):
    rc = cli.main(["search"] + _IMPOSSIBLE + ["--first-n", "3"])
    assert rc == cli.EXIT_NO_CONFIG
    capsys.readouterr()
    rc = cli.main(["search"] + _IMPOSSIBLE + ["--stream", "--first-n", "3"])
    records = _records(capsys)
    assert rc == cli.EXIT_NO_CONFIG
    assert records[-1]["type"] == "summary"
    assert records[-1]["best"] is None
    assert records[-1]["early_exit"] is None     # never found 3 valid


def test_cli_first_n_validation_error_exits_2(capsys):
    rc = cli.main(["search"] + _STREAM_ARGS + ["--first-n", "-1"])
    assert rc == cli.EXIT_USAGE
    assert "error" in capsys.readouterr().err


def test_cli_stream_honors_save_flags(tmp_path, capsys):
    rep_path = str(tmp_path / "report.json")
    launch_path = str(tmp_path / "launch.json")
    rc = cli.main(["search"] + _STREAM_ARGS
                  + ["--stream", "--first-n", "2",
                     "--save-report", rep_path, "--save-launch", launch_path])
    assert rc == 0
    capsys.readouterr()
    saved = json.load(open(rep_path))
    assert saved["schema_version"] == SCHEMA_VERSION
    assert saved["search"]["early_exit"]["reason"] == "stop_after_n_valid(2)"
    launch = json.load(open(launch_path))
    assert launch == saved["launch"]["raw"]


# ---------------------------------------------------------------------------
# legacy flat-flag shim: still byte-identical to the subcommand
# ---------------------------------------------------------------------------

def _normalize_timing(text):
    return re.sub(r"in \d+\.\d+s \(\d+\.\d+ ms/config\)",
                  "in <T>s (<T> ms/config)", text)


def test_legacy_shim_matches_subcommand_with_new_flags(capsys):
    rc_new = cli.main(["search"] + _STREAM_ARGS + ["--first-n", "2"])
    out_new = capsys.readouterr().out
    rc_old = cli.main(_STREAM_ARGS + ["--first-n", "2"])
    captured = capsys.readouterr()
    assert "deprecated" in captured.err
    assert rc_old == rc_new == 0
    assert _normalize_timing(captured.out) == _normalize_timing(out_new)


# ---------------------------------------------------------------------------
# help text stays honest about replay semantics
# ---------------------------------------------------------------------------

def _help_text(argv, capsys):
    with pytest.raises(SystemExit) as exc:
        cli.main(argv)
    assert exc.value.code == 0
    # undo argparse's line wrapping so assertions survive reflowing
    return re.sub(r"\s+", " ", capsys.readouterr().out)


def test_search_help_documents_replay_semantics(capsys):
    """`search --trace` replays open-loop (queueing counts into TTFT) and
    `--replay-top-k` skips disaggregated composites — the help must say
    so rather than drift from the implementation."""
    text = _help_text(["search", "--help"], capsys)
    assert "queueing delay counts into TTFT" in text
    assert "disaggregated composites are skipped" in text


def test_workload_replay_help_documents_queueing_ttft(capsys):
    text = _help_text(["workload", "--help"], capsys)
    assert "queueing delay counts" in text
