"""Configurator CLI (the paper's Fig. 2 workflow as one command)."""
import json

import pytest

from repro.core import cli


def test_cli_end_to_end(tmp_path, capsys):
    out = str(tmp_path / "launch.json")
    rc = cli.main(["--model", "llama3.1-8b", "--isl", "1024", "--osl", "256",
                   "--ttft", "2000", "--min-speed", "10", "--chips", "16",
                   "--dtype", "fp8", "--save-launch", out])
    assert rc == 0
    text = capsys.readouterr().out
    assert "launch command:" in text
    assert "tok/s/chip" in text
    raw = json.load(open(out))
    assert raw["model"] == "llama3.1-8b"
    assert raw["mode"] in ("static", "aggregated", "disaggregated")


def test_cli_unsatisfiable_sla(capsys):
    rc = cli.main(["--model", "qwen3-235b", "--isl", "8192", "--osl", "512",
                   "--ttft", "1", "--min-speed", "10000", "--chips", "8",
                   "--dtype", "fp8"])
    assert rc == 1
    assert "no configuration satisfies" in capsys.readouterr().out
