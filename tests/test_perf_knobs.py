"""Perf-iteration knobs (§Perf) must be semantics-preserving: with no mesh
context they are exact no-ops; spec resolution for the 3-axis expert mesh
is consistent; the causal block-skip is bit-compatible with the plain path
(exercised in test_recurrent_forms too)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import models
from repro.configs import get_config
from repro.launch import sharding as shd
from repro.models import common as cm


def _with_rules(cfg, **kw):
    return dataclasses.replace(
        cfg, sharding=dataclasses.replace(cfg.sharding, **kw))


@pytest.mark.parametrize("knobs", [
    {"decode_attn_pin": True},
    {"shard_kv_seq": True},
    {"blockwise_q_shard": True},
    {"decode_attn_pin": True, "shard_kv_seq": True,
     "blockwise_q_shard": True},
])
def test_knobs_preserve_decode_semantics(knobs):
    rng = jax.random.PRNGKey(0)
    base = get_config("qwen3-moe-30b-a3b").reduced()
    tuned = _with_rules(base, **knobs)
    params = models.init_params(base, rng)
    toks = jax.random.randint(rng, (2, 13), 0, base.vocab_size)

    def run(cfg):
        lg, cache = models.prefill(params, cfg, toks[:, :12], max_len=20)
        lg2, _ = models.decode_step(params, cfg, toks[:, 12:13], cache)
        return lg2

    np.testing.assert_array_equal(np.asarray(run(base)),
                                  np.asarray(run(tuned)))


def test_blockwise_q_shard_exact_on_long_seq():
    """q_shard changes sharding only; values identical (no mesh -> no-op,
    and the lax.cond skip path must agree with plain attention)."""
    rng = jax.random.PRNGKey(1)
    q = jax.random.normal(rng, (1, 96, 4, 32))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (1, 96, 2, 32))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (1, 96, 2, 32))
    a = cm._blockwise_attention(q.reshape(1, 96, 2, 2, 32), k, v, True, 0, 0,
                                bq=16, bk=16, q_shard=True)
    b = cm._blockwise_attention(q.reshape(1, 96, 2, 2, 32), k, v, True, 0, 0,
                                bq=16, bk=16, q_shard=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _expert_mesh():
    class M:
        axis_names = ("data", "expert", "model")
        class devices:
            pass
    m = M()
    m.devices = np.empty((16, 8, 2), dtype=object)
    return m


def test_expert_mesh_param_specs():
    cfg = get_config("mixtral-8x22b")
    specs = shd.param_specs(cfg, "train", _expert_mesh())
    lay = specs["layers"]
    # expert weights: E on 'expert', per-expert ffn on 'model', D on 'data'
    assert lay["we_gate"] == P(None, "expert", "data", "model")
    # attention heads TP across the combined axes
    assert lay["wq"][2] == ("expert", "model")
    # vocab TP across combined axes
    assert specs["embed"]["tok_embed"][0] == ("expert", "model")


def test_expert_mesh_moe_ffn_tp_off():
    cfg = _with_rules(get_config("mixtral-8x22b"), moe_ffn_tp=False)
    specs = shd.param_specs(cfg, "train", _expert_mesh())
    assert specs["layers"]["we_gate"] == P(None, "expert", "data", None)


def test_tp_size_and_model_axes():
    cm.set_mesh_axes(("data", "expert", "model"), (16, 8, 2))
    try:
        assert cm.model_axes() == ("expert", "model")
        assert cm.tp_size() == 16
    finally:
        cm.set_mesh_axes(())
    assert cm.tp_size() == 1


def test_constrain_noop_without_mesh():
    cm.set_mesh_axes(())
    x = jnp.ones((4, 8))
    assert cm.constrain(x, "batch", "tp") is x
    assert cm.seq_shard(jnp.ones((2, 8, 4))).shape == (2, 8, 4)


def test_int8_kv_cache_quantization():
    """kv_quant roundtrip + decode consistency within quantization error."""
    import jax.numpy as jnp
    from repro.models.common import kv_quantize, kv_dequantize
    rng = jax.random.PRNGKey(3)
    k = jax.random.normal(rng, (2, 8, 4, 64))
    q, s = kv_quantize(k)
    assert q.dtype == jnp.int8 and s.shape == (2, 8, 4)
    back = kv_dequantize(q, s, k.dtype)
    # absmax scaling: error bounded by scale/2 per element
    assert float(jnp.max(jnp.abs(k - back))) < float(jnp.max(s))


def test_int8_kv_cache_decode():
    import jax.numpy as jnp
    from repro.models import common as cm
    cfg = get_config("qwen3-14b").reduced()
    cfgq = _with_rules(cfg, kv_quant=True)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 13), 0,
                              cfg.vocab_size)
    hidden, _ = models.forward_train(params, cfg, toks)
    ref = cm.lm_logits(params["embed"], hidden[:, -1:], cfg)
    _, cache = models.prefill(params, cfgq, toks[:, :12], max_len=20)
    assert cache["k"].dtype == jnp.int8
    assert "k_scale" in cache
    lg, cache2 = models.decode_step(params, cfgq, toks[:, 12:13], cache)
    assert cache2["k"].dtype == jnp.int8
    rel = float(jnp.max(jnp.abs(lg - ref))) / float(jnp.max(jnp.abs(ref)))
    assert rel < 0.05, rel
