"""Training substrate: optimizer convergence, schedule, grad clipping,
microbatch-accumulation equivalence, checkpoint round-trip, data
determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import get_config
from repro.training import checkpoint as ckpt
from repro.training import data as dat
from repro.training import optimizer as opt
from repro.training.train_step import loss_fn, make_train_step


def test_overfit_single_batch():
    cfg = get_config("internlm2-1.8b").reduced()
    p = models.init_params(cfg, jax.random.PRNGKey(0))
    st = opt.init(p)
    ocfg = opt.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=1000,
                           weight_decay=0.0)
    step = jax.jit(make_train_step(cfg, ocfg))
    b = dat.make_dataset(cfg, 16, 4).batch(0)
    t, l = jnp.asarray(b["tokens"]), jnp.asarray(b["labels"])
    losses = []
    for _ in range(25):
        p, st, m = step(p, st, t, l)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 2.0


def test_lr_schedule():
    c = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110)
    assert float(opt.schedule(c, jnp.int32(5))) == pytest.approx(0.5)
    assert float(opt.schedule(c, jnp.int32(10))) == pytest.approx(1.0)
    assert float(opt.schedule(c, jnp.int32(110))) == pytest.approx(0.0, abs=1e-6)


def test_grad_clipping():
    c = opt.AdamWConfig(grad_clip=1.0)
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 100.0)}
    state = opt.init(params)
    _, _, m = opt.update(c, grads, state, params)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_microbatch_equivalence():
    """n_mb=2 grad accumulation == full-batch loss/grads (linear loss avg)."""
    import dataclasses
    cfg = get_config("internlm2-1.8b").reduced()
    cfg2 = dataclasses.replace(
        cfg, sharding=dataclasses.replace(cfg.sharding, microbatches=2))
    p = models.init_params(cfg, jax.random.PRNGKey(0))
    b = dat.make_dataset(cfg, 16, 4).batch(0)
    t, l = jnp.asarray(b["tokens"]), jnp.asarray(b["labels"])

    g_full = jax.grad(lambda p_: loss_fn(p_, cfg, t, l, {})[0])(p)
    # manual accumulation like make_train_step's scan
    g_a = jax.grad(lambda p_: loss_fn(p_, cfg, t[:2], l[:2], {})[0])(p)
    g_b = jax.grad(lambda p_: loss_fn(p_, cfg, t[2:], l[2:], {})[0])(p)
    for full, a, bb in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_a),
                           jax.tree.leaves(g_b)):
        np.testing.assert_allclose(np.asarray(full, np.float32),
                                   (np.asarray(a, np.float32)
                                    + np.asarray(bb, np.float32)) / 2,
                                   rtol=2e-2, atol=2e-3)

    st = opt.init(p)
    step2 = jax.jit(make_train_step(cfg2))
    p2, st2, m2 = step2(p, st, t, l)
    assert jnp.isfinite(m2["loss"])


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("xlstm-350m").reduced()
    p = models.init_params(cfg, jax.random.PRNGKey(0))
    st = opt.init(p)
    path = str(tmp_path / "ck.npz")
    ckpt.save(path, p, st, step=7)
    p2, st2, step = ckpt.restore(path, p, st)
    assert step == 7
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    cfg = get_config("xlstm-350m").reduced()
    p = models.init_params(cfg, jax.random.PRNGKey(0))
    path = str(tmp_path / "ck.npz")
    ckpt.save(path, p, step=1)
    other = get_config("internlm2-1.8b").reduced()
    p_other = models.init_params(other, jax.random.PRNGKey(0))
    with pytest.raises((ValueError, KeyError)):
        ckpt.restore(path, p_other)


def test_data_deterministic_and_seekable():
    cfg = get_config("internlm2-1.8b").reduced()
    ds = dat.make_dataset(cfg, 32, 4, seed=3)
    b1, b2 = ds.batch(17), ds.batch(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(ds.batch(18)["tokens"], b1["tokens"])
    # labels are next-token shifted
    full = ds.batch(5)
    assert full["tokens"].shape == (4, 32)
    assert (full["tokens"] < cfg.vocab_size).all()


def test_prefetcher():
    cfg = get_config("internlm2-1.8b").reduced()
    ds = dat.make_dataset(cfg, 16, 2)
    pf = dat.Prefetcher(ds)
    b0 = next(pf)
    np.testing.assert_array_equal(b0["tokens"], ds.batch(0)["tokens"])
    b1 = next(pf)
    np.testing.assert_array_equal(b1["tokens"], ds.batch(1)["tokens"])
    pf.close()
