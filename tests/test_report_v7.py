"""SearchReport schema v7: the replay-carrying sections gain latency
histogram blocks (request-level flight recorder), the new v6 golden
fixture migrates losslessly — its ``workload_eval``, ``capacity``,
``autoscale``, and ``telemetry`` sections byte-for-byte, with no
histogram block invented for them — and every older golden still
loads."""
import json
import os

import pytest

from repro.api import Configurator, SCHEMA_VERSION, SearchReport
from repro.obs import disable_metrics, disable_tracing
from repro.obs.flight import HISTOGRAM_METRICS
from repro.obs.metrics import LATENCY_MS_BUCKETS
from repro.workloads import (ArrivalSpec, LengthSpec, SLOSpec, TenantSpec,
                             TraceSpec, generate_trace)

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")
V6_FIXTURE = os.path.join(FIXTURES, "search_report_v6.json")


def _configurator():
    return (Configurator.for_model("llama3.1-8b")
            .traffic(isl=256, osl=64)
            .sla(ttft_ms=2000, min_tokens_per_s_user=10)
            .cluster(chips=8).backend("repro-jax").dtype("fp8")
            .modes("aggregated"))


def _trace():
    return generate_trace(TraceSpec(
        n_requests=40,
        arrivals=ArrivalSpec(kind="poisson", rate_rps=2.0),
        tenants=(TenantSpec(lengths=LengthSpec(kind="fixed",
                                               isl=256, osl=64)),)),
        seed=7)


_SLO = SLOSpec(ttft_p99_ms=2000.0, tpot_p99_ms=100.0)


@pytest.fixture(autouse=True)
def _clean_instrumentation():
    disable_tracing()
    disable_metrics()
    yield
    disable_tracing()
    disable_metrics()


@pytest.fixture(scope="module")
def full_report():
    """One search carried through every replay-backed section."""
    cfg = _configurator()
    trace = _trace()
    report = cfg.search(generate_launch=False)
    cfg.evaluate_frontier(trace, _SLO, top_k=2, report=report)
    cfg.plan_capacity(trace, _SLO, ladder=(1, 2), report=report)
    cfg.autoscale(trace, _SLO, ladder=(1, 2), report=report)
    return report


def _assert_histogram_block(h):
    assert set(h) == set(HISTOGRAM_METRICS)
    for name, hist in h.items():
        assert hist["buckets"] == list(LATENCY_MS_BUCKETS), name
        assert len(hist["counts"]) == len(LATENCY_MS_BUCKETS) + 1
        assert sum(hist["counts"]) == hist["count"]
        assert hist["sum"] >= 0.0


# ---------------------------------------------------------------------------
# the v7 histogram blocks
# ---------------------------------------------------------------------------

def test_schema_version_is_7():
    assert SCHEMA_VERSION == 7


def test_workload_eval_carries_histograms(full_report):
    replayed = [c for c in full_report.workload_eval["candidates"]
                if c["replay"] is not None]
    assert replayed
    for cand in replayed:
        _assert_histogram_block(cand["replay"]["histograms"])
        assert cand["replay"]["histograms"]["e2e_ms"]["count"] > 0


def test_capacity_rungs_carry_histograms(full_report):
    rungs = [r for r in full_report.capacity["rungs"]
             if r["metrics"] is not None]
    assert rungs
    for rung in rungs:
        _assert_histogram_block(rung["metrics"]["histograms"])


def test_autoscale_run_carries_histograms(full_report):
    _assert_histogram_block(
        full_report.autoscale["run"]["metrics"]["histograms"])


def test_histograms_survive_roundtrip(full_report):
    blob = full_report.to_json()
    assert json.loads(blob)["schema_version"] == SCHEMA_VERSION
    back = SearchReport.from_json(blob)
    assert back == full_report
    assert back.to_json() == blob            # byte-stable second hop
    _assert_histogram_block(
        back.autoscale["run"]["metrics"]["histograms"])


def test_histogram_percentiles_consistent_with_exact(full_report):
    """The serialized distribution must agree with the exact percentile
    the same section already records (within one bucket)."""
    from repro.obs.metrics import histogram_quantile
    for cand in full_report.workload_eval["candidates"]:
        if cand["replay"] is None:
            continue
        h = cand["replay"]["histograms"]["ttft_ms"]
        exact_p99 = cand["replay"]["ttft_ms"]["p99"]
        est = histogram_quantile(h["buckets"], h["counts"], 0.99)
        idx = next((i for i, le in enumerate(h["buckets"])
                    if exact_p99 <= le), len(h["buckets"]))
        lo = h["buckets"][idx - 1] if idx > 0 else 0.0
        hi = h["buckets"][idx] if idx < len(h["buckets"]) \
            else h["buckets"][-1]
        assert lo <= est <= hi or abs(est - exact_p99) <= hi - lo


# ---------------------------------------------------------------------------
# golden fixture: v6 migrates losslessly, sections byte-for-byte
# ---------------------------------------------------------------------------

def test_v6_golden_fixture_migrates_losslessly():
    with open(V6_FIXTURE) as f:
        payload = json.load(f)
    assert payload["schema_version"] == 6
    rep = SearchReport.load(V6_FIXTURE)
    assert rep.schema_version == SCHEMA_VERSION
    assert rep.n_candidates == payload["search"]["n_candidates"]
    assert rep.frontier_indices == payload["frontier"]
    assert rep.best_index == payload["best"]
    assert rep.fingerprint == payload["database"]
    assert rep.telemetry == payload["telemetry"]


def test_v6_golden_migration_preserves_sections_bytes():
    """Every v6 section must survive the v6→v7 migration byte-for-byte:
    identical JSON serialization, not merely equal-ish — and no
    histogram block may be invented for a report that never carried
    one."""
    with open(V6_FIXTURE) as f:
        payload = json.load(f)
    for section in ("workload_eval", "capacity", "autoscale", "telemetry"):
        assert payload[section] is not None, section
    rep = SearchReport.load(V6_FIXTURE)
    reserialized = rep.to_dict()
    for section in ("workload_eval", "capacity", "autoscale", "telemetry"):
        assert json.dumps(reserialized[section], sort_keys=True) \
            == json.dumps(payload[section], sort_keys=True), section
    again = SearchReport.from_json(rep.to_json())
    assert again == rep


def test_migrated_v6_report_has_no_histograms():
    rep = SearchReport.load(V6_FIXTURE)
    for cand in rep.workload_eval["candidates"]:
        if cand["replay"] is not None:
            assert "histograms" not in cand["replay"]
    for rung in rep.capacity["rungs"]:
        if rung["metrics"] is not None:
            assert "histograms" not in rung["metrics"]
    assert "histograms" not in rep.autoscale["run"]["metrics"]


def test_all_golden_fixtures_still_load():
    for name, version in (("search_report_v1.json", 1),
                          ("search_report_v2.json", 2),
                          ("search_report_v3.json", 3),
                          ("search_report_v4.json", 4),
                          ("search_report_v5.json", 5),
                          ("search_report_v6.json", 6)):
        path = os.path.join(FIXTURES, name)
        with open(path) as f:
            assert json.load(f)["schema_version"] == version
        rep = SearchReport.load(path)
        assert rep.schema_version == SCHEMA_VERSION
        if version < 6:
            assert rep.telemetry is None
