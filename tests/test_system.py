"""End-to-end system behaviour: configurator -> generator -> real engine.

The closed loop the paper ships: describe a workload, search the config
space, emit a launch config, and run the recommended (reduced-scale)
deployment on the real JAX engine.
"""
import json
import time

import jax
import numpy as np
import pytest

from repro import models
from repro.configs import get_config
from repro.core import (ClusterSpec, PerfDatabase, SLA, TaskRunner,
                        WorkloadDescriptor, generate)
from repro.serving.engine import Engine, EngineConfig
from repro.serving.request import Request
from repro.serving.scheduler import SchedulerConfig
from repro.serving.sim import ServingSimulator, StepSpec
from repro.core.session import InferenceSession
from repro.core.config import CandidateConfig, ParallelismConfig, RuntimeFlags


@pytest.fixture(scope="module")
def db():
    return PerfDatabase("tpu_v5e", "repro-jax")


def test_configurator_to_engine_loop(db):
    w = WorkloadDescriptor(
        model="internlm2-1.8b", isl=512, osl=128,
        sla=SLA(ttft_ms=2000, min_tokens_per_s_user=10),
        cluster=ClusterSpec(n_chips=8), backend="repro-jax", dtype="bf16",
        modes=("aggregated",))
    result = TaskRunner(w, db).run()
    assert result.best is not None
    launch = generate(w, result.best)
    raw = json.loads(launch.to_json())

    # drive the real engine with the recommended batch size (reduced scale)
    cfg = get_config(w.model).reduced()
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, EngineConfig(
        max_batch=min(raw["batch_size"], 4), max_seq=64))
    rng = np.random.default_rng(0)
    for i in range(4):
        prompt = rng.integers(0, cfg.vocab_size, 8).tolist()
        eng.add_request(Request(rid=i, isl=8, osl=4,
                                arrival=time.perf_counter(), prompt=prompt))
    done = eng.run_until_drained()
    assert len(done) == 4
    assert all(r.tpot is not None for r in done)


def test_model_vs_simulator_fidelity(db):
    """Algorithm 2's closed form tracks the step-accurate simulator within
    a generous MAPE bound (the full Fig. 6 sweep lives in benchmarks)."""
    w = WorkloadDescriptor(
        model="llama3.1-8b", isl=512, osl=128,
        sla=SLA(ttft_ms=5000), cluster=ClusterSpec(n_chips=8),
        backend="repro-jax", dtype="fp8")
    session = InferenceSession(w, db)
    par = ParallelismConfig(tp=8)
    flags = RuntimeFlags()
    cand = CandidateConfig(parallel=par, batch_size=16, flags=flags)
    proj = session.evaluate_aggregated(cand)
    assert proj is not None

    def lat(spec: StepSpec) -> float:
        return session.spec_latency_ms(par, spec, flags) / 1e3

    sim = ServingSimulator(SchedulerConfig(
        max_batch=16, max_num_tokens=flags.max_num_tokens), lat)
    m = sim.run(isl=512, osl=128, concurrency=16, max_requests=24)
    ape_tpot = abs(proj.tpot_ms - m.tpot_ms) / m.tpot_ms
    assert ape_tpot < 0.5, (proj.tpot_ms, m.tpot_ms)


def test_search_covers_all_three_modes(db):
    w = WorkloadDescriptor(
        model="qwen3-32b", isl=4000, osl=500,
        sla=SLA(ttft_ms=1200, min_tokens_per_s_user=60),
        cluster=ClusterSpec(n_chips=16), backend="repro-jax", dtype="fp8",
        modes=("static", "aggregated", "disaggregated"))
    r = TaskRunner(w, db).run()
    modes_seen = {p.mode for p in r.projections}
    assert {"static", "aggregated"} <= modes_seen
    assert r.best is not None
