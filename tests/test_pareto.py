"""Property tests for the Pareto analyzer."""
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare environment: deterministic fallback shim
    from _hypothesis_compat import given, settings, st

from repro.core import pareto
from repro.core.config import Projection, SLA


def _proj(speed, thru, ttft=100.0):
    return Projection(ttft_ms=ttft, tpot_ms=1000.0 / max(speed, 1e-6),
                      tokens_per_s_user=speed, tokens_per_s_per_chip=thru,
                      chips=8, batch_size=8, mode="aggregated", config={})


pts = st.lists(
    st.tuples(st.floats(1, 500), st.floats(1, 5000)),
    min_size=1, max_size=60)


@given(pts)
@settings(max_examples=100, deadline=None)
def test_frontier_non_dominated(points):
    projs = [_proj(s, t) for s, t in points]
    front = pareto.frontier(projs)
    # no point in the frontier is dominated by any input point
    for f in front:
        for p in projs:
            strictly_better = (p.tokens_per_s_user > f.tokens_per_s_user
                               and p.tokens_per_s_per_chip > f.tokens_per_s_per_chip)
            assert not strictly_better
    # every input point is dominated-or-equal by some frontier point
    for p in projs:
        assert any(f.tokens_per_s_user >= p.tokens_per_s_user
                   and f.tokens_per_s_per_chip >= p.tokens_per_s_per_chip
                   for f in front)


@given(pts, st.floats(5, 400))
@settings(max_examples=50, deadline=None)
def test_sla_filter_and_best(points, min_speed):
    sla = SLA(ttft_ms=500, min_tokens_per_s_user=min_speed)
    projs = [_proj(s, t) for s, t in points]
    ok = pareto.sla_filter(projs, sla)
    assert all(p.tokens_per_s_user >= min_speed - 1e-6 for p in ok)
    best = pareto.best(projs, sla)
    if ok:
        assert best is not None
        assert best.tokens_per_s_per_chip == max(
            p.tokens_per_s_per_chip for p in ok)
    else:
        assert best is None


def test_ttft_violations_filtered():
    sla = SLA(ttft_ms=50)
    projs = [_proj(10, 100, ttft=200.0), _proj(10, 1, ttft=10.0)]
    best = pareto.best(projs, sla)
    assert best is not None and best.ttft_ms == 10.0
