"""Property tests for the Pareto analyzer (batch + online accumulator)."""
import random

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare environment: deterministic fallback shim
    from _hypothesis_compat import given, settings, st

from repro.core import pareto
from repro.core.config import Projection, SLA


def _proj(speed, thru, ttft=100.0):
    return Projection(ttft_ms=ttft, tpot_ms=1000.0 / max(speed, 1e-6),
                      tokens_per_s_user=speed, tokens_per_s_per_chip=thru,
                      chips=8, batch_size=8, mode="aggregated", config={})


pts = st.lists(
    st.tuples(st.floats(1, 500), st.floats(1, 5000)),
    min_size=1, max_size=60)


@given(pts)
@settings(max_examples=100, deadline=None)
def test_frontier_non_dominated(points):
    projs = [_proj(s, t) for s, t in points]
    front = pareto.frontier(projs)
    # no point in the frontier is dominated by any input point
    for f in front:
        for p in projs:
            strictly_better = (p.tokens_per_s_user > f.tokens_per_s_user
                               and p.tokens_per_s_per_chip > f.tokens_per_s_per_chip)
            assert not strictly_better
    # every input point is dominated-or-equal by some frontier point
    for p in projs:
        assert any(f.tokens_per_s_user >= p.tokens_per_s_user
                   and f.tokens_per_s_per_chip >= p.tokens_per_s_per_chip
                   for f in front)


@given(pts, st.floats(5, 400))
@settings(max_examples=50, deadline=None)
def test_sla_filter_and_best(points, min_speed):
    sla = SLA(ttft_ms=500, min_tokens_per_s_user=min_speed)
    projs = [_proj(s, t) for s, t in points]
    ok = pareto.sla_filter(projs, sla)
    assert all(p.tokens_per_s_user >= min_speed - 1e-6 for p in ok)
    best = pareto.best(projs, sla)
    if ok:
        assert best is not None
        assert best.tokens_per_s_per_chip == max(
            p.tokens_per_s_per_chip for p in ok)
    else:
        assert best is None


def test_ttft_violations_filtered():
    sla = SLA(ttft_ms=50)
    projs = [_proj(10, 100, ttft=200.0), _proj(10, 1, ttft=10.0)]
    best = pareto.best(projs, sla)
    assert best is not None and best.ttft_ms == 10.0


# ---------------------------------------------------------------------------
# FrontierAccumulator: streaming/batch equivalence invariant
# ---------------------------------------------------------------------------

def _keys(projs):
    return {(p.tokens_per_s_user, p.tokens_per_s_per_chip) for p in projs}


@given(pts, st.integers(0, 2 ** 31))
@settings(max_examples=100, deadline=None)
def test_accumulator_any_permutation_matches_batch(points, seed):
    """The streaming/batch equivalence invariant: feeding ANY permutation
    of a projection list through the online accumulator yields the same
    frontier set as batch `pareto.frontier` on the full list."""
    projs = [_proj(s, t) for s, t in points]
    order = list(projs)
    random.Random(seed).shuffle(order)
    acc = pareto.FrontierAccumulator()
    for p in order:
        acc.add(p)
    assert _keys(acc.frontier()) == _keys(pareto.frontier(projs))
    # structural invariant: speed strictly descending, thru strictly rising
    front = acc.frontier()
    for a, b in zip(front, front[1:]):
        assert a.tokens_per_s_user > b.tokens_per_s_user
        assert a.tokens_per_s_per_chip < b.tokens_per_s_per_chip


@given(pts)
@settings(max_examples=50, deadline=None)
def test_accumulator_matches_batch_at_every_prefix(points):
    """Mid-stream the accumulator equals batch over what has streamed so
    far — what a progress UI reads while the search is still running."""
    projs = [_proj(s, t) for s, t in points]
    acc = pareto.FrontierAccumulator()
    for i, p in enumerate(projs):
        joined = acc.add(p)
        assert _keys(acc.frontier()) == _keys(pareto.frontier(projs[:i + 1]))
        # a point that joined is on the frontier; one that was rejected
        # leaves its (speed, thru) key covered by some frontier point
        key = (p.tokens_per_s_user, p.tokens_per_s_per_chip)
        if joined:
            assert key in _keys(acc.frontier())
        else:
            assert any(f.tokens_per_s_user >= key[0]
                       and f.tokens_per_s_per_chip >= key[1]
                       for f in acc.frontier())


def test_accumulator_in_insertion_order_matches_batch_in_pricing_order():
    # identical (speed, thru) duplicates: first-seen survives, like the
    # stable batch sort; dominated points evict cleanly in the middle
    a, b = _proj(10, 5), _proj(10, 5)
    dominated = _proj(5, 8)
    spoiler = _proj(7, 9)
    acc = pareto.FrontierAccumulator([a, dominated])
    assert not acc.add(b)               # duplicate of a: rejected
    assert acc.frontier() == [a, dominated]
    assert acc.dominates(b) and not acc.dominates(spoiler)
    assert acc.add(spoiler)             # evicts `dominated` (5,8) ≤ (7,9)
    assert acc.frontier() == [a, spoiler]
    assert len(acc) == 2
    assert pareto.frontier([a, dominated, b, spoiler]) == [a, spoiler]


def test_accumulator_seeded_from_iterable():
    projs = [_proj(s, t) for s, t in ((1, 10), (2, 8), (3, 6), (3, 7))]
    acc = pareto.FrontierAccumulator(projs)
    assert _keys(acc.frontier()) == _keys(pareto.frontier(projs))
